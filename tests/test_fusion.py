"""Operator fusion as a costed plan dimension (ISSUE 9).

Four families of guarantees:

  * the two op-profile bugfixes — ssd_scan's inter-chunk state traffic
    ceils instead of flooring to zero, and windowed-causal attention gets
    the exact averaged keys-per-query discount (the legacy path granted
    frac=0.5 only at eff_kv == skv == sq);
  * fused <= unfused HBM bytes on every emitted variant, at the op level
    and over whole generated plans (deterministic sweeps here; the
    hypothesis-randomized versions run when hypothesis is installed);
  * ``fusion="off"`` (the default everywhere) stays bit-identical to the
    frozen PRE_FUSION golden cells — the knob cannot move old numbers;
  * the batched/vectorized coster is bit-exact across fusion structure
    groups, and the ``PlanCostCache`` fingerprint separates fusion
    settings (no cross-contamination through a shared cache).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs import SHAPES, get_config
from repro.core.cluster import multi_pod_config, single_pod_config
from repro.core.costmodel import PlanCostCache, estimate
from repro.core.linalg_ops import avg_keys_per_query, profile
from repro.core.planner import (SearchStats, _cost_candidate,
                                _cost_group_vectorized, _structure_key,
                                build_step_program, choose_plan,
                                enumerate_plans)
from repro.core.symbols import TensorStat
from repro.core.sweep import CLUSTERS

POD = single_pod_config()
MULTI = multi_pod_config()

# ---------------------------------------------------------------------------
# Frozen pre-fusion baseline: beam choose_plan with every default, captured
# before the fusion knob landed.  These values must NEVER change — the knob
# defaults to "off" and "off" is the legacy program tree bit for bit.
# ---------------------------------------------------------------------------
PRE_FUSION_STEP_TIMES = {
    ("qwen1.5-0.5b", "train_4k", "pod"): 0.1210152587780616,
    ("qwen1.5-0.5b", "decode_32k", "pod"): 0.0027855075299145302,
    ("qwen1.5-0.5b", "decode_32k", "v5p-pod"): 0.002752198992027129,
    ("gemma3-12b", "train_4k", "v5p-pod"): 5.470500259268863,
    ("gemma3-12b", "decode_32k", "v5p-pod"): 0.011174433533523029,
    ("mamba2-1.3b", "train_4k", "pod"): 0.2971891713601879,
    ("mamba2-1.3b", "decode_32k", "v6e-pod"): 2.833234691535151e-05,
    ("qwen1.5-110b", "train_4k", "v5p-dcn"): 21.582674758621934,
}


def test_fusion_off_bit_identical_to_pre_fusion_golden():
    cache = PlanCostCache()
    for (arch_id, shape_id, cl), want in PRE_FUSION_STEP_TIMES.items():
        best = choose_plan(get_config(arch_id), SHAPES[shape_id],
                           CLUSTERS[cl], cache=cache)[0]
        assert best.cost.total == want, (arch_id, shape_id, cl)
        assert best.plan.fusion == "off"


# ---------------------------------------------------------------------------
# Bugfix 1: ssd_scan inter-chunk state traffic
# ---------------------------------------------------------------------------
def _ssd_state_bytes(s, chunk=256, b=2, h=8, p=64, n=128):
    prof = profile("ssd_scan", [TensorStat((b, s, h, p), "bfloat16")],
                   state=n, chunk=chunk)
    x_bytes = b * s * h * p * 2
    return prof.read_bytes - x_bytes


def test_ssd_short_sequence_still_pays_state_traffic():
    """s < chunk used to floor to ZERO state bytes; now exactly one chunk."""
    one_chunk = 2 * 8 * 64 * 128 * 2          # b*h*p*n * bf16
    assert _ssd_state_bytes(100, chunk=256) == one_chunk
    assert _ssd_state_bytes(1, chunk=256) == one_chunk    # decode step
    # divisible sequences are unchanged by the ceil (floor == ceil there)
    assert _ssd_state_bytes(512, chunk=256) == 2 * one_chunk
    # and a ragged tail rounds UP, not down
    assert _ssd_state_bytes(700, chunk=256) == 3 * one_chunk


# ---------------------------------------------------------------------------
# Bugfix 2: windowed-causal attention discount
# ---------------------------------------------------------------------------
def test_avg_keys_per_query_closed_form():
    # full causal self-attention: classic (n+1)/2
    assert avg_keys_per_query(4096, 4096, None, True) == (1 + 4096) / 2.0
    # window overhanging the sequence start: mixed regime, exact average
    assert avg_keys_per_query(4096, 4096, 1024, True) == 896.125
    # window never binding (w >= skv): same as unwindowed
    assert avg_keys_per_query(4096, 4096, 8192, True) == (1 + 4096) / 2.0
    # decode suffix (sq=1 of a long context): window fully binding
    assert avg_keys_per_query(1, 32768, 1024, True) == 1024.0
    # non-causal: plain window size
    assert avg_keys_per_query(4096, 4096, 1024, False) == 1024.0
    # brute-force cross-check of the mixed regime
    sq = skv = 64
    w = 16
    brute = sum(min(skv - sq + i + 1, w) for i in range(sq)) / sq
    assert avg_keys_per_query(sq, skv, w, True) == brute


def test_windowed_causal_attention_now_discounted():
    """Legacy path charges the full window everywhere (frac=1 since
    eff_kv != skv); the fused variant pays only the averaged visible keys."""
    q = TensorStat((1, 8, 4096, 128), "bfloat16")
    k = v = TensorStat((1, 8, 4096, 128), "bfloat16")
    legacy = profile("attention", [q, k, v], causal=True, window=1024)
    fused = profile("attention", [q, k, v], causal=True, window=1024,
                    fused=True)
    assert legacy.flops > fused.flops
    # exact ratio: legacy charges eff_kv=1024 per query, fused 896.125
    assert fused.flops == pytest.approx(legacy.flops * 896.125 / 1024.0)
    # unwindowed full causal is unchanged in flops (0.5 == (n+1)/2n asympt.)
    full_legacy = profile("attention", [q, k, v], causal=True)
    full_fused = profile("attention", [q, k, v], causal=True, fused=True)
    assert full_fused.flops == pytest.approx(full_legacy.flops, rel=1e-3)


# ---------------------------------------------------------------------------
# Fused <= unfused, op level
# ---------------------------------------------------------------------------
def _attn_stats(b, hq, hkv, sq, skv, d, dtype="bfloat16"):
    return [TensorStat((b, hq, sq, d), dtype),
            TensorStat((b, hkv, skv, d), dtype),
            TensorStat((b, hkv, skv, d), dtype)]


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,window", [
    (1, 8, 8, 4096, 4096, 128, True, None),
    (4, 16, 4, 1, 32768, 128, True, None),         # decode
    (1, 8, 2, 4096, 4096, 64, True, 1024),         # sliding window
    (2, 4, 4, 512, 512, 64, False, None),
])
def test_attention_fused_cheaper_than_materialized(b, hq, hkv, sq, skv, d,
                                                   causal, window):
    ins = _attn_stats(b, hq, hkv, sq, skv, d)
    fused = profile("attention", list(ins), causal=causal, window=window,
                    fused=True)
    mat = profile("attention", list(ins), causal=causal, window=window,
                  fused=False)
    assert fused.flops == mat.flops          # the delta is traffic only
    assert fused.read_bytes < mat.read_bytes
    assert fused.write_bytes < mat.write_bytes
    # the delta is exactly the score matrix's round trip (fp32 scores +
    # input-width probs, written once and read once each)
    score_cells = b * hq * sq * skv
    assert mat.read_bytes - fused.read_bytes == score_cells * (4 + 2)
    assert mat.write_bytes - fused.write_bytes == score_cells * (4 + 2)


@pytest.mark.parametrize("epi,ew_op", [("silu", "silu"), ("gelu", "gelu"),
                                       ("layernorm", "layernorm")])
def test_matmul_epilogue_cheaper_than_separate_op(epi, ew_op):
    m, k, n = 8192, 4096, 4096
    a = TensorStat((m, k), "bfloat16")
    w = TensorStat((k, n), "bfloat16")
    fused = profile("matmul", [a, w], epilogue=epi)
    plain = profile("matmul", [a, w])
    sep = profile(ew_op, [plain.out])
    # same arithmetic: the epilogue charge equals the standalone op's flops
    assert fused.flops == plain.flops + sep.flops
    # strictly less traffic: the m x n intermediate never round-trips
    fused_bytes = fused.read_bytes + fused.write_bytes
    unfused_bytes = (plain.read_bytes + plain.write_bytes
                     + sep.read_bytes + sep.write_bytes)
    assert fused_bytes < unfused_bytes
    inter = m * n * 2                        # bf16 intermediate
    assert unfused_bytes - fused_bytes == 2 * inter   # write + re-read


def test_matmul_cast_sinking_beats_materialized_cast():
    m, k, n = 8192, 4096, 4096
    a = TensorStat((m, k), "float32")
    w = TensorStat((k, n), "float32")
    sunk = profile("matmul", [a, w], sink_cast_bytes=2)
    plain = profile("matmul", [a, w])
    cast = profile("cast", [plain.out], from_bytes=4, to_bytes=2)
    assert sunk.write_bytes == m * n * 2
    sunk_total = sunk.read_bytes + sunk.write_bytes
    unfused_total = (plain.read_bytes + plain.write_bytes
                     + cast.read_bytes + cast.write_bytes)
    assert sunk_total < unfused_total


def test_matmul_epilogue_epi_cols_narrows_the_charge():
    a = TensorStat((1024, 512), "bfloat16")
    w = TensorStat((512, 3 * 1024), "bfloat16")   # fused gated-MLP proj
    narrow = profile("matmul", [a, w], epilogue="silu", epi_cols=1024)
    wide = profile("matmul", [a, w], epilogue="silu")
    plain = profile("matmul", [a, w])
    assert narrow.flops - plain.flops == 6.0 * 1024 * 1024
    assert wide.flops - plain.flops == 6.0 * 1024 * (3 * 1024)


# ---------------------------------------------------------------------------
# Fused <= unfused, whole generated plans
# ---------------------------------------------------------------------------
_PLAN_CELLS = [("qwen1.5-0.5b", "train_4k", POD),
               ("qwen1.5-0.5b", "decode_32k", POD),
               ("gemma3-12b", "decode_32k", POD),
               ("mamba2-1.3b", "train_4k", POD),
               ("qwen1.5-0.5b", "train_4k", MULTI)]


@pytest.mark.parametrize("arch_id,shape_id", sorted({(a, s)
                                                     for a, s, _ in _PLAN_CELLS}))
def test_plan_level_fused_hbm_never_exceeds_materialized(arch_id, shape_id):
    arch, shape = get_config(arch_id), SHAPES[shape_id]
    cc = POD
    by_fusion = {}
    for plan in enumerate_plans(arch, shape, cc, fusion="search"):
        key = (plan.name, plan.remat, plan.microbatches,
               plan.grad_reduce_dtype)
        by_fusion.setdefault(key, {})[plan.fusion] = estimate(
            build_step_program(arch, shape, plan, cc), cc).totals.hbm_bytes
    assert by_fusion
    for key, totals in by_fusion.items():
        assert set(totals) == {"off", "none", "full"}, key
        assert totals["full"] <= totals["none"], key
        # "off" is the fusion-blind legacy tree: between the two honest
        # variants it under-counts the materialized plan
        assert totals["off"] <= totals["none"], key


def test_fusion_search_widens_space_and_beam_matches_exhaustive():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["decode_32k"]
    assert len(enumerate_plans(arch, shape, POD, fusion="search")) == \
        3 * len(enumerate_plans(arch, shape, POD))
    beam = choose_plan(arch, shape, POD, fusion="search")[0]
    exh = choose_plan(arch, shape, POD, search="exhaustive",
                      fusion="search")[0]
    assert beam.cost.total == exh.cost.total
    assert beam.plan.fusion == exh.plan.fusion


# ---------------------------------------------------------------------------
# Batched costing: bit-exact across fusion structure groups
# ---------------------------------------------------------------------------
def test_structure_key_separates_fusion_settings():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    plans = enumerate_plans(arch, shape, POD, fusion="search")
    p = plans[0]
    keys = {f: _structure_key(
        type(p)(**{**p.__dict__, "fusion": f}), shape.mode)
        for f in ("off", "none", "full")}
    assert len(set(keys.values())) == 3


def test_batched_walk_bit_exact_across_fusion_groups():
    arch = get_config("qwen1.5-0.5b")
    for shape_id, cc in (("train_4k", POD), ("decode_32k", POD),
                         ("train_4k", MULTI)):
        shape = SHAPES[shape_id]
        groups = {}
        for p in enumerate_plans(arch, shape, cc, fusion="search"):
            groups.setdefault(_structure_key(p, shape.mode), []).append(p)
        fusions_seen = set()
        for members in groups.values():
            fusions_seen.add(members[0].fusion)
            assert len({m.fusion for m in members}) == 1   # never mixed
            if len(members) < 2:
                continue
            vec = _cost_group_vectorized(arch, shape, members, cc)
            for p, got in zip(members, vec):
                base = _cost_candidate(arch, shape, p, cc, None,
                                       SearchStats()).cost
                assert got.total == base.total, p.describe()
                assert got.totals.as_tuple() == base.totals.as_tuple(), \
                    p.describe()
        assert fusions_seen == {"off", "none", "full"}


def test_batched_search_matches_exhaustive_over_fusion_space():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["decode_32k"]
    bat = choose_plan(arch, shape, POD, search="batched", fusion="search")[0]
    exh = choose_plan(arch, shape, POD, search="exhaustive",
                      fusion="search")[0]
    assert bat.cost.total == exh.cost.total
    assert bat.plan.fusion == exh.plan.fusion


# ---------------------------------------------------------------------------
# Cache-fingerprint separation
# ---------------------------------------------------------------------------
def test_shared_cache_never_mixes_fusion_settings():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    base = enumerate_plans(arch, shape, POD)[0]
    cache = PlanCostCache()

    def cost(f):
        plan = type(base)(**{**base.__dict__, "fusion": f})
        return estimate(build_step_program(arch, shape, plan, POD), POD,
                        cache=cache)

    cold = {f: cost(f).total for f in ("off", "none", "full")}
    assert len(set(cold.values())) == 3       # three distinct plans
    # warm replay through the now-populated shared cache: bit-identical
    for f, want in cold.items():
        assert cost(f).total == want, f


# ---------------------------------------------------------------------------
# Hypothesis-randomized properties (skipped when hypothesis is absent; the
# deterministic sweeps above run always)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    _dim = st.integers(min_value=1, max_value=64).map(lambda x: x * 8)
    _seq = st.integers(min_value=1, max_value=512).map(lambda x: x * 8)

    @settings(max_examples=60, deadline=None)
    @given(b=st.integers(1, 8), h=st.integers(1, 16), sq=_seq, skv=_seq,
           d=_dim, causal=st.booleans(),
           window=st.one_of(st.none(), st.integers(8, 4096)))
    def test_prop_attention_fused_never_more_bytes(b, h, sq, skv, d,
                                                   causal, window):
        if sq > skv:
            sq = skv                         # suffix convention
        ins = _attn_stats(b, h, h, sq, skv, d)
        fused = profile("attention", list(ins), causal=causal,
                        window=window, fused=True)
        mat = profile("attention", list(ins), causal=causal,
                      window=window, fused=False)
        assert fused.flops == mat.flops
        assert fused.read_bytes <= mat.read_bytes
        assert fused.write_bytes <= mat.write_bytes

    @settings(max_examples=60, deadline=None)
    @given(sq=st.integers(1, 4096), extra=st.integers(0, 4096),
           w=st.one_of(st.none(), st.integers(1, 8192)),
           causal=st.booleans())
    def test_prop_avg_keys_matches_brute_force(sq, extra, w, causal):
        skv = sq + extra
        brute = sum(min(min(w, skv) if w else skv,
                        (skv - sq + i + 1) if causal else skv)
                    for i in range(sq)) / sq
        assert avg_keys_per_query(sq, skv, w, causal) == \
            pytest.approx(brute, rel=1e-12)

    @settings(max_examples=40, deadline=None)
    @given(m=_seq, k=_dim, n=_dim,
           epi=st.sampled_from(["bias", "silu", "gelu", "layernorm"]))
    def test_prop_matmul_epilogue_strictly_less_traffic(m, k, n, epi):
        a, w = TensorStat((m, k), "bfloat16"), TensorStat((k, n), "bfloat16")
        fused = profile("matmul", [a, w], epilogue=epi)
        plain = profile("matmul", [a, w])
        assert fused.read_bytes + fused.write_bytes <= \
            plain.read_bytes + plain.write_bytes + 4 * n
        assert fused.flops > plain.flops
