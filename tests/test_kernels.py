"""Per-kernel allclose validation against the pure-jnp oracles —
shape/dtype sweeps, interpret mode (kernel bodies execute on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.mamba import ssd_decode_step

RNG = np.random.default_rng(7)


def randn(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# --------------------------------------------------------------- tsmm
@pytest.mark.parametrize("m,n,bm,bn", [
    (512, 256, 256, 128),
    (1024, 512, 512, 256),
    (768, 384, 256, 128),       # non-power-of-two multiples
    (2048, 128, 512, 128),      # single block column
])
def test_tsmm_shapes(m, n, bm, bn):
    x = randn((m, n))
    out = ops.tsmm(x, bm=bm, bn=bn)
    expect = ref.tsmm_ref(x)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_tsmm_dtypes(dtype, tol):
    x = randn((512, 256), dtype)
    out = ops.tsmm(x, bm=256, bn=128)
    expect = np.asarray(ref.tsmm_ref(x), np.float32)
    np.testing.assert_allclose(np.asarray(out, np.float32), expect,
                               rtol=tol, atol=tol * 30)


def test_tsmm_symmetry():
    x = randn((512, 256))
    out = np.asarray(ops.tsmm(x, bm=256, bn=128))
    np.testing.assert_allclose(out, out.T, rtol=1e-6)


def test_tsmm_ridge_epilogue():
    """Fused G = X^T X + reg*I shifts exactly the diagonal, all blocks."""
    x = randn((512, 256))
    reg = 7.25
    out = ops.tsmm(x, bm=256, bn=128, reg=reg)      # 2 block cols: tests
    plain = ops.tsmm(x, bm=256, bn=128)             # on/off-diagonal tiles
    np.testing.assert_allclose(
        np.asarray(out) - np.asarray(plain),
        reg * np.eye(256, dtype=np.float32), rtol=0, atol=1e-4)
    np.testing.assert_allclose(out, ref.tsmm_ref(x, reg=reg),
                               rtol=2e-5, atol=2e-4)


# ------------------------------------------------------ matmul epilogue
@pytest.mark.parametrize("epilogue", [None, "bias", "silu", "gelu"])
@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (512, 256, 256, 256, 128, 128),
    (256, 512, 384, 128, 256, 128),     # non-square, 3 k-steps
])
def test_matmul_epilogue_sweep(epilogue, m, n, k, bm, bn, bk):
    x, w = randn((m, k)), randn((k, n))
    bias = randn((n,)) if epilogue == "bias" else None
    out = ops.matmul_epilogue(x, w, bias, epilogue=epilogue,
                              bm=bm, bn=bn, bk=bk)
    expect = ref.matmul_epilogue_ref(x, w, bias, epilogue=epilogue)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-4)


def test_matmul_epilogue_layernorm_full_row():
    x, w = randn((256, 256)), randn((256, 256))
    out = ops.matmul_epilogue(x, w, epilogue="layernorm",
                              bm=128, bn=256, bk=128)
    expect = ref.matmul_epilogue_ref(x, w, epilogue="layernorm")
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-4)
    rows = np.asarray(out, np.float32)
    np.testing.assert_allclose(rows.mean(axis=-1), 0.0, atol=1e-4)


@pytest.mark.parametrize("out_dtype,tol", [
    (jnp.bfloat16, 3e-2), (jnp.float32, 2e-4)])
def test_matmul_epilogue_cast_sinking(out_dtype, tol):
    """out_dtype narrows during the single flush write (fp32 accumulate)."""
    x, w = randn((256, 256)), randn((256, 256))
    out = ops.matmul_epilogue(x, w, epilogue="silu", out_dtype=out_dtype,
                              bm=128, bn=128, bk=128)
    assert out.dtype == out_dtype
    expect = ref.matmul_epilogue_ref(x, w, epilogue="silu",
                                     out_dtype=out_dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_matmul_epilogue_bf16_inputs():
    x = randn((256, 256), jnp.bfloat16)
    w = randn((256, 256), jnp.bfloat16)
    out = ops.matmul_epilogue(x, w, epilogue="gelu", bm=128, bn=128, bk=128)
    expect = ref.matmul_epilogue_ref(x, w, epilogue="gelu")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2, atol=3e-2)


# ----------------------------------------------------------- flash attn
@pytest.mark.parametrize("b,hq,hkv,s,d,causal,window", [
    (2, 4, 2, 256, 64, True, None),
    (1, 4, 4, 256, 32, False, None),
    (2, 8, 2, 512, 64, True, 128),
    (1, 2, 1, 512, 128, True, None),
    (1, 4, 1, 256, 64, False, 64),
])
def test_flash_attention_sweep(b, hq, hkv, s, d, causal, window):
    q, k, v = randn((b, hq, s, d)), randn((b, hkv, s, d)), randn((b, hkv, s, d))
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=128, bk=128)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = randn((1, 2, 256, 64), jnp.bfloat16)
    k = randn((1, 2, 256, 64), jnp.bfloat16)
    v = randn((1, 2, 256, 64), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, bq=128, bk=128)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_block_shape_invariance():
    q, k, v = randn((1, 2, 512, 64)), randn((1, 2, 512, 64)), randn((1, 2, 512, 64))
    o1 = ops.flash_attention(q, k, v, bq=64, bk=64)
    o2 = ops.flash_attention(q, k, v, bq=256, bk=128)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 128, 4, 16, 32, 32),
    (1, 256, 2, 64, 128, 64),
    (2, 64, 8, 32, 16, 16),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    g = 1
    x = randn((b, s, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A_log = jnp.asarray(RNG.uniform(-1, 1, (h,)), jnp.float32)
    B = randn((b, s, g, n))
    C = randn((b, s, g, n))
    D = randn((h,))
    y_k, st_k = ops.ssd_scan(x, dt, A_log, B, C, D, chunk=chunk)
    y_r, st_r = ref.ssd_scan_ref(x, dt, A_log, B, C, D, chunk=chunk)
    np.testing.assert_allclose(y_k, y_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_k, st_r, rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_sequential_decode():
    b, s, h, p, n = 1, 32, 2, 8, 16
    x = randn((b, s, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A_log = jnp.asarray(RNG.uniform(-1, 1, (h,)), jnp.float32)
    B, C = randn((b, s, 1, n)), randn((b, s, 1, n))
    D = randn((h,))
    y_k, st_k = ops.ssd_scan(x, dt, A_log, B, C, D, chunk=8)
    st = jnp.zeros((b, h, p, n))
    for t in range(s):
        y_t, st = ssd_decode_step(st, x[:, t], dt[:, t], A_log,
                                  B[:, t], C[:, t], D)
        np.testing.assert_allclose(y_k[:, t], y_t, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_k, st, rtol=2e-4, atol=2e-4)
