"""Vectorized (lane-vector) plan costing: bit-exactness vs the scalar walk.

One tree walk per structure signature covers a whole knob grid
(microbatches, grad-reduce dtype) as numpy lanes.  These tests sweep every
K>1 structure group of real cells and assert the lane extraction equals
the scalar estimator bit for bit — every CostBreakdown field, every
ProgramTotals field, and peak HBM.  The hypothesis-randomized
counterparts live in tests/test_properties.py; this module runs always.
"""
import dataclasses

from repro.configs import SHAPES, get_config
from repro.core.cluster import (multi_pod_config, single_pod_config,
                                torus_3d_config)
from repro.core.planner import (SearchStats, _cost_candidate,
                                _cost_group_vectorized, _structure_key,
                                cost_candidates_batched, choose_plan,
                                enumerate_plans)

POD = single_pod_config()
MULTI = multi_pod_config()
TORUS = torus_3d_config()


def _knob_groups(arch, shape, cc):
    """Structure groups with more than one knob-grid member."""
    groups = {}
    for p in enumerate_plans(arch, shape, cc):
        groups.setdefault(_structure_key(p, shape.mode), []).append(p)
    return [g for g in groups.values() if len(g) > 1]


def _assert_lane_exact(arch, shape, members, cc):
    """The vectorized group walk must engage (no fallback) and reproduce
    the scalar walk bit-for-bit on every lane."""
    vec = _cost_group_vectorized(arch, shape, members, cc)
    for p, got in zip(members, vec):
        base = _cost_candidate(arch, shape, p, cc, None,
                               SearchStats()).cost
        assert got.total == base.total, p.describe()
        for field in ("io", "compute", "collective", "latency"):
            assert getattr(got.breakdown, field) == \
                getattr(base.breakdown, field), (p.describe(), field)
        assert got.peak_hbm_per_device == base.peak_hbm_per_device, \
            p.describe()
        assert got.totals.as_tuple() == base.totals.as_tuple(), p.describe()


def test_batched_walk_bit_exact_on_every_structure_group():
    """Every K>1 structure group of one train cell and one decode cell,
    on the 2D pod, the 3D torus and the pipeline-bearing multi-pod mesh
    — no sampling, no fallback tolerated."""
    arch = get_config("qwen1.5-0.5b")
    for shape_id in ("train_4k", "decode_32k"):
        shape = SHAPES[shape_id]
        for cc in (POD, TORUS, MULTI):
            for members in _knob_groups(arch, shape, cc):
                _assert_lane_exact(arch, shape, members, cc)


def test_batched_walk_bit_exact_on_moe_and_pipelined_groups():
    """MoE roles (expert-parallel collectives) and multi-pod pipelined
    roles exercise the per-lane critical-stage argmax and the routed-
    activation sparsity math."""
    arch, shape = get_config("phi3.5-moe-42b-a6.6b"), SHAPES["train_4k"]
    for cc in (POD, MULTI):
        groups = _knob_groups(arch, shape, cc)
        assert groups
        for members in groups:
            _assert_lane_exact(arch, shape, members, cc)
    assert any(m.pp_axes
               for g in _knob_groups(arch, shape, MULTI) for m in g), \
        "multi-pod grid lost its pipelined roles"


def test_batched_decisions_match_scalar_in_input_order():
    """cost_candidates_batched returns input-order PlanDecisions whose
    time/hbm/feasibility equal the scalar path's, grid-wide."""
    arch, shape = get_config("pixtral-12b"), SHAPES["train_4k"]
    cands = enumerate_plans(arch, shape, POD)
    batched = cost_candidates_batched(arch, shape, cands, POD)
    for p, got in zip(cands, batched):
        base = _cost_candidate(arch, shape, p, POD, None, SearchStats())
        assert got.plan == p == base.plan
        assert got.time == base.time
        assert got.hbm_est == base.hbm_est
        assert got.feasible == base.feasible


def test_batched_walk_counts_one_walk_per_structure():
    """The engine's whole point: far fewer tree walks than candidates.
    Walk count is observed by intercepting the group walker."""
    from repro.core import planner
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    cands = enumerate_plans(arch, shape, POD)
    n_groups = len({_structure_key(p, shape.mode) for p in cands})
    walks = []
    orig = planner._cost_group_vectorized
    planner._cost_group_vectorized = \
        lambda *a: walks.append(1) or orig(*a)
    try:
        cost_candidates_batched(arch, shape, cands, POD)
    finally:
        planner._cost_group_vectorized = orig
    assert len(walks) <= n_groups < len(cands)
    assert len(walks) >= 1


def test_choose_plan_batched_ranking_matches_exhaustive():
    """search="batched" at full top_k reproduces the exhaustive ranking
    decision-for-decision (identical rank keys => identical order)."""
    for arch_id, cc in (("qwen1.5-0.5b", POD), ("pixtral-12b", MULTI)):
        arch, shape = get_config(arch_id), SHAPES["train_4k"]
        k = len(enumerate_plans(arch, shape, cc))
        ex = choose_plan(arch, shape, cc, top_k=k, search="exhaustive")
        ba = choose_plan(arch, shape, cc, top_k=k, search="batched")
        assert [d.plan for d in ex] == [d.plan for d in ba]
        assert [d.time for d in ex] == [d.time for d in ba]
        assert [d.feasible for d in ex] == [d.feasible for d in ba]


def test_choose_plan_batched_top1_prunes_and_preserves_winner():
    """At top_k=1 the role-floor dominance pool may skip whole structure
    groups, but the returned winner must equal the exhaustive winner."""
    for arch_id in ("qwen1.5-0.5b", "pixtral-12b", "gemma3-12b"):
        for cc in (POD, MULTI):
            arch, shape = get_config(arch_id), SHAPES["train_4k"]
            stats = SearchStats()
            ba = choose_plan(arch, shape, cc, top_k=1, search="batched",
                             stats=stats)[0]
            ex = choose_plan(arch, shape, cc, top_k=1,
                             search="exhaustive")[0]
            assert ba.plan == ex.plan
            assert ba.time == ex.time
            n_space = len(enumerate_plans(arch, shape, cc))
            assert stats.costed + stats.pruned_dominated >= n_space


def test_scalar_fallback_is_exact_when_lanes_disagree():
    """A hand-built group whose lanes would take different structural
    branches must fall back to per-member scalar costing and still return
    exact decisions (the 'exact by construction' contract)."""
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    base = [p for p in enumerate_plans(arch, shape, POD)
            if p.microbatches > 1][0]
    # microbatch values straddling the shape's dp divisibility: eff_degree
    # collapses on some lanes only, so resident shapes disagree -> the
    # driver must not silently mis-vectorize
    odd = [dataclasses.replace(base, microbatches=m) for m in (2, 3, 5, 8)]
    got = cost_candidates_batched(arch, shape, odd, POD)
    for p, d in zip(odd, got):
        ref = _cost_candidate(arch, shape, p, POD, None, SearchStats())
        assert d.time == ref.time and d.hbm_est == ref.hbm_est
