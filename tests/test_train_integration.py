"""End-to-end training integration on CPU (tiny config):
loss decreases, checkpoint/resume is exact, elastic replan works."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.cluster import cpu_host_config
from repro.core.planner import ShardingPlan
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.runtime.train_loop import Trainer, TrainerConfig

pytestmark = pytest.mark.slow   # jit-compiles the real train step on CPU

TINY_SHAPE = ShapeConfig("tiny", seq_len=32, global_batch=8, mode="train")


def _trainer(tmp_path=None, steps=12, **tkw):
    arch = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                               dtype="float32")
    mesh = make_host_mesh()
    cc = cpu_host_config().with_mesh(tuple(mesh.devices.shape),
                                     tuple(mesh.axis_names))
    plan = ShardingPlan(batch_axes=("data",))
    tcfg = TrainerConfig(steps=steps, log_every=1,
                         checkpoint_every=5,
                         ckpt_dir=str(tmp_path) if tmp_path else None,
                         seed=0, **tkw)
    opt = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    return Trainer(arch, TINY_SHAPE, cc, mesh, plan=plan, opt_cfg=opt,
                   tcfg=tcfg)


def test_loss_decreases_over_training():
    t = _trainer(steps=15)
    result = t.run()
    hist = result["history"]
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first - 0.1, f"{first} -> {last}"


def test_checkpoint_resume_exact(tmp_path):
    # run 1: 10 steps straight through
    t1 = _trainer(None, steps=10, donate=False)
    r1 = t1.run()
    # run 2: 6 steps (checkpoint lands at step 5), then resume to 10
    t2 = _trainer(tmp_path / "ck", steps=6, donate=False)
    r2a = t2.run()
    t3 = _trainer(tmp_path / "ck", steps=10, donate=False)
    r2b = t3.run()
    # same final loss trajectory tail (deterministic data by step index)
    tail1 = [h["loss"] for h in r1["history"] if h["step"] >= 6]
    tail2 = [h["loss"] for h in r2b["history"] if h["step"] >= 6]
    np.testing.assert_allclose(tail1, tail2, rtol=1e-4)


def test_grad_compression_schemes_still_learn():
    for scheme in ("bf16", "int8_ef"):
        t = _trainer(steps=12, compress_scheme=scheme)
        hist = t.run()["history"]
        assert hist[-1]["loss"] < hist[0]["loss"], scheme


def test_elastic_replan_changes_lr_scale():
    from repro.runtime.elastic import replan
    arch = get_config("qwen1.5-0.5b")
    from repro.configs import SHAPES
    from repro.core.cluster import single_pod_config
    old_cc = single_pod_config()
    ep = replan(arch, SHAPES["train_4k"], old_cc=old_cc,
                new_mesh_shape=(8, 16), new_mesh_axes=("data", "model"))
    assert ep.lr_scale == pytest.approx(0.5)
    assert ep.decision.plan is not None
