"""Sharding-rule tests: divisibility guards, axis-conflict resolution,
ZeRO-1 moment sharding — on an AbstractMesh shaped like the production pod."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.planner import ShardingPlan
from repro.launch import shardings as S
from repro.launch.mesh import abstract_mesh
from repro.models.model import build_model

MESH = abstract_mesh((16, 16), ("data", "model"))
PLAN_TP = ShardingPlan(batch_axes=("data",), tp_axes=("model",))
PLAN_EPTP = ShardingPlan(batch_axes=("data",), tp_axes=("model",),
                         ep_axes=("model",))


def _flat_specs(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {"/".join(S._pstr(p) for p in path): leaf.spec
            for path, leaf in flat}


def test_no_axis_used_twice_in_any_spec():
    for arch_id in ("deepseek-v3-671b", "phi3.5-moe-42b-a6.6b",
                    "gemma3-12b", "mamba2-1.3b", "whisper-small"):
        shapes = build_model(get_config(arch_id)).init_shapes()
        specs = _flat_specs(S.params_shardings(MESH, PLAN_EPTP, shapes))
        for key, spec in specs.items():
            used = []
            for entry in spec:
                if entry is None:
                    continue
                used += list(entry) if isinstance(entry, tuple) else [entry]
            assert len(used) == len(set(used)), (arch_id, key, spec)


def test_divisibility_guard_falls_back_to_replication():
    # whisper has 12 heads; 12 q-heads x 64 = 768 columns: 768 % 16 == 0 so
    # the flat dim shards; but a 10-wide dim must stay replicated
    sh = S.param_sharding(MESH, PLAN_TP, "blocks/attn/w_q", (12, 768, 770))
    assert sh.spec[1] in ("model", None)
    sh2 = S.param_sharding(MESH, PLAN_TP, "blocks/attn/w_q", (12, 768, 10))
    assert sh2.spec[-1] is None


def test_moe_experts_shard_over_ep():
    sh = S.param_sharding(MESH, PLAN_EPTP, "blocks/moe/w_up", (58, 256, 7168, 2048))
    assert sh.spec[1] == "model"         # experts win the model axis
    assert sh.spec[3] is None            # tp lost the tie -> replicated


def test_batch_sharding_divides_batch_dim():
    shapes = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    sh = S.batch_shardings(MESH, PLAN_TP, shapes)
    assert sh["tokens"].spec[0] == "data"
    odd = {"tokens": jax.ShapeDtypeStruct((7, 64), jnp.int32)}
    assert S.batch_shardings(MESH, PLAN_TP, odd)["tokens"].spec[0] is None


def test_cache_seq_fallback_when_batch_unshardable():
    # long_500k: batch=1 -> KV length dim takes the data axis
    shapes = {"self": {"k": jax.ShapeDtypeStruct((48, 1, 8, 524288, 256),
                                                 jnp.bfloat16)}}
    sh = S.cache_shardings(MESH, PLAN_TP, shapes)
    assert sh["self"]["k"].spec[1] is None
    assert sh["self"]["k"].spec[3] == "data"


def test_zero1_moments_pick_up_data_axis():
    from repro.optim import adamw
    shapes = build_model(get_config("qwen1.5-0.5b")).init_shapes()
    psh = S.params_shardings(MESH, PLAN_TP, shapes)
    opt_shapes = jax.eval_shape(
        lambda: adamw.init(adamw.AdamWConfig(), shapes))
    osh = S.opt_state_shardings(MESH, PLAN_TP, psh, opt_shapes)
    m_specs = _flat_specs(osh.m)
    p_specs = _flat_specs(psh)
    extra = sum("data" in str(m) and "data" not in str(p_specs[k])
                for k, m in m_specs.items())
    assert extra > 0, "ZeRO-1 should shard some moments over data"
