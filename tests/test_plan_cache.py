"""Plan-search subsystem tests: sub-plan cost memoization (exactness, hit
accounting, invalidation keys), the staged beam search vs. the exhaustive
scan, and the scenario sweep engine."""
import math

import pytest

from repro.configs import SHAPES, get_config
from repro.core import (Compute, ForBlock, GenericBlock, IfBlock,
                        PlanCostCache, Program, estimate, single_chip_config,
                        single_pod_config)
from repro.core.planner import (SearchStats, ShardingPlan, build_step_program,
                                choose_plan, enumerate_plans)
from repro.core.sweep import SweepEngine, format_table, sweep_rows
from repro.core.symbols import MemState, TensorStat

CC = single_pod_config()
CHIP = single_chip_config()


def _lm_programs(arch_id="qwen1.5-0.5b", shape_id="train_4k"):
    arch = get_config(arch_id)
    shape = SHAPES[shape_id]
    return [build_step_program(arch, shape, p, CC)
            for p in enumerate_plans(arch, shape, CC)]


# ----------------------------------------------------------- cache: exactness
def test_cached_total_equals_uncached_within_1e9():
    """Cost invariance: memoization must be bit-for-bit (well under 1e-9)."""
    cache = PlanCostCache()
    for prog in _lm_programs():
        base = estimate(prog, CC)
        hit = estimate(prog, CC, cache=cache)
        assert abs(base.total - hit.total) < 1e-9
        assert abs(base.breakdown.io - hit.breakdown.io) < 1e-12
        assert abs(base.breakdown.collective - hit.breakdown.collective) < 1e-9
        assert abs(base.peak_hbm_per_device - hit.peak_hbm_per_device) < 1e-3


def test_cache_hit_miss_counters():
    prog_a = _lm_programs()[0]
    cache = PlanCostCache()
    estimate(prog_a, CC, cache=cache)
    first = cache.stats()
    assert first.misses > 0
    assert first.entries == first.misses
    # the per-layer ForBlock warm body must already hit within one program
    assert first.hits > 0
    # an identical program re-costed is (almost) all hits: the only misses
    # allowed are none — every block/instruction state was seen already
    estimate(prog_a, CC, cache=cache)
    second = cache.stats()
    assert second.misses == first.misses
    assert second.hits > first.hits
    assert 0.0 < second.hit_rate <= 1.0
    cache.clear()
    assert cache.stats() == type(first)(0, 0, 0)


def test_cache_distinguishes_cluster_configs():
    """Same program, different cluster: totals must differ (no false hits)."""
    prog = _lm_programs()[0]
    cache = PlanCostCache()
    t_pod = estimate(prog, CC, cache=cache).total
    slow_cc = CC.with_overlap(0.9)
    t_overlap = estimate(prog, slow_cc, cache=cache).total
    assert t_overlap < t_pod          # overlap discounts collectives
    assert abs(estimate(prog, CC).total - t_pod) < 1e-9
    assert abs(estimate(prog, slow_cc).total - t_overlap) < 1e-9


def test_cache_respects_symbol_state_first_vs_warm():
    """A loop body reading a DISK input pays IO only on the first pass even
    through the cache (read-set fingerprints include memory state)."""
    x = TensorStat((10_000, 1000), "float64", state=MemState.DISK)
    body = [Compute("unary", ("X",), "Y", exec_type="CP")]
    p = Program("t", blocks=[ForBlock("l", 5, body=body)], inputs={"X": x})
    base = estimate(p, CHIP)
    cached = estimate(p, CHIP, cache=(c := PlanCostCache()))
    assert abs(base.total - cached.total) < 1e-12
    assert abs(base.breakdown.io - cached.breakdown.io) < 1e-12
    # first/warm bodies are distinct read states -> two entries, not one
    assert c.stats().misses >= 2


def test_if_blocks_are_costed_but_not_cached():
    x = TensorStat((2048, 2048), "float32")
    heavy = [Compute("matmul", ("X", "X"), "Y", exec_type="CP")]
    light = [Compute("unary", ("X",), "Y", exec_type="CP")]
    p = Program("t", blocks=[IfBlock("if", branches=[heavy, light],
                                     weights=[0.25, 0.75])], inputs={"X": x})
    cache = PlanCostCache()
    t0 = estimate(p, CHIP, cache=cache).total
    t1 = estimate(p, CHIP, cache=cache).total
    assert math.isclose(t0, estimate(p, CHIP).total, rel_tol=1e-12)
    assert math.isclose(t0, t1, rel_tol=1e-12)


def test_cache_shared_across_candidates_saves_walks():
    progs = _lm_programs()
    cache = PlanCostCache()
    for prog in progs:
        estimate(prog, CC, cache=cache)
    st = cache.stats()
    # candidates share per-layer bodies: most lookups must be hits
    assert st.hits > st.misses


def test_program_totals_round_trip_bit_exact_through_cache():
    """ProgramTotals (the floor's input) must replay bit-exact from the
    cache: cold record and warm replay both equal the uncached walk, field
    for field, with exact float equality (not isclose)."""
    cache = PlanCostCache()
    for prog in _lm_programs()[:6]:
        base = estimate(prog, CC).totals
        cold = estimate(prog, CC, cache=cache).totals
        warm = estimate(prog, CC, cache=cache).totals
        assert base.as_tuple() == cold.as_tuple() == warm.as_tuple()
        # totals carry real work in every bucket this program exercises
        assert sum(base.mxu_flops.values()) > 0
        assert base.vpu_flops > 0 and base.hbm_bytes > 0
        assert base.collective_bytes == base.ici_bytes + base.dcn_bytes


def test_program_totals_track_link_classes():
    """Collective volume lands in the bucket of the axis's fabric: "pod"
    crosses DCN, every other axis rides ICI."""
    from repro.core import multi_pod_config
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    pod_cc = CC
    dcn_cc = multi_pod_config()
    plan = ShardingPlan(name="dp+tp", batch_axes=("pod", "data"),
                        tp_axes=("model",))
    single = estimate(build_step_program(
        arch, shape, ShardingPlan(name="dp+tp", batch_axes=("data",),
                                  tp_axes=("model",)), pod_cc), pod_cc).totals
    multi = estimate(build_step_program(arch, shape, plan, dcn_cc),
                     dcn_cc).totals
    assert single.dcn_bytes == 0.0          # no pod axis on a single slice
    assert single.ici_bytes > 0.0           # tp collectives ride ICI
    assert multi.dcn_bytes > 0.0            # grad reduce crosses DCN


# ------------------------------------------------------ beam vs. exhaustive
@pytest.mark.parametrize("arch_id", ["qwen1.5-0.5b", "gemma3-12b"])
def test_beam_matches_exhaustive_winner(arch_id):
    arch = get_config(arch_id)
    shape = SHAPES["train_4k"]
    stats = SearchStats()
    beam = choose_plan(arch, shape, CC, top_k=1, search="beam", stats=stats)
    exhaustive = choose_plan(arch, shape, CC, top_k=1, search="exhaustive")
    assert beam[0].plan == exhaustive[0].plan
    assert math.isclose(beam[0].time, exhaustive[0].time, rel_tol=1e-12)
    # the beam must actually search less than the full space
    assert stats.costed < len(enumerate_plans(arch, shape, CC))
    assert stats.pruned_infeasible + stats.pruned_dominated > 0


def test_beam_handles_all_infeasible_space():
    d = choose_plan(get_config("deepseek-v3-671b"), SHAPES["train_4k"], CC,
                    top_k=1, search="beam")[0]
    assert not d.feasible


def test_explicit_candidates_still_scanned_linearly():
    arch = get_config("qwen1.5-0.5b")
    shape = SHAPES["train_4k"]
    cands = [ShardingPlan(tp_axes=("model",)),
             ShardingPlan(name="dp-pure", batch_axes=("data", "model"))]
    stats = SearchStats()
    out = choose_plan(arch, shape, CC, candidates=cands, stats=stats)
    assert len(out) == 2
    assert stats.costed == 2


# ------------------------------------------------------------- sweep engine
def test_sweep_engine_ranks_and_reuses_cache():
    eng = SweepEngine()
    cells = eng.sweep(["qwen1.5-0.5b"], ["train_4k", "decode_32k"], ["pod"])
    assert len(cells) == 2
    times = [c.time for c in cells if not c.skipped]
    assert times == sorted(times)
    total = eng.cache.stats()
    assert total.hits > 0
    # a repeated cell is nearly free: no new cache entries are created
    before = eng.cache.entries
    cell = eng.cost_cell("qwen1.5-0.5b", "train_4k", "pod")
    assert eng.cache.entries == before
    assert cell.stats.cache.misses == 0


def test_sweep_skips_inapplicable_cells_and_formats():
    eng = SweepEngine()
    cells = eng.sweep(["qwen1.5-0.5b"], ["long_500k", "decode_32k"], ["pod"])
    skipped = [c for c in cells if c.skipped]
    assert len(skipped) == 1 and skipped[0].shape_id == "long_500k"
    table = format_table(cells)
    assert "skip" in table and "decode_32k" in table
    rows = sweep_rows(cells)
    assert any(r.startswith("sweep.qwen1.5-0.5b|decode_32k|pod,") for r in rows)
    assert any(";cache=" in r for r in rows)
