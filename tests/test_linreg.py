"""Paper §2 reproduction: LinReg DS plan generation across Table-1
scenarios must make the SAME operator/execution-type switches the paper
reports, and costing must order the scenarios sensibly."""
import pytest

from repro.core import estimate, explain
from repro.core.cluster import ClusterConfig, CPU_HOST, single_pod_config
from repro.core.linreg import (PAPER_BUDGETS, SCENARIOS, build_linreg_program,
                               select_operators, tpu_budgets)

PAPER_CC = ClusterConfig(chip=CPU_HOST, mesh_shape=(72,), mesh_axes=("data",),
                         dispatch_latency=20.0)


@pytest.mark.parametrize("name,exec_type,tsmm_op,mm_op,part_y", [
    ("XS", "CP", "tsmm", "mm", False),          # Fig. 2
    ("XL1", "DIST", "tsmm+ak+", "mapmm", True),  # Fig. 3
    ("XL2", "DIST", "cpmm", "mapmm", True),      # wide X blocks tsmm
    ("XL3", "DIST", "tsmm+ak+", "cpmm", False),  # big y blocks broadcast
    ("XL4", "DIST", "cpmm", "cpmm", False),      # both
])
def test_paper_plan_switches(name, exec_type, tsmm_op, mm_op, part_y):
    choice = select_operators(SCENARIOS[name], PAPER_CC, PAPER_BUDGETS)
    assert choice.exec_type == exec_type
    assert choice.tsmm_op == tsmm_op
    assert choice.mm_op == mm_op
    assert choice.partition_y == part_y


def test_yt_rewrite_only_in_cp():
    assert select_operators(SCENARIOS["XS"], PAPER_CC, PAPER_BUDGETS).yt_rewrite
    assert not select_operators(SCENARIOS["XL1"], PAPER_CC,
                                PAPER_BUDGETS).yt_rewrite


def test_costs_increase_with_scale():
    costs = {}
    for name, sc in SCENARIOS.items():
        prog, _ = build_linreg_program(sc, PAPER_CC)
        costs[name] = estimate(prog, PAPER_CC).total
    assert costs["XS"] < costs["XL1"] < costs["XL4"]
    assert costs["XL2"] > costs["XL1"]      # cpmm shuffle costs more


def test_xs_dominated_by_tsmm_compute():
    """Paper Fig. 4: tsmm computation dominates scenario XS."""
    prog, _ = build_linreg_program(SCENARIOS["XS"], PAPER_CC)
    costed = estimate(prog, PAPER_CC)
    lines = explain(costed)
    assert "tsmm" in lines
    core = costed.root.children[-1]
    tsmm_node = next(c for c in core.children if "tsmm" in c.label)
    assert tsmm_node.cost.total > 0.5 * costed.total


def test_tsmm_pays_x_read_in_xs():
    prog, _ = build_linreg_program(SCENARIOS["XS"], PAPER_CC)
    costed = estimate(prog, PAPER_CC)
    core = costed.root.children[-1]
    tsmm_node = next(c for c in core.children if "tsmm" in c.label)
    assert tsmm_node.cost.io > 0


def test_tpu_budgets_shift_cp_boundary():
    """On TPU the CP/local boundary moves: XS stays local, and the larger
    local memory means XL-scale inputs shard instead of spilling."""
    cc = single_pod_config()
    b = tpu_budgets(cc)
    assert select_operators(SCENARIOS["XS"], cc, b).exec_type == "CP"
    assert select_operators(SCENARIOS["XL1"], cc, b).exec_type == "DIST"
    # wide X: TPU block bound is 8192 cols, so XL2 keeps the tsmm operator
    assert select_operators(SCENARIOS["XL2"], cc, b).tsmm_op == "tsmm+ak+"


def test_explain_has_paper_shape():
    prog, _ = build_linreg_program(SCENARIOS["XL1"], PAPER_CC)
    text = explain(estimate(prog, PAPER_CC))
    assert "PROGRAM" in text
    assert "# C=" in text
    assert "all_reduce" in text           # the ak+ aggregation analogue
    assert "total cost C=" in text
