"""Unit tests for the cost estimator (paper §3): symbol-table state
machine, per-instruction costing, Eq (1) control-flow aggregation."""
import math

import pytest

from repro.core import (Call, Collective, Compute, CreateVar, DataGen,
                        ForBlock, FunctionBlock, GenericBlock, IfBlock, IO,
                        ParForBlock, Program, WhileBlock, estimate,
                        single_chip_config, single_pod_config)
from repro.core.costmodel import TINY, CostBreakdown
from repro.core.symbols import MemState, SymbolTable, TensorStat


CC = single_chip_config()
POD = single_pod_config()


def prog_with(children, inputs=None, name="t"):
    p = Program(name=name, blocks=[GenericBlock("b", children)])
    if inputs:
        p.inputs.update(inputs)
    return p


# ----------------------------------------------------------- symbol table
def test_symbol_table_create_copy_remove():
    t = SymbolTable()
    t.createvar("x", TensorStat((4, 4)))
    t.cpvar("x", "y")
    assert "y" in t and t.get("y").shape == (4, 4)
    t.rmvar("x", "y")
    assert len(t) == 0


def test_symbol_table_state_and_sizes():
    st = TensorStat((1000, 1000), "float64", state=MemState.DISK)
    assert st.bytes_in_memory() == 8e6
    t = SymbolTable()
    t.createvar("x", st)
    assert t.live_hbm_bytes() == 0.0
    t.touch_hbm("x")
    assert t.live_hbm_bytes() == 8e6


def test_sparse_serialized_size_smaller():
    dense = TensorStat((1000, 1000), "float64", sparsity=1.0)
    sparse = TensorStat((1000, 1000), "float64", sparsity=0.01)
    assert sparse.bytes_serialized() < dense.bytes_serialized() / 10


# ----------------------------------------------------- IO-once semantics
def test_first_use_pays_io_second_is_free():
    """Paper §3.2: only the first consumer of a persistent input pays IO."""
    x = TensorStat((10_000, 1000), "float64", state=MemState.DISK)
    p = prog_with([
        Compute("tsmm", ("X",), "A", exec_type="CP"),
        Compute("transpose", ("X",), "Xt", exec_type="CP"),
    ], inputs={"X": x})
    costed = estimate(p, CC)
    tsmm_node = costed.root.children[0].children[0]
    tr_node = costed.root.children[0].children[1]
    assert tsmm_node.cost.io > 0.0
    assert tr_node.cost.io == 0.0


def test_explicit_io_changes_state():
    x = TensorStat((1000, 1000), "float64", state=MemState.DISK)
    p = prog_with([
        IO("read", "X", src=MemState.DISK, dst=MemState.HBM),
        Compute("tsmm", ("X",), "A", exec_type="CP"),
    ], inputs={"X": x})
    costed = estimate(p, CC)
    read_node = costed.root.children[0].children[0]
    tsmm_node = costed.root.children[0].children[1]
    assert read_node.cost.io > 0
    assert tsmm_node.cost.io == 0


# ------------------------------------------------------ instruction costs
def test_tsmm_half_of_full_matmul():
    """FLOP(tsmm) = 0.5 * FLOP(X^T @ X) (paper Eq (2))."""
    x = TensorStat((65536, 2048), "float32")   # both ops at full MXU util
    p1 = prog_with([Compute("tsmm", ("X",), "A", exec_type="CP")],
                   inputs={"X": x})
    p2 = prog_with([
        Compute("transpose", ("X",), "Xt", exec_type="CP"),
        Compute("matmul", ("Xt", "X"), "A", exec_type="CP"),
    ], inputs={"X": x})
    c1 = estimate(p1, CC)
    mm = estimate(p2, CC).root.children[0].children[1]
    tsmm = c1.root.children[0].children[0]
    assert tsmm.cost.compute == pytest.approx(mm.cost.compute * 0.5, rel=0.05)


def test_compute_roofline_max_of_flops_and_bytes():
    # tiny matmul is bandwidth bound; huge matmul is flops bound
    small = TensorStat((128, 128), "float32")
    big = TensorStat((8192, 8192), "float32")
    for stat, bound in ((small, "mem"), (big, "flops")):
        p = prog_with([Compute("matmul", ("A", "B"), "C", exec_type="CP")],
                      inputs={"A": stat, "B": stat})
        node = estimate(p, CC).root.children[0].children[0]
        flops = 2 * stat.shape[0] ** 3
        t_flops_small = flops / (CC.chip.peak("float32") * CC.small_matmul_util)
        t_flops_big = flops / (CC.chip.peak("float32") * CC.matmul_util)
        t_mem = 3 * stat.bytes_in_memory() / CC.hbm_bw_eff
        if bound == "mem":
            assert node.cost.compute == pytest.approx(max(t_mem, t_flops_small), rel=1e-6)
        else:
            assert node.cost.compute == pytest.approx(t_flops_big, rel=1e-6)


def test_dist_compute_divided_by_shards():
    x = TensorStat((65536, 4096), "bfloat16", shards=256)
    p_cp = prog_with([Compute("tsmm", ("X",), "A", exec_type="CP")],
                     inputs={"X": TensorStat((65536, 4096), "bfloat16")})
    p_dist = prog_with([Compute("tsmm", ("X",), "A", exec_type="DIST",
                                shard_axes=("data", "model"))],
                       inputs={"X": x})
    c_cp = estimate(p_cp, POD).root.children[0].children[0].cost.compute
    c_dist = estimate(p_dist, POD).root.children[0].children[0].cost.compute
    assert c_dist == pytest.approx(c_cp / 256, rel=0.01)


# ------------------------------------------------------------ collectives
def test_all_reduce_ring_formula():
    x = TensorStat((1024, 1024), "float32")  # 4 MB payload
    p = prog_with([Collective("all_reduce", "X", ("data",))],
                  inputs={"X": x})
    t = estimate(p, POD).root.children[0].children[0].cost.collective
    n = 16
    wire = 2 * (n - 1) / n * x.bytes_in_memory() / POD.ici_bw_eff
    lat = 2 * (n - 1) * POD.collective_phase_latency
    assert t == pytest.approx(wire + lat, rel=1e-6)


def test_collective_single_device_free():
    x = TensorStat((1024, 1024), "float32")
    p = prog_with([Collective("all_reduce", "X", ("data",))], inputs={"X": x})
    assert estimate(p, CC).root.children[0].children[0].cost.collective == 0.0


def test_overlap_fraction_discounts_collectives():
    x = TensorStat((4096, 4096), "float32")
    p = prog_with([Collective("all_reduce", "X", ("data",))], inputs={"X": x})
    t0 = estimate(p, POD).total
    t1 = estimate(p, POD.with_overlap(0.7)).total
    assert t1 == pytest.approx(t0 * 0.3, rel=1e-6)


# --------------------------------------------------- control flow (Eq 1)
def _loop_body(var="X"):
    return [Compute("unary", (var,), "Y", exec_type="CP")]


def test_for_loop_scales_by_iterations():
    x = TensorStat((1024, 1024), "float32")
    body_cost = estimate(prog_with(_loop_body(), inputs={"X": x}), CC).total
    p = Program("t", blocks=[ForBlock("l", 10, body=_loop_body())],
                inputs={"X": x})
    assert estimate(p, CC).total == pytest.approx(10 * body_cost, rel=1e-3)


def test_while_unknown_uses_default_constant():
    x = TensorStat((1024, 1024), "float32")
    body_cost = estimate(prog_with(_loop_body(), inputs={"X": x}), CC).total
    p = Program("t", blocks=[WhileBlock("w", body=_loop_body())],
                inputs={"X": x})
    n_hat = CC.default_loop_iterations
    assert estimate(p, CC).total == pytest.approx(n_hat * body_cost, rel=1e-3)


def test_loop_first_iteration_io_correction():
    """Only the first iteration pays the persistent read (paper §3.2)."""
    x = TensorStat((10_000, 1000), "float64", state=MemState.DISK)
    p = Program("t", blocks=[ForBlock("l", 5, body=_loop_body())],
                inputs={"X": x})
    costed = estimate(p, CC)
    read_once = x.bytes_serialized() / CC.chip.disk_bw \
        + x.bytes_serialized() / CC.chip.pcie_bw
    assert costed.breakdown.io == pytest.approx(read_once, rel=1e-6)


def test_parfor_divides_by_parallelism():
    x = TensorStat((1024, 1024), "float32")
    p_seq = Program("t", blocks=[ForBlock("l", 12, body=_loop_body())],
                    inputs={"X": x})
    p_par = Program("t", blocks=[ParForBlock("l", 12, parallelism=4,
                                             body=_loop_body())],
                    inputs={"X": x})
    t_seq = estimate(p_seq, CC).total
    t_par = estimate(p_par, CC).total
    assert t_par == pytest.approx(t_seq * math.ceil(12 / 4) / 12, rel=1e-3)


def test_if_weighted_branches():
    x = TensorStat((2048, 2048), "float32")
    heavy = [Compute("matmul", ("X", "X"), "Y", exec_type="CP")]
    light = [Compute("unary", ("X",), "Y", exec_type="CP")]
    t_h = estimate(prog_with(heavy, inputs={"X": x}), CC).total
    t_l = estimate(prog_with(light, inputs={"X": x}), CC).total
    p = Program("t", blocks=[IfBlock("if", branches=[heavy, light],
                                     weights=[0.25, 0.75])],
                inputs={"X": x})
    assert estimate(p, CC).total == pytest.approx(
        0.25 * t_h + 0.75 * t_l, rel=1e-3)


def test_function_call_and_recursion_guard():
    x = TensorStat((1024, 1024), "float32")
    f = FunctionBlock("f", body=[Compute("unary", ("X",), "Y", exec_type="CP"),
                                 Call("f")])   # recursive
    p = Program("t", blocks=[Call("f")], functions={"f": f}, inputs={"X": x})
    costed = estimate(p, CC)        # must terminate
    base = estimate(prog_with(_loop_body(), inputs={"X": x}), CC).total
    assert costed.total < 3 * base + 1e-3


def test_peak_hbm_tracking():
    big = TensorStat((8192, 8192), "float32")
    p = prog_with([
        DataGen("rand", "A", big),
        DataGen("rand", "B", big),
    ])
    costed = estimate(p, CC)
    assert costed.peak_hbm_per_device >= 2 * big.bytes_in_memory()
