"""Resource-optimizer tests: the cluster/plan co-search must return the
exact exhaustive (cluster x plan) winner under every objective, at a
fraction of the full plan evaluations; its cluster cost floors must be
sound; elastic replanning must route through it."""
import math

import pytest

from repro.configs import SHAPES, get_config
from repro.core.costmodel import PlanCostCache, estimate
from repro.core.planner import build_step_program, enumerate_plans
from repro.core.resource import (ResourceSearchStats, _rank_key,
                                 cluster_floor_time, enumerate_clusters,
                                 format_decisions, mesh_candidates,
                                 optimize_resources)
from repro.core.sweep import SweepEngine

# The verification grid: 4 archs x 2 shapes x 3 objectives = 24 cells, each
# co-searched over the same 13-candidate cluster grid (3 chip types, 1-2
# pods, both mesh layouts, ICI and DCN multi-slice topologies).
VERIFY_CLUSTERS = enumerate_clusters(pod_counts=(1, 2))
GRID_ARCHS = ("qwen1.5-0.5b", "gemma3-12b", "mamba2-1.3b", "qwen1.5-4b")
GRID_SHAPES = ("train_4k", "decode_32k")
GRID_OBJECTIVES = (("step_time", None), ("cost", None), ("slo", 0.25))


def _exhaustive_oracle(arch, shape, cache):
    """The full (cluster x plan) scan, costed once; within a fixed cluster
    the fastest plan is also the cheapest (cost = time x chips x rate), so
    re-ranking the same scan serves every objective."""
    return optimize_resources(arch, shape, VERIFY_CLUSTERS,
                              objective="step_time", search="exhaustive",
                              cache=cache)


def test_co_search_matches_exhaustive_on_24_cell_grid():
    cells = [(a, s, o, slo) for a in GRID_ARCHS for s in GRID_SHAPES
             for o, slo in GRID_OBJECTIVES]
    assert len(cells) >= 24
    stats = ResourceSearchStats()
    cache = PlanCostCache()
    ex_cache = PlanCostCache()
    oracles = {}
    for arch_id, shape_id, objective, slo in cells:
        arch, shape = get_config(arch_id), SHAPES[shape_id]
        beam = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                                  objective=objective, slo=slo,
                                  cache=cache, stats=stats)
        if (arch_id, shape_id) not in oracles:
            oracles[arch_id, shape_id] = _exhaustive_oracle(arch, shape,
                                                            ex_cache)
        oracle = sorted(oracles[arch_id, shape_id],
                        key=_rank_key(objective, slo))
        w, we = beam[0], oracle[0]
        cell = f"{arch_id}|{shape_id}|{objective}"
        assert w.cluster_id == we.cluster_id, cell
        assert w.decision.plan == we.decision.plan, cell
        assert math.isclose(w.time, we.time, rel_tol=1e-9), cell
    # the whole grid must cost >=3x fewer full plan evaluations than the
    # exhaustive (cluster x plan) scan would
    assert stats.plan_evals * 3 <= stats.exhaustive_plan_space, \
        stats.describe()
    assert stats.clusters_pruned > 0
    assert stats.cache.hits > 0


def test_cluster_floor_is_sound():
    """No plan on a cluster may cost less than the cluster's floor — the
    property that makes skip-without-costing pruning exact."""
    cache = PlanCostCache()
    arch = get_config("qwen1.5-0.5b")
    for shape_id in GRID_SHAPES:
        shape = SHAPES[shape_id]
        for cand in VERIFY_CLUSTERS[::3]:
            floor = cluster_floor_time(arch, shape, cand.cc)
            assert floor > 0
            for plan in enumerate_plans(arch, shape, cand.cc)[:6]:
                costed = estimate(build_step_program(arch, shape, plan,
                                                     cand.cc),
                                  cand.cc, cache=cache)
                assert costed.total >= floor, (shape_id, cand.cid,
                                               plan.describe())


def test_cost_objective_trades_speed_for_price():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    cache = PlanCostCache()
    fastest = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                                 objective="step_time", cache=cache)[0]
    cheapest = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                                  objective="cost", cache=cache)[0]
    assert cheapest.cost_per_step <= fastest.cost_per_step
    assert fastest.time <= cheapest.time
    assert fastest.cost_per_step > 0       # the $-proxy field is wired


def test_slo_objective_picks_cheapest_meeting_target():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    cache = PlanCostCache()
    fastest = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                                 objective="step_time", cache=cache)[0]
    slo = fastest.time * 2.0               # satisfiable target
    best = optimize_resources(arch, shape, VERIFY_CLUSTERS, objective="slo",
                              slo=slo, cache=cache)[0]
    assert best.meets(slo)
    assert best.cost_per_step <= fastest.cost_per_step
    # unsatisfiable target: the honest ranking still returns a winner
    tight = optimize_resources(arch, shape, VERIFY_CLUSTERS, objective="slo",
                               slo=fastest.time / 1e6, cache=cache)[0]
    assert not tight.meets(fastest.time / 1e6)


def test_objective_validation():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["decode_32k"]
    with pytest.raises(ValueError):
        optimize_resources(arch, shape, VERIFY_CLUSTERS, objective="nope")
    with pytest.raises(ValueError):
        optimize_resources(arch, shape, VERIFY_CLUSTERS, objective="slo")


def test_format_decisions_renders_pruned_and_costed():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    decisions = optimize_resources(arch, shape, VERIFY_CLUSTERS)
    table = format_decisions(decisions)
    assert "pruned" in table and "chosen plan" in table
    assert decisions[0].cluster_id in table


def test_sweep_engine_optimize_cell_shares_cache():
    eng = SweepEngine()
    decisions, stats = eng.optimize_cell("qwen1.5-0.5b", "train_4k",
                                         VERIFY_CLUSTERS)
    assert decisions[0].feasible
    before = eng.cache.entries
    again, stats2 = eng.optimize_cell("qwen1.5-0.5b", "train_4k",
                                      VERIFY_CLUSTERS)
    assert again[0].cluster_id == decisions[0].cluster_id
    assert eng.cache.entries == before       # pure replay, no new walks
    assert stats2.cache.hits > 0


def test_elastic_replan_consults_resource_optimizer():
    from repro.core.cluster import single_pod_config
    from repro.runtime.elastic import replan
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    old_cc = single_pod_config()
    # lose a quarter of the pod: 192 chips have several factorizations; the
    # optimizer must pick the best one by C(P, cc), not a hand-rolled guess
    ep = replan(arch, shape, old_cc=old_cc, available_chips=192)
    assert ep.cc.num_chips == 192
    assert ep.decision.feasible
    assert 0 < ep.lr_scale <= 1.0
    # the pick must beat (or tie) every other *feasible* factorization of
    # the survivors (infeasible ones sink regardless of speed)
    from repro.core.planner import choose_plan
    for cand in mesh_candidates(old_cc.chip, 192, base=old_cc):
        other = choose_plan(arch, shape, cand.cc, top_k=1)[0]
        if other.feasible:
            assert ep.decision.time <= other.time + 1e-12
    with pytest.raises(ValueError):
        replan(arch, shape, old_cc=old_cc)


# ------------------------------------------------------------- hypothesis
# (only this randomized property needs it; the rest of the module must run
# even where hypothesis is absent)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _PROP_CACHE = PlanCostCache()      # shared: examples replay each other
    _PROP_EX_CACHE = PlanCostCache()

    @settings(max_examples=10, deadline=None)
    @given(idx=st.sets(st.integers(0, len(VERIFY_CLUSTERS) - 1), min_size=2),
           objective=st.sampled_from(["step_time", "cost"]),
           shape_id=st.sampled_from(GRID_SHAPES))
    def test_property_winner_equals_exhaustive_on_cluster_subsets(
            idx, objective, shape_id):
        """On any seeded subset of the cluster grid, pruned+beamed co-search
        returns exactly the exhaustive subset scan's winner."""
        arch, shape = get_config("qwen1.5-0.5b"), SHAPES[shape_id]
        subset = [VERIFY_CLUSTERS[i] for i in sorted(idx)]
        beam = optimize_resources(arch, shape, subset, objective=objective,
                                  cache=_PROP_CACHE)
        full = optimize_resources(arch, shape, subset, objective=objective,
                                  search="exhaustive", cache=_PROP_EX_CACHE)
        assert beam[0].cluster_id == full[0].cluster_id
        assert beam[0].decision.plan == full[0].decision.plan
else:
    def test_property_winner_equals_exhaustive_on_cluster_subsets():
        pytest.skip("property test needs hypothesis "
                    "(pip install -r requirements-dev.txt)")
