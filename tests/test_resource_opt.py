"""Resource-optimizer tests: the cluster/plan co-search must return the
exact exhaustive (cluster x plan) winner under every objective (step time,
$/step, $/job, SLO), at a fraction of the full plan evaluations; its
estimator-totals cluster floors must be sound; decode cells must prune
strictly more than they did before job-level pricing; elastic replanning
must route through it."""
import math

import pytest

from repro.configs import SHAPES, get_config
from repro.core.costmodel import PlanCostCache, estimate
from repro.core.planner import build_step_program, enumerate_plans
from repro.core.resource import (ResourceSearchStats, _rank_key,
                                 checkpoint_bytes,
                                 checkpoint_restore_seconds,
                                 cluster_floor_time, enumerate_clusters,
                                 format_decisions, job_dollars, job_seconds,
                                 mesh_candidates, optimize_resources)
from repro.core.sweep import SweepEngine

# The verification grid: 4 archs x 2 shapes x 4 objectives = 32 cells, each
# co-searched over the same 17-candidate cluster grid (3 chip types, 1-2
# pods, both 2D mesh layouts, ICI and DCN multi-slice topologies, and the
# v5p 3D-torus family).
VERIFY_CLUSTERS = enumerate_clusters(pod_counts=(1, 2))
GRID_ARCHS = ("qwen1.5-0.5b", "gemma3-12b", "mamba2-1.3b", "qwen1.5-4b")
GRID_SHAPES = ("train_4k", "decode_32k")
GRID_OBJECTIVES = (("step_time", None), ("cost", None), ("job_cost", None),
                   ("slo", 0.25))

# Clusters pruned per decode cell by the PR-2 optimizer (per-step ``cost``
# objective, compute/memory-only floors) on the 13-candidate pre-torus
# grid — measured before the PR-3 refactor (VERIFY_CLUSTERS has since
# gained 4 v5p 3D cells, which only makes the > comparisons easier to
# clear).  Memory-bound decode scales ~perfectly,
# so per-step $ is nearly flat across clusters and the old $-objective
# could barely separate them; job-level pricing must beat every baseline
# strictly (see test_decode_cells_prune_strictly_more_than_before).
PRE_JOB_COST_DECODE_PRUNED = {
    "qwen1.5-0.5b": 4, "gemma3-12b": 9, "mamba2-1.3b": 9, "qwen1.5-4b": 9,
}


def _exhaustive_oracle(arch, shape, cache):
    """The full (cluster x plan) scan, costed once; within a fixed cluster
    the fastest plan is also the cheapest per step AND per job ($/step =
    time x chips x rate; $/job is strictly increasing in step time), so
    re-ranking the same scan serves every objective."""
    return optimize_resources(arch, shape, VERIFY_CLUSTERS,
                              objective="step_time", search="exhaustive",
                              cache=cache)


def test_co_search_matches_exhaustive_on_32_cell_grid():
    cells = [(a, s, o, slo) for a in GRID_ARCHS for s in GRID_SHAPES
             for o, slo in GRID_OBJECTIVES]
    assert len(cells) >= 32
    stats = ResourceSearchStats()
    cache = PlanCostCache()
    ex_cache = PlanCostCache()
    oracles = {}
    for arch_id, shape_id, objective, slo in cells:
        arch, shape = get_config(arch_id), SHAPES[shape_id]
        beam = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                                  objective=objective, slo=slo,
                                  cache=cache, stats=stats)
        if (arch_id, shape_id) not in oracles:
            oracles[arch_id, shape_id] = _exhaustive_oracle(arch, shape,
                                                            ex_cache)
        oracle = sorted(oracles[arch_id, shape_id],
                        key=_rank_key(objective, slo))
        w, we = beam[0], oracle[0]
        cell = f"{arch_id}|{shape_id}|{objective}"
        assert w.cluster_id == we.cluster_id, cell
        assert w.decision.plan == we.decision.plan, cell
        assert math.isclose(w.time, we.time, rel_tol=1e-9), cell
    # the whole grid must cost >=3x fewer full plan evaluations than the
    # exhaustive (cluster x plan) scan would
    assert stats.plan_evals * 3 <= stats.exhaustive_plan_space, \
        stats.describe()
    assert stats.clusters_pruned > 0
    assert stats.cache.hits > 0


def test_cluster_floor_is_sound():
    """No plan on a cluster may cost less than the cluster's floor — the
    property that makes skip-without-costing pruning exact."""
    cache = PlanCostCache()
    arch = get_config("qwen1.5-0.5b")
    for shape_id in GRID_SHAPES:
        shape = SHAPES[shape_id]
        for cand in VERIFY_CLUSTERS[::3]:
            floor = cluster_floor_time(arch, shape, cand.cc)
            assert floor > 0
            for plan in enumerate_plans(arch, shape, cand.cc)[:6]:
                costed = estimate(build_step_program(arch, shape, plan,
                                                     cand.cc),
                                  cand.cc, cache=cache)
                assert costed.total >= floor, (shape_id, cand.cid,
                                               plan.describe())


def test_cluster_floor_is_sound_under_calibration():
    """The floor/plan soundness invariant must survive ANY profile with
    factors <= 1 — including asymmetric per-fabric overlap, which is what
    the per-fabric wire split in ``cluster_floor_time`` exists for (see
    docs/COST_MODEL.md §Calibration).  Full enumeration per cluster: the
    calibrated floor stays below every calibrated plan cost."""
    from repro.core.calibration import SHAPE_CLASSES, CalibrationProfile

    profile = CalibrationProfile(
        chip_name="any",
        mxu={"bfloat16": {c: f for c, f in zip(SHAPE_CLASSES,
                                               (0.22, 0.48, 0.67))},
             "float32": {"large": 0.6}},
        hbm_fraction=0.71, ici_fraction=0.55, dcn_fraction=0.62,
        overlap_ici=0.45, overlap_dcn=0.15)    # deliberately asymmetric
    cache = PlanCostCache()
    arch = get_config("qwen1.5-0.5b")
    for shape_id in GRID_SHAPES:
        shape = SHAPES[shape_id]
        for cand in VERIFY_CLUSTERS[::3]:
            cc = cand.cc.with_calibration(profile)
            floor = cluster_floor_time(arch, shape, cc)
            assert floor > 0
            for plan in enumerate_plans(arch, shape, cc):
                costed = estimate(build_step_program(arch, shape, plan, cc),
                                  cc, cache=cache)
                assert costed.total >= floor, (shape_id, cand.cid,
                                               plan.describe())


def test_decode_cells_prune_strictly_more_than_before():
    """Decode-shaped cells must prune strictly more clusters than the PR-2
    optimizer managed.  Per-step $ is nearly flat across clusters for
    memory-bound decode (the work shards ~perfectly, so time x chips is
    ~constant), which is why the old per-step ``cost`` objective barely
    pruned — the floors were already tight; the *objective* carried no
    separating information.  Job-level pricing adds exactly that
    information (startup/preemption overheads scale with chip count), and
    the tight floors let it prune almost everything without costing."""
    cache = PlanCostCache()
    for arch_id in GRID_ARCHS:
        arch, shape = get_config(arch_id), SHAPES["decode_32k"]
        base = PRE_JOB_COST_DECODE_PRUNED[arch_id]
        st_cost = ResourceSearchStats()
        optimize_resources(arch, shape, VERIFY_CLUSTERS, objective="cost",
                           cache=cache, stats=st_cost)
        st_job = ResourceSearchStats()
        optimize_resources(arch, shape, VERIFY_CLUSTERS, objective="job_cost",
                           cache=cache, stats=st_job)
        # no regression under the old objective...
        assert st_cost.clusters_pruned >= base, arch_id
        # ...and a strict improvement under the $-objective family
        assert st_job.clusters_pruned > base, (
            f"{arch_id}: job_cost pruned {st_job.clusters_pruned} "
            f"<= PR-2 baseline {base}")


def test_floor_has_collective_term_on_train_cells():
    """The tightened floor must strictly exceed the old global
    compute/memory roofline on train cells (gradient/TP collectives are
    unavoidable there) — the measured tightening the pruning gains rest
    on."""
    from repro.core.cluster import ClusterConfig
    from repro.core.costmodel import VPU_FRACTION
    from repro.core.planner import ShardingPlan
    for arch_id in GRID_ARCHS:
        arch, shape = get_config(arch_id), SHAPES["train_4k"]
        # the PR-2 floor: global totals of a 1-chip reference, divided by
        # full-cluster parallelism — no collectives, no replication
        ref_cc = ClusterConfig(mesh_shape=(1,), mesh_axes=("data",))
        ref = ShardingPlan(name="floor-ref", batch_axes=("data",))
        t = estimate(build_step_program(arch, shape, ref, ref_cc),
                     ref_cc).totals
        for cand in VERIFY_CLUSTERS[::4]:
            cc = cand.cc
            denom = max(cc.num_chips * (max(cc.mesh_shape)
                                        if arch.moe is not None else 1), 1)
            util = max(cc.matmul_util, cc.small_matmul_util)
            old = max(
                sum(f / (denom * cc.chip.peak(dt) * util)
                    for dt, f in t.mxu_flops.items())
                + t.vpu_flops / (denom * cc.chip.peak("float32")
                                 * VPU_FRACTION),
                t.hbm_bytes / (denom * cc.hbm_bw_eff))
            new = cluster_floor_time(arch, shape, cc)
            assert new > old * 1.05, (arch_id, cand.cid, new, old)


def test_checkpoint_restore_derived_from_bytes_over_disk():
    """Restore time scales with checkpoint bytes / disk bandwidth per
    chip — a 12B model restores ~24x slower than a 0.5B one on the same
    cluster — with the constant-override field still honored."""
    import dataclasses
    from repro.core.cluster import (DEFAULT_CHECKPOINT_RESTORE_SECONDS,
                                    single_pod_config)
    cc = single_pod_config()
    small, big = get_config("qwen1.5-0.5b"), get_config("gemma3-12b")
    t_small = checkpoint_restore_seconds(cc, small)
    t_big = checkpoint_restore_seconds(cc, big)
    assert 0 < t_small < t_big
    ratio = checkpoint_bytes(big) / checkpoint_bytes(small)
    assert math.isclose(t_big / t_small, ratio, rel_tol=1e-9)
    # more chips -> each restores a smaller shard
    half = cc.with_mesh((8, 16), ("data", "model"))
    assert checkpoint_restore_seconds(half, big) > t_big
    # no arch in hand: the old constant fallback
    assert checkpoint_restore_seconds(cc) == DEFAULT_CHECKPOINT_RESTORE_SECONDS
    # explicit override wins over the derivation (backward compatibility)
    pinned = dataclasses.replace(cc, checkpoint_restore_seconds=60.0)
    assert checkpoint_restore_seconds(pinned, big) == 60.0
    # and job pricing threads the arch through: deriving (tiny restore)
    # must price below the pinned 60 s constant, all else equal
    assert (job_dollars(cc, 0.1, 1000, arch=big)
            < job_dollars(pinned, 0.1, 1000, arch=big))


def test_optimizer_decisions_price_restore_per_arch():
    """ResourceDecision.cost_per_job must use the searched architecture's
    derived restore time, not the global constant."""
    arch, shape = get_config("gemma3-12b"), SHAPES["train_4k"]
    rd = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                            objective="job_cost")[0]
    assert rd.arch is arch
    expect = job_dollars(rd.cc, rd.time, rd.steps_per_job, arch)
    assert math.isclose(rd.cost_per_job, expect, rel_tol=1e-12)
    assert rd.cost_per_job != job_dollars(rd.cc, rd.time, rd.steps_per_job)


def test_job_cost_amortizes_startup_restore_and_preemption():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    cache = PlanCostCache()
    fastest = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                                 objective="step_time", cache=cache)[0]
    cc, t = fastest.cc, fastest.time
    # a job is never cheaper than its bare compute, and the overheads of
    # startup + expected preemption are visible on top of it
    bare = t * 10_000 * cc.num_chips * cc.chip.cost_per_chip_hour / 3600.0
    assert job_dollars(cc, t, 10_000) > bare
    assert job_seconds(cc, t, 10_000) > t * 10_000 + cc.job_startup_seconds - 1
    # strictly increasing in step time (the property floor-pruning needs)
    assert job_dollars(cc, t * 1.01, 10_000) > job_dollars(cc, t, 10_000)
    # longer jobs amortize startup: $/step falls with steps_per_job
    per_step_short = job_dollars(cc, t, 100) / 100
    per_step_long = job_dollars(cc, t, 100_000) / 100_000
    assert per_step_long < per_step_short


def test_job_cost_objective_picks_cheapest_job():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["decode_32k"]
    cache = PlanCostCache()
    by_step = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                                 objective="cost", cache=cache)[0]
    by_job = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                                objective="job_cost", cache=cache)[0]
    assert by_job.cost_per_job <= by_step.cost_per_job
    assert by_job.feasible
    # steps_per_job threads through to the decision's pricing
    short = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                               objective="job_cost", steps_per_job=100,
                               cache=cache)[0]
    assert short.steps_per_job == 100
    assert short.cost_per_job < by_job.cost_per_job   # 100 steps << 10k steps


def test_cost_objective_trades_speed_for_price():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    cache = PlanCostCache()
    fastest = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                                 objective="step_time", cache=cache)[0]
    cheapest = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                                  objective="cost", cache=cache)[0]
    assert cheapest.cost_per_step <= fastest.cost_per_step
    assert fastest.time <= cheapest.time
    assert fastest.cost_per_step > 0       # the $-proxy field is wired


def test_slo_objective_picks_cheapest_meeting_target():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    cache = PlanCostCache()
    fastest = optimize_resources(arch, shape, VERIFY_CLUSTERS,
                                 objective="step_time", cache=cache)[0]
    slo = fastest.time * 2.0               # satisfiable target
    best = optimize_resources(arch, shape, VERIFY_CLUSTERS, objective="slo",
                              slo=slo, cache=cache)[0]
    assert best.meets(slo)
    assert best.cost_per_step <= fastest.cost_per_step
    # unsatisfiable target: the honest ranking still returns a winner
    tight = optimize_resources(arch, shape, VERIFY_CLUSTERS, objective="slo",
                               slo=fastest.time / 1e6, cache=cache)[0]
    assert not tight.meets(fastest.time / 1e6)


def test_objective_validation():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["decode_32k"]
    with pytest.raises(ValueError):
        optimize_resources(arch, shape, VERIFY_CLUSTERS, objective="nope")
    with pytest.raises(ValueError):
        optimize_resources(arch, shape, VERIFY_CLUSTERS, objective="slo")


def test_format_decisions_renders_pruned_and_costed():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    decisions = optimize_resources(arch, shape, VERIFY_CLUSTERS)
    table = format_decisions(decisions)
    assert "pruned" in table and "chosen plan" in table
    assert decisions[0].cluster_id in table


def test_sweep_engine_optimize_cell_shares_cache():
    eng = SweepEngine()
    decisions, stats = eng.optimize_cell("qwen1.5-0.5b", "train_4k",
                                         VERIFY_CLUSTERS)
    assert decisions[0].feasible
    before = eng.cache.entries
    again, stats2 = eng.optimize_cell("qwen1.5-0.5b", "train_4k",
                                      VERIFY_CLUSTERS)
    assert again[0].cluster_id == decisions[0].cluster_id
    assert eng.cache.entries == before       # pure replay, no new walks
    assert stats2.cache.hits > 0


def test_elastic_replan_consults_resource_optimizer():
    from repro.core.cluster import single_pod_config
    from repro.runtime.elastic import replan
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    old_cc = single_pod_config()
    # lose a quarter of the pod: 192 chips have several factorizations; the
    # optimizer must pick the best one by C(P, cc), not a hand-rolled guess
    ep = replan(arch, shape, old_cc=old_cc, available_chips=192)
    assert ep.cc.num_chips == 192
    assert ep.decision.feasible
    assert 0 < ep.lr_scale <= 1.0
    # the pick must beat (or tie) every other *feasible* factorization of
    # the survivors (infeasible ones sink regardless of speed)
    from repro.core.planner import choose_plan
    for cand in mesh_candidates(old_cc.chip, 192, base=old_cc):
        other = choose_plan(arch, shape, cand.cc, top_k=1)[0]
        if other.feasible:
            assert ep.decision.time <= other.time + 1e-12
    with pytest.raises(ValueError):
        replan(arch, shape, old_cc=old_cc)


# ------------------------------------------------------------- hypothesis
# (only this randomized property needs it; the rest of the module must run
# even where hypothesis is absent)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _PROP_CACHE = PlanCostCache()      # shared: examples replay each other
    _PROP_EX_CACHE = PlanCostCache()

    @settings(max_examples=10, deadline=None)
    @given(idx=st.sets(st.integers(0, len(VERIFY_CLUSTERS) - 1), min_size=2),
           objective=st.sampled_from(["step_time", "cost"]),
           shape_id=st.sampled_from(GRID_SHAPES))
    def test_property_winner_equals_exhaustive_on_cluster_subsets(
            idx, objective, shape_id):
        """On any seeded subset of the cluster grid, pruned+beamed co-search
        returns exactly the exhaustive subset scan's winner."""
        arch, shape = get_config("qwen1.5-0.5b"), SHAPES[shape_id]
        subset = [VERIFY_CLUSTERS[i] for i in sorted(idx)]
        beam = optimize_resources(arch, shape, subset, objective=objective,
                                  cache=_PROP_CACHE)
        full = optimize_resources(arch, shape, subset, objective=objective,
                                  search="exhaustive", cache=_PROP_EX_CACHE)
        assert beam[0].cluster_id == full[0].cluster_id
        assert beam[0].decision.plan == full[0].decision.plan
else:
    def test_property_winner_equals_exhaustive_on_cluster_subsets():
        pytest.skip("property test needs hypothesis "
                    "(pip install -r requirements-dev.txt)")
