"""Data pipeline: determinism, host sharding, prefetch thread."""
import numpy as np

from repro.data.pipeline import PrefetchIterator, SyntheticLM, make_pipeline


def test_batch_deterministic_per_step():
    src = SyntheticLM(vocab_size=100, seq_len=16, batch=4, seed=1)
    a = src.batch_at(7)["tokens"]
    b = src.batch_at(7)["tokens"]
    assert np.array_equal(a, b)
    c = src.batch_at(8)["tokens"]
    assert not np.array_equal(a, c)


def test_tokens_in_range_and_learnable_structure():
    src = SyntheticLM(vocab_size=64, seq_len=128, batch=8, seed=0)
    t = src.batch_at(0)["tokens"]
    assert t.min() >= 0 and t.max() < 64
    # structured stream: consecutive-token deltas are far from uniform
    deltas = (t[:, 1:] - t[:, :-1]) % 64
    _, counts = np.unique(deltas, return_counts=True)
    assert counts.max() > 3 * deltas.size / 64


def test_host_sharding_distinct_streams():
    a = SyntheticLM(100, 16, 4, seed=0).batch_at(0)["tokens"]
    b = SyntheticLM(100, 16, 4, seed=1).batch_at(0)["tokens"]
    assert not np.array_equal(a, b)


def test_prefetch_iterator_yields_in_order():
    pipe = make_pipeline(vocab_size=100, seq_len=8, global_batch=4)
    try:
        steps = [next(pipe)[0] for _ in range(5)]
        assert steps == [0, 1, 2, 3, 4]
    finally:
        pipe.close()


def test_frontend_shapes():
    src = SyntheticLM(100, 16, 4, seed=0, frontend_shape=(4, 8, 32))
    b = src.batch_at(0)
    assert b["frontend"].shape == (4, 8, 32)
