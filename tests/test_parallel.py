"""Parallel grid search and the mergeable, persistent plan-cost cache.

Covers the PR-10 contracts:

  * save/load round-trip, including cost-model-fingerprint
    self-invalidation and calibrated-vs-uncalibrated separation inside
    one snapshot file (cluster fingerprints embed the calibration);
  * merge is commutative and idempotent, and costing against a merged
    cache is bit-exact vs a cold walk;
  * ``jobs=4`` reproduces the ``jobs=1`` golden-grid table exactly, for
    the sweep, ``optimize_resources`` and ``optimize_serving``;
  * a bounded (even size-1) cache stays bit-exact — eviction only costs
    misses — and respects its cap;
  * per-cell cache stats stay attributed to the cache that served them
    (worker-local on pool workers, labelled ``@w<N>``).
"""
import dataclasses
import importlib.util
import os

import pytest

from repro.configs import SHAPES, get_config
from repro.core.calibration import CalibrationProfile
from repro.core.costmodel import (CacheStats, PlanCostCache,
                                  cost_model_fingerprint)
from repro.core import costmodel
from repro.core.parallel import default_jobs, shard_specs
from repro.core.resource import (ResourceSearchStats, enumerate_clusters,
                                 optimize_resources)
from repro.core.serving import optimize_serving
from repro.core.sweep import CLUSTERS, SweepEngine, sweep_rows
from repro.core.workload import SERVE_WORKLOADS

ARCH = "qwen1.5-0.5b"
SHAPE = "train_4k"


def _cost(cache, arch=ARCH, shape=SHAPE, cluster="pod"):
    engine = SweepEngine(search="beam", cache=cache)
    return engine.cost_cell(arch, shape, cluster)


def _decision_sig(cell):
    d = cell.decision
    return (d.plan.describe(), repr(d.time), repr(d.hbm_est), d.feasible)


def _cache_keyset(cache):
    return {(key, tuple(sorted(e.reads.items())), e.payload_sig())
            for key, bucket in cache._buckets.items() for e in bucket}


# --------------------------------------------------------- persistence
def test_save_load_round_trip(tmp_path):
    cache = PlanCostCache()
    cold = _cost(cache)
    path = str(tmp_path / "plans.cache")
    assert cache.save(path) == cache.entries

    warm_cache = PlanCostCache.load(path)
    assert _cache_keyset(warm_cache) == _cache_keyset(cache)
    warm = _cost(warm_cache)
    assert _decision_sig(warm) == _decision_sig(cold)
    # the warm pass re-walks nothing — every lookup replays, and outer
    # block hits absorb the inner lookups the cold pass paid individually
    assert warm_cache.misses == 0
    assert 0 < warm_cache.hits < cache.hits + cache.misses


def test_stale_fingerprint_self_invalidates(tmp_path, monkeypatch):
    cache = PlanCostCache()
    _cost(cache)
    path = str(tmp_path / "plans.cache")
    cache.save(path)
    # a different cost-model version must drop the snapshot, not raise
    monkeypatch.setattr(costmodel, "_COST_MODEL_FP", "0" * 16)
    assert PlanCostCache.load(path).entries == 0
    monkeypatch.setattr(costmodel, "_COST_MODEL_FP", None)
    assert PlanCostCache.load(path).entries == cache.entries


def test_load_missing_or_corrupt_is_empty(tmp_path):
    assert PlanCostCache.load(str(tmp_path / "nope.cache")).entries == 0
    bad = tmp_path / "corrupt.cache"
    bad.write_bytes(b"not a pickle")
    assert PlanCostCache.load(str(bad)).entries == 0


def test_calibrated_and_uncalibrated_share_one_file(tmp_path):
    """Cluster fingerprints embed the calibration profile, so one snapshot
    holds both economies and each replays only its own entries."""
    plain = CLUSTERS["pod"]
    calibrated = dataclasses.replace(
        plain, calibration=CalibrationProfile(chip_name=plain.chip.name,
                                              hbm_fraction=0.5))
    cache = PlanCostCache()
    cold_plain = _cost(cache, cluster=plain)
    cold_cal = _cost(cache, cluster=calibrated)
    assert _decision_sig(cold_plain) != _decision_sig(cold_cal)
    path = str(tmp_path / "plans.cache")
    cache.save(path)

    for cluster, cold in ((plain, cold_plain), (calibrated, cold_cal)):
        warm_cache = PlanCostCache.load(path)
        warm = _cost(warm_cache, cluster=cluster)
        assert _decision_sig(warm) == _decision_sig(cold)
        assert warm_cache.misses == 0


# -------------------------------------------------------------- merging
@pytest.fixture(scope="module")
def cell_deltas():
    """One independently-recorded CacheDelta per scenario (cold caches)."""
    deltas = []
    for arch, shape, cluster in ((ARCH, "train_4k", "pod"),
                                 (ARCH, "decode_32k", "pod"),
                                 ("gemma3-12b", "train_4k", "2pod")):
        cache = PlanCostCache()
        _cost(cache, arch=arch, shape=shape, cluster=cluster)
        deltas.append(cache.export_delta())
    return deltas


def test_merge_commutative_and_idempotent(cell_deltas):
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(order=st.permutations(range(len(cell_deltas))),
           repeat=st.integers(min_value=0, max_value=len(cell_deltas) - 1))
    def prop(order, repeat):
        forward = PlanCostCache()
        for i in order:
            forward.merge(cell_deltas[i])
        n = forward.entries
        forward.merge(cell_deltas[repeat])       # idempotent
        assert forward.entries == n
        reference = PlanCostCache()
        for delta in cell_deltas:                # canonical order
            reference.merge(delta)
        assert _cache_keyset(forward) == _cache_keyset(reference)

    prop()


def test_merged_cache_costing_bit_exact_vs_cold(cell_deltas):
    merged = PlanCostCache()
    for delta in cell_deltas:
        merged.merge(delta)
    cold = _cost(PlanCostCache(), arch="gemma3-12b", cluster="2pod")
    warm = _cost(merged, arch="gemma3-12b", cluster="2pod")
    assert _decision_sig(warm) == _decision_sig(cold)
    assert merged.misses == 0


def test_export_delta_excludes_seed(cell_deltas):
    cache = PlanCostCache()
    cache.merge(cell_deltas[0])
    cache.mark()
    _cost(cache, shape="decode_32k")
    delta = cache.export_delta()
    assert 0 < delta.entries < cache.entries
    merged_keys = _cache_keyset(cache)
    delta_keys = {(key, tuple(sorted(e.reads.items())), e.payload_sig())
                  for key, b in delta.buckets.items() for e in b}
    assert delta_keys <= merged_keys
    assert not delta_keys & {(key, tuple(sorted(e.reads.items())),
                              e.payload_sig())
                             for key, b in cell_deltas[0].buckets.items()
                             for e in b}


def test_merge_rejects_foreign_fingerprint(cell_deltas):
    delta = dataclasses.replace(cell_deltas[0], fingerprint="f" * 16)
    with pytest.raises(ValueError, match="cost-model"):
        PlanCostCache().merge(delta)


def test_cache_stats_add():
    a = CacheStats(10, 5, 100, 1)
    b = CacheStats(1, 2, 3, 0)
    assert a + b == CacheStats(11, 7, 103, 1)
    assert (a + b).hit_rate == 11 / 18


# -------------------------------------------------------- bounded cache
def test_size1_bounded_cache_bit_exact():
    cold = _cost(PlanCostCache())
    tiny = PlanCostCache(max_entries=1)
    bounded = _cost(tiny)
    assert _decision_sig(bounded) == _decision_sig(cold)
    assert tiny.entries <= 1
    assert tiny.evictions > 0
    assert tiny.stats().evictions == tiny.evictions


def test_bounded_cache_respects_cap():
    cap = 64
    cache = PlanCostCache(max_entries=cap)
    _cost(cache)
    _cost(cache, shape="decode_32k")
    assert cache.entries <= cap
    assert cache.evictions > 0
    # entry count stays consistent with the bucket map
    assert cache.entries == sum(len(b) for b in cache._buckets.values())
    with pytest.raises(ValueError):
        PlanCostCache(max_entries=0)


# ------------------------------------------------------------- sharding
def test_shard_specs_affinity_and_balance():
    specs = [(a, s) for a in "abcd" for s in range(3)]
    shards = shard_specs(specs, 4, key=lambda p: p[0])
    assert sorted(sum(shards, [])) == sorted(specs)
    for shard in shards:       # a group never splits across shards
        assert len({a for a, _ in shard}) == len(shard) // 3
    # deterministic: same input, same sharding
    assert shards == shard_specs(specs, 4, key=lambda p: p[0])
    # more jobs than groups: no empty shards returned
    assert all(shard_specs(specs, 64, key=lambda p: p[0]))
    assert default_jobs() >= 1


# ------------------------------------------------------- parallel parity
def _canon_cells(cells):
    return [(c.key, c.skipped) if c.skipped else (c.key, _decision_sig(c))
            for c in cells]


def test_jobs4_equals_jobs1_on_golden_grid():
    # same import style as test_golden_sweep: the regen script IS the grid
    spec = importlib.util.spec_from_file_location(
        "regen_sweep_golden",
        os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden",
                     "regen_sweep_golden.py"))
    regen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(regen)
    assert regen.compute_cells(jobs=1) == regen.compute_cells(jobs=4)


def test_parallel_sweep_worker_labels_and_stats():
    serial_engine = SweepEngine(search="beam")
    serial = serial_engine.sweep((ARCH,), ("train_4k", "decode_32k"),
                                 ("pod", "2pod"))
    par_engine = SweepEngine(search="beam", jobs=2)
    par = par_engine.sweep((ARCH,), ("train_4k", "decode_32k"),
                           ("pod", "2pod"))
    assert _canon_cells(serial) == _canon_cells(par)
    assert all(c.worker >= 0 for c in par)
    assert all(c.worker == -1 for c in serial)
    assert all("@w" in row for row in sweep_rows(par))
    assert all("@w" not in row for row in sweep_rows(serial))
    # worker stats aggregate into honest engine traffic; merged entries
    # come from the engine cache itself, not the double-counting sum
    assert par_engine.last_worker_stats
    traffic = par_engine.traffic_stats()
    assert traffic.hits == sum(w.hits for w in par_engine.last_worker_stats)
    assert traffic.entries == par_engine.cache.entries
    # per-cell marginal traffic is attributed against exactly one cache
    # (the worker's own) — so it is real lookup activity, never zero and
    # never another worker's counters bleeding in
    for c in par:
        assert c.stats.cache.hits + c.stats.cache.misses > 0


def test_optimize_resources_jobs_parity():
    arch = get_config(ARCH)
    shape = SHAPES[SHAPE]
    cands = enumerate_clusters()[:8]

    def run(jobs):
        stats = ResourceSearchStats()
        out = optimize_resources(arch, shape, cands, objective="job_cost",
                                 stats=stats, jobs=jobs)
        return [(rd.cluster_id, rd.pruned,
                 None if rd.decision is None else
                 (rd.decision.plan.describe(), repr(rd.decision.time)))
                for rd in out], stats

    serial, s1 = run(1)
    parallel, s4 = run(4)
    assert serial == parallel
    assert s4.worker_cache and s1.worker_cache is None
    assert "workers=" in s4.describe()
    # the warm serial pass re-walks nothing: every plan eval is a replay
    assert s4.cache.misses < s1.cache.misses / 10


def test_optimize_serving_jobs_parity():
    arch = get_config(ARCH)
    wl = SERVE_WORKLOADS["chat_2k"]
    cands = [CLUSTERS["pod"], CLUSTERS["v5p-pod"], CLUSTERS["2pod"]]

    def run(jobs):
        out = optimize_serving(arch, wl, cands, jobs=jobs)
        return [(sd.cluster_id, sd.slots, sd.pruned,
                 None if sd.decode_decision is None else
                 (sd.decode_decision.plan.describe(),
                  repr(sd.decode_decision.time)))
                for sd in out]

    assert run(1) == run(3)


def test_sweep_engine_cache_path_warmstart(tmp_path):
    path = str(tmp_path / "sweep.cache")
    grid = ((ARCH,), ("train_4k", "decode_32k"), ("pod", "2pod"))
    first_engine = SweepEngine(cache_path=path)
    first = first_engine.sweep(*grid)
    assert os.path.exists(path)

    second_engine = SweepEngine(cache_path=path)
    assert second_engine.cache.entries == first_engine.cache.entries
    second = second_engine.sweep(*grid)
    assert _canon_cells(first) == _canon_cells(second)
    st = second_engine.traffic_stats()
    assert st.misses == 0 and st.hit_rate == 1.0


def test_fingerprint_stable_within_process():
    assert cost_model_fingerprint() == cost_model_fingerprint()
    assert len(cost_model_fingerprint()) == 16
