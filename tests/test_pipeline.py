"""Pipeline-parallelism tests (ISSUE 5 acceptance).

The pipeline tentpole's contract, deterministic versions (the randomized
hypothesis variants live in tests/test_properties.py):

  * an S=1 ``PipelinedLoopBlock`` costs bit-exactly like the sequential
    microbatch loop — the construct is a strict generalization;
  * the GPipe-style schedule is bounded by [sequential/S, sequential];
  * p2p transfers price at ONE link of the axis fabric (never the
    torus-doubled ``axis_bandwidth``), ride DCN on the pod axis, and
    no-op on size-1 axes;
  * the planner partitions the layer stack into per-stage bodies with
    per-stage resident weights/optimizer state (~S-fold HBM drop), which
    opens train cells where no sequential role fits;
  * cluster floors stay sound on pipeline-inclusive cells — verified by
    full plan enumeration, PR-3/PR-4 style;
  * cached replay of pipelined step programs is bit-exact;
  * job pricing applies E[preemptions] to the *inflated* wall time
    (closed-form geometric series) and charges checkpoint-write stalls,
    while staying monotone in step time (floor-pruning soundness).
"""
import dataclasses
import math

import pytest

from repro.configs import SHAPES, get_config
from repro.core.cluster import (TPU_V5P, ClusterConfig, multi_pod_config,
                                single_pod_config, torus_3d_config)
from repro.core.costmodel import PlanCostCache, estimate
from repro.core.linalg_ops import p2p_cost, p2p_wire
from repro.core.plan import (Compute, ForBlock, P2P, PipelinedLoopBlock,
                             Program)
from repro.core.planner import (MAX_MICROBATCHES, ShardingPlan,
                                build_step_program, choose_plan,
                                enumerate_plans, estimate_hbm)
from repro.core.resource import (checkpoint_write_seconds, cluster_floor_time,
                                 job_dollars, job_seconds, optimize_resources)
from repro.core.sweep import CLUSTERS
from repro.core.symbols import MemState, TensorStat

POD = single_pod_config()
TORUS = torus_3d_config()
DCN = CLUSTERS["v5p-dcn"]              # 4 v5p slices of 8x8 over DCN
DCN_3D = CLUSTERS["v5p-dcn-3d"]        # pod x full 3D inner torus (4-axis)


def _two_stage_program(m: int):
    body0 = [Compute("tsmm", ("X",), "A", exec_type="DIST",
                     shard_axes=("data",)),
             P2P("act", "pod", bytes_override=1e7)]
    body1 = [Compute("tsmm", ("X",), "B", exec_type="DIST",
                     shard_axes=("data",))]
    return Program("p", blocks=[PipelinedLoopBlock("mb", m,
                                                   stages=[body0, body1])],
                   inputs={"X": TensorStat((4096, 4096))})


# ---------------------------------------------------------------------------
# IR / estimator semantics
# ---------------------------------------------------------------------------


def test_s1_pipeline_degenerates_to_sequential_loop_bit_exact():
    body = [Compute("tsmm", ("X",), "A", exec_type="DIST",
                    shard_axes=("data",)),
            Compute("unary", ("A",), "B", exec_type="CP")]
    inputs = {"X": TensorStat((4096, 4096), state=MemState.HOST)}
    for cc in (POD, TORUS, DCN):
        for m in (1, 2, 8):
            pipe = Program("p", blocks=[PipelinedLoopBlock(
                "mb", m, stages=[list(body)])], inputs=dict(inputs))
            seq = Program("s", blocks=[ForBlock("mb", m, body=list(body))],
                          inputs=dict(inputs))
            a, b = estimate(pipe, cc), estimate(seq, cc)
            assert a.total == b.total
            for f in ("io", "compute", "collective", "latency"):
                assert getattr(a.breakdown, f) == getattr(b.breakdown, f), f
            assert a.peak_hbm_per_device == b.peak_hbm_per_device
            assert a.totals.as_tuple() == b.totals.as_tuple()


def test_pipeline_cost_between_steady_state_and_sequential():
    for m in (1, 2, 4, 8):
        pipe = estimate(_two_stage_program(m), DCN)
        body0, body1 = _two_stage_program(m).blocks[0].stages
        seq = estimate(Program("s", blocks=[ForBlock("mb", m,
                                                     body=body0 + body1)],
                               inputs={"X": TensorStat((4096, 4096))}), DCN)
        assert pipe.total <= seq.total * (1 + 1e-12)
        assert pipe.total >= seq.total / 2 * (1 - 1e-12)
        # work totals are never overlapped away
        assert pipe.totals.as_tuple() == seq.totals.as_tuple()
    # more microbatches amortize the fixed fill/drain: per-microbatch
    # time improves monotonically toward the steady state
    per_mb = [estimate(_two_stage_program(m), DCN).total / m
              for m in (1, 2, 4, 8)]
    assert per_mb == sorted(per_mb, reverse=True)


def test_p2p_prices_one_link_never_torus_doubled():
    """On a wrapped-ring mesh a collective rides 2 links per axis but a
    neighbor send/recv rides exactly one — p2p time must be blind to
    ``torus_links``."""
    payload = 1e8
    flat = dataclasses.replace(TORUS, torus_links=())
    prog = Program("p", blocks=[P2P("X", "model")],
                   inputs={"X": TensorStat((4096, 4096))})
    on_torus = estimate(prog, TORUS)
    on_flat = estimate(prog, flat)
    assert on_torus.total == on_flat.total
    assert TORUS.p2p_bw("model") == TORUS.link_bw("model")
    assert TORUS.axis_bandwidth("model") == 2 * TORUS.p2p_bw("model")
    # the DCN path: pod-axis p2p prices at dcn_bw_eff
    t_dcn = estimate(Program("p", blocks=[P2P("X", "pod",
                                              bytes_override=payload)],
                             inputs={"X": TensorStat((8, 8))}), DCN)
    want = payload / DCN.dcn_bw_eff + DCN.collective_phase_latency
    assert math.isclose(t_dcn.breakdown.collective, want, rel_tol=1e-12)
    assert t_dcn.totals.dcn_bytes == payload and t_dcn.totals.ici_bytes == 0
    # size-1 axis: no neighbor, no-op
    assert p2p_wire(payload, 1) == (0.0, 0)
    assert p2p_cost(payload, 1, 1e9, 1e-6) == 0.0
    none = estimate(Program("p", blocks=[P2P("X", "depth")],
                            inputs={"X": TensorStat((8, 8))}), POD)
    assert none.total == 0.0


def test_p2p_overlap_discount_matches_collectives():
    prog = Program("p", blocks=[P2P("X", "pod", bytes_override=1e8)],
                   inputs={"X": TensorStat((8, 8))})
    full = estimate(prog, DCN).breakdown.collective
    hidden = estimate(prog, DCN.with_overlap(0.7)).breakdown.collective
    assert math.isclose(hidden, full * 0.3, rel_tol=1e-12)


# ---------------------------------------------------------------------------
# Planner: stage partitioning + per-stage residency
# ---------------------------------------------------------------------------

ARCH110 = get_config("qwen1.5-110b")
TRAIN = SHAPES["train_4k"]


def _pp_plan(axes=("pod",), micro=8, remat="full"):
    return ShardingPlan(name="pp-dcn+tp", batch_axes=("data",),
                        tp_axes=("model",), pp_axes=axes,
                        remat=remat, microbatches=micro,
                        grad_reduce_dtype="bfloat16")


def test_pipelined_program_structure():
    prog = build_step_program(ARCH110, TRAIN, _pp_plan(), DCN)
    pipes = [b for b in prog.blocks if isinstance(b, PipelinedLoopBlock)]
    assert len(pipes) == 1
    pipe = pipes[0]
    s = DCN.axis_size("pod")
    assert len(pipe.stages) == s
    assert pipe.microbatches == 8
    # every layer lands in exactly one stage
    layer_loops = [n for stage in pipe.stages for n in stage
                   if isinstance(n, ForBlock) and "fwd layers" in n.label]
    assert sum(fb.iterations for fb in layer_loops) == ARCH110.n_layers
    # 2 transfers per stage boundary: fwd activations + bwd gradients
    p2ps = [n for stage in pipe.stages for n in stage if isinstance(n, P2P)]
    assert len(p2ps) == 2 * (s - 1)
    assert all(p.axis == "pod" for p in p2ps)


def test_per_stage_residency_drops_s_fold():
    seq = ShardingPlan(name="dp+tp", batch_axes=("pod", "data"),
                       tp_axes=("model",), remat="full", microbatches=8,
                       grad_reduce_dtype="bfloat16")
    hbm_seq = estimate_hbm(ARCH110, TRAIN, seq, DCN)
    hbm_pp = estimate_hbm(ARCH110, TRAIN, _pp_plan(), DCN)
    assert hbm_pp < hbm_seq
    # weights/grads/opt divide by S; the 1F1B activation stash does not —
    # so the drop is real but sub-S-fold overall
    from repro.core.planner import resident_components
    comp_seq = resident_components(ARCH110, TRAIN, seq, DCN)
    comp_pp = resident_components(ARCH110, TRAIN, _pp_plan(), DCN)
    s = DCN.axis_size("pod")
    for name in ("params", "grads"):
        assert math.isclose(comp_pp[name], comp_seq[name] / s,
                            rel_tol=1e-9), name
    # optimizer state is already dp-sharded under zero1; losing the pod
    # axis from dp and gaining the S-fold stage cut cancel exactly here
    assert comp_pp["opt_state"] <= comp_seq["opt_state"] * (1 + 1e-9)


def test_pipelining_opens_cell_where_nothing_fit():
    """The headline scenario: frontier-dense train on DCN-joined slices.
    Every sequential role OOMs; only pipelined plans fit, and the chosen
    winner is pipelined — on beam AND exhaustive search."""
    plans = enumerate_plans(ARCH110, TRAIN, DCN)
    assert any(p.pp_axes for p in plans)
    budget = DCN.hbm_budget
    seq_fits = [p for p in plans if not p.pp_axes
                and estimate_hbm(ARCH110, TRAIN, p, DCN) <= budget]
    assert not seq_fits, "a sequential role fits — scenario lost its point"
    pp_fits = [p for p in plans if p.pp_axes
               and estimate_hbm(ARCH110, TRAIN, p, DCN) <= budget]
    assert pp_fits, "no pipelined plan fits either"
    cache = PlanCostCache()
    beam = choose_plan(ARCH110, TRAIN, DCN, top_k=1, cache=cache)[0]
    exhaustive = choose_plan(ARCH110, TRAIN, DCN, top_k=1,
                             search="exhaustive", cache=cache)[0]
    assert beam.feasible and beam.plan.pp_axes == ("pod",)
    assert beam.plan == exhaustive.plan


def test_depth_axis_carries_pipeline_roles_too():
    arch = get_config("qwen1.5-0.5b")
    names = {p.name for p in enumerate_plans(arch, TRAIN, TORUS)}
    assert {"pp+tp", "dp+pp"} <= names
    # 4-axis mesh: pp over DCN with a tp2 interior
    names4 = {p.name for p in enumerate_plans(arch, TRAIN, DCN_3D)}
    assert "pp-dcn+tp2" in names4
    # decode never pipelines (no microbatch stream to fill the pipe)
    decode = {p.name for p in enumerate_plans(arch, SHAPES["decode_32k"],
                                              TORUS)}
    assert not any("pp" in n for n in decode)


def test_micro_knob_is_M_and_more_microbatches_amortize_bubbles():
    cache = PlanCostCache()
    times = []
    for m in (1, 2, 4, 8):
        prog = build_step_program(ARCH110, TRAIN, _pp_plan(micro=m), DCN)
        times.append(estimate(prog, DCN, cache=cache).total)
    assert times == sorted(times, reverse=True)
    # and the winning M on the open cell is the ceiling (bubble ~ (S-1)/M)
    best = choose_plan(ARCH110, TRAIN, DCN, top_k=1, cache=cache)[0]
    assert best.plan.microbatches == MAX_MICROBATCHES


def test_costed_peak_hbm_at_least_estimate_hbm_for_pp_plans():
    """The planner invariant extends to pipelined plans: the pre-filter
    can never reject a plan whose costed peak fits."""
    cache = PlanCostCache()
    for plan in [p for p in enumerate_plans(ARCH110, TRAIN, DCN)
                 if p.pp_axes][:6]:
        prog = build_step_program(ARCH110, TRAIN, plan, DCN)
        costed = estimate(prog, DCN, cache=cache)
        assert costed.peak_hbm_per_device >= estimate_hbm(
            ARCH110, TRAIN, plan, DCN) * (1 - 1e-9), plan.describe()


def test_cache_replay_bit_exact_on_pipelined_step_programs():
    cache = PlanCostCache()
    for plan in (_pp_plan(), _pp_plan(micro=4, remat="none")):
        prog = build_step_program(ARCH110, TRAIN, plan, DCN)
        base = estimate(prog, DCN)
        cold = estimate(prog, DCN, cache=cache)
        warm = estimate(prog, DCN, cache=cache)
        for got in (cold, warm):
            assert got.total == base.total
            assert got.totals.as_tuple() == base.totals.as_tuple()
            assert got.peak_hbm_per_device == base.peak_hbm_per_device
    assert cache.hits > 0


# ---------------------------------------------------------------------------
# Floors: sound on every pipeline-inclusive cell (full enumeration)
# ---------------------------------------------------------------------------


def test_floor_sound_over_full_enumeration_on_pipeline_cells():
    """The acceptance-criterion check: cost EVERY enumerated plan —
    pipelined ones included — on every pipeline-inclusive cell and assert
    nothing dips below the cluster floor."""
    cache = PlanCostCache()
    cells = [("qwen1.5-0.5b", "train_4k", multi_pod_config()),
             ("qwen1.5-0.5b", "train_4k", DCN),
             ("qwen1.5-0.5b", "train_4k", DCN_3D),
             ("qwen1.5-110b", "train_4k", DCN)]
    tightest = float("inf")
    for arch_id, shape_id, cc in cells:
        arch, shape = get_config(arch_id), SHAPES[shape_id]
        floor = cluster_floor_time(arch, shape, cc)
        assert floor > 0
        for plan in enumerate_plans(arch, shape, cc):
            costed = estimate(build_step_program(arch, shape, plan, cc),
                              cc, cache=cache)
            ratio = costed.total / floor
            tightest = min(tightest, ratio)
            assert ratio >= 1.0, (arch_id, cc.mesh_shape, plan.describe(),
                                  ratio)
    assert 1.0 <= tightest < 10.0     # a bound, not a fiction


def test_pipeline_floor_only_drops_where_pipelining_helps():
    """The pp reference's bound is roofline/S * (1 + (S-1)/M): on a mesh
    with pipeline roles the floor may sit below the sequential roofline
    (that is the point), but never below the schedule bound itself."""
    arch = get_config("qwen1.5-110b")
    floor = cluster_floor_time(arch, TRAIN, DCN)
    best_pp = choose_plan(arch, TRAIN, DCN, top_k=1)[0]
    assert best_pp.feasible and best_pp.plan.pp_axes
    assert floor <= best_pp.time
    s = DCN.axis_size("pod")
    assert floor > 0 and (1 + (s - 1) / MAX_MICROBATCHES) > 1


def test_resource_optimizer_surfaces_pipelined_winner():
    """optimize_resources on a DCN multi-slice grid must return a
    pipelined, feasible winner for the frontier-dense train cell and
    match the exhaustive oracle.  (On the 4-axis dcn-3d mesh the
    model x depth tensor-parallel interior fits sequentially — honest,
    and checked for beam==exhaustive — so the pipelined-win cell is the
    2D-interior DCN grid where nothing sequential fits.)"""
    cache = PlanCostCache()
    beam = optimize_resources(ARCH110, TRAIN, [("dcn", DCN)], cache=cache)
    full = optimize_resources(ARCH110, TRAIN, [("dcn", DCN)],
                              search="exhaustive", cache=cache)
    assert beam[0].cluster_id == full[0].cluster_id
    assert beam[0].decision.plan == full[0].decision.plan
    assert beam[0].feasible and beam[0].decision.plan.pp_axes
    both = optimize_resources(ARCH110, TRAIN, [("dcn", DCN),
                                               ("dcn-3d", DCN_3D)],
                              cache=cache)
    both_full = optimize_resources(ARCH110, TRAIN, [("dcn", DCN),
                                                    ("dcn-3d", DCN_3D)],
                                   search="exhaustive", cache=cache)
    assert both[0].cluster_id == both_full[0].cluster_id
    assert both[0].decision.plan == both_full[0].decision.plan


# ---------------------------------------------------------------------------
# Job pricing: preemption fixpoint + checkpoint-write stalls (satellite)
# ---------------------------------------------------------------------------


def test_job_seconds_is_geometric_series_fixpoint():
    cc = single_pod_config()
    arch = get_config("gemma3-12b")
    step, steps = 0.1, 10_000
    wall = job_seconds(cc, step, steps, arch)
    from repro.core.resource import checkpoint_restore_seconds
    restart = (cc.job_startup_seconds + checkpoint_restore_seconds(cc, arch)
               + 0.5 * cc.checkpoint_interval_steps * step)
    lam = cc.preemption_rate_per_chip_hour * cc.num_chips / 3600.0
    base = (cc.job_startup_seconds + step * steps
            + (steps // cc.checkpoint_interval_steps)
            * checkpoint_write_seconds(cc, arch))
    # the closed form IS the fixpoint: wall = base + lam*wall*restart
    assert math.isclose(wall, base + lam * wall * restart, rel_tol=1e-12)
    assert math.isclose(wall, base / (1 - lam * restart), rel_tol=1e-12)
    # rate applied to wall time > rate applied to compute time (pre-PR-5)
    first_order = base + lam * (step * steps) * restart
    assert wall > first_order


def test_job_seconds_diverges_when_restarts_outpace_work():
    cc = dataclasses.replace(single_pod_config(),
                             preemption_rate_per_chip_hour=10.0,
                             job_startup_seconds=1e5)
    assert job_seconds(cc, 0.1, 1000) == float("inf")


def test_checkpoint_write_stalls_charged():
    cc = single_pod_config()
    arch = get_config("gemma3-12b")
    assert checkpoint_write_seconds(cc, arch) > 0
    assert checkpoint_write_seconds(cc) == 0.0
    # a job with an arch in hand pays its write stalls
    with_arch = job_seconds(cc, 0.1, 10_000, arch)
    anon = job_seconds(cc, 0.1, 10_000)
    assert with_arch > anon
    # more chips -> smaller per-host shard -> shorter stall
    bigger = cc.with_mesh((32, 16), ("data", "model"))
    assert (checkpoint_write_seconds(bigger, arch)
            < checkpoint_write_seconds(cc, arch))


def test_job_cost_stays_monotone_in_step_time():
    """The property floor pruning rests on, preserved through the
    fixpoint: longer steps can never price a job cheaper."""
    cc = single_pod_config()
    arch = get_config("qwen1.5-0.5b")
    prev = 0.0
    for step in (0.01, 0.02, 0.1, 0.5, 2.0):
        cur = job_dollars(cc, step, 10_000, arch)
        assert cur > prev
        prev = cur
