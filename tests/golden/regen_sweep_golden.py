"""Regenerate tests/golden/sweep_golden.json — the expected
(arch, shape, cluster) -> winning plan + cost cells that tests/
test_golden_sweep.py diffs against, so cost-model drift is visible (and
reviewable) instead of silent.

Run after any *intentional* cost-model change:
  PYTHONPATH=src python tests/golden/regen_sweep_golden.py
and commit the JSON diff alongside the change that caused it.  ``--jobs N``
costs the grid over a worker pool — the cells are identical to a serial
regen (gated by tests/test_parallel.py), it is just faster on a multi-core
machine.
"""
import argparse
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_SRC = os.path.join(os.path.dirname(os.path.dirname(_HERE)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

GOLDEN_PATH = os.path.join(_HERE, "sweep_golden.json")

# 4 archs x 2 shapes x 6 clusters (two chip generations, both torus
# dimensionalities, and a DCN multi-slice among them) = 48 cells — small
# enough to re-cost in seconds, broad enough that any change to op
# formulas, collective models, HBM accounting, topology link counts, or
# plan enumeration shows up as a diff.  ``v5p-3d`` is the 3D-torus family
# (4x4x4, 2 links/axis); ``v5p-dcn`` (4 slices over DCN) and
# ``qwen1.5-110b`` are the pipeline-parallelism family — the frontier-
# dense train cell only fits (and wins) with pp stages over the pod axis.
# The 2D cells predate the torus work and their costs must never move
# when topology- or pipeline-only changes land; likewise every
# pre-pipeline cell is pinned to a frozen baseline
# (tests/test_golden_sweep.py).
GOLDEN_ARCHS = ("qwen1.5-0.5b", "gemma3-12b", "mamba2-1.3b",
                "qwen1.5-110b")
GOLDEN_SHAPES = ("train_4k", "decode_32k")
GOLDEN_CLUSTERS = ("pod", "2pod", "v5p-pod", "v6e-pod", "v5p-3d",
                   "v5p-dcn")
# Serving cells (PR-6): the same sweep surface handed a ServeWorkload name
# costs a (slots x plan) serving schedule per cluster — the winning decode
# plan/step-time/HBM land in the same cell shape.  Two archs x chat_2k x
# the cluster table = 12 cells; train/decode cells above must never move
# when serving-only changes land.
GOLDEN_SERVE_ARCHS = ("qwen1.5-0.5b", "gemma3-12b")
GOLDEN_SERVE_WORKLOADS = ("chat_2k",)


def compute_cells(jobs=1):
    """Cost the golden grid and return {cell-key: expected values}."""
    from repro.core.sweep import SweepEngine

    engine = SweepEngine(search="beam", jobs=jobs)
    cells = engine.sweep(GOLDEN_ARCHS, GOLDEN_SHAPES, GOLDEN_CLUSTERS)
    cells += engine.sweep(GOLDEN_SERVE_ARCHS, GOLDEN_SERVE_WORKLOADS,
                          GOLDEN_CLUSTERS)
    out = {}
    for c in cells:
        d = c.decision
        out[c.key] = {
            "plan": d.plan.describe(),
            "step_time_s": d.time,
            "hbm_est_bytes": d.hbm_est,
            "feasible": d.feasible,
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=1,
                    help="cost the grid over N spawn workers (identical "
                         "cells, faster regen)")
    args = ap.parse_args()
    cells = compute_cells(jobs=args.jobs)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(cells, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(cells)} cells to {GOLDEN_PATH}")


if __name__ == "__main__":
    main()
