"""Cost-based plan selection tests (execution-type decisions at LM scale)."""
import dataclasses

import pytest

from repro.configs import SHAPES, get_config
from repro.core.cluster import multi_pod_config, single_pod_config
from repro.core.planner import (ShardingPlan, build_step_program, choose_plan,
                                enumerate_plans, estimate_hbm,
                                resident_components)
from repro.core.costmodel import PlanCostCache, estimate

CC = single_pod_config()


def test_enumerate_covers_roles():
    plans = enumerate_plans(get_config("qwen1.5-0.5b"), SHAPES["train_4k"], CC)
    names = {p.name for p in plans}
    assert {"dp+tp", "fsdp", "dp-pure"} <= names


def test_moe_gets_expert_parallel_candidates():
    plans = enumerate_plans(get_config("phi3.5-moe-42b-a6.6b"),
                            SHAPES["train_4k"], CC)
    assert any(p.ep_axes for p in plans)


def test_best_plan_feasible_for_mid_size_models():
    for arch_id in ("qwen1.5-0.5b", "pixtral-12b", "gemma3-12b",
                    "mamba2-1.3b"):
        d = choose_plan(get_config(arch_id), SHAPES["train_4k"], CC, top_k=1)[0]
        assert d.feasible, f"{arch_id}: {d.plan.describe()} {d.hbm_est/1e9:.1f}GB"


def test_deepseek_train_single_pod_is_infeasible():
    """671B + AdamW fp32 state cannot fit 256 x 16 GB — the cost model must
    say so (documented in EXPERIMENTS.md, not hidden)."""
    d = choose_plan(get_config("deepseek-v3-671b"), SHAPES["train_4k"], CC,
                    top_k=1)[0]
    assert not d.feasible


def test_tp_reduces_hbm_where_params_dominate():
    # decode: params+KV dominate, so TP sharding must reduce per-device HBM
    arch = get_config("pixtral-12b")
    shape = SHAPES["decode_32k"]
    dp = ShardingPlan(name="dp-pure", batch_axes=("data", "model"))
    tp = ShardingPlan(name="dp+tp", batch_axes=("data",), tp_axes=("model",))
    assert estimate_hbm(arch, shape, tp, CC) < estimate_hbm(arch, shape, dp, CC)


def test_train_tp_activation_tradeoff_is_modeled():
    # at fixed global batch, dp+tp (dp=16) holds 16x more tokens/device than
    # dp-pure (dp=256): with remat=none the activation term must reflect it
    arch = get_config("pixtral-12b")
    shape = SHAPES["train_4k"]
    dp = ShardingPlan(name="dp-pure", batch_axes=("data", "model"))
    tp = ShardingPlan(name="dp+tp", batch_axes=("data",), tp_axes=("model",))
    assert estimate_hbm(arch, shape, tp, CC) > estimate_hbm(arch, shape, dp, CC)
    # ...which is exactly why the chosen plan pairs TP with microbatching
    best = choose_plan(arch, shape, CC, top_k=1)[0]
    assert best.plan.microbatches > 1 or best.plan.remat != "none"


def test_zero1_shards_optimizer_memory():
    arch = get_config("pixtral-12b")
    shape = SHAPES["train_4k"]
    base = ShardingPlan(tp_axes=("model",), zero1=False)
    z1 = ShardingPlan(tp_axes=("model",), zero1=True)
    assert estimate_hbm(arch, shape, z1, CC) < estimate_hbm(arch, shape, base, CC)


def test_remat_trades_memory_for_time():
    arch = get_config("gemma3-12b")
    shape = SHAPES["train_4k"]
    none = ShardingPlan(tp_axes=("model",), remat="none")
    full = ShardingPlan(tp_axes=("model",), remat="full")
    assert estimate_hbm(arch, shape, full, CC) < estimate_hbm(arch, shape, none, CC)
    t_none = estimate(build_step_program(arch, shape, none, CC), CC).total
    t_full = estimate(build_step_program(arch, shape, full, CC), CC).total
    assert t_full > t_none


def test_microbatching_reduces_activation_memory():
    arch = get_config("stablelm-12b")
    shape = SHAPES["train_4k"]
    m1 = ShardingPlan(tp_axes=("model",), microbatches=1)
    m8 = ShardingPlan(tp_axes=("model",), microbatches=8)
    assert estimate_hbm(arch, shape, m8, CC) < estimate_hbm(arch, shape, m1, CC)


def test_multi_pod_adds_pod_to_batch_axes():
    cc = multi_pod_config()
    plans = enumerate_plans(get_config("qwen1.5-4b"), SHAPES["train_4k"], cc)
    # the pod axis always carries work: extra data-parallelism by default,
    # or pipeline stages when the plan pipelines over DCN — never both
    assert all(("pod" in p.batch_axes) != ("pod" in p.pp_axes)
               for p in plans)
    assert any("pod" in p.pp_axes for p in plans)      # pp-over-DCN exists
    assert all("pod" in p.batch_axes for p in plans if not p.pp_axes)


def test_decode_plan_prefers_tp_for_big_models():
    d = choose_plan(get_config("stablelm-12b"), SHAPES["decode_32k"], CC,
                    top_k=1)[0]
    assert d.feasible
    assert d.plan.tp_axes, d.plan.describe()


def test_hbm_prefilter_agrees_with_costed_peak():
    """The HBM-feasibility pre-filter (estimate_hbm) must never reject a
    plan whose costed peak-HBM excursion fits: the generated plan
    materializes every resident component the pre-filter counts, so the
    walk's peak is always >= the pre-filter's bound."""
    cache = PlanCostCache()
    budget = CC.hbm_budget
    for arch_id in ("qwen1.5-0.5b", "gemma3-12b", "phi3.5-moe-42b-a6.6b",
                    "mamba2-1.3b"):
        arch = get_config(arch_id)
        for shape_id in ("train_4k", "decode_32k"):
            shape = SHAPES[shape_id]
            for plan in enumerate_plans(arch, shape, CC):
                est = estimate_hbm(arch, shape, plan, CC)
                costed = estimate(build_step_program(arch, shape, plan, CC),
                                  CC, cache=cache)
                label = (arch_id, shape_id, plan.describe())
                assert costed.peak_hbm_per_device >= est - 1.0, label
                # therefore: a rejected plan's costed peak never fits
                if est > budget:
                    assert costed.peak_hbm_per_device > budget, label


def test_resident_components_sum_to_estimate():
    arch, shape = get_config("gemma3-12b"), SHAPES["train_4k"]
    plan = ShardingPlan(tp_axes=("model",))
    comp = resident_components(arch, shape, plan, CC)
    assert {"params", "opt_state", "grads", "act_stash", "ce_head"} \
        <= set(comp)
    assert sum(comp.values()) == pytest.approx(
        estimate_hbm(arch, shape, plan, CC))


def test_step_program_costs_scale_with_model():
    shape = SHAPES["train_4k"]
    plan = ShardingPlan(tp_axes=("model",))
    small = estimate(build_step_program(get_config("qwen1.5-0.5b"), shape,
                                        plan, CC), CC).total
    big = estimate(build_step_program(get_config("qwen1.5-4b"), shape,
                                      plan, CC), CC).total
    assert big > 3 * small
