"""3D-torus topology tests.

The topology tentpole's contract: the third mesh axis is *purely
additive*.  Every invariant the 2D cost model was calibrated under — ring
collective formulas, floor soundness, cache bit-exactness, beam ==
exhaustive — must survive on the enlarged space, and a degenerate third
axis (size 1, flat link model) must reproduce the 2D numbers bit for bit.
Deterministic versions of every property run everywhere; the randomized
(hypothesis) versions ride along where requirements-dev is installed.
"""
import dataclasses
import itertools
import math

import pytest

from repro.configs import SHAPES, get_config
from repro.core.cluster import (TPU_V5E, TPU_V5P, ClusterConfig,
                                single_pod_config, torus_3d_config)
from repro.core.costmodel import PlanCostCache, estimate
from repro.core.linalg_ops import collective_cost, collective_wire
from repro.core.planner import (ShardingPlan, build_step_program, choose_plan,
                                enumerate_plans)
from repro.core.resource import (cluster_floor_time, enumerate_clusters,
                                 mesh_candidates, mesh_factorizations,
                                 mesh_factorizations_3d, optimize_resources)

KINDS = ("all_reduce", "all_gather", "reduce_scatter", "all_to_all",
         "permute")
TORUS = torus_3d_config()                      # v5p 4x4x4, 2 links/axis


# ---------------------------------------------------------------------------
# collective_wire on the new axis
# ---------------------------------------------------------------------------


def test_collective_wire_3d_degenerates_to_2d_bit_exact():
    """A size-1 third axis adds exactly nothing: same wire, same hops."""
    for kind in KINDS:
        for x, y in itertools.product((2, 4, 16), repeat=2):
            for b in (1.0, 4096.0, 7.3e8):
                flat = collective_wire(kind, b, (x, y))
                cube = collective_wire(kind, b, (x, y, 1))
                assert flat == cube, (kind, x, y, b)
                mid1 = collective_wire(kind, b, (x, 1, y))
                assert flat == mid1, (kind, x, y, b)


def test_collective_wire_multi_axis_matches_manual_phasing():
    """The tuple form is the estimator's per-axis phasing, folded: wire
    and hops add, and hierarchical all_gather grows the payload."""
    for kind in KINDS:
        b, axes = 1e6, (4, 2, 8)
        wire, hops = 0.0, 0
        payload = b
        for n in axes:
            w, h = collective_wire(kind, payload, n)
            wire += w
            hops += h
            if kind == "all_gather":
                payload *= n
        got_wire, got_hops = collective_wire(kind, b, axes)
        assert math.isclose(got_wire, wire, rel_tol=1e-12) and got_hops == hops


def test_collective_wire_monotone_in_axis_size():
    """Growing any axis never shrinks the per-device wire volume."""
    for kind in KINDS:
        for n in (2, 4, 8, 64, 255):
            lo, _ = collective_wire(kind, 1e6, n)
            hi, _ = collective_wire(kind, 1e6, n + 1)
            assert hi >= lo, (kind, n)
        # and in the multi-axis form, along the new axis specifically
        for z in (1, 2, 4, 8):
            lo, _ = collective_wire(kind, 1e6, (4, 4, z))
            hi, _ = collective_wire(kind, 1e6, (4, 4, 2 * z))
            assert hi >= lo, (kind, z)


def test_collective_cost_links_divide_bandwidth_term_only():
    """2 links halve the wire time but never the hop latency."""
    bw, lat = 90e9, 1e-6
    for kind in KINDS:
        one = collective_cost(kind, 1e8, 4, bw, 0.0, links=1)
        two = collective_cost(kind, 1e8, 4, bw, 0.0, links=2)
        assert math.isclose(one, 2.0 * two, rel_tol=1e-12), kind
        lat_one = collective_cost(kind, 0.0, 4, bw, lat, links=1)
        lat_two = collective_cost(kind, 0.0, 4, bw, lat, links=2)
        assert math.isclose(lat_one, lat_two, rel_tol=1e-12), kind


# ---------------------------------------------------------------------------
# ClusterConfig geometry
# ---------------------------------------------------------------------------


def test_axis_bandwidth_doubles_on_torus_axes_only():
    for ax in ("data", "model", "depth"):
        assert TORUS.axis_links(ax) == 2
        assert TORUS.axis_bandwidth(ax) == 2 * TORUS.link_bw(ax)
    assert TORUS.max_ici_links == 2
    flat = single_pod_config()
    for ax in flat.mesh_axes:
        assert flat.axis_links(ax) == 1
        assert flat.axis_bandwidth(ax) == flat.link_bw(ax)
    assert flat.max_ici_links == 1
    # DCN ("pod") axes ignore link counts even if someone sets them
    dcn = ClusterConfig(mesh_shape=(2, 8, 8), mesh_axes=("pod", "data",
                                                         "model"),
                        torus_links=(2, 2, 2))
    assert dcn.axis_links("pod") == 1
    assert dcn.axis_bandwidth("pod") == dcn.dcn_bw_eff


def test_with_mesh_never_leaks_torus_links():
    flat = TORUS.with_mesh((16, 4), ("data", "model"))
    assert flat.torus_links == ()
    assert flat.max_ici_links == 1
    kept = TORUS.with_mesh((8, 4, 2), ("data", "model", "depth"),
                           torus_links=(2, 2, 2))
    assert kept.torus_links == (2, 2, 2)


def test_torus_3d_config_validates():
    with pytest.raises(ValueError):
        torus_3d_config((8, 8))
    with pytest.raises(ValueError):
        torus_3d_config((4, 4, 4), chip=TPU_V5E)   # 2D-torus fabric


def test_size1_depth_axis_prices_identically_to_2d_mesh():
    """The same plan on (8, 8) and on (8, 8, 1)+flat-links must cost
    bit-identically — the 2D calibration is a strict special case."""
    arch = get_config("qwen1.5-0.5b")
    cc2 = ClusterConfig(chip=TPU_V5P, mesh_shape=(8, 8),
                        mesh_axes=("data", "model"))
    cc3 = ClusterConfig(chip=TPU_V5P, mesh_shape=(8, 8, 1),
                        mesh_axes=("data", "model", "depth"))
    plan = ShardingPlan(name="dp+tp", batch_axes=("data",),
                        tp_axes=("model",))
    for shape_id in ("train_4k", "decode_32k"):
        shape = SHAPES[shape_id]
        a = estimate(build_step_program(arch, shape, plan, cc2), cc2)
        b = estimate(build_step_program(arch, shape, plan, cc3), cc3)
        assert a.total == b.total, shape_id
        assert a.totals.as_tuple() == b.totals.as_tuple(), shape_id


def test_torus_links_discount_collectives_but_never_below_half():
    """2 links/axis at most halve the collective time (hop latency is not
    bandwidth) and never touch io/compute."""
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    flat = dataclasses.replace(TORUS, torus_links=())
    plan = choose_plan(arch, shape, flat, top_k=1)[0].plan
    a = estimate(build_step_program(arch, shape, plan, flat), flat)
    b = estimate(build_step_program(arch, shape, plan, TORUS), TORUS)
    assert b.breakdown.collective < a.breakdown.collective
    assert b.breakdown.collective >= a.breakdown.collective / 2 - 1e-15
    assert b.breakdown.compute == a.breakdown.compute
    assert b.breakdown.io == a.breakdown.io
    # totals hold wire *volume*, which links do not change
    assert a.totals.as_tuple() == b.totals.as_tuple()


# ---------------------------------------------------------------------------
# The enlarged plan/cluster space
# ---------------------------------------------------------------------------

V5P_CLUSTERS = enumerate_clusters(chips=["tpu_v5p"], pod_counts=(1, 2))
V5P_3D = [c for c in V5P_CLUSTERS if c.cid.endswith("-3d")]


def test_enumerate_clusters_emits_3d_family_for_v5p_only():
    assert len(V5P_3D) >= 2
    for cand in V5P_3D:
        if "-dcn-" in cand.cid:
            # the (pod x 3D inner torus) 4-axis family
            assert len(cand.cc.mesh_shape) == 4
            assert cand.cc.mesh_axes == ("pod", "data", "model", "depth")
        else:
            assert len(cand.cc.mesh_shape) == 3
            assert cand.cc.mesh_axes == ("data", "model", "depth")
        # wraparound fidelity: an axis only closes its ring (2 links) when
        # it spans whole 4-chip building cubes; sub-cube axes are open
        # lines, and the DCN pod axis never wraps
        want = tuple(
            1 if (a == "pod" or n < 2 or n % TPU_V5P.ici_cube_dim) else 2
            for a, n in zip(cand.cc.mesh_axes, cand.cc.mesh_shape))
        assert cand.cc.torus_links == want, cand.cid
    assert any(c.cc.torus_links and 1 in c.cc.torus_links[1:]
               for c in V5P_3D), "no open-line (sub-cube) axis in the grid"
    # and concrete pinned cases, independent of the implementation's rule
    from repro.core.resource import torus_links_for
    dmz = ("data", "model", "depth")
    assert torus_links_for(dmz, TPU_V5P, (4, 4, 4)) == (2, 2, 2)
    assert torus_links_for(dmz, TPU_V5P, (12, 4, 4)) == (2, 2, 2)
    assert torus_links_for(dmz, TPU_V5P, (8, 4, 2)) == (2, 2, 1)
    assert torus_links_for(dmz, TPU_V5P, (16, 2, 2)) == (2, 1, 1)
    assert torus_links_for(dmz, TPU_V5P, (6, 3, 2)) == ()   # nothing wraps
    assert torus_links_for(("pod",) + dmz, TPU_V5P,
                           (2, 4, 4, 4)) == (1, 2, 2, 2)
    assert torus_links_for(dmz, TPU_V5E, (4, 4, 4)) == ()   # 2D-torus chip
    assert torus_links_for(("data", "model"), TPU_V5P, (8, 8)) == ()
    flat_chips = enumerate_clusters(chips=["tpu_v5e", "tpu_v6e"],
                                    pod_counts=(1, 2))
    assert not any(c.cid.endswith("-3d") for c in flat_chips)
    # and the 2D family is unchanged by the new axis: same cids as before
    v5p_2d = [c.cid for c in V5P_CLUSTERS if not c.cid.endswith("-3d")]
    assert v5p_2d == ["v5p-8x8", "v5p-16x4", "v5p-16x8", "v5p-32x4",
                     "v5p-2x8x8-dcn"]


def test_mesh_factorizations_3d_is_valid_and_balanced_first():
    for n in (8, 64, 128, 256, 192):
        facs = mesh_factorizations_3d(n, variants=8)
        assert facs, n
        ratios = []
        for mesh, axes in facs:
            d, m, z = mesh
            assert d * m * z == n
            assert d >= m >= z >= 2
            assert axes == ("data", "model", "depth")
            ratios.append(d / z)
        assert ratios == sorted(ratios)       # most cube-like first
    assert mesh_factorizations_3d(7) == []    # primes have no 3D split
    # 2D factorizations are byte-identical with or without the torus flag
    for n in (64, 256):
        flat = mesh_factorizations(n, torus_dims=2)
        both = mesh_factorizations(n, torus_dims=3)
        assert both[:len(flat)] == flat
        assert all(len(mesh) == 3 for mesh, _ in both[len(flat):])


def test_depth_axis_roles_enumerate_and_fit():
    """Every 3D role must build and cost; the plan space strictly grows
    versus the 2D mesh of the same chip count."""
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    cc2 = ClusterConfig(chip=TPU_V5P, mesh_shape=(16, 4),
                        mesh_axes=("data", "model"))
    plans3 = enumerate_plans(arch, shape, TORUS)
    assert len(plans3) > len(enumerate_plans(arch, shape, cc2))
    names = {p.name for p in plans3}
    assert {"dp+tp2", "dp+tp", "tp+fsdp", "fsdp2", "dp-pure"} <= names
    cache = PlanCostCache()
    for p in plans3[:12]:
        costed = estimate(build_step_program(arch, shape, p, TORUS), TORUS,
                          cache=cache)
        assert costed.total > 0


def test_moe_and_prefill_roles_reach_depth_axis():
    arch = get_config("phi3.5-moe-42b-a6.6b")
    names = {p.name for p in enumerate_plans(arch, SHAPES["train_4k"], TORUS)}
    assert "dp+ep+tp" in names and "dp+ep" in names
    dense = get_config("qwen1.5-4b")
    pnames = {p.name
              for p in enumerate_plans(dense, SHAPES["prefill_32k"], TORUS)}
    assert "tp+seq" in pnames


def test_floor_sound_over_full_enumeration_on_3d_meshes():
    """The acceptance-criterion check: cost every enumerated plan on every
    3D v5p cell and assert nothing dips below the cluster floor — the
    tightest plan/floor ratio over the whole enumeration stays >= 1.0."""
    cache = PlanCostCache()
    arch = get_config("qwen1.5-0.5b")
    tightest = float("inf")
    for shape_id in ("train_4k", "decode_32k"):
        shape = SHAPES[shape_id]
        for cand in V5P_3D:
            floor = cluster_floor_time(arch, shape, cand.cc)
            assert floor > 0
            for plan in enumerate_plans(arch, shape, cand.cc):
                costed = estimate(build_step_program(arch, shape, plan,
                                                     cand.cc),
                                  cand.cc, cache=cache)
                ratio = costed.total / floor
                tightest = min(tightest, ratio)
                assert ratio >= 1.0, (shape_id, cand.cid, plan.describe(),
                                      ratio)
    assert tightest >= 1.0
    assert tightest < 10.0       # the floor is a *bound*, not a fiction


def test_beam_matches_exhaustive_on_3d_inclusive_grid():
    """Winner equality on the v5p grid with its 3D family included, under
    both time and $ objectives — per the acceptance criteria."""
    cache, ex_cache = PlanCostCache(), PlanCostCache()
    for arch_id in ("qwen1.5-0.5b", "mamba2-1.3b"):
        arch = get_config(arch_id)
        for shape_id in ("train_4k", "decode_32k"):
            shape = SHAPES[shape_id]
            for objective in ("step_time", "cost", "job_cost"):
                beam = optimize_resources(arch, shape, V5P_CLUSTERS,
                                          objective=objective, cache=cache)
                full = optimize_resources(arch, shape, V5P_CLUSTERS,
                                          objective=objective,
                                          search="exhaustive",
                                          cache=ex_cache)
                cell = f"{arch_id}|{shape_id}|{objective}"
                assert beam[0].cluster_id == full[0].cluster_id, cell
                assert beam[0].decision.plan == full[0].decision.plan, cell


def test_plan_cache_replay_bit_exact_on_3d_meshes():
    """Cold record and warm replay through a shared cache must reproduce
    the uncached walk exactly — cost, breakdown, peak HBM and totals —
    for plans spanning every 3D role."""
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    cache = PlanCostCache()
    plans = enumerate_plans(arch, shape, TORUS)
    picked = {p.name: p for p in plans}.values()   # one per role
    for plan in picked:
        prog = build_step_program(arch, shape, plan, TORUS)
        base = estimate(prog, TORUS)
        cold = estimate(prog, TORUS, cache=cache)
        warm = estimate(prog, TORUS, cache=cache)
        for got in (cold, warm):
            assert got.total == base.total, plan.name
            assert got.totals.as_tuple() == base.totals.as_tuple(), plan.name
            assert got.peak_hbm_per_device == base.peak_hbm_per_device
    assert cache.hits > 0


def test_elastic_replan_survives_prime_survivor_counts():
    """Device loss can leave a chip count with no non-trivial 2D split;
    the degenerate 1D mesh must keep replan working."""
    from repro.runtime.elastic import replan
    cands = mesh_candidates(TPU_V5E, 7)
    assert [tuple(c.cc.mesh_shape) for c in cands] == [(7,)]
    with pytest.raises(ValueError):
        mesh_candidates(TPU_V5E, 0)
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["decode_32k"]
    old_cc = single_pod_config()
    ep = replan(arch, shape, old_cc=old_cc, available_chips=7)
    assert ep.cc.num_chips == 7
    assert ep.decision is not None
    # 3D-capable chips re-factor survivors into torus layouts too
    v5p_cands = mesh_candidates(TPU_V5P, 192)
    assert any(c.cid.endswith("-3d") for c in v5p_cands)


# ---------------------------------------------------------------------------
# Randomized versions (hypothesis, where installed)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(kind=st.sampled_from(KINDS),
           b=st.floats(min_value=1.0, max_value=1e12),
           x=st.integers(1, 1024), y=st.integers(1, 1024))
    def test_property_3d_degenerates_to_2d(kind, b, x, y):
        """Bit-exact equality, size-1 third axis in any position."""
        flat = collective_wire(kind, b, (x, y))
        assert collective_wire(kind, b, (x, y, 1)) == flat
        assert collective_wire(kind, b, (x, 1, y)) == flat
        assert collective_wire(kind, b, (1, x, y)) == flat

    @settings(max_examples=60, deadline=None)
    @given(kind=st.sampled_from(KINDS),
           b=st.floats(min_value=1.0, max_value=1e12),
           x=st.integers(1, 256), y=st.integers(1, 256),
           z=st.integers(1, 255))
    def test_property_wire_monotone_in_third_axis(kind, b, x, y, z):
        lo, lo_hops = collective_wire(kind, b, (x, y, z))
        hi, hi_hops = collective_wire(kind, b, (x, y, z + 1))
        assert hi >= lo
        assert hi_hops >= lo_hops
else:
    def test_property_3d_degenerates_to_2d():
        pytest.skip("randomized variant needs hypothesis "
                    "(pip install -r requirements-dev.txt)")
