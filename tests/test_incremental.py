"""Incremental re-costing: a single-knob mutation equals from-scratch.

:class:`repro.core.planner.IncrementalCoster` re-costs a mutated plan
through the shared sub-plan cache; only the dirty subtree recomputes.
These tests assert the contract per knob — remat, microbatches,
grad-reduce dtype, and (for serving) the slot count via a shape override —
and that the cache fingerprint keeps calibrated and uncalibrated worlds
apart (a profile swap must never replay stale entries).
"""
import dataclasses

import pytest

from repro.configs import SHAPES, get_config
from repro.core.calibration import CalibrationProfile
from repro.core.cluster import single_pod_config
from repro.core.costmodel import PlanCostCache
from repro.core.planner import (IncrementalCoster, SearchStats,
                                ShardingPlan, _cost_candidate)
from repro.core.serving import decode_shape
from repro.core.workload import SERVE_WORKLOADS

CC = single_pod_config()
ARCH = get_config("qwen1.5-0.5b")
TRAIN = SHAPES["train_4k"]
BASE = ShardingPlan(name="dp+tp", batch_axes=("data",), tp_axes=("model",),
                    remat="none", microbatches=2,
                    grad_reduce_dtype="float32")


def _scratch(plan, shape=TRAIN, cc=CC):
    """From-scratch scalar costing with a cold private cache."""
    return _cost_candidate(ARCH, shape, plan, cc, PlanCostCache(),
                           SearchStats())


def _assert_equal(a, b, what):
    assert a.time == b.time, what
    assert a.hbm_est == b.hbm_est, what
    assert a.feasible == b.feasible, what
    assert a.cost.totals.as_tuple() == b.cost.totals.as_tuple(), what
    for field in ("io", "compute", "collective", "latency"):
        assert getattr(a.cost.breakdown, field) == \
            getattr(b.cost.breakdown, field), (what, field)


@pytest.mark.parametrize("mutation", [
    {"remat": "selective"},
    {"remat": "full"},
    {"microbatches": 4},
    {"microbatches": 8},
    {"microbatches": 1},
    {"grad_reduce_dtype": "bfloat16"},
    {"grad_reduce_dtype": "float8_e4m3fn"},
    {"overlap": False},
    {"zero1": True},
])
def test_single_knob_recost_equals_from_scratch(mutation):
    ic = IncrementalCoster(ARCH, TRAIN, CC)
    ic.cost(BASE)
    got = ic.recost(BASE, **mutation)
    want = _scratch(dataclasses.replace(BASE, **mutation))
    _assert_equal(got, want, mutation)


def test_recost_is_marginal_not_full():
    """The whole point: after the base walk, a knob flip re-walks only
    the dirty subtree — the marginal walk must be mostly cache hits."""
    ic = IncrementalCoster(ARCH, TRAIN, CC)
    ic.cost(BASE)
    base_misses = ic.marginal.misses
    assert base_misses > 0                      # cold walk populated it
    ic.recost(BASE, grad_reduce_dtype="bfloat16")
    m = ic.marginal
    assert m.hits > 0, "mutation re-walked nothing from cache"
    assert m.misses < base_misses, \
        f"grad-dtype flip recomputed {m.misses}/{base_misses} blocks"
    # re-costing the original plan again is a pure replay
    ic.recost(BASE)
    assert ic.marginal.misses == 0


def test_knob_walkthrough_every_mutation_stays_exact():
    """A chained session: each mutation applies to the previous plan (not
    the base), as an anytime search would drive it."""
    ic = IncrementalCoster(ARCH, TRAIN, CC)
    plan = BASE
    ic.cost(plan)
    for mutation in ({"remat": "selective"}, {"microbatches": 4},
                     {"grad_reduce_dtype": "bfloat16"}, {"remat": "none"},
                     {"microbatches": 2}):
        plan = dataclasses.replace(plan, **mutation)
        got = ic.recost(plan)
        _assert_equal(got, _scratch(plan), mutation)


def test_slots_knob_via_shape_override():
    """Serving's slot count is a *shape* knob: re-costing a decode plan
    under a re-slotted shape through the shared cache must equal the
    from-scratch walk of that shape."""
    wl = SERVE_WORKLOADS["chat_2k"]
    plan = ShardingPlan(name="dp+tp", batch_axes=("data",),
                        tp_axes=("model",))
    ic = IncrementalCoster(ARCH, decode_shape(wl, 8), CC)
    ic.cost(plan)
    for slots in (32, 128, 8):
        shape = decode_shape(wl, slots)
        got = ic.recost(plan, shape=shape)
        want = _scratch(plan, shape=shape)
        _assert_equal(got, want, f"slots={slots}")


def test_calibration_profile_separates_cache_entries():
    """One shared cache serving calibrated and uncalibrated ClusterConfigs
    must keep their sub-plan entries apart (the cc fingerprint embeds the
    profile) — and each world's incremental answers stay exact."""
    profile = CalibrationProfile(chip_name=CC.chip.name, hbm_fraction=0.5,
                                 ici_fraction=0.6)
    cal = CC.with_calibration(profile)
    cache = PlanCostCache()
    ic_raw = IncrementalCoster(ARCH, TRAIN, CC, cache=cache)
    ic_cal = IncrementalCoster(ARCH, TRAIN, cal, cache=cache)
    raw = ic_raw.cost(BASE)
    got_cal = ic_cal.cost(BASE)
    want_cal = _scratch(BASE, cc=cal)
    _assert_equal(got_cal, want_cal, "calibrated world")
    assert got_cal.time > raw.time, \
        "derated profile must slow the plan down"
    # warm replays on both sides of the fingerprint stay exact
    _assert_equal(ic_raw.cost(BASE), raw, "uncalibrated replay")
    assert ic_raw.marginal.misses == 0
    _assert_equal(ic_cal.cost(BASE), want_cal, "calibrated replay")
    assert ic_cal.marginal.misses == 0


def test_incremental_matches_batched_engine_lanewise():
    """Cross-check the two PR-8 engines against each other: for one knob
    grid, incremental re-costs and the lane-vector walk agree exactly."""
    from repro.core.planner import cost_candidates_batched
    grid = [dataclasses.replace(BASE, microbatches=m, grad_reduce_dtype=g)
            for m in (2, 4, 8) for g in ("float32", "bfloat16")]
    batched = cost_candidates_batched(ARCH, TRAIN, grid, CC)
    ic = IncrementalCoster(ARCH, TRAIN, CC)
    ic.cost(BASE)
    for p, b in zip(grid, batched):
        _assert_equal(ic.recost(p), b, p.describe())
