"""Docs suite checks (fast tier + CI `docs` job).

* Every ``>>>`` block in the README and docs/ is a doctest — run them, so
  the quickstart and the cost-model examples can never silently rot.
* Every relative markdown link must resolve to a real file (anchors
  stripped; external http(s) links are not fetched — no network in CI).
"""
import doctest
import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOCTEST_FILES = (
    "README.md",
    os.path.join("docs", "ARCHITECTURE.md"),
    os.path.join("docs", "COST_MODEL.md"),
    "CONTRIBUTING.md",
)

# [text](target) — excluding images; inline code spans are not links
_LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def _markdown_files():
    out = []
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames
                       if d not in (".git", "__pycache__", ".github")]
        out.extend(os.path.join(dirpath, f) for f in filenames
                   if f.endswith(".md"))
    return sorted(out)


@pytest.mark.parametrize("relpath", DOCTEST_FILES)
def test_doc_doctests(relpath):
    path = os.path.join(ROOT, relpath)
    assert os.path.exists(path), f"{relpath} missing — the docs suite is " \
                                 "part of the repo contract"
    results = doctest.testfile(path, module_relative=False, verbose=False)
    assert results.failed == 0, (
        f"{relpath}: {results.failed}/{results.attempted} doctests failed "
        "(run `PYTHONPATH=src python -m doctest " + relpath + "` for detail)")


def test_quickstart_doctest_exists():
    """The README quickstart must actually BE a doctest (>=3 examples), not
    a dead code block."""
    path = os.path.join(ROOT, "README.md")
    with open(path) as f:
        examples = doctest.DocTestParser().get_examples(f.read())
    assert len(examples) >= 3


def test_markdown_links_resolve():
    bad = []
    for md in _markdown_files():
        base = os.path.dirname(md)
        with open(md) as f:
            text = f.read()
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:               # pure in-page anchor
                continue
            if not os.path.exists(os.path.normpath(os.path.join(base, rel))):
                bad.append(f"{os.path.relpath(md, ROOT)} -> {target}")
    assert not bad, "broken markdown links:\n  " + "\n  ".join(bad)


def test_docs_name_only_living_symbols():
    """Back-tick references like `module.symbol` in docs/ must exist in the
    public core API when they name repro.core members — docs rot check."""
    import repro.core as core
    pat = re.compile(r"`(?:repro\.core\.)?(?:costmodel|resource|planner|"
                     r"sweep|cluster|serving|workload)\."
                     r"([A-Za-z_][A-Za-z0-9_]*)`")
    missing = []
    for rel in ("docs/ARCHITECTURE.md", "docs/COST_MODEL.md"):
        with open(os.path.join(ROOT, rel)) as f:
            text = f.read()
        for name in pat.findall(text):
            if not (hasattr(core, name)
                    or any(hasattr(getattr(core, m), name)
                           for m in ("costmodel", "resource", "planner",
                                     "sweep", "cluster", "serving",
                                     "workload")
                           if hasattr(core, m))):
                missing.append(f"{rel}: {name}")
    assert not missing, "docs reference symbols that do not exist:\n  " \
                        + "\n  ".join(missing)
