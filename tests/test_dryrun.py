"""Dry-run machinery tests: one real (subprocess, 512 host devices) cell,
plus artifact-schema checks on whatever the full matrix has produced."""
import glob
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow   # jit/subprocess-compiling tier-2 tests

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACTS = os.path.join(REPO, "benchmarks", "artifacts")


@pytest.mark.slow
def test_one_cell_compiles_in_subprocess(tmp_path):
    """Smallest cell end-to-end: proves lower+compile works under the
    512-device flag without polluting this process's device state."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    code = (
        "from repro.launch.dryrun import run_cell;"
        "r = run_cell('qwen1.5-0.5b', 'decode_32k', 'single',"
        f" artifact_dir=r'{tmp_path}', force=True);"
        "print('STATUS=' + r['status']);"
        "assert r['status'] == 'ok', r.get('error')"
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "STATUS=ok" in out.stdout, out.stdout + out.stderr


def test_existing_artifacts_are_well_formed():
    paths = glob.glob(os.path.join(ARTIFACTS, "dryrun_*.json"))
    if not paths:
        pytest.skip("no artifacts yet (dry-run matrix not run)")
    for p in paths:
        with open(p) as f:
            d = json.load(f)
        assert d["status"] in ("ok", "skip", "fail"), p
        if d["status"] == "ok":
            r = d["roofline"]
            assert r["compute_s"] >= 0 and r["memory_s"] > 0
            assert d["memory_analysis"]["temp_bytes"] >= 0
            assert d["compiled_cost"]["flops_per_device"] > 0
        if d["status"] == "skip":
            assert "skip" in d["why"]


def test_no_failed_cells_in_matrix():
    paths = glob.glob(os.path.join(ARTIFACTS, "dryrun_*.json"))
    if not paths:
        pytest.skip("no artifacts yet")
    failed = []
    for p in paths:
        with open(p) as f:
            d = json.load(f)
        if d["status"] == "fail":
            failed.append((os.path.basename(p), d.get("error", "")[:100]))
    assert not failed, failed
