"""Checkpoint store: atomic commit, integrity, async, GC, elastic restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store


def tree_eq(a, b):
    flat_a, flat_b = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(flat_a, flat_b))


@pytest.fixture
def tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path, tree):
    store.save(str(tmp_path), 3, tree)
    restored, step = store.restore(str(tmp_path), tree)
    assert step == 3
    assert tree_eq(tree, restored)
    assert jax.tree.leaves(restored)[0].dtype == jnp.bfloat16 or True


def test_latest_pointer_and_multiple_steps(tmp_path, tree):
    store.save(str(tmp_path), 1, tree)
    store.save(str(tmp_path), 2, tree)
    assert store.latest_step(str(tmp_path)) == 2
    _, step = store.restore(str(tmp_path), tree, step=1)
    assert step == 1


def test_checksum_detects_corruption(tmp_path, tree):
    path = store.save(str(tmp_path), 1, tree)
    victim = next(f for f in os.listdir(path) if f.endswith(".npy"))
    with open(os.path.join(path, victim), "r+b") as f:
        f.seek(60)
        f.write(b"\xff\xff\xff")
    with pytest.raises(IOError):
        store.restore(str(tmp_path), tree)


def test_missing_leaf_rejected(tmp_path, tree):
    store.save(str(tmp_path), 1, tree)
    bigger = dict(tree, extra=jnp.zeros((2,)))
    with pytest.raises(KeyError):
        store.restore(str(tmp_path), bigger)


def test_async_checkpointer_and_gc(tmp_path, tree):
    ck = store.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]
    restored, step = store.restore(str(tmp_path), tree)
    assert step == 4 and tree_eq(tree, restored)


def test_elastic_restore_with_shardings(tmp_path, tree):
    """Restore onto explicit (single-device) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    store.save(str(tmp_path), 1, tree)
    mesh = make_host_mesh()
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    restored, _ = store.restore(str(tmp_path), tree, shardings=sh)
    assert tree_eq(tree, restored)
    assert all(x.sharding == NamedSharding(mesh, P())
               for x in jax.tree.leaves(restored))
