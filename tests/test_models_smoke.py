"""Per-architecture smoke tests (assignment requirement): instantiate a
REDUCED config of the same family, run one forward + one train step on
CPU, assert output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import build_model
from repro.optim import adamw

RNG = jax.random.PRNGKey(0)


def _tiny(arch_id):
    return dataclasses.replace(get_config(arch_id).reduced(), dtype="float32")


# Two cheap-to-compile families stay in the fast tier as the smoke signal;
# the rest jit-compile for tens of seconds each and run in the slow tier.
FAST_ARCHS = ("qwen1.5-0.5b", "mamba2-1.3b")


@pytest.mark.parametrize(
    "arch_id",
    [a if a in FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
     for a in ARCH_IDS])
def test_forward_and_train_step(arch_id):
    cfg = _tiny(arch_id)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 16
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    fs = model.frontend_shape(B)
    if fs is not None:
        batch["frontend"] = jax.random.normal(RNG, fs, jnp.float32)

    # forward: shape + finite
    logits, aux = model.forward(params, tokens, batch.get("frontend"))
    exp_s = S + (fs[1] if (fs is not None and cfg.enc_dec is None) else 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one full train step: grads finite, params actually change
    opt_cfg = adamw.AdamWConfig(lr=1e-3, total_steps=10)
    opt_state = adamw.init(opt_cfg, params)
    (loss, _), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = adamw.global_norm(grads)
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    new_params, _, _ = adamw.apply(opt_cfg, opt_state, grads, params)
    changed = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    assert max(jax.tree.leaves(changed)) > 0


@pytest.mark.parametrize("arch_id", ["qwen1.5-0.5b", "gemma3-12b",
                                     "mamba2-1.3b", "zamba2-2.7b"])
def test_decode_cache_shapes(arch_id):
    cfg = _tiny(arch_id)
    model = build_model(cfg)
    cache = model.init_cache(batch=2, max_len=32)
    shapes = model.cache_shapes(batch=2, max_len=32)
    concrete = jax.tree.map(lambda x: (x.shape, str(x.dtype)), cache)
    spec = jax.tree.map(lambda x: (x.shape, str(x.dtype)), shapes)
    assert concrete == spec


def test_full_configs_param_counts_match_published():
    expect = {
        "phi3.5-moe-42b-a6.6b": (41.9e9, 6.6e9),
        "deepseek-v3-671b": (671e9, 37.6e9),
        "pixtral-12b": (12.2e9, 12.2e9),
        "qwen1.5-0.5b": (0.62e9, 0.62e9),
        "mamba2-1.3b": (1.4e9, 1.4e9),
    }
    for arch_id, (tot, act) in expect.items():
        pc = get_config(arch_id).param_counts()
        assert abs(pc["total"] - tot) / tot < 0.1, arch_id
        assert abs(pc["active"] - act) / act < 0.15, arch_id
