"""Dominance-pool pruning must never drop the exhaustive winner.

:class:`repro.core.dominance.DominancePool` centralizes the incumbent/
floor pruning of the three optimizers.  Unit tests pin its two modes
(rank-key incumbent, Pareto frontier); the integration tests full-
enumerate the PR-5 pipeline-inclusive resource grid and the PR-6 serving
grid and assert pruned search == exhaustive winner, then re-run on
seeded-random cluster subsets (the order/subset robustness property).
"""
import random

import pytest

from repro.configs import SHAPES, get_config
from repro.core.costmodel import PlanCostCache
from repro.core.dominance import DominancePool, pareto_dominates
from repro.core.planner import SearchStats, choose_plan
from repro.core.resource import (ResourceSearchStats, enumerate_clusters,
                                 optimize_resources)
from repro.core.serving import ServingCandidate, disaggregate, optimize_serving
from repro.core.sweep import CLUSTERS
from repro.core.workload import SERVE_WORKLOADS

CHAT = SERVE_WORKLOADS["chat_2k"]
GRID = enumerate_clusters(pod_counts=(1, 2))


# ------------------------------------------------------------- unit: Pareto


def test_pareto_dominates_semantics():
    assert pareto_dominates((1, 2), (2, 2))        # <= all, < one
    assert not pareto_dominates((2, 2), (1, 2))
    assert not pareto_dominates((1, 2), (1, 2))    # ties never dominate
    assert not pareto_dominates((1, 3), (2, 2))    # incomparable
    assert pareto_dominates((1, 1, 1), (1, 1, 2))


def test_pareto_pool_keeps_frontier_and_counts():
    pool = DominancePool()
    assert pool.admit((3.0, 5.0)) and pool.offer((3.0, 5.0))
    assert pool.admit((5.0, 3.0)) and pool.offer((5.0, 3.0))
    assert len(pool) == 2                           # incomparable pair
    # dominated bound: pruned without costing
    assert not pool.admit((4.0, 6.0))
    assert pool.admitted == 2 and pool.pruned == 1
    # exact ties are admitted AND offered: strict dominance never fires
    assert pool.admit((3.0, 5.0))
    assert pool.offer((3.0, 5.0))
    assert len(pool) == 3
    # a dominator evicts everything it beats
    assert pool.offer((2.0, 2.0))
    assert pool.frontier == [(2.0, 2.0)]


def test_pareto_pool_never_prunes_the_monotone_optimum():
    """Any ranking monotone in each coordinate picks its optimum from the
    admitted stream: the exhaustive winner is never strictly dominated,
    hence never pruned — for random streams in random orders."""
    rng = random.Random(7)
    for _ in range(50):
        pts = [(rng.randint(0, 9), rng.randint(0, 9), rng.randint(0, 9))
               for _ in range(30)]
        for key in (lambda t: t, lambda t: (t[2], t[0], t[1]),
                    lambda t: sum(t)):
            best = min(pts, key=key)
            pool = DominancePool()
            survived = []
            for t in pts:
                if pool.admit(t):
                    pool.offer(t)
                    survived.append(t)
            assert key(min(survived, key=key)) == key(best)


# ----------------------------------------------------------- unit: rank-key


def test_rank_key_pool_never_prunes_without_incumbent():
    pool = DominancePool(rank_key=lambda d: d,
                         cannot_win=lambda bound, best: True)
    assert pool.admit(123)                    # no incumbent yet
    pool.offer(5)
    assert not pool.admit(123)                # now the predicate rules
    assert pool.pruned == 1


def test_rank_key_pool_keeps_strictly_best_incumbent():
    pool = DominancePool(rank_key=lambda d: d)
    assert pool.offer(5) and pool.best == 5
    assert not pool.offer(5)                  # ties do not replace
    assert pool.offer(3) and pool.best == 3
    assert not pool.offer(4)
    assert len(pool) == 1


# ----------------------------------- integration: plan-search group pruning


def test_batched_plan_search_prunes_groups_and_keeps_winner():
    """choose_plan(search="batched", top_k=1) skips whole structure
    groups by their role floors on at least one real cell — and still
    returns the exhaustive winner on every cell."""
    pruned_anywhere = 0
    for arch_id in ("qwen1.5-0.5b", "gemma3-12b", "qwen1.5-4b"):
        for cc in (CLUSTERS["pod"], CLUSTERS["v5p-dcn"]):
            arch, shape = get_config(arch_id), SHAPES["train_4k"]
            stats = SearchStats()
            ba = choose_plan(arch, shape, cc, top_k=1, search="batched",
                             stats=stats)[0]
            ex = choose_plan(arch, shape, cc, top_k=1,
                             search="exhaustive")[0]
            assert (ba.plan, ba.time) == (ex.plan, ex.time), arch_id
            pruned_anywhere += stats.pruned_dominated
    assert pruned_anywhere > 0, "role-floor pruning never engaged"


# ------------------------------------ integration: PR-5 resource co-search


@pytest.mark.parametrize("objective,slo", [("step_time", None),
                                           ("cost", None),
                                           ("job_cost", None),
                                           ("slo", 0.25)])
def test_resource_pruning_keeps_exhaustive_winner(objective, slo):
    """Full enumeration over the pipeline-inclusive cluster grid (DCN
    multi-slice members carry pp roles since PR 5): the pool-pruned
    search returns the exhaustive scan's winner under every objective."""
    cache = PlanCostCache()
    for arch_id in ("qwen1.5-0.5b", "mamba2-1.3b"):
        arch, shape = get_config(arch_id), SHAPES["train_4k"]
        stats = ResourceSearchStats()
        pruned = optimize_resources(arch, shape, GRID, objective=objective,
                                    slo=slo, cache=cache, stats=stats)
        full = optimize_resources(arch, shape, GRID, objective=objective,
                                  slo=slo, search="exhaustive", cache=cache)
        assert pruned[0].cluster_id == full[0].cluster_id, arch_id
        assert pruned[0].decision.plan == full[0].decision.plan
        assert pruned[0].time == full[0].time
        # the pool actually pruned: its rows carry the incumbent's id
        marks = [d for d in pruned if d.pruned]
        assert stats.clusters_pruned == len(marks)
        for d in marks:
            assert "loses to" in d.pruned


def test_resource_pruning_on_seeded_random_cluster_subsets():
    """The winner-preservation property must hold for ANY subset of the
    grid (incumbents form in different orders): seeded random subsets,
    pruned vs exhaustive, bit-equal winners."""
    cache = PlanCostCache()
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    for seed in range(6):
        rng = random.Random(seed)
        subset = rng.sample(GRID, rng.randint(3, len(GRID)))
        pruned = optimize_resources(arch, shape, subset,
                                    objective="job_cost", cache=cache)
        full = optimize_resources(arch, shape, subset, objective="job_cost",
                                  search="exhaustive", cache=cache)
        assert pruned[0].cluster_id == full[0].cluster_id, seed
        assert pruned[0].cost_per_job == full[0].cost_per_job, seed


# ------------------------------------- integration: PR-6 serving co-search


def test_serving_pruning_keeps_exhaustive_winner():
    """The (candidate x slots x plan) serving grid with a disaggregated
    member: pool-pruned search == exhaustive winner for both serving
    objectives, and the pruned rows carry the pool incumbent's identity."""
    cands = ([ServingCandidate(cid, CLUSTERS[cid], CLUSTERS[cid])
              for cid in ("pod", "v5p-pod", "v5p-dcn")]
             + [disaggregate(CLUSTERS["v5p-dcn"])])
    cache = PlanCostCache()
    for objective in ("tokens_per_dollar", "ttft_p99"):
        stats = ResourceSearchStats()
        beam = optimize_serving(get_config("qwen1.5-0.5b"), CHAT, cands,
                                objective=objective, cache=cache,
                                stats=stats)
        full = optimize_serving(get_config("qwen1.5-0.5b"), CHAT, cands,
                                objective=objective, search="exhaustive",
                                cache=cache)
        assert (beam[0].cluster_id, beam[0].slots) == \
            (full[0].cluster_id, full[0].slots), objective
        assert beam[0].decode_decision.plan == full[0].decode_decision.plan
        for d in beam:
            if d.pruned:
                assert f"{beam[0].cluster_id}@B{beam[0].slots}" \
                    in d.pruned or "loses to" in d.pruned
