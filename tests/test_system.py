"""End-to-end behaviour tests for the paper's system: the full pipeline
from script-level program -> generated plan -> cost -> decision, plus the
production stack wired together."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.core import (estimate, explain, multi_pod_config,
                        single_pod_config)
from repro.core.cluster import ClusterConfig, CPU_HOST
from repro.core.linreg import SCENARIOS, build_linreg_program
from repro.core.planner import build_step_program, choose_plan

pytestmark = pytest.mark.slow   # end-to-end: jit-compiles the full stack


def test_end_to_end_costing_pipeline():
    """Script -> runtime plan -> symbol-table costing -> EXPLAIN."""
    cc = ClusterConfig(chip=CPU_HOST, mesh_shape=(72,), mesh_axes=("data",),
                       dispatch_latency=20.0)
    prog, choice = build_linreg_program(SCENARIOS["XL1"], cc)
    costed = estimate(prog, cc)
    assert costed.total > 0
    text = explain(costed)
    # every instruction visible with a cost annotation
    assert text.count("# C=") > 10
    # plan reflects the paper's XL1 decisions
    assert choice.tsmm_op == "tsmm+ak+" and choice.mm_op == "mapmm"


def test_cost_model_drives_consistent_decisions_across_meshes():
    """R3: the same arch/shape gets re-planned per cluster, and the chosen
    plan's estimated time never improves when the cluster shrinks."""
    arch = get_config("qwen1.5-4b")
    shape = SHAPES["train_4k"]
    pod = choose_plan(arch, shape, single_pod_config(), top_k=1)[0]
    two = choose_plan(arch, shape, multi_pod_config(), top_k=1)[0]
    assert pod.feasible and two.feasible
    # two pods must not be slower than 4x one pod (sanity band)
    assert two.time < 4 * pod.time


def test_analytical_vs_generated_plan_agreement():
    """The analytical program's FLOP total must agree with 6*N*D within a
    factor band (remat/attention overheads make HLO higher, never 5x)."""
    arch = get_config("qwen1.5-0.5b")
    shape = SHAPES["train_4k"]
    cc = single_pod_config()
    d = choose_plan(arch, shape, cc, top_k=1)[0]
    prog = build_step_program(arch, shape, d.plan, cc)
    costed = estimate(prog, cc)
    model_flops = 6 * arch.n_params * shape.tokens
    ideal_s = model_flops / (cc.num_chips * cc.chip.peak("bfloat16")
                             * cc.matmul_util)
    assert ideal_s * 0.5 < costed.breakdown.compute < ideal_s * 6


def test_trainer_smoke():
    from repro.configs.base import ShapeConfig
    from repro.core.cluster import cpu_host_config
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adamw
    from repro.runtime.train_loop import Trainer, TrainerConfig

    arch = dataclasses.replace(get_config("mamba2-1.3b").reduced(),
                               dtype="float32")
    shape = ShapeConfig("t", seq_len=32, global_batch=4, mode="train")
    mesh = make_host_mesh()
    cc = cpu_host_config().with_mesh(tuple(mesh.devices.shape),
                                     tuple(mesh.axis_names))
    tr = Trainer(arch, shape, cc, mesh,
                 tcfg=TrainerConfig(steps=3, log_every=1),
                 opt_cfg=adamw.AdamWConfig(total_steps=3))
    hist = tr.run()["history"]
    assert len(hist) == 3
    assert all(np.isfinite(h["loss"]) for h in hist)
