"""Serving-path correctness: prefill+decode must match full forward
(ring caches, absorbed-MLA decode, SSD decode state), and the engine
must produce deterministic greedy completions."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.runtime.serve_engine import EngineConfig, Request, ServeEngine

RNG = jax.random.PRNGKey(0)


def _tiny(arch_id):
    return dataclasses.replace(get_config(arch_id).reduced(), dtype="float32")


slow = pytest.mark.slow      # heavy jit-compiles: slow tier only


@pytest.mark.parametrize("arch_id", [
    "qwen1.5-0.5b",                            # dense, full cache
    pytest.param("gemma3-12b", marks=slow),    # local/global cycle, ring caches
    "mamba2-1.3b",                             # ssm state decode
    pytest.param("zamba2-2.7b", marks=slow),   # hybrid: ssm + shared attn caches
    pytest.param("whisper-small", marks=slow),  # enc-dec: self + cross caches
    pytest.param("deepseek-v3-671b", marks=slow),  # MLA absorbed decode
])
def test_prefill_decode_matches_forward(arch_id):
    cfg = _tiny(arch_id)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S, P = 2, 24, 16
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    fe = None
    fs = model.frontend_shape(B)
    if fs is not None:
        fe = jax.random.normal(RNG, fs, jnp.float32)
    cf = float(cfg.moe.n_experts) if cfg.moe else None   # dropless

    from repro.models import transformer as T
    logits_full, _ = T.forward(cfg, params, tokens, fe, capacity_factor=cf)
    off = fs[1] if (fs is not None and cfg.enc_dec is None) else 0

    cache = model.init_cache(B, S + off)
    lg, cache = model.prefill(params, tokens[:, :P], cache, fe,
                              capacity_factor=cf)
    np.testing.assert_allclose(lg, logits_full[:, off + P - 1],
                               rtol=1e-4, atol=1e-4)
    for t in range(P, S):
        lg, cache = model.decode_step(params, tokens[:, t], cache,
                                      capacity_factor=cf)
        np.testing.assert_allclose(lg, logits_full[:, off + t],
                                   rtol=1e-4, atol=2e-4)


def test_engine_greedy_deterministic():
    cfg = _tiny("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(RNG)
    engine = ServeEngine(model, params, max_len=64)
    reqs = [Request(prompt=[5, 6, 7, 8], max_new_tokens=8),
            Request(prompt=[9, 10, 11], max_new_tokens=8)]
    out1 = engine.generate(reqs)
    out2 = engine.generate(reqs)
    assert [c.tokens for c in out1] == [c.tokens for c in out2]
    assert all(len(c.tokens) == 8 for c in out1)


def test_engine_eos_stops_early():
    cfg = _tiny("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(RNG)
    engine = ServeEngine(model, params, max_len=64)
    base = engine.generate([Request(prompt=[3, 4, 5], max_new_tokens=8)])[0]
    eos = base.tokens[2]
    out = engine.generate([Request(prompt=[3, 4, 5], max_new_tokens=8,
                                   eos_id=int(eos))])[0]
    assert out.tokens == base.tokens[:3]


def test_engine_config_and_continuous_batching():
    """The EngineConfig surface; static batching is the degenerate
    continuous schedule (enough slots + everything submitted upfront ==
    bit-identical outputs); a smaller pool refills via admission rounds
    and still completes every request deterministically."""
    with pytest.raises(ValueError):
        EngineConfig(batching="sometimes")
    with pytest.raises(ValueError):
        EngineConfig(slots=0)
    cfg = _tiny("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(RNG)
    reqs = [Request(prompt=[5, 6, 7, 8], max_new_tokens=4),
            Request(prompt=[9, 10, 11], max_new_tokens=4),
            Request(prompt=[3, 4, 5], max_new_tokens=4)]
    # legacy kwargs == explicit config
    static = ServeEngine(model, params, max_len=64).generate(reqs)
    cfgd = ServeEngine(model, params, EngineConfig(max_len=64)).generate(reqs)
    assert [c.tokens for c in cfgd] == [c.tokens for c in static]
    # degenerate continuous schedule: slots cover the batch
    wide = ServeEngine(model, params,
                       EngineConfig(max_len=64, batching="continuous",
                                    slots=3))
    assert [c.tokens for c in wide.generate(reqs)] == \
        [c.tokens for c in static]
    assert wide.stats["admission_rounds"] == 1
    # 2 slots over 3 requests: a refill round must happen, all complete
    narrow = ServeEngine(model, params,
                         EngineConfig(max_len=64, batching="continuous",
                                      slots=2))
    out1 = narrow.generate(reqs)
    assert all(len(c.tokens) == 4 for c in out1)
    assert narrow.stats["admission_rounds"] >= 2
    assert [c.tokens for c in narrow.generate(reqs)] == \
        [c.tokens for c in out1]          # deterministic across sessions
    # submit()/run() matches generate() and reports rids in order
    for r in reqs:
        narrow.submit(r)
    drained = narrow.run()
    assert [c.rid for c in drained] == sorted(c.rid for c in drained)


def test_engine_masks_finished_slots_and_reports_per_request_decode():
    """A slot that stops early is masked out of the token accounting
    (wasted_slot_steps counts its padding decodes) and its decode seconds
    stop accruing — the lockstep-waste fix."""
    cfg = _tiny("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(RNG)
    engine = ServeEngine(model, params, max_len=64)
    base = engine.generate([Request(prompt=[5, 6, 7, 8], max_new_tokens=8),
                            Request(prompt=[9, 10, 11], max_new_tokens=8)])
    eos = base[0].tokens[1]
    engine2 = ServeEngine(model, params, max_len=64)
    out = engine2.generate(
        [Request(prompt=[5, 6, 7, 8], max_new_tokens=8, eos_id=int(eos)),
         Request(prompt=[9, 10, 11], max_new_tokens=8)])
    assert out[0].tokens == base[0].tokens[:2]     # stopped at eos
    assert out[1].tokens == base[1].tokens         # unaffected neighbour
    assert engine2.stats["wasted_slot_steps"] > 0
    assert out[0].decode_time_s < out[1].decode_time_s
