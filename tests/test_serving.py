"""Serving-path correctness: prefill+decode must match full forward
(ring caches, absorbed-MLA decode, SSD decode state), and the engine
must produce deterministic greedy completions."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.runtime.serve_engine import Request, ServeEngine

RNG = jax.random.PRNGKey(0)


def _tiny(arch_id):
    return dataclasses.replace(get_config(arch_id).reduced(), dtype="float32")


slow = pytest.mark.slow      # heavy jit-compiles: slow tier only


@pytest.mark.parametrize("arch_id", [
    "qwen1.5-0.5b",                            # dense, full cache
    pytest.param("gemma3-12b", marks=slow),    # local/global cycle, ring caches
    "mamba2-1.3b",                             # ssm state decode
    pytest.param("zamba2-2.7b", marks=slow),   # hybrid: ssm + shared attn caches
    pytest.param("whisper-small", marks=slow),  # enc-dec: self + cross caches
    pytest.param("deepseek-v3-671b", marks=slow),  # MLA absorbed decode
])
def test_prefill_decode_matches_forward(arch_id):
    cfg = _tiny(arch_id)
    model = build_model(cfg)
    params = model.init(RNG)
    B, S, P = 2, 24, 16
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    fe = None
    fs = model.frontend_shape(B)
    if fs is not None:
        fe = jax.random.normal(RNG, fs, jnp.float32)
    cf = float(cfg.moe.n_experts) if cfg.moe else None   # dropless

    from repro.models import transformer as T
    logits_full, _ = T.forward(cfg, params, tokens, fe, capacity_factor=cf)
    off = fs[1] if (fs is not None and cfg.enc_dec is None) else 0

    cache = model.init_cache(B, S + off)
    lg, cache = model.prefill(params, tokens[:, :P], cache, fe,
                              capacity_factor=cf)
    np.testing.assert_allclose(lg, logits_full[:, off + P - 1],
                               rtol=1e-4, atol=1e-4)
    for t in range(P, S):
        lg, cache = model.decode_step(params, tokens[:, t], cache,
                                      capacity_factor=cf)
        np.testing.assert_allclose(lg, logits_full[:, off + t],
                                   rtol=1e-4, atol=2e-4)


def test_engine_greedy_deterministic():
    cfg = _tiny("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(RNG)
    engine = ServeEngine(model, params, max_len=64)
    reqs = [Request(prompt=[5, 6, 7, 8], max_new_tokens=8),
            Request(prompt=[9, 10, 11], max_new_tokens=8)]
    out1 = engine.generate(reqs)
    out2 = engine.generate(reqs)
    assert [c.tokens for c in out1] == [c.tokens for c in out2]
    assert all(len(c.tokens) == 8 for c in out1)


def test_engine_eos_stops_early():
    cfg = _tiny("qwen1.5-0.5b")
    model = build_model(cfg)
    params = model.init(RNG)
    engine = ServeEngine(model, params, max_len=64)
    base = engine.generate([Request(prompt=[3, 4, 5], max_new_tokens=8)])[0]
    eos = base.tokens[2]
    out = engine.generate([Request(prompt=[3, 4, 5], max_new_tokens=8,
                                   eos_id=int(eos))])[0]
    assert out.tokens == base.tokens[:3]
