"""Shared test fixtures.  NOTE: no XLA_FLAGS here — tests run on the real
single CPU device; only dryrun.py forces 512 host devices."""
import dataclasses

import jax
import pytest

from repro.configs import get_config


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny(arch_id: str):
    """Reduced fp32 config for CPU tests."""
    return dataclasses.replace(get_config(arch_id).reduced(), dtype="float32")
