"""Straggler monitor + cost-based re-mesh decision."""
import numpy as np

from repro.core.cluster import single_pod_config
from repro.runtime.straggler import (StepTimeMonitor, StragglerVerdict,
                                     decide_remesh)


def feed(monitor, healthy, slow_entity=None, slow_factor=1.0, steps=16,
         n_entities=8):
    rng = np.random.default_rng(0)
    for _ in range(steps):
        times = {e: healthy * (1 + 0.02 * rng.standard_normal())
                 for e in range(n_entities)}
        if slow_entity is not None:
            times[slow_entity] *= slow_factor
        monitor.record(times)


def test_no_false_positive_on_healthy_cluster():
    m = StepTimeMonitor()
    feed(m, 0.5)
    v = m.detect()
    assert not v.is_straggler


def test_detects_single_slow_host():
    m = StepTimeMonitor()
    feed(m, 0.5, slow_entity=3, slow_factor=1.8)
    v = m.detect()
    assert v.is_straggler
    assert v.slow_entities == [3]
    assert 1.5 < v.slowdown < 2.1


def test_warmup_period_defers_judgement():
    m = StepTimeMonitor(min_samples=8)
    feed(m, 0.5, slow_entity=1, slow_factor=3.0, steps=3)
    assert not m.detect().is_straggler


def test_cost_based_decision_remesh_when_slowdown_large():
    cc = single_pod_config()
    v = StragglerVerdict(True, [3], slowdown=2.5, action="detected")
    out = decide_remesh(v, cc=cc, healthy_step_time=2.0,
                        remaining_steps=50_000,
                        checkpoint_bytes_per_device=2e9,
                        excluded_fraction=1 / 16)
    assert out.action == "remesh"
    assert "C(tolerate)" in out.detail


def test_cost_based_decision_tolerate_when_nearly_done():
    cc = single_pod_config()
    v = StragglerVerdict(True, [3], slowdown=1.2, action="detected")
    out = decide_remesh(v, cc=cc, healthy_step_time=2.0,
                        remaining_steps=10,
                        checkpoint_bytes_per_device=2e9,
                        excluded_fraction=1 / 16)
    assert out.action == "tolerate"
