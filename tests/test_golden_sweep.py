"""Golden regression: the sweep grid's winning plans and costs must match
the checked-in tests/golden/sweep_golden.json cell for cell.

Cost-model drift (op formulas, collective models, HBM accounting, plan
enumeration, search behavior) shows up here as a readable diff at review
time.  If the change is intentional, regenerate and commit:

  PYTHONPATH=src python tests/golden/regen_sweep_golden.py
"""
import importlib.util
import json
import math
import os

_GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden")

# Import the regen script itself, so the grid definition and the cell
# builder can never drift between the test and the regeneration path.
_spec = importlib.util.spec_from_file_location(
    "regen_sweep_golden", os.path.join(_GOLDEN_DIR, "regen_sweep_golden.py"))
_regen = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_regen)


# Frozen pre-torus (PR-3) step times of every 2D golden cell.  The 3D-torus
# topology work (per-axis link counts, depth-axis roles, 3D candidates) must
# be purely additive: a 2D mesh prices exactly as it did before the new
# axis existed.  Any drift here means the flat model was disturbed — which
# is a bug, not a regeneration event (the new-axis cells live in
# sweep_golden.json and MAY move with intentional cost-model changes; these
# may not).
PRE_TORUS_2D_STEP_TIMES = {
    "gemma3-12b|decode_32k|2pod": 0.005640601213984056,
    "gemma3-12b|decode_32k|pod": 0.01040860402796811,
    "gemma3-12b|decode_32k|v5p-pod": 0.011174433533523029,
    "gemma3-12b|decode_32k|v6e-pod": 0.005640400373059142,
    "gemma3-12b|train_4k|2pod": 2.006239356136516,
    "gemma3-12b|train_4k|pod": 3.9446190679731217,
    "gemma3-12b|train_4k|v5p-pod": 5.470500259268863,
    "gemma3-12b|train_4k|v6e-pod": 1.3299013060655531,
    "mamba2-1.3b|decode_32k|2pod": 2.8364671636859875e-05,
    "mamba2-1.3b|decode_32k|pod": 5.487094327371975e-05,
    "mamba2-1.3b|decode_32k|v5p-pod": 6.465244810658441e-05,
    "mamba2-1.3b|decode_32k|v6e-pod": 2.833234691535151e-05,
    "mamba2-1.3b|train_4k|2pod": 0.2823090089153571,
    "mamba2-1.3b|train_4k|pod": 0.2971891713601879,
    "mamba2-1.3b|train_4k|v5p-pod": 0.4217759356538556,
    "mamba2-1.3b|train_4k|v6e-pod": 0.09377336990569207,
    "qwen1.5-0.5b|decode_32k|2pod": 0.0016120377649572653,
    "qwen1.5-0.5b|decode_32k|pod": 0.0027855075299145302,
    "qwen1.5-0.5b|decode_32k|v5p-pod": 0.002752198992027129,
    "qwen1.5-0.5b|decode_32k|v6e-pod": 0.0016120126856368562,
    "qwen1.5-0.5b|train_4k|2pod": 0.14174567748918163,
    "qwen1.5-0.5b|train_4k|pod": 0.1210152587780616,
    "qwen1.5-0.5b|train_4k|v5p-pod": 0.1652115513696153,
    "qwen1.5-0.5b|train_4k|v6e-pod": 0.039441672748381694,
}


# Frozen pre-pipeline (PR-4) step times of every golden cell that existed
# before pipeline parallelism became a costed construct — the 24 2D cells
# above plus the six v5p-3d cells.  Teaching the stack pipelined loops
# (pp roles, P2P pricing, the PipelinedLoopBlock schedule) must be purely
# additive on these: every pre-pipeline winner keeps its exact cost.  The
# new pipeline cells (arch qwen1.5-110b, cluster v5p-dcn) live only in
# sweep_golden.json and MAY move with intentional cost-model changes;
# these may not.
PRE_PIPELINE_STEP_TIMES = dict(PRE_TORUS_2D_STEP_TIMES)
PRE_PIPELINE_STEP_TIMES.update({
    "gemma3-12b|decode_32k|v5p-3d": 0.011174433533523029,
    "gemma3-12b|train_4k|v5p-3d": 4.433797577840346,
    "mamba2-1.3b|decode_32k|v5p-3d": 6.465244810658441e-05,
    "mamba2-1.3b|train_4k|v5p-3d": 0.4083821445427445,
    "qwen1.5-0.5b|decode_32k|v5p-3d": 0.002752198992027129,
    "qwen1.5-0.5b|train_4k|v5p-3d": 0.159472255073319,
})


def test_pre_pipeline_cells_unchanged_by_pipeline_parallelism():
    """The checked-in golden file's pre-pipeline cells must equal the
    frozen PR-4 baseline bit for bit — pipelined loops are additive — and
    the grid must actually contain a *winning* pipelined cell (the
    frontier-dense train cell that only fits with stages over DCN)."""
    with open(_regen.GOLDEN_PATH) as f:
        golden = json.load(f)
    drift = []
    for key, want in PRE_PIPELINE_STEP_TIMES.items():
        got = golden.get(key)
        if got is None:
            drift.append(f"{key}: cell missing from golden")
        elif got["step_time_s"] != want:
            drift.append(f"{key}: {want!r} -> {got['step_time_s']!r}")
    assert not drift, (
        "pre-pipeline golden cells moved — the pipeline-parallelism "
        "change leaked into existing plans:\n  " + "\n  ".join(drift))
    pipelined = [k for k, v in golden.items() if "pp=" in v["plan"]]
    assert pipelined, "golden grid has no pipelined winner"
    assert any(golden[k]["feasible"] and "dcn" in k for k in pipelined), \
        "no feasible pipelined winner on a DCN multi-slice cell"


def test_2d_cells_unchanged_by_torus_topology():
    """The checked-in golden file's 2D cells must equal the frozen
    pre-torus baseline bit for bit — the 3D axis is additive."""
    with open(_regen.GOLDEN_PATH) as f:
        golden = json.load(f)
    drift = []
    for key, want in PRE_TORUS_2D_STEP_TIMES.items():
        got = golden.get(key)
        if got is None:
            drift.append(f"{key}: cell missing from golden")
        elif got["step_time_s"] != want:
            drift.append(f"{key}: {want!r} -> {got['step_time_s']!r}")
    assert not drift, (
        "2D golden cells moved — the torus topology change leaked into "
        "the flat model:\n  " + "\n  ".join(drift))
    # and the golden grid actually gained the 3D family
    assert any(k.endswith("|v5p-3d") for k in golden), \
        "golden grid has no v5p-3d cells"


def test_serving_cells_present_and_additive():
    """The golden grid gained the PR-6 serving cells (workload chat_2k on
    every golden cluster) without moving a single train/decode cell: the
    pre-pipeline frozen baselines above still pin those, and this test
    pins the serving family's existence and shape."""
    with open(_regen.GOLDEN_PATH) as f:
        golden = json.load(f)
    serve_keys = [k for k in golden if "|chat_2k|" in k]
    want = {f"{a}|chat_2k|{c}" for a in _regen.GOLDEN_SERVE_ARCHS
            for c in _regen.GOLDEN_CLUSTERS}
    assert set(serve_keys) == want
    assert any(golden[k]["feasible"] for k in serve_keys)


def test_sweep_grid_matches_golden():
    with open(_regen.GOLDEN_PATH) as f:
        golden = json.load(f)
    got = _regen.compute_cells()
    assert len(golden) >= 60
    assert set(got) == set(golden), (
        "grid keys drifted — regenerate the golden file if intentional")
    drift = []
    for key, want in golden.items():
        cell = got[key]
        if cell["plan"] != want["plan"]:
            drift.append(f"{key}: plan {want['plan']} -> {cell['plan']}")
        if not math.isclose(cell["step_time_s"], want["step_time_s"],
                            rel_tol=1e-9):
            drift.append(f"{key}: step {want['step_time_s']:.6g}s -> "
                         f"{cell['step_time_s']:.6g}s")
        if not math.isclose(cell["hbm_est_bytes"], want["hbm_est_bytes"],
                            rel_tol=1e-9):
            drift.append(f"{key}: hbm {want['hbm_est_bytes']:.6g} -> "
                         f"{cell['hbm_est_bytes']:.6g}")
        if cell["feasible"] != want["feasible"]:
            drift.append(f"{key}: feasible {want['feasible']} -> "
                         f"{cell['feasible']}")
    assert not drift, (
        "cost-model drift vs tests/golden/sweep_golden.json "
        "(PYTHONPATH=src python tests/golden/regen_sweep_golden.py "
        "if intentional):\n  " + "\n  ".join(drift))
