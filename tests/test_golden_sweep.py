"""Golden regression: the sweep grid's winning plans and costs must match
the checked-in tests/golden/sweep_golden.json cell for cell.

Cost-model drift (op formulas, collective models, HBM accounting, plan
enumeration, search behavior) shows up here as a readable diff at review
time.  If the change is intentional, regenerate and commit:

  PYTHONPATH=src python tests/golden/regen_sweep_golden.py
"""
import importlib.util
import json
import math
import os

_GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden")

# Import the regen script itself, so the grid definition and the cell
# builder can never drift between the test and the regeneration path.
_spec = importlib.util.spec_from_file_location(
    "regen_sweep_golden", os.path.join(_GOLDEN_DIR, "regen_sweep_golden.py"))
_regen = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_regen)


def test_sweep_grid_matches_golden():
    with open(_regen.GOLDEN_PATH) as f:
        golden = json.load(f)
    got = _regen.compute_cells()
    assert len(golden) >= 24
    assert set(got) == set(golden), (
        "grid keys drifted — regenerate the golden file if intentional")
    drift = []
    for key, want in golden.items():
        cell = got[key]
        if cell["plan"] != want["plan"]:
            drift.append(f"{key}: plan {want['plan']} -> {cell['plan']}")
        if not math.isclose(cell["step_time_s"], want["step_time_s"],
                            rel_tol=1e-9):
            drift.append(f"{key}: step {want['step_time_s']:.6g}s -> "
                         f"{cell['step_time_s']:.6g}s")
        if not math.isclose(cell["hbm_est_bytes"], want["hbm_est_bytes"],
                            rel_tol=1e-9):
            drift.append(f"{key}: hbm {want['hbm_est_bytes']:.6g} -> "
                         f"{cell['hbm_est_bytes']:.6g}")
        if cell["feasible"] != want["feasible"]:
            drift.append(f"{key}: feasible {want['feasible']} -> "
                         f"{cell['feasible']}")
    assert not drift, (
        "cost-model drift vs tests/golden/sweep_golden.json "
        "(PYTHONPATH=src python tests/golden/regen_sweep_golden.py "
        "if intentional):\n  " + "\n  ".join(drift))
