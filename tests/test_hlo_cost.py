"""Costing the generated plan: HLO collective parsing + cost_analysis
agreement with the analytical op library."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import hlo_cost
from repro.core.cluster import single_pod_config
from repro.core.linalg_ops import profile
from repro.core.symbols import TensorStat

HLO_SAMPLE = """
HloModule jit_step

%region_0 (a: f32[], b: f32[]) -> f32[] { ... }

ENTRY %main {
  %p0 = bf16[256,1024]{1,0} parameter(0)
  %mul = bf16[256,1024]{1,0} multiply(%p0, %p0)
  %all-gather = bf16[4096,1024]{1,0} all-gather(%mul), replica_groups=[16,16]<=[256], dimensions={0}
  %all-reduce = f32[1024]{0} all-reduce(%conv), channel_id=2, replica_groups=[2,128]<=[256], to_apply=%region_0
  %rs = bf16[16,1024]{1,0} reduce-scatter(%mul), channel_id=3, replica_groups={{0,1,2,3}}, dimensions={0}
  %a2a = bf16[256,1024]{1,0} all-to-all(%mul), replica_groups=[4,64]<=[256]
  %cp-start = bf16[256,1024]{1,0} collective-permute-start(%mul), source_target_pairs={{0,1}}
  %cp-done = bf16[256,1024]{1,0} collective-permute-done(%cp-start)
}
"""


def test_parse_collectives_kinds_and_bytes():
    colls = hlo_cost.parse_collectives(HLO_SAMPLE)
    kinds = sorted(c.kind for c in colls)
    assert kinds == ["all_gather", "all_reduce", "all_to_all",
                     "collective_permute", "reduce_scatter"]
    ag = next(c for c in colls if c.kind == "all_gather")
    assert ag.operand_bytes == 256 * 1024 * 2          # bf16 operand
    assert ag.result_bytes == 4096 * 1024 * 2
    assert ag.group_size == 16
    rs = next(c for c in colls if c.kind == "reduce_scatter")
    assert rs.group_size == 4                           # explicit groups
    # -done must not double count: exactly one collective_permute entry
    assert sum(c.kind == "collective_permute" for c in colls) == 1


def test_parse_ignores_non_collectives():
    assert hlo_cost.parse_collectives("%x = f32[2]{0} add(%a, %b)") == []


def test_compiled_matmul_flops_match_analytical():
    """cost_analysis FLOPs == the white-box matmul formula (both count
    mul+add as 2) — ties the two cost paths together."""
    m, k, n = 256, 512, 128
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    prof = profile("matmul", [TensorStat((m, k)), TensorStat((k, n))])
    assert float(ca["flops"]) == pytest.approx(prof.flops, rel=0.01)


def test_compiled_cost_roundtrip_and_roofline():
    x = jnp.zeros((512, 512), jnp.float32)
    compiled = jax.jit(lambda x: (x @ x).sum()).lower(x).compile()
    cost = hlo_cost.from_compiled("t", compiled, num_devices=1)
    blob = cost.to_json()
    cost2 = hlo_cost.CompiledCost.from_json(blob)
    assert cost2.flops_per_device == cost.flops_per_device
    r = cost.roofline(single_pod_config())
    assert set(r) >= {"compute_s", "memory_s", "collective_s", "dominant"}
    assert r["compute_s"] > 0 and r["memory_s"] > 0
    assert r["collective_s"] == 0.0


def test_time_breakdown_monotone_in_cluster_speed():
    x = jnp.zeros((512, 512), jnp.float32)
    compiled = jax.jit(lambda x: x @ x).lower(x).compile()
    cost = hlo_cost.from_compiled("t", compiled, num_devices=1)
    import dataclasses
    cc = single_pod_config()
    slow_chip = dataclasses.replace(cc.chip, peak_flops={
        k: v / 10 for k, v in cc.chip.peak_flops.items()})
    slow = dataclasses.replace(cc, chip=slow_chip)
    assert (cost.time_breakdown(slow).compute
            >= cost.time_breakdown(cc).compute)
