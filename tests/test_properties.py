"""Hypothesis property tests on the cost model's invariants."""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (Collective, Compute, ForBlock, GenericBlock, IfBlock,
                        IO, P2P, ParForBlock, PipelinedLoopBlock,
                        PlanCostCache, Program, WhileBlock, estimate,
                        single_chip_config, single_pod_config,
                        torus_3d_config)
from repro.core.linalg_ops import collective_cost, profile
from repro.core.symbols import MemState, TensorStat

CC = single_chip_config()
POD = single_pod_config()
# The 3D-torus mesh: programs whose collectives/shardings touch the third
# ("depth") axis must satisfy every invariant the 2D meshes do.
TORUS = torus_3d_config()

dims = st.integers(min_value=1, max_value=512).map(lambda x: x * 8)


@settings(max_examples=50, deadline=None)
@given(m=dims, k=dims, n=dims)
def test_matmul_flops_formula(m, k, n):
    prof = profile("matmul", [TensorStat((m, k)), TensorStat((k, n))])
    assert prof.flops == 2.0 * m * k * n
    assert prof.out.shape == (m, n)


@settings(max_examples=50, deadline=None)
@given(m=dims, n=dims)
def test_tsmm_always_half_of_matmul(m, n):
    t = profile("tsmm", [TensorStat((m, n))])
    mm = profile("matmul", [TensorStat((n, m)), TensorStat((m, n))])
    assert t.flops == 0.5 * mm.flops


@settings(max_examples=50, deadline=None)
@given(m=dims, n=dims, s=st.floats(min_value=0.01, max_value=1.0))
def test_cost_monotone_in_size_and_sparsity(m, n, s):
    """More cells or higher density never cost less."""
    small = TensorStat((m, n), sparsity=s)
    big = TensorStat((m * 2, n), sparsity=s)
    denser = TensorStat((m, n), sparsity=min(1.0, s * 2))

    def cost(stat):
        p = Program("t", blocks=[GenericBlock("b", [
            Compute("tsmm", ("X",), "A", exec_type="CP")])],
            inputs={"X": stat})
        return estimate(p, CC).total

    assert cost(big) >= cost(small)
    assert cost(denser) >= cost(small)


@settings(max_examples=50, deadline=None)
@given(payload=st.floats(min_value=1.0, max_value=1e9),
       n=st.integers(min_value=2, max_value=512))
def test_collective_formulas_positive_and_ordered(payload, n):
    bw, lat = 45e9, 1e-6
    ar = collective_cost("all_reduce", payload, n, bw, lat)
    rs = collective_cost("reduce_scatter", payload, n, bw, lat)
    ag = collective_cost("all_gather", payload, n, bw, lat)
    pm = collective_cost("permute", payload, n, bw, lat)
    assert ar > 0 and rs > 0 and ag > 0 and pm > 0
    # all_reduce == reduce_scatter + all_gather of the scattered shard
    ag_shard = collective_cost("all_gather", payload / n, n, bw, lat)
    assert math.isclose(ar, rs + ag_shard, rel_tol=1e-6)


@settings(max_examples=30, deadline=None)
@given(n_ops=st.integers(min_value=1, max_value=20))
def test_block_cost_is_sum_of_children(n_ops):
    x = TensorStat((256, 256))
    ops = [Compute("unary", ("X",), f"Y{i}", exec_type="CP")
           for i in range(n_ops)]
    p = Program("t", blocks=[GenericBlock("b", ops)], inputs={"X": x})
    costed = estimate(p, CC)
    child_sum = sum(c.cost.total for c in costed.root.children[0].children)
    assert math.isclose(costed.total, child_sum, rel_tol=1e-9)


# ------------------------------------------------------------------------
# Randomized programs with loops/branches: memoized costing must be
# bit-exact vs. the uncached estimator, including warm replays.
# ------------------------------------------------------------------------

_INPUT_NAMES = ("X0", "X1", "X2")

_tensor_stats = st.builds(
    TensorStat,
    shape=st.tuples(st.integers(1, 64).map(lambda x: x * 4),
                    st.integers(1, 64).map(lambda x: x * 4)),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    sparsity=st.floats(min_value=0.2, max_value=1.0),
    state=st.sampled_from([MemState.HBM, MemState.HOST, MemState.DISK]),
    shards=st.sampled_from([1, 2, 4]),
)

_out_names = st.sampled_from([f"V{i}" for i in range(6)])


# Axis tuples include the 3D torus's "depth": on 2D meshes the unknown
# axis has size 1 (degenerate, charged nothing), on TORUS it carries real
# shards and wire — the same program must stay exact on both.
_shard_axes = st.sampled_from([("data",), ("model",), ("depth",),
                               ("data", "depth"), ("model", "depth")])


def _leaf_nodes():
    x = st.sampled_from(_INPUT_NAMES)
    return st.one_of(
        st.builds(Compute, opcode=st.just("unary"),
                  inputs=x.map(lambda n: (n,)), output=_out_names,
                  exec_type=st.just("CP")),
        st.builds(Compute, opcode=st.just("tsmm"),
                  inputs=x.map(lambda n: (n,)), output=_out_names,
                  exec_type=st.just("DIST"),
                  shard_axes=_shard_axes),
        st.builds(Collective,
                  kind=st.sampled_from(["all_reduce", "all_gather",
                                        "reduce_scatter"]),
                  var=x, axes=_shard_axes),
        # pipeline stage-boundary transfers: one link of the axis fabric,
        # no-ops on size-1 axes (2D meshes see a degenerate "depth")
        st.builds(P2P, var=x,
                  axis=st.sampled_from(["data", "model", "depth"])),
        st.builds(IO, op=st.just("read"), var=x,
                  src=st.sampled_from([MemState.HOST, MemState.DISK]),
                  dst=st.just(MemState.HBM)),
    )


def _block_nodes(children):
    body = st.lists(children, min_size=1, max_size=3)
    return st.one_of(
        st.builds(GenericBlock, label=st.just("g"), children=body),
        st.builds(ForBlock, label=st.just("f"),
                  iterations=st.one_of(st.none(), st.integers(1, 4)),
                  body=body),
        st.builds(WhileBlock, label=st.just("w"), body=body,
                  predicate=st.lists(_leaf_nodes(), max_size=1),
                  iterations=st.one_of(st.none(), st.integers(1, 3))),
        st.builds(ParForBlock, label=st.just("p"),
                  iterations=st.integers(1, 6),
                  parallelism=st.integers(1, 4), body=body),
        st.builds(IfBlock, label=st.just("i"),
                  branches=st.lists(body, min_size=1, max_size=3),
                  weights=st.none()),
    )


def _pp_block(children):
    """Software-pipelined loops — kept OUT of :func:`_block_nodes`: the
    wire-floor and roofline-bound properties below hold for *sequential*
    control flow (a pipeline hides time across stages without discounting
    the work totals; its floor uses the /S schedule bound instead, see
    ``cluster_floor_time``).  The cache-exactness properties mix them in
    via ``_pp_programs``."""
    return st.builds(PipelinedLoopBlock, label=st.just("pp"),
                     microbatches=st.integers(1, 8),
                     stages=st.lists(st.lists(children, min_size=1,
                                              max_size=3),
                                     min_size=1, max_size=3))


_programs = st.builds(
    Program, name=st.just("rnd"),
    blocks=st.lists(_block_nodes(st.one_of(_leaf_nodes(),
                                           _block_nodes(_leaf_nodes()))),
                    min_size=1, max_size=4),
    inputs=st.fixed_dictionaries(
        {name: _tensor_stats for name in _INPUT_NAMES}),
)

# Same shape, but pipelined loops allowed anywhere a block may appear
# (including nested inside sequential blocks) — the memoization layer must
# stay bit-exact on them like on everything else.
_pp_programs = st.builds(
    Program, name=st.just("rnd-pp"),
    blocks=st.lists(st.one_of(
        _block_nodes(st.one_of(_leaf_nodes(), _pp_block(_leaf_nodes()))),
        _pp_block(st.one_of(_leaf_nodes(), _block_nodes(_leaf_nodes())))),
        min_size=1, max_size=4),
    inputs=st.fixed_dictionaries(
        {name: _tensor_stats for name in _INPUT_NAMES}),
)


@settings(max_examples=40, deadline=None)
@given(prog=_pp_programs)
def test_cached_costing_bit_exact_on_random_programs(prog):
    for cc in (POD, TORUS):
        base = estimate(prog, cc)
        cache = PlanCostCache()
        cold = estimate(prog, cc, cache=cache)      # record path
        warm = estimate(prog, cc, cache=cache)      # replay path
        for got in (cold, warm):
            assert math.isclose(base.total, got.total,
                                rel_tol=1e-9, abs_tol=1e-12)
            for field in ("io", "compute", "collective", "latency"):
                assert math.isclose(getattr(base.breakdown, field),
                                    getattr(got.breakdown, field),
                                    rel_tol=1e-9, abs_tol=1e-12), field
            assert math.isclose(base.peak_hbm_per_device,
                                got.peak_hbm_per_device,
                                rel_tol=1e-9, abs_tol=1e-3)


@settings(max_examples=15, deadline=None)
@given(progs=st.lists(_pp_programs, min_size=2, max_size=4))
def test_shared_cache_never_leaks_across_random_programs(progs):
    """One cache serving many random programs must stay exact for each."""
    cache = PlanCostCache()
    bases = [estimate(p, POD) for p in progs]
    for p, base in zip(progs, bases):
        got = estimate(p, POD, cache=cache)
        assert math.isclose(base.total, got.total,
                            rel_tol=1e-9, abs_tol=1e-12)
    # and again, fully warm, in reverse order
    for p, base in zip(reversed(progs), reversed(bases)):
        got = estimate(p, POD, cache=cache)
        assert math.isclose(base.total, got.total,
                            rel_tol=1e-9, abs_tol=1e-12)


@settings(max_examples=40, deadline=None)
@given(prog=_programs)
def test_collective_floor_bounds_costed_collective_time(prog):
    """The collective-floor term the resource optimizer builds from
    ProgramTotals — wire volume over the effective link bandwidth at the
    mesh's *best* per-axis link count, discounted by the overlap
    fraction — must never exceed the collective time the estimator
    actually charged.  This is the property that makes the tightened
    cluster floors sound (docs/COST_MODEL.md §floors), including on
    3D-torus meshes where wrapped rings double per-axis bandwidth."""
    for cc in (POD, POD.with_overlap(0.7), TORUS, TORUS.with_overlap(0.7)):
        costed = estimate(prog, cc)
        t = costed.totals
        floor = (t.ici_bytes / (cc.ici_bw_eff * cc.max_ici_links)
                 + t.dcn_bytes / cc.dcn_bw_eff) \
            * (1.0 - cc.overlap_fraction)
        assert floor <= costed.breakdown.collective * (1 + 1e-12)


@settings(max_examples=40, deadline=None)
@given(prog=_programs)
def test_totals_roofline_bounds_costed_compute_time(prog):
    """Aggregate compute/memory rooflines priced from ProgramTotals at the
    most generous rates lower-bound the charged compute time — the other
    half of the cluster-floor soundness argument."""
    from repro.core.costmodel import VPU_FRACTION
    cc = POD
    costed = estimate(prog, cc)
    t = costed.totals
    util = max(cc.matmul_util, cc.small_matmul_util)
    t_flops = sum(f / (cc.chip.peak(dt) * util)
                  for dt, f in t.mxu_flops.items())
    t_flops += t.vpu_flops / (cc.chip.peak("float32") * VPU_FRACTION)
    t_mem = t.hbm_bytes / cc.hbm_bw_eff
    assert max(t_flops, t_mem) <= costed.breakdown.compute * (1 + 1e-12)


@settings(max_examples=40, deadline=None)
@given(prog=_pp_programs)
def test_totals_replay_bit_exact_on_random_programs(prog):
    """Cached replay must reproduce ProgramTotals exactly — the floor
    would silently drift otherwise.  One shared cache serves the 2D and
    3D meshes back to back: the cluster fingerprint (which embeds the
    torus link counts) must keep their entries apart."""
    cache = PlanCostCache()
    for cc in (POD, TORUS):
        base = estimate(prog, cc).totals
        cold = estimate(prog, cc, cache=cache).totals
        warm = estimate(prog, cc, cache=cache).totals
        assert base.as_tuple() == cold.as_tuple() == warm.as_tuple()


# ------------------------------------------------------------------------
# Pipelined loops: schedule bounds and sequential degeneracy on random
# stage bodies (the ISSUE-5 acceptance properties).
# ------------------------------------------------------------------------

_stage_bodies = st.lists(st.lists(_leaf_nodes(), min_size=1, max_size=3),
                         min_size=1, max_size=4)


@settings(max_examples=40, deadline=None)
@given(stages=_stage_bodies, m=st.integers(1, 8))
def test_pipelined_cost_bounded_by_steady_state_and_sequential(stages, m):
    """For ANY stage bodies: seq/S <= pipelined <= sequential.  The upper
    bound is the unpipelined M x body loop (pipelining only overlaps);
    the lower bound is perfect S-way overlap of every iteration (the
    steady state can never beat the slowest stage, and S stages can hide
    at most S-1 of each other's time).  Work totals must be *exactly*
    sequential — overlap hides time, never work."""
    s = len(stages)
    inputs = {n: TensorStat((256, 256), state=MemState.HOST)
              for n in _INPUT_NAMES}
    pipe = Program("p", blocks=[PipelinedLoopBlock("pp", m, stages=stages)],
                   inputs=dict(inputs))
    seq = Program("s", blocks=[ForBlock("pp", m,
                                        body=[n for b in stages for n in b])],
                  inputs=dict(inputs))
    for cc in (POD, TORUS):
        cp, cs = estimate(pipe, cc), estimate(seq, cc)
        assert cp.total <= cs.total * (1 + 1e-12)
        assert cp.total >= cs.total / s * (1 - 1e-12)
        assert cp.totals.as_tuple() == cs.totals.as_tuple()


@settings(max_examples=40, deadline=None)
@given(body=st.lists(_leaf_nodes(), min_size=1, max_size=4),
       m=st.integers(1, 8))
def test_pipelined_s1_degenerates_to_for_loop_bit_exact(body, m):
    """An S=1 'pipeline' IS the sequential microbatch loop: identical
    total, breakdown, peak HBM and totals, bit for bit."""
    inputs = {n: TensorStat((256, 256), state=MemState.HOST)
              for n in _INPUT_NAMES}
    pipe = Program("p", blocks=[PipelinedLoopBlock("mb", m, stages=[body])],
                   inputs=dict(inputs))
    seq = Program("s", blocks=[ForBlock("mb", m, body=list(body))],
                  inputs=dict(inputs))
    for cc in (POD, TORUS):
        cp, cs = estimate(pipe, cc), estimate(seq, cc)
        assert cp.total == cs.total
        for field in ("io", "compute", "collective", "latency"):
            assert getattr(cp.breakdown, field) == getattr(cs.breakdown,
                                                           field), field
        assert cp.peak_hbm_per_device == cs.peak_hbm_per_device
        assert cp.totals.as_tuple() == cs.totals.as_tuple()


@settings(max_examples=30, deadline=None)
@given(stages=_stage_bodies, m=st.integers(1, 8))
def test_pipelined_cache_replay_bit_exact(stages, m):
    """Cold record and warm replay of pipelined programs through a shared
    cache reproduce the uncached walk exactly (cost, totals, peak HBM)."""
    inputs = {n: TensorStat((256, 256), state=MemState.HOST)
              for n in _INPUT_NAMES}
    prog = Program("p", blocks=[PipelinedLoopBlock("pp", m, stages=stages)],
                   inputs=inputs)
    cache = PlanCostCache()
    for cc in (POD, TORUS):
        base = estimate(prog, cc)
        cold = estimate(prog, cc, cache=cache)
        warm = estimate(prog, cc, cache=cache)
        for got in (cold, warm):
            assert got.total == base.total
            assert got.totals.as_tuple() == base.totals.as_tuple()
            assert got.peak_hbm_per_device == base.peak_hbm_per_device


# ------------------------------------------------------------------------
# Batched (lane-vector) costing: one walk per structure signature must be
# bit-exact vs. the scalar walk on every knob-grid member — every
# CostBreakdown field, every ProgramTotals field, peak HBM (the ISSUE-8
# acceptance properties).  The programs under test are the real enumerated
# LM step plans: layer loops, remat re-emission, microbatch loops, grad
# branches, and (on the multi-pod mesh) software-pipelined stages.
# ------------------------------------------------------------------------

import dataclasses as _dc

from repro.configs import SHAPES, get_config
from repro.core.cluster import multi_pod_config
from test_batched_costing import _assert_lane_exact, _knob_groups

MULTI = multi_pod_config()
_MESHES = {"pod": POD, "torus": TORUS, "multi": MULTI}


@settings(max_examples=12, deadline=None)
@given(arch_id=st.sampled_from(["qwen1.5-0.5b", "pixtral-12b",
                                "phi3.5-moe-42b-a6.6b", "mamba2-1.3b"]),
       mesh=st.sampled_from(["pod", "torus", "multi"]),
       mult=st.sampled_from([1, 2, 4]),
       data=st.data())
def test_batched_walk_bit_exact_on_enumerated_knob_grids(arch_id, mesh,
                                                         mult, data):
    """For a random (arch, mesh, batch) cell, a random structure group of
    the enumerated plan space costs bit-exact through one lane-vector
    walk — loops, remat branches, microbatch wraps and (multi-pod)
    pipelined stages included."""
    arch = get_config(arch_id)
    shape = _dc.replace(SHAPES["train_4k"],
                        global_batch=SHAPES["train_4k"].global_batch * mult)
    cc = _MESHES[mesh]
    groups = _knob_groups(arch, shape, cc)
    assert groups, "knob grid unexpectedly degenerate"
    members = data.draw(st.sampled_from(groups))
    _assert_lane_exact(arch, shape, members, cc)


# (The deterministic, no-sampling counterparts — every structure group of
# whole cells, input-order decision equality — live in
# tests/test_batched_costing.py so they run even without hypothesis.)


@settings(max_examples=30, deadline=None)
@given(sh=st.sampled_from([1, 2, 4, 8, 16]))
def test_sharded_collective_payload_scales(sh):
    x = TensorStat((4096, 4096), "float32", shards=sh)
    p = Program("t", blocks=[GenericBlock("b", [
        Collective("all_reduce", "X", ("data",))])], inputs={"X": x})
    t = estimate(p, POD).total
    x1 = TensorStat((4096, 4096), "float32", shards=1)
    p1 = Program("t", blocks=[GenericBlock("b", [
        Collective("all_reduce", "X", ("data",))])], inputs={"X": x1})
    t1 = estimate(p1, POD).total
    assert t <= t1 + 1e-12
