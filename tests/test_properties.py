"""Hypothesis property tests on the cost model's invariants."""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (Collective, Compute, GenericBlock, Program, estimate,
                        single_chip_config, single_pod_config)
from repro.core.linalg_ops import collective_cost, profile
from repro.core.symbols import MemState, TensorStat

CC = single_chip_config()
POD = single_pod_config()

dims = st.integers(min_value=1, max_value=512).map(lambda x: x * 8)


@settings(max_examples=50, deadline=None)
@given(m=dims, k=dims, n=dims)
def test_matmul_flops_formula(m, k, n):
    prof = profile("matmul", [TensorStat((m, k)), TensorStat((k, n))])
    assert prof.flops == 2.0 * m * k * n
    assert prof.out.shape == (m, n)


@settings(max_examples=50, deadline=None)
@given(m=dims, n=dims)
def test_tsmm_always_half_of_matmul(m, n):
    t = profile("tsmm", [TensorStat((m, n))])
    mm = profile("matmul", [TensorStat((n, m)), TensorStat((m, n))])
    assert t.flops == 0.5 * mm.flops


@settings(max_examples=50, deadline=None)
@given(m=dims, n=dims, s=st.floats(min_value=0.01, max_value=1.0))
def test_cost_monotone_in_size_and_sparsity(m, n, s):
    """More cells or higher density never cost less."""
    small = TensorStat((m, n), sparsity=s)
    big = TensorStat((m * 2, n), sparsity=s)
    denser = TensorStat((m, n), sparsity=min(1.0, s * 2))

    def cost(stat):
        p = Program("t", blocks=[GenericBlock("b", [
            Compute("tsmm", ("X",), "A", exec_type="CP")])],
            inputs={"X": stat})
        return estimate(p, CC).total

    assert cost(big) >= cost(small)
    assert cost(denser) >= cost(small)


@settings(max_examples=50, deadline=None)
@given(payload=st.floats(min_value=1.0, max_value=1e9),
       n=st.integers(min_value=2, max_value=512))
def test_collective_formulas_positive_and_ordered(payload, n):
    bw, lat = 45e9, 1e-6
    ar = collective_cost("all_reduce", payload, n, bw, lat)
    rs = collective_cost("reduce_scatter", payload, n, bw, lat)
    ag = collective_cost("all_gather", payload, n, bw, lat)
    pm = collective_cost("permute", payload, n, bw, lat)
    assert ar > 0 and rs > 0 and ag > 0 and pm > 0
    # all_reduce == reduce_scatter + all_gather of the scattered shard
    ag_shard = collective_cost("all_gather", payload / n, n, bw, lat)
    assert math.isclose(ar, rs + ag_shard, rel_tol=1e-6)


@settings(max_examples=30, deadline=None)
@given(n_ops=st.integers(min_value=1, max_value=20))
def test_block_cost_is_sum_of_children(n_ops):
    x = TensorStat((256, 256))
    ops = [Compute("unary", ("X",), f"Y{i}", exec_type="CP")
           for i in range(n_ops)]
    p = Program("t", blocks=[GenericBlock("b", ops)], inputs={"X": x})
    costed = estimate(p, CC)
    child_sum = sum(c.cost.total for c in costed.root.children[0].children)
    assert math.isclose(costed.total, child_sum, rel_tol=1e-9)


@settings(max_examples=30, deadline=None)
@given(sh=st.sampled_from([1, 2, 4, 8, 16]))
def test_sharded_collective_payload_scales(sh):
    x = TensorStat((4096, 4096), "float32", shards=sh)
    p = Program("t", blocks=[GenericBlock("b", [
        Collective("all_reduce", "X", ("data",))])], inputs={"X": x})
    t = estimate(p, POD).total
    x1 = TensorStat((4096, 4096), "float32", shards=1)
    p1 = Program("t", blocks=[GenericBlock("b", [
        Collective("all_reduce", "X", ("data",))])], inputs={"X": x1})
    t1 = estimate(p1, POD).total
    assert t <= t1 + 1e-12
