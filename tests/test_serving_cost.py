"""Costed serving schedules: degeneracy pins, floor soundness, cache
round-trips, and the typed Workload/Objective API surface.

The two load-bearing degeneracies:
  * a zero-arrival, batch-1, page-free serving schedule's decode step
    must cost BIT-EXACT what the plain decode shape costs today (serving
    is an extension, not a reprice), and
  * a disaggregated pool pair at zero arrival and zero handoff bytes has
    latency metrics bit-exact equal to the colocated pool's — the only
    things disaggregation adds are the handoff and the overlap algebra.
"""
import dataclasses
import math

import pytest

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.core.cluster import ClusterConfig, single_pod_config
from repro.core.costmodel import PlanCostCache, estimate
from repro.core.planner import (OVERLAP_FRACTION, ShardingPlan,
                                build_step_program, estimate_hbm,
                                resident_components)
from repro.core.resource import optimize_resources
from repro.core.serving import (SLOT_OPTS, ServingCandidate, ServingFloor,
                                cost_serving_schedule, decode_shape,
                                disaggregate, enumerate_serving_clusters,
                                kv_handoff_bytes, optimize_serving,
                                prefill_shape, serve_cell, serving_floor)
from repro.core.sweep import CLUSTERS, SweepEngine
from repro.core.workload import (SERVE_WORKLOADS, LengthDistribution,
                                 Objective, ServeWorkload, TrainWorkload,
                                 as_objective)

ARCH = get_config("qwen1.5-0.5b")
POD = single_pod_config()
CHAT = SERVE_WORKLOADS["chat_2k"]


def _wl(**kw) -> ServeWorkload:
    base = dict(name="wl", arrival_rate=4.0,
                prompt_len=LengthDistribution(1024, 2048),
                output_len=LengthDistribution(128, 256),
                ttft_slo=1.0, kv_page_tokens=128)
    base.update(kw)
    return ServeWorkload(**base)


def _colocated(cc: ClusterConfig, cid: str = "pod") -> ServingCandidate:
    return ServingCandidate(cid, cc, cc)


# ---------------------------------------------------------------- degeneracy


def test_zero_arrival_batch1_decode_step_bit_exact():
    """A B=1, zero-arrival, page-free schedule's decode step is the plain
    decode step: same program walk, same estimator, same float."""
    wl = _wl(arrival_rate=0.0, kv_page_tokens=0)
    plan = ShardingPlan()
    sched = cost_serving_schedule(ARCH, wl, _colocated(POD), 1, plan, plan)
    ctx = int(round(wl.prompt_len.mean + wl.output_len.mean))
    plain = ShapeConfig("plain", ctx, 1, "decode")
    cc_p = POD.with_overlap(OVERLAP_FRACTION if plan.overlap else 0.0)
    direct = estimate(build_step_program(ARCH, plain, plan, cc_p), cc_p)
    assert sched.decode_step_time == direct.total
    assert sched.arrival_rate == 0.0
    assert sched.utilization == 0.0 and sched.stable
    assert sched.handoff_time == 0.0


def test_page_free_serving_shape_prices_like_plain_decode():
    """kv_page_tokens=0 leaves resident_components untouched; a paged
    workload adds a nonnegative kv_paging term and nothing else."""
    plan = ShardingPlan()
    free = decode_shape(_wl(kv_page_tokens=0), 8)
    plain = ShapeConfig("p", free.seq_len, 8, "decode")
    a = resident_components(ARCH, free, plan, POD)
    b = resident_components(ARCH, plain, plan, POD)
    assert a == b
    paged = decode_shape(_wl(kv_page_tokens=128), 8)
    c = resident_components(ARCH, paged, plan, POD)
    assert c.pop("kv_paging") > 0
    assert c == b
    assert estimate_hbm(ARCH, paged, plan, POD) > \
        estimate_hbm(ARCH, free, plan, POD)


def test_paging_term_page_rounds_the_tail():
    """The paging term reserves whole pages out to the p99 context."""
    plan = ShardingPlan()
    wl = _wl(kv_page_tokens=4096,
             prompt_len=LengthDistribution(1024, 5000),
             output_len=LengthDistribution(128, 200))
    sh = decode_shape(wl, 4)
    comp = resident_components(ARCH, sh, plan, POD)
    pages = math.ceil(max(sh.max_context, sh.seq_len) / 4096) * 4096
    plain_at = resident_components(
        ARCH, ShapeConfig("x", pages, 4, "decode"), plan, POD)["kv_cache"]
    assert comp["kv_cache"] + comp["kv_paging"] == pytest.approx(plain_at)


def test_ssm_family_has_no_paging_pressure():
    """SSM decode state is sequence-independent: pages add nothing."""
    mamba = get_config("mamba2-1.3b")
    comp = resident_components(mamba, decode_shape(_wl(), 8),
                               ShardingPlan(), POD)
    assert comp.get("kv_paging", 0.0) == 0.0


def test_disaggregated_zero_handoff_latency_equals_colocated():
    """Zero arrival + zero handoff bytes: the disaggregated pool pair's
    latency metrics are bit-exact the colocated ones (queue waits vanish,
    the handoff is free, and both pools run the colocated pool's config)."""
    wl = _wl(arrival_rate=0.0)
    plan = ShardingPlan()
    cache = PlanCostCache()
    colo = cost_serving_schedule(ARCH, wl, _colocated(POD), 8, plan, plan,
                                 cache=cache)
    pair = ServingCandidate("pair", POD, POD, handoff_cc=CLUSTERS["2pod"],
                            handoff_axis="pod")
    assert not pair.colocated
    disagg = cost_serving_schedule(ARCH, wl, pair, 8, plan, plan,
                                   cache=cache, handoff_bytes=0.0)
    assert disagg.handoff_time == 0.0
    assert disagg.decode_step_time == colo.decode_step_time
    assert disagg.prefill_time == colo.prefill_time
    assert disagg.prefill_time_p99 == colo.prefill_time_p99
    assert disagg.ttft_p99 == colo.ttft_p99
    assert disagg.ttft_mean == colo.ttft_mean
    # ... but the schedule algebra differs exactly as documented:
    assert colo.window_time == colo.prefill_window_time + \
        colo.decode_window_time
    assert disagg.window_time == max(disagg.prefill_window_time,
                                     disagg.decode_window_time)


def test_real_handoff_is_positive_and_priced_on_one_link():
    """With real KV bytes the handoff costs > 0 and scales ~linearly in
    the payload (one-link path: no ring phases to amortize)."""
    wl = _wl(arrival_rate=0.1)
    plan = ShardingPlan()
    pair = disaggregate(CLUSTERS["v5p-dcn"])
    assert pair is not None and not pair.colocated
    s1 = cost_serving_schedule(ARCH, wl, pair, 8, plan, plan,
                               handoff_bytes=1e9)
    s2 = cost_serving_schedule(ARCH, wl, pair, 8, plan, plan,
                               handoff_bytes=2e9)
    s3 = cost_serving_schedule(ARCH, wl, pair, 8, plan, plan,
                               handoff_bytes=3e9)
    assert s1.handoff_time > 0
    # affine in the payload: one wire transfer plus a fixed message
    # latency — equal marginal cost per extra byte, no ring phases
    assert (s2.handoff_time - s1.handoff_time) == pytest.approx(
        s3.handoff_time - s2.handoff_time, rel=1e-6)
    assert s2.handoff_time < 2 * s1.handoff_time   # latency term amortizes
    assert kv_handoff_bytes(ARCH, 2048) > 0


def test_cross_chip_pool_pairs():
    """Heterogeneous disaggregation: cross-chip pairs join single-slice
    pools of different chip families, price per-pool dollars, and carry a
    DCN-classed handoff mesh."""
    grid = enumerate_serving_clusters(chips=["tpu_v6e", "tpu_v5e"],
                                      pod_counts=(1,), mesh_variants=1,
                                      cross_chip=True)
    pairs = [c for c in grid if not c.colocated]
    assert pairs, "cross_chip=True emitted no pool pairs"
    for pair in pairs:
        assert pair.prefill_cc.chip.name != pair.decode_cc.chip.name
        assert pair.handoff_cc.mesh_axes[0] == "pod"
        assert pair.handoff_cc.mesh_shape[0] == 2
        assert pair.handoff_cc.link_class("pod") == "dcn"
        assert pair.dollars_per_hour == pytest.approx(
            pair.prefill_cc.num_chips
            * pair.prefill_cc.chip.cost_per_chip_hour
            + pair.decode_cc.num_chips
            * pair.decode_cc.chip.cost_per_chip_hour)
    # without the flag the grid stays homogeneous, as before
    assert all(c.prefill_cc.chip.name == c.decode_cc.chip.name
               for c in enumerate_serving_clusters(
                   chips=["tpu_v6e", "tpu_v5e"], pod_counts=(1,)))


def test_disaggregated_pair_wins_heterogeneous_fleet():
    """The scenario the resource_opt.serving benchmark gates: under
    prefill-heavy traffic at an arrival rate above every cheaper colocated
    candidate's capacity, the cheapest *stable* fleet is a v6e prefill pod
    feeding a v5e decode pod — and the beam finds the exhaustive winner."""
    arch = get_config("gemma3-12b")
    wl = ServeWorkload("hetero", arrival_rate=450.0,
                       prompt_len=LengthDistribution(8192, 16384),
                       output_len=LengthDistribution(64, 128),
                       ttft_slo=0.5, kv_page_tokens=128)
    grid = enumerate_serving_clusters(chips=["tpu_v6e", "tpu_v5e"],
                                      pod_counts=(1, 2), mesh_variants=1,
                                      cross_chip=True)
    cache = PlanCostCache()
    dec = optimize_serving(arch, wl, grid, objective="tokens_per_dollar",
                           cache=cache)
    ex = optimize_serving(arch, wl, grid, objective="tokens_per_dollar",
                          search="exhaustive", cache=cache)
    best = dec[0]
    assert best.feasible and not best.cand.colocated
    assert best.cand.prefill_cc.chip.name == "tpu_v6e"
    assert best.cand.decode_cc.chip.name == "tpu_v5e"
    assert (best.cluster_id, best.slots) == (ex[0].cluster_id, ex[0].slots)
    # every colocated candidate cheaper than the pair is saturated
    for d in dec:
        if d.cand.colocated and d.dollars_per_hour < best.dollars_per_hour:
            assert not d.feasible


# ----------------------------------------------------------- traffic math


def test_metrics_monotone_in_arrival_rate():
    """Utilization and p99 TTFT never improve with more traffic — the
    property the floor-pruning argument leans on."""
    plan = ShardingPlan()
    cache = PlanCostCache()
    prev_util, prev_ttft = -1.0, -1.0
    for lam in (0.0, 2.0, 8.0, 32.0, 128.0, 512.0):
        s = cost_serving_schedule(ARCH, _wl(arrival_rate=lam),
                                  _colocated(POD), 32, plan, plan,
                                  cache=cache)
        assert s.utilization >= prev_util
        assert s.ttft_p99 >= prev_ttft
        prev_util, prev_ttft = s.utilization, s.ttft_p99
    # saturation: unstable schedules deliver nothing and price at infinity
    sat = cost_serving_schedule(ARCH, _wl(arrival_rate=1e9),
                                _colocated(POD), 8, plan, plan, cache=cache)
    assert not sat.stable
    assert sat.tokens_per_second == 0.0
    assert sat.ttft_p99 == float("inf")
    assert sat.cost_per_1k_tokens == float("inf")


def test_serving_floor_is_sound():
    """Every floor metric lower-bounds its costed value, for colocated and
    disaggregated candidates, across slot counts."""
    wl = _wl(arrival_rate=16.0)
    cands = [_colocated(POD), disaggregate(CLUSTERS["v5p-dcn"])]
    for cand in cands:
        for slots in SLOT_OPTS:
            fl = serving_floor(ARCH, wl, cand, slots)
            # the floor must hold for EVERY plan, not just the default
            for plan in (ShardingPlan(),
                         ShardingPlan(name="tp", batch_axes=(),
                                      tp_axes=("model",))):
                s = cost_serving_schedule(ARCH, wl, cand, slots, plan, plan)
                assert fl.decode_step <= s.decode_step_time + 1e-12
                assert fl.prefill_step <= s.prefill_time + 1e-12
                assert fl.prefill_step_p99 <= s.prefill_time_p99 + 1e-12
                assert fl.utilization <= s.utilization + 1e-12
                assert fl.ttft_p99 <= s.ttft_p99 + 1e-12


# ------------------------------------------------------------ cache replay


def test_schedule_costs_replay_bit_exact_through_shared_cache():
    """Costing the same schedule through a fresh cache and through a cache
    warmed by other schedules returns identical floats (the PlanCostCache
    replay guarantee extended to serving programs)."""
    plan = ShardingPlan()
    warm = PlanCostCache()
    # warm the cache with neighbours
    for slots in (8, 32):
        cost_serving_schedule(ARCH, CHAT, _colocated(POD), slots, plan, plan,
                              cache=warm)
    a = cost_serving_schedule(ARCH, CHAT, _colocated(POD), 32, plan, plan,
                              cache=warm)
    b = cost_serving_schedule(ARCH, CHAT, _colocated(POD), 32, plan, plan,
                              cache=PlanCostCache())
    assert a == b
    assert warm.stats().hits > 0


# ------------------------------------------------------- typed API surface


def test_objective_aliases_and_validation():
    assert as_objective("time").kind == "step_time"
    assert as_objective("ttft").kind == "ttft_p99"
    assert Objective.step_slo(0.05).slo == 0.05
    assert as_objective(Objective.job_cost(500)).steps_per_job == 500
    # typed fields win over loose kwargs
    assert as_objective(Objective.step_slo(0.1), slo=0.2).slo == 0.1
    with pytest.raises(ValueError):
        Objective("nonsense")
    with pytest.raises(ValueError):
        Objective("slo", slo=-1.0)
    with pytest.raises(ValueError):
        LengthDistribution(100, 50)       # p99 below mean
    with pytest.raises(ValueError):
        ServeWorkload("w", -1.0, LengthDistribution(10),
                      LengthDistribution(10))


def test_typed_train_workload_matches_string_call():
    shape = SHAPES["decode_32k"]
    clusters = [CLUSTERS["pod"], CLUSTERS["v5p-pod"]]
    legacy = optimize_resources(ARCH, shape, clusters, objective="step_time")
    typed = optimize_resources(ARCH, TrainWorkload(shape), clusters,
                               objective=Objective.step_time())
    assert [d.cluster_id for d in typed] == [d.cluster_id for d in legacy]
    assert typed[0].time == legacy[0].time
    # TrainWorkload carries its own job length into job_cost
    j = optimize_resources(ARCH, TrainWorkload(shape, steps_per_job=77),
                           clusters, objective="job_cost")
    assert j[0].steps_per_job == 77


def test_serving_objective_on_plain_shape_raises_helpfully():
    with pytest.raises(ValueError, match="ServeWorkload"):
        optimize_resources(ARCH, SHAPES["decode_32k"], objective="ttft_p99")
    with pytest.raises(ValueError, match="slo"):
        optimize_serving(ARCH, _wl(ttft_slo=None), [_colocated(POD)],
                         objective="ttft_p99")


def test_optimize_resources_dispatches_serve_workload():
    cands = [_colocated(POD, "pod"), disaggregate(CLUSTERS["v5p-dcn"])]
    via_resources = optimize_resources(ARCH, CHAT, cands,
                                       objective="tokens_per_dollar")
    direct = optimize_serving(ARCH, CHAT, cands,
                              objective="tokens_per_dollar")
    assert [(d.cluster_id, d.slots) for d in via_resources] == \
        [(d.cluster_id, d.slots) for d in direct]
    best = via_resources[0]
    assert best.feasible and best.decision is not None
    assert best.schedule.stable


# --------------------------------------------------- co-search correctness


def test_beam_equals_exhaustive_on_serving_grid():
    """The acceptance property, in-tree at small scale: pruned beam search
    returns the exhaustive (candidate x slots x plan) scan's winner, with
    at least one disaggregated candidate in the grid."""
    cands = ([_colocated(CLUSTERS["pod"], "pod"),
              _colocated(CLUSTERS["v5p-pod"], "v5p-pod"),
              _colocated(CLUSTERS["v5p-dcn"], "v5p-dcn")]
             + [disaggregate(CLUSTERS["v5p-dcn"])])
    for objective in ("tokens_per_dollar", "ttft_p99"):
        beam = optimize_serving(ARCH, CHAT, cands, objective=objective)
        full = optimize_serving(ARCH, CHAT, cands, objective=objective,
                                search="exhaustive")
        assert (beam[0].cluster_id, beam[0].slots) == \
            (full[0].cluster_id, full[0].slots)
        assert beam[0].decode_decision.plan == full[0].decode_decision.plan


def test_sweep_accepts_serving_workloads():
    eng = SweepEngine()
    cells = eng.sweep(["qwen1.5-0.5b"], ["chat_2k"], ["pod"])
    assert len(cells) == 1
    c = cells[0]
    assert c.key == "qwen1.5-0.5b|chat_2k|pod"
    assert not c.skipped and c.decision is not None
    assert c.stats.costed > 0
    # the workload object spells the same cell
    c2 = eng.cost_cell("qwen1.5-0.5b", CHAT, "pod")
    assert c2.decision.time == c.decision.time
    with pytest.raises(KeyError):
        eng.cost_cell("qwen1.5-0.5b", "no_such_shape", "pod")


def test_serve_cell_feasibility_requires_stability():
    """A cluster that fits in HBM but cannot carry the traffic reports an
    infeasible serving cell."""
    tiny = ClusterConfig(mesh_shape=(2,), mesh_axes=("data",))
    hot = _wl(arrival_rate=1e9)
    pd, _ = serve_cell(ARCH, hot, tiny, cluster_id="tiny")
    assert not pd.feasible
    calm, _ = serve_cell(ARCH, _wl(arrival_rate=0.001), POD,
                         cluster_id="pod")
    assert calm.feasible


def test_elastic_replan_serving_workload():
    from repro.runtime.elastic import replan
    ep = replan(ARCH, CHAT, old_cc=POD, available_chips=128,
                objective=Objective.ttft_p99())
    assert ep.cc.num_chips == 128
    assert ep.decision is not None


# ------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _PROP_CACHE = PlanCostCache()      # shared: examples replay each other

    @settings(max_examples=20, deadline=None)
    @given(lam=st.floats(0.0, 64.0),
           slots=st.sampled_from(SLOT_OPTS),
           prompt=st.integers(64, 4096),
           out=st.integers(16, 512),
           disagg=st.booleans())
    def test_property_schedule_costs_round_trip_plan_cost_cache(
            lam, slots, prompt, out, disagg):
        """Any schedule costed through the shared cache equals the same
        schedule costed on a cold cache — sub-plan replay is bit-exact
        across arbitrary (traffic x slots x pool) neighbours."""
        wl = _wl(arrival_rate=lam,
                 prompt_len=LengthDistribution(prompt, 2 * prompt),
                 output_len=LengthDistribution(out, 2 * out))
        cand = disaggregate(CLUSTERS["v5p-dcn"]) if disagg \
            else _colocated(POD)
        plan = ShardingPlan()
        warm = cost_serving_schedule(ARCH, wl, cand, slots, plan, plan,
                                     cache=_PROP_CACHE)
        cold = cost_serving_schedule(ARCH, wl, cand, slots, plan, plan,
                                     cache=PlanCostCache())
        assert warm == cold
        fl = serving_floor(ARCH, wl, cand, slots)
        assert fl.decode_step <= warm.decode_step_time + 1e-12
        assert fl.utilization <= warm.utilization + 1e-12
else:
    def test_property_schedule_costs_round_trip_plan_cost_cache():
        pytest.skip("property test needs hypothesis "
                    "(pip install -r requirements-dev.txt)")
