"""The estimate↔reality loop (repro.core.calibration + the online
recalibrator):

* the least-squares fitter recovers known synthetic factors, clamps
  super-peak coefficients, and rejects polluted samples;
* ``calibration=None`` (and the empty profile) change nothing — golden
  cells stay byte-identical;
* ``PlanCostCache`` keeps calibrated and uncalibrated costs apart
  (cluster-fingerprint separation);
* the drift band triggers a refit when the EWMA leaves it, and the
  drift-triggered ``elastic.replan`` fires exactly when the re-costed
  plan ranking flips — not merely when the ratio moves;
* profile (de)serialization round-trips (hypothesis).
"""
import json
import math

import pytest

from repro.configs import SHAPES, get_config
from repro.core.calibration import (SHAPE_CLASSES, CalibrationProfile,
                                    CalibrationSample, fit_profile,
                                    shape_class)
from repro.core.cluster import single_pod_config
from repro.core.costmodel import PlanCostCache, estimate
from repro.core.planner import (OVERLAP_FRACTION, build_step_program,
                                choose_plan, enumerate_plans)
from repro.core.sweep import SweepEngine
from repro.runtime.train_loop import OnlineRecalibrator


# ---------------------------------------------------------------------------
# The fitter
# ---------------------------------------------------------------------------

def test_fitter_recovers_synthetic_factors():
    """Generated (features, measured) pairs with known achieved fractions:
    the fit must invert them near-exactly (the system is well-posed)."""
    true = {"mxu:bfloat16:large": 0.55, "hbm": 0.80, "ici": 0.40}
    mixes = [
        {"mxu:bfloat16:large": 1.0, "hbm": 0.2, "ici": 0.05},
        {"mxu:bfloat16:large": 0.1, "hbm": 1.5, "ici": 0.30},
        {"mxu:bfloat16:large": 0.5, "hbm": 0.1, "ici": 1.20},
        {"mxu:bfloat16:large": 2.0, "hbm": 0.6, "ici": 0.70},
    ]
    samples = [
        CalibrationSample(
            features=m,
            measured_seconds=0.01 + sum(x / true[k] for k, x in m.items()),
            fixed_seconds=0.01, label=f"synth{i}")
        for i, m in enumerate(mixes)
    ]
    fit = fit_profile(samples, chip_name="synth")
    assert fit.n_samples == 4 and fit.n_rejected == 0
    for k, f in true.items():
        assert math.isclose(fit.factors[k], f, rel_tol=1e-6), k
    assert math.isclose(fit.profile.mxu["bfloat16"]["large"], 0.55,
                        rel_tol=1e-6)
    assert math.isclose(fit.profile.hbm_fraction, 0.80, rel_tol=1e-6)
    assert math.isclose(fit.profile.ici_fraction, 0.40, rel_tol=1e-6)
    assert fit.profile.dcn_fraction is None     # no feature mass -> absent
    assert fit.residual < 1e-6


def test_fitter_clamps_factors_into_bounds():
    # measured faster than ideal-at-peak: clamp to max_factor (a profile
    # must never promise super-peak rates — floor soundness)
    fast = [CalibrationSample(features={"hbm": 1.0}, measured_seconds=0.5)]
    assert fit_profile(fast).factors["hbm"] == 1.0
    # absurdly slow: clamp to min_factor
    slow = [CalibrationSample(features={"hbm": 1.0}, measured_seconds=1000.0)]
    assert fit_profile(slow).factors["hbm"] == pytest.approx(0.02)


def test_fitter_rejects_polluted_and_degenerate_samples():
    clean = CalibrationSample(features={"hbm": 1.0}, measured_seconds=2.0)
    polluted = CalibrationSample(features={"hbm": 1.0}, measured_seconds=9.0,
                                 polluted=True)
    negative = CalibrationSample(features={"hbm": 1.0}, measured_seconds=0.1,
                                 fixed_seconds=0.2)   # y <= 0
    empty = CalibrationSample(features={}, measured_seconds=1.0)
    fit = fit_profile([clean, polluted, negative, empty])
    assert fit.n_samples == 1 and fit.n_rejected == 3
    assert fit.factors["hbm"] == pytest.approx(0.5)
    # nothing usable at all -> identity profile, not a crash
    empty_fit = fit_profile([polluted, empty])
    assert empty_fit.profile.is_empty() and empty_fit.n_samples == 0


def test_shape_class_breakpoints_match_util_ramp():
    assert shape_class(1e7) == "small"
    assert shape_class(1e8) == "small"
    assert shape_class(1e9) == "medium"
    assert shape_class(1e10) == "large"
    assert shape_class(1e12) == "large"


# ---------------------------------------------------------------------------
# Bit-identity of the uncalibrated path
# ---------------------------------------------------------------------------

# Frozen step times from tests/test_golden_sweep.py's pre-pipeline
# baseline: cells built with the default ``calibration=None`` must
# reproduce them to the last bit — the calibration threading may not
# perturb the uncalibrated walk in any way.
FROZEN_CELLS = {
    "mamba2-1.3b|train_4k|pod": 0.2971891713601879,
    "qwen1.5-0.5b|decode_32k|v6e-pod": 0.0016120126856368562,
}


def test_calibration_none_keeps_golden_cells_byte_identical():
    engine = SweepEngine(search="beam")
    cells = engine.sweep(("mamba2-1.3b", "qwen1.5-0.5b"),
                         ("train_4k", "decode_32k"), ("pod", "v6e-pod"))
    got = {c.key: c.decision.time for c in cells}
    for key, frozen in FROZEN_CELLS.items():
        assert got[key] == frozen, key     # exact, not approx


def test_empty_profile_is_exact_identity():
    """An all-``None`` profile attached to the config changes nothing:
    every consultation falls back to the hand-set constants."""
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    cc = single_pod_config()
    cc_id = cc.with_calibration(CalibrationProfile(chip_name="x"))
    plan = enumerate_plans(arch, shape, cc)[0]
    for occ, occ_id in ((cc, cc_id),
                        (cc.with_overlap(OVERLAP_FRACTION),
                         cc_id.with_overlap(OVERLAP_FRACTION))):
        a = estimate(build_step_program(arch, shape, plan, occ), occ)
        b = estimate(build_step_program(arch, shape, plan, occ_id), occ_id)
        assert a.total == b.total          # bit-identical
        assert a.breakdown.collective == b.breakdown.collective


def test_calibrated_factors_slow_the_estimate():
    """Factors strictly below the hand-set constants can only add time."""
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    cc = single_pod_config()
    slow = CalibrationProfile(
        chip_name=cc.chip.name,
        mxu={dt: {c: 0.25 for c in SHAPE_CLASSES}
             for dt in ("bfloat16", "float32")},
        hbm_fraction=0.4, ici_fraction=0.3, dcn_fraction=0.3)
    plan = enumerate_plans(arch, shape, cc)[0]
    base = estimate(build_step_program(arch, shape, plan, cc), cc)
    cal = estimate(build_step_program(arch, shape, plan, cc),
                   cc.with_calibration(slow))
    assert cal.total > base.total


# ---------------------------------------------------------------------------
# Cache separation
# ---------------------------------------------------------------------------

def test_plan_cost_cache_never_mixes_calibrated_and_uncalibrated():
    arch, shape = get_config("qwen1.5-0.5b"), SHAPES["train_4k"]
    cc = single_pod_config()
    profile = CalibrationProfile(chip_name=cc.chip.name, hbm_fraction=0.3,
                                 mxu={"bfloat16": {"large": 0.3}})
    cc_cal = cc.with_calibration(profile)
    assert cc.fingerprint() != cc_cal.fingerprint()
    assert cc.fingerprint()[-1] is None
    assert cc_cal.fingerprint()[-1] == profile.fingerprint()

    cache = PlanCostCache()
    plan = enumerate_plans(arch, shape, cc)[0]
    prog = build_step_program(arch, shape, plan, cc)
    first = estimate(prog, cc, cache=cache).total
    calibrated = estimate(prog, cc_cal, cache=cache).total
    again = estimate(prog, cc, cache=cache).total
    assert calibrated > first              # the slow profile took effect
    assert again == first                  # cache did not cross-serve


# ---------------------------------------------------------------------------
# The online loop: drift band -> refit -> replan iff the ranking flips
# ---------------------------------------------------------------------------

def _flip_candidates(arch, shape, cc):
    """The verified swapped pair on mamba2-1.3b x train_4k x single pod:
    under a profile fitted from plan a's drifted (x4) step times, a's
    re-costed time overtakes b's, flipping the ranking."""
    plans = {p.describe(): p for p in enumerate_plans(arch, shape, cc)}
    a = plans["dp-pure[batch=dataxmodel,remat=selective]"]
    b = plans["dp-pure[batch=dataxmodel,remat=full,gdtype=bfloat16]"]
    return a, b


def test_in_band_measurements_never_trigger():
    arch, shape = get_config("mamba2-1.3b"), SHAPES["train_4k"]
    cc = single_pod_config()
    rec = OnlineRecalibrator(arch, shape, cc)
    for step in range(20):
        assert rec.observe(rec.estimated * 1.05, step=step) is None
    assert rec.events == [] and rec.cc.calibration is None


def test_uniform_drift_refits_without_replan():
    """A single-candidate family can never flip: drift must refit the
    profile (the ratio left the band) but NOT fire elastic.replan."""
    arch, shape = get_config("mamba2-1.3b"), SHAPES["train_4k"]
    cc = single_pod_config()
    a, _ = _flip_candidates(arch, shape, cc)
    rec = OnlineRecalibrator(arch, shape, cc, candidates=[a])
    est0 = rec.estimated
    measured = est0 * 3.0                  # the drifted reality, fixed
    events = []
    for step in range(200):
        e = rec.observe(measured, step=step)
        if e is not None:
            events.append(e)
    assert events                           # drift tripped the band
    for e in events:
        assert not e.replanned and e.elastic is None
        assert not e.profile.is_empty()
    assert rec.plan == a
    assert rec.cc.calibration is not None   # estimates now calibrated
    # each refit pulls the calibrated estimate toward the measurement
    # (the linearized fit can't exactly match the max-roofline walk, so
    # convergence may take more than one refit — but it must improve)
    assert abs(rec.estimated - measured) < abs(est0 - measured)


def test_drift_triggers_replan_exactly_when_ranking_flips():
    """End-to-end: perturb measured step times until the re-costed plan
    ranking flips — the event must carry the elastic.replan decision that
    switches the job onto the new winner, priced under the fitted
    profile."""
    arch, shape = get_config("mamba2-1.3b"), SHAPES["train_4k"]
    cc = single_pod_config()
    a, b = _flip_candidates(arch, shape, cc)
    cache = PlanCostCache()
    rec = OnlineRecalibrator(arch, shape, cc, candidates=[a, b], cache=cache)
    assert rec.plan == a                   # a wins uncalibrated

    # a dozen in-band steps: nothing happens
    for step in range(12):
        assert rec.observe(rec.estimated, step=step) is None

    # drift: measured step times settle at 4x the estimate.  The min-norm
    # fit loads the drift onto a's term mix, which penalizes a (selective
    # remat: more HBM-bound re-compute) harder than b — the ranking flips.
    event = None
    for step in range(12, 64):
        event = rec.observe(rec.estimated * 4.0, step=step)
        if event is not None:
            break
    assert event is not None and event.replanned
    assert event.ratio > 1.18              # the band's upper edge
    assert event.old_plan == a.describe()
    assert event.new_plan == b.describe()
    assert event.elastic is not None
    assert event.elastic.decision.plan == b
    assert event.elastic.cc.calibration is not None
    # the recalibrator adopted the new winner and its calibrated estimate
    assert rec.plan == b
    assert rec.cc.calibration is not None
    assert rec.estimated == pytest.approx(
        choose_plan(arch, shape,
                    rec.cc, top_k=1, candidates=[a, b])[0].time)


# ---------------------------------------------------------------------------
# (De)serialization round-trip
# ---------------------------------------------------------------------------

def _roundtrip(p: CalibrationProfile) -> None:
    assert CalibrationProfile.loads(p.dumps()) == p
    wire = json.loads(json.dumps(p.to_json()))      # a real wire trip
    assert CalibrationProfile.from_json(wire) == p
    assert CalibrationProfile.loads(p.dumps()).fingerprint() == p.fingerprint()


def test_profile_serialization_roundtrip_fixed_cases():
    _roundtrip(CalibrationProfile())
    _roundtrip(CalibrationProfile(chip_name="tpu_v5e",
                                  mxu={"bfloat16": {"large": 0.61}}))
    _roundtrip(CalibrationProfile(
        chip_name="cpu_host",
        mxu={"bfloat16": {"small": 0.21, "medium": 0.5, "large": 0.68},
             "float64": {"large": 1.0}},
        hbm_fraction=1 / 3, ici_fraction=0.55, dcn_fraction=0.625,
        overlap_ici=0.45, overlap_dcn=0.2))


# The generative version runs where hypothesis is installed (CI's
# requirements-dev tier); the fixed cases above keep local coverage.
try:
    from hypothesis import given, settings, strategies as st

    _frac = st.one_of(st.none(), st.floats(min_value=0.02, max_value=1.0,
                                           allow_nan=False))
    _mxu = st.dictionaries(
        st.sampled_from(["bfloat16", "float32", "float64", "int8"]),
        st.dictionaries(st.sampled_from(list(SHAPE_CLASSES)),
                        st.floats(min_value=0.02, max_value=1.0,
                                  allow_nan=False), max_size=3),
        max_size=3)

    @settings(max_examples=60, deadline=None)
    @given(mxu=_mxu, hbm=_frac, ici=_frac, dcn=_frac, oi=_frac, od=_frac)
    def test_profile_serialization_roundtrip(mxu, hbm, ici, dcn, oi, od):
        _roundtrip(CalibrationProfile(
            chip_name="chip", mxu=mxu, hbm_fraction=hbm, ici_fraction=ici,
            dcn_fraction=dcn, overlap_ici=oi, overlap_dcn=od))
except ImportError:      # pragma: no cover - exercised on bare containers
    @pytest.mark.skip(reason="property round-trip needs hypothesis "
                      "(pip install -r requirements-dev.txt)")
    def test_profile_serialization_roundtrip():
        pass
