"""Serving co-search gates: the (cluster x plan x schedule) beam search
must return the exhaustive winner, and prefill/decode disaggregation must
actually win somewhere on the grid.

Rows:
  * ``resource_opt.serving.<workload>|<objective>`` — winner identity
    (pool layout, slot count, per-pool plans) for the beam co-search and
    winner-match vs. the exhaustive (cluster x slots x plan) scan.
  * ``resource_opt.serving`` — the gate: every cell's beam winner matches
    exhaustive, at least one cell's winner is a *disaggregated*
    prefill/decode pool pair, and the beam costs >=3x fewer plan
    evaluations than the exhaustive space.

The disaggregation cell is a heterogeneous fleet question: gemma3-12b
under prefill-heavy traffic (8k-token prompts, 64-token outputs) at an
arrival rate sized so every colocated candidate cheaper than the pair is
unstable (rho >= 1).  Within one chip family every phase scales ~linearly
with chips, so a same-chip split never beats its colocated parent — but
prefill is compute-bound (v6e: best FLOPs/$) while decode streams KV
(v5e: best HBM-BW/$, yet hopeless at prefill: the 12B weights don't fit
one chip, forcing collective-bound plans), and pods come in discrete
sizes.  The cheapest stable fleet is a v6e prefill pod feeding a v5e
decode pod across the DCN KV handoff — the "+pd"/cross-pool candidates
:func:`repro.core.serving.enumerate_serving_clusters` emits.

Any gate failure prints FAIL/MISMATCH in the derived column; CI greps for
both.
"""
from __future__ import annotations

import time
from typing import List

from repro.configs import get_config
from repro.core.costmodel import PlanCostCache
from repro.core.resource import ResourceSearchStats
from repro.core.serving import enumerate_serving_clusters, optimize_serving
from repro.core.workload import (LengthDistribution, SERVE_WORKLOADS,
                                 ServeWorkload)

MIN_EVALS_RATIO = 3.0

# The heterogeneous-fleet workload (see module docstring): arrival rate
# 450 req/s sits between the v6e colocated pod's capacity (~390/s: its
# window serializes prefill into the decode budget) and the v6e>v5e
# pair's (~560/s: the pools run concurrently, stability is the max of
# per-pool utilizations, and the v5e pool only ever decodes).
HETERO_WL = ServeWorkload(
    "hetero_prefill_heavy", arrival_rate=450.0,
    prompt_len=LengthDistribution(8192, 16384),
    output_len=LengthDistribution(64, 128),
    ttft_slo=0.5, kv_page_tokens=128)

OBJECTIVES = ("tokens_per_dollar", "ttft_p99")


def _winner_id(d) -> str:
    """The full winner identity the beam must reproduce: pool layout x
    slot count x per-pool plans."""
    pf = d.prefill_decision.plan.describe() if d.prefill_decision else "-"
    return (f"{d.cluster_id}@B{d.slots}"
            f"+{d.decode_decision.plan.describe()}/{pf}")


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    arch = get_config("gemma3-12b")
    hetero_grid = enumerate_serving_clusters(
        chips=["tpu_v6e", "tpu_v5e"], pod_counts=(1, 2), mesh_variants=1,
        cross_chip=True)
    cells = [(HETERO_WL, hetero_grid, "hetero")]
    if not quick:
        # A standard-workload cell on the homogeneous v5p grid (its "+pd"
        # same-chip splits included — they should *lose* here).
        v5p_grid = enumerate_serving_clusters(chips=["tpu_v5p"],
                                              pod_counts=(1, 2))
        cells.append((SERVE_WORKLOADS["chat_2k"], v5p_grid, "v5p"))

    all_match = True
    disagg_wins = 0
    total_evals = total_space = 0
    ex_cache = PlanCostCache()
    for wl, grid, tag in cells:
        cache = PlanCostCache()
        for objective in OBJECTIVES:
            stats = ResourceSearchStats()
            t0 = time.perf_counter()
            dec = optimize_serving(arch, wl, grid, objective=objective,
                                   cache=cache, stats=stats)
            us = (time.perf_counter() - t0) * 1e6
            ex = optimize_serving(arch, wl, grid, objective=objective,
                                  search="exhaustive", cache=ex_cache)
            match = _winner_id(dec[0]) == _winner_id(ex[0])
            all_match &= match
            disagg_wins += not dec[0].cand.colocated
            total_evals += stats.plan_evals
            total_space += stats.exhaustive_plan_space
            rows.append(
                f"resource_opt.serving.{wl.name}|{objective},{us:.0f},"
                f"win={_winner_id(dec[0])};"
                f"ttft_p99={dec[0].ttft_p99 * 1e3:.1f}ms;"
                f"$1k={dec[0].cost_per_1k_tokens:.4f};"
                f"evals={stats.plan_evals}/{stats.exhaustive_plan_space};"
                f"{'MATCH' if match else 'MISMATCH'}")
    ratio = total_space / max(total_evals, 1)
    gate = all_match and disagg_wins > 0 and ratio >= MIN_EVALS_RATIO
    rows.append(
        f"resource_opt.serving,0,cells={len(cells) * len(OBJECTIVES)};"
        f"disagg_wins={disagg_wins};"
        f"evals={total_evals}/{total_space}({ratio:.1f}x);"
        f"claim={MIN_EVALS_RATIO:.0f}x;"
        f"{'MATCH' if all_match else 'MISMATCH'};"
        f"{'PASS' if gate else 'FAIL'}")
    return rows
