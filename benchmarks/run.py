"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_scenarios     — Table 1 / §2 plan generation across scales
  * bench_plan_costing  — Figures 4 & 5 costed plans
  * bench_accuracy      — §3.4 "within 2x of actual execution time"
  * bench_costing_speed — §2 "<0.5 ms to generate+cost a plan"
  * bench_roofline      — (beyond paper) roofline terms per dry-run cell
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_accuracy, bench_costing_speed,
                            bench_plan_costing, bench_roofline,
                            bench_scenarios)
    mods = [
        ("scenarios", bench_scenarios),
        ("plan_costing", bench_plan_costing),
        ("accuracy", bench_accuracy),
        ("costing_speed", bench_costing_speed),
        ("roofline", bench_roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in mods:
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception:
            failures += 1
            print(f"{name},0,EXCEPTION", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
