"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * bench_scenarios     — Table 1 / §2 LinReg plan generation across scales,
                          then the LM scenario sweep: one
                          ``sweep.<arch>|<shape>|<mesh>`` row per grid cell
                          with ``best=<plan>;T=<ms>;hbm=<GB>;feas=<bool>;
                          costed=<n>;pruned=<n>;cache=<hits>/<lookups>``,
                          ranked fastest-first, plus a ``sweep.cache``
                          summary row for the shared sub-plan cache
  * bench_plan_costing  — Figures 4 & 5 costed plans
  * bench_accuracy      — §3.4 "within 2x of actual execution time"
  * bench_costing_speed — §2 "<0.5 ms to generate+cost a plan", plus the
                          plan-search gates: ``candidate_set`` (cached
                          engine must be >=5x the uncached path on an
                          enumerated candidate set, bit-exact),
                          ``candidate_throughput`` (the lane-vector batched
                          engine must be >=10x the uncached scalar walk on
                          an expanded knob grid, bit-exact, same winner)
                          and ``beam_matches_exhaustive`` per config
  * bench_resource_opt  — the cluster/plan co-search gates: the resource
                          optimizer must return the exhaustive
                          (cluster x plan) winner (``MATCH`` per cell) with
                          >=3x fewer plan evaluations and a minimum shared
                          cache hit rate (``resource_opt.cache,...,PASS``),
                          plus the topology (``resource_opt.torus3d``) and
                          pipeline-parallelism (``resource_opt.pipeline``:
                          a feasible pipelined winner on a DCN multi-slice
                          train cell, beam==exhaustive) gates
  * bench_serving       — the serving co-search gate
                          (``resource_opt.serving``): beam==exhaustive over
                          (cluster x slots x plan) serving schedules, >=3x
                          fewer evaluations, and at least one cell won by a
                          disaggregated prefill/decode pool pair
  * bench_parallel      — the parallel/persistent costing gates:
                          ``resource_opt.parallel`` (a jobs=4 sweep and
                          optimize_resources return byte-identical ranked
                          tables to serial; the >=2.5x wall-clock half of
                          the gate is enforced on >=4-core hosts) and
                          ``resource_opt.warmstart`` (a sweep seeded from
                          the persisted cache snapshot replays >=50% of
                          lookups and returns identical winners), plus the
                          informational ``parallel.affinity`` visit-order
                          row
  * bench_roofline      — (beyond paper) roofline terms per dry-run cell
  * bench_calibrate     — the estimate↔reality loop: harvests measured
                          runtimes (matmul/stream microbenches, the §3.4
                          LinReg cells, the two cheap jit smoke archs),
                          fits a CalibrationProfile, and gates on the
                          median |est/measured − 1| strictly improving
                          under the fitted profile
                          (``calib.drift,...,PASS``)

``--quick`` shrinks every module to tiny configs (CI smoke tier); any
module that raises prints an ``EXCEPTION`` row and the run exits non-zero.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

# Make `python benchmarks/run.py` work from anywhere: the repo root (for
# the benchmarks package) and src/ (for repro) both belong on sys.path.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tiny configs / fewer reps (CI benchmark smoke)")
    ap.add_argument("--only", default=None,
                    help="run a single module (e.g. costing_speed)")
    args = ap.parse_args()

    from benchmarks import (bench_accuracy, bench_calibrate,
                            bench_costing_speed, bench_fusion,
                            bench_parallel, bench_plan_costing,
                            bench_resource_opt, bench_roofline,
                            bench_scenarios, bench_serving)
    mods = [
        ("scenarios", bench_scenarios),
        ("plan_costing", bench_plan_costing),
        ("accuracy", bench_accuracy),
        ("costing_speed", bench_costing_speed),
        ("resource_opt", bench_resource_opt),
        ("serving", bench_serving),
        ("fusion", bench_fusion),
        ("parallel", bench_parallel),
        ("roofline", bench_roofline),
        ("calibrate", bench_calibrate),
    ]
    if args.only:
        mods = [(n, m) for n, m in mods if n == args.only]
        if not mods:
            sys.exit(f"unknown module {args.only!r}")
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in mods:
        try:
            for row in mod.run(quick=args.quick):
                print(row, flush=True)
        except Exception:
            failures += 1
            print(f"{name},0,EXCEPTION", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
