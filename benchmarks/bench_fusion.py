"""Fusion-as-a-plan-dimension gates (ISSUE 9).

Rows:
  * ``fusion.flip.<arch>|<shape>|<mesh>`` — the fusion="off" winner vs the
    fusion="search" winner on a grid cell: step times, HBM totals, and
    whether the knob flipped the winner (derived ``FLIP``/``same``).
  * ``fusion.search.<arch>|<shape>|<mesh>`` — beam and batched searches
    over the fusion-widened plan space vs the exhaustive scan (``MATCH``
    or ``MISMATCH`` on winner total + fusion setting).
  * ``fusion.hlo.<case>`` — the analytical fused-vs-materialized HBM
    ranking checked against the compiled plan (`hlo_cost.lower_and_cost`):
    the fused form is one jit; the materialized form forces the round trip
    with a jit boundary per op.  The compiled measure is each module's
    *boundary* traffic (``memory_analysis`` argument + output bytes — the
    jit boundary IS the materialization the profiles price; the CPU
    backend's ``bytes_accessed`` can't epilogue-fuse into library dots, so
    it is only gated non-strictly).  ``MATCH`` requires the compiled
    ranking to agree AND the compiled fused/unfused byte delta to equal
    the analytical delta within 5%.
  * ``resource_opt.fusion`` — the gate: >=1 winner flip on a memory-bound
    (decode) cell with strictly smaller HBM totals, beam == exhaustive ==
    batched over the widened space on every cell, and every hlo ranking
    agreement holds.  CI greps this row for ``PASS``.
"""
from __future__ import annotations

import time
from typing import List

from repro.configs import SHAPES, get_config
from repro.core.costmodel import PlanCostCache
from repro.core.linalg_ops import profile
from repro.core.planner import choose_plan
from repro.core.symbols import TensorStat
from repro.core.sweep import CLUSTERS

# Memory-bound serving cells (decode streams weights+KV; epilogue fusion
# trims the elementwise round trips) plus one train cell where the knob
# also pays — mamba2 decode is the control: emit_ssm has no separate
# elementwise tail to fuse, so its winner must stay fusion="off".
FLIP_CELLS = [
    ("qwen1.5-0.5b", "decode_32k", "pod"),
    ("gemma3-12b", "decode_32k", "pod"),
    ("gemma3-12b", "decode_32k", "v5p-pod"),
    ("qwen1.5-0.5b", "train_4k", "pod"),
]
DECODE_FLIP_REQUIRED = {("qwen1.5-0.5b", "decode_32k", "pod"),
                        ("gemma3-12b", "decode_32k", "pod"),
                        ("gemma3-12b", "decode_32k", "v5p-pod")}


def _flip_rows(quick: bool, cache: PlanCostCache):
    rows: List[str] = []
    decode_flip = False
    all_match = True
    cells = FLIP_CELLS[:2] if quick else FLIP_CELLS
    for arch_id, shape_id, cl in cells:
        arch, shape, cc = get_config(arch_id), SHAPES[shape_id], CLUSTERS[cl]
        t0 = time.perf_counter()
        off = choose_plan(arch, shape, cc, search="exhaustive",
                          cache=cache)[0]
        exh = choose_plan(arch, shape, cc, search="exhaustive",
                          fusion="search", cache=cache)[0]
        us = (time.perf_counter() - t0) * 1e6
        flipped = (exh.plan.fusion != "off"
                   and exh.cost.total < off.cost.total
                   and exh.cost.totals.hbm_bytes < off.cost.totals.hbm_bytes)
        if flipped and shape.mode != "train":
            decode_flip = True
        rows.append(
            f"fusion.flip.{arch_id}|{shape_id}|{cl},{us:.0f},"
            f"off_T={off.cost.total * 1e3:.4f}ms;"
            f"search_T={exh.cost.total * 1e3:.4f}ms;"
            f"fusion={exh.plan.fusion};"
            f"hbm_off={off.cost.totals.hbm_bytes:.4e};"
            f"hbm_search={exh.cost.totals.hbm_bytes:.4e};"
            f"{'FLIP' if flipped else 'same'}")
        # beam and batched must reproduce the exhaustive winner over the
        # fusion-widened space
        beam = choose_plan(arch, shape, cc, fusion="search", cache=cache)[0]
        bat = choose_plan(arch, shape, cc, search="batched",
                          fusion="search", cache=cache)[0]
        match = all(d.cost.total == exh.cost.total
                    and d.plan.fusion == exh.plan.fusion
                    for d in (beam, bat))
        all_match = all_match and match
        rows.append(
            f"fusion.search.{arch_id}|{shape_id}|{cl},0,"
            f"beam_T={beam.cost.total * 1e3:.4f}ms;"
            f"batched_T={bat.cost.total * 1e3:.4f}ms;"
            f"{'MATCH' if match else 'MISMATCH'}")
    return rows, decode_flip, all_match


# ---------------------------------------------------------------------------
# Compiled-plan agreement: jit boundaries force materialization
# ---------------------------------------------------------------------------
def _mesh1():
    import jax
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices("cpu")[:1]), ("data",))


def _hlo_cases(quick: bool):
    """(name, analytical fused/unfused byte totals, fused fn, split fns,
    example args) per smoke-arch case."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    qwen = get_config("qwen1.5-0.5b")
    mamba = get_config("mamba2-1.3b")
    m = 256 if quick else 2048
    cases = []

    def matmul_case(tag, d_in, d_out, act):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((m, d_in)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
        a = TensorStat((m, d_in), "float32")
        ws = TensorStat((d_in, d_out), "float32")
        fused_p = profile("matmul", [a, ws], epilogue=act)
        plain_p = profile("matmul", [a, ws])
        ew_p = profile(act, [plain_p.out])
        ana_fused = fused_p.read_bytes + fused_p.write_bytes
        ana_unf = (plain_p.read_bytes + plain_p.write_bytes
                   + ew_p.read_bytes + ew_p.write_bytes)
        activation = jax.nn.silu if act == "silu" else jax.nn.gelu
        fused = lambda a_, w_: activation(a_ @ w_)
        split = [lambda a_, w_: a_ @ w_, activation]
        return (tag, ana_fused, ana_unf, fused, split, (x, w))

    # qwen's gated-MLP up-projection (SiLU tail) and mamba's output
    # projection with the GELU tail stand-in for its gated elementwise mix
    cases.append(matmul_case(
        "qwen1.5-0.5b.mlp_silu", qwen.d_model,
        min(qwen.d_ff, 512) if quick else qwen.d_ff, "silu"))
    cases.append(matmul_case(
        "mamba2-1.3b.proj_gelu", min(mamba.d_model, 512) if quick else
        mamba.d_model, min(mamba.d_model, 512) if quick else mamba.d_model,
        "gelu"))

    # attention on qwen's geometry: one-jit vs per-op jit boundaries
    hq = 4 if quick else qwen.n_heads
    s = 128 if quick else 1024
    d = (qwen.d_model // qwen.n_heads)
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((hq, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((hq, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((hq, s, d)), jnp.float32)
    qs = [TensorStat((1, hq, s, d), "float32")] * 3
    f_p = profile("attention", list(qs), fused=True)
    m_p = profile("attention", list(qs), fused=False)
    fused_attn = lambda q_, k_, v_: jax.nn.softmax(
        q_ @ k_.transpose(0, 2, 1) / jnp.sqrt(d), axis=-1) @ v_
    split_attn = [
        lambda q_, k_, v_: q_ @ k_.transpose(0, 2, 1) / jnp.sqrt(d),
        lambda s_: jax.nn.softmax(s_, axis=-1),
        # bind q,k operands for the probe signature; av takes (probs, v)
    ]
    cases.append(("qwen1.5-0.5b.attention",
                  f_p.read_bytes + f_p.write_bytes,
                  m_p.read_bytes + m_p.write_bytes,
                  fused_attn, split_attn, (q, k, v)))
    return cases


def _hlo_rows(quick: bool):
    import jax
    from repro.core.hlo_cost import lower_and_cost

    rows: List[str] = []
    all_match = True
    mesh = _mesh1()

    def boundary(cost):
        return cost.argument_bytes + cost.output_bytes

    for tag, ana_fused, ana_unf, fused_fn, split_fns, args in _hlo_cases(quick):
        t0 = time.perf_counter()
        _, fused_cost = lower_and_cost(f"{tag}.fused", fused_fn, args, mesh)
        hlo_fused = boundary(fused_cost)
        acc_fused = fused_cost.bytes_per_device
        # chain the split stages, summing each compiled module's traffic
        hlo_unf = acc_unf = 0.0
        cur = args
        for i, fn in enumerate(split_fns):
            compiled, cost = lower_and_cost(f"{tag}.split{i}", fn, cur, mesh)
            hlo_unf += boundary(cost)
            acc_unf += cost.bytes_per_device
            cur = (compiled(*cur),)
        if tag.endswith("attention"):
            # the AV matmul closes the materialized chain: probs @ v
            av = lambda p_, v_: p_ @ v_
            _, cost = lower_and_cost(f"{tag}.split_av", av,
                                     (cur[0], args[2]), mesh)
            hlo_unf += boundary(cost)
            acc_unf += cost.bytes_per_device
        us = (time.perf_counter() - t0) * 1e6
        rank = ana_fused < ana_unf and hlo_fused < hlo_unf
        delta_agree = abs((ana_unf - ana_fused) - (hlo_unf - hlo_fused)) \
            <= 0.05 * (ana_unf - ana_fused)
        match = rank and delta_agree and acc_fused <= acc_unf
        all_match = all_match and match
        rows.append(
            f"fusion.hlo.{tag},{us:.0f},"
            f"ana_fused={ana_fused:.3e};ana_unfused={ana_unf:.3e};"
            f"hlo_fused={hlo_fused:.3e};hlo_unfused={hlo_unf:.3e};"
            f"{'MATCH' if match else 'MISMATCH'}")
    return rows, all_match


def run(quick: bool = False) -> List[str]:
    cache = PlanCostCache()
    rows, decode_flip, search_match = _flip_rows(quick, cache)
    hlo_rows, hlo_match = _hlo_rows(quick)
    rows.extend(hlo_rows)
    gate = decode_flip and search_match and hlo_match
    rows.append(
        f"resource_opt.fusion,0,"
        f"decode_flip={decode_flip};search_match={search_match};"
        f"hlo_match={hlo_match};{'PASS' if gate else 'FAIL'}")
    return rows
