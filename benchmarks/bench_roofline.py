"""Roofline table (assignment §Roofline) — reads the dry-run artifacts.

Per (arch x shape x mesh): the three roofline terms from the compiled
plan, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio,
and the fits-HBM verdict from memory_analysis.
"""
from __future__ import annotations

import glob
import json
import os
from typing import List

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")
HBM_BUDGET = 16e9 * 0.9


def load_artifacts(mesh=None, tag=""):
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "dryrun_*.json"))):
        with open(path) as f:
            d = json.load(f)
        if mesh and d.get("mesh") != mesh:
            continue
        if d.get("tag", "") != tag:
            continue
        rows.append(d)
    return rows


def describe(d) -> str:
    r = d["roofline"]
    ma = d["memory_analysis"]
    used = ma["peak_bytes"] or (ma["argument_bytes"] + ma["temp_bytes"]
                                + ma["output_bytes"])
    ufr = d.get("useful_flops_ratio")
    parts = [
        f"dom={r['dominant'].replace('_s', '')}",
        f"compute={r['compute_s']*1e3:.2f}ms",
        f"mem={r['memory_s']*1e3:.2f}ms",
        f"coll={r['collective_s']*1e3:.2f}ms",
        f"useful={ufr:.2f}" if ufr else "useful=n/a",
        f"hbm={used/1e9:.1f}GB",
        f"fits={used <= HBM_BUDGET}",
    ]
    return ";".join(parts)


def run(quick: bool = False) -> List[str]:
    rows = []
    for d in load_artifacts():
        cell = f"{d['arch']}|{d['shape']}|{d['mesh']}"
        if d["status"] == "skip":
            rows.append(f"roofline.{cell},0,SKIP;{d['why'][:60]}")
        elif d["status"] != "ok":
            rows.append(f"roofline.{cell},0,FAIL;{d.get('error', '')[:80]}")
        else:
            bound_us = d["roofline"]["roofline_bound_s"] * 1e6
            rows.append(f"roofline.{cell},{bound_us:.1f},{describe(d)}")
    return rows
