"""Resource-optimizer gates: the co-search must return the exhaustive
(cluster x plan) winner while evaluating a small fraction of the space.

Rows:
  * ``resource_opt.<arch>|<shape>|<objective>`` — the winning cluster+plan,
    the search cost (plan evaluations vs. the exhaustive space, gated at
    >=3x fewer) and winner-match vs. the exhaustive scan.
  * ``resource_opt.cache`` — shared sub-plan cache traffic across the whole
    grid, gated on a minimum hit rate (the co-search only stays cheap if
    candidates keep replaying each other's sub-plans).

Any gate failure prints FAIL/MISMATCH in the derived column; CI greps for
both.
"""
from __future__ import annotations

import time
from typing import List

from repro.configs import SHAPES, get_config
from repro.core.costmodel import PlanCostCache
from repro.core.resource import (ResourceSearchStats, enumerate_clusters,
                                 optimize_resources)

GRID_ARCHS = ("qwen1.5-0.5b", "gemma3-12b", "mamba2-1.3b")
GRID_SHAPES = ("train_4k", "decode_32k")
OBJECTIVES = (("step_time", None), ("cost", None), ("slo", 0.25))

MIN_EVALS_RATIO = 3.0
# quick mode runs a single-arch grid with less cross-candidate reuse; the
# full grid clears ~0.6 — gate with headroom for both
MIN_HIT_RATE = 0.4


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    archs = GRID_ARCHS[:1] if quick else GRID_ARCHS
    clusters = enumerate_clusters(pod_counts=(1, 2) if quick else (1, 2, 4))
    cache = PlanCostCache()
    ex_cache = PlanCostCache()
    total_evals = total_space = 0
    for arch_id in archs:
        arch = get_config(arch_id)
        for shape_id in GRID_SHAPES:
            shape = SHAPES[shape_id]
            for objective, slo in OBJECTIVES:
                stats = ResourceSearchStats()
                t0 = time.perf_counter()
                dec = optimize_resources(arch, shape, clusters,
                                         objective=objective, slo=slo,
                                         cache=cache, stats=stats)
                us = (time.perf_counter() - t0) * 1e6
                ex = optimize_resources(arch, shape, clusters,
                                        objective=objective, slo=slo,
                                        search="exhaustive", cache=ex_cache)
                match = (dec[0].cluster_id == ex[0].cluster_id
                         and dec[0].decision.plan == ex[0].decision.plan)
                total_evals += stats.plan_evals
                total_space += stats.exhaustive_plan_space
                rows.append(
                    f"resource_opt.{arch_id}|{shape_id}|{objective},{us:.0f},"
                    f"win={dec[0].cluster_id}+{dec[0].decision.plan.describe()};"
                    f"T={dec[0].time * 1e3:.2f}ms;$={dec[0].cost_per_step:.5f};"
                    f"evals={stats.plan_evals}/{stats.exhaustive_plan_space};"
                    f"{'MATCH' if match else 'MISMATCH'}")
    ratio = total_space / max(total_evals, 1)
    st = cache.stats()
    gate = (ratio >= MIN_EVALS_RATIO and st.hit_rate >= MIN_HIT_RATE)
    rows.append(
        f"resource_opt.cache,0,evals={total_evals}/{total_space};"
        f"ratio={ratio:.1f}x;claim={MIN_EVALS_RATIO:.0f}x;"
        f"hit_rate={st.hit_rate:.2f};min_hit_rate={MIN_HIT_RATE};"
        f"entries={st.entries};{'PASS' if gate else 'FAIL'}")
    return rows
