"""Resource-optimizer gates: the co-search must return the exhaustive
(cluster x plan) winner while evaluating a small fraction of the space.

Rows:
  * ``resource_opt.<arch>|<shape>|<objective>`` — the winning cluster+plan,
    the search cost (plan evaluations vs. the exhaustive space, gated at
    >=3x fewer) and winner-match vs. the exhaustive scan.  The objective
    grid includes ``job_cost`` ($/job with startup/restore/preemption
    amortized over steps_per_job).
  * ``resource_opt.decode_pruning`` — decode-shaped cells must prune
    strictly more clusters under the $-objective family than they did
    before job-level pricing (per-step $ is nearly flat across clusters
    for memory-bound decode, so the old per-step ``cost`` objective barely
    pruned; the baselines below are the PR-2 measurements of exactly
    those cells).
  * ``resource_opt.torus3d`` — the topology gate: on the v5p grid with
    its 3D-torus family included, the beam co-search must return the
    exhaustive winner for every (shape x objective) cell AND at least one
    3D cell must win somewhere, at >=3x fewer plan evaluations than the
    exhaustive scan of that 3D-inclusive grid.
  * ``resource_opt.pipeline`` — the pipeline gate: on the pipeline-
    inclusive v5p multi-slice grid, beam==exhaustive for the frontier-
    dense (qwen1.5-110b) train cell under every objective, at least one
    DCN multi-slice candidate's chosen plan must be a *feasible
    pipelined* plan (per-stage residency is what lets 110B dense train
    fit at all), and the co-search must hold >=3x fewer evaluations.
  * ``resource_opt.cache`` — shared sub-plan cache traffic across the whole
    grid, gated on a minimum hit rate (the co-search only stays cheap if
    candidates keep replaying each other's sub-plans).

Any gate failure prints FAIL/MISMATCH in the derived column; CI greps for
both.
"""
from __future__ import annotations

import time
from typing import List

from repro.configs import SHAPES, get_config
from repro.core.costmodel import PlanCostCache
from repro.core.resource import (ResourceSearchStats, enumerate_clusters,
                                 optimize_resources)

GRID_ARCHS = ("qwen1.5-0.5b", "gemma3-12b", "mamba2-1.3b")
GRID_SHAPES = ("train_4k", "decode_32k")
OBJECTIVES = (("step_time", None), ("cost", None), ("job_cost", None),
              ("slo", 0.25))

MIN_EVALS_RATIO = 3.0
# The tightened floors prune most clusters before any plan is costed, so
# far fewer warm replays happen at all (full grid ~0.40, quick ~0.43, down
# from ~0.6 when 3x more cells were costed).  The gate guards against the
# cache breaking (rate near zero), not against pruning getting better.
MIN_HIT_RATE = 0.3

# Clusters pruned on each decode cell by the PR-2 optimizer (per-step
# ``cost`` objective, compute/memory-only floors) — measured on the same
# grids this benchmark runs.  The job-cost objective must beat every one
# of these strictly (the decode-pruning gate).
PRE_JOB_COST_DECODE_PRUNED = {
    # (arch_id, quick): pruned clusters out of 13 (quick) / 20 (full)
    ("qwen1.5-0.5b", True): 4,
    ("qwen1.5-0.5b", False): 6,
    ("gemma3-12b", False): 14,
    ("mamba2-1.3b", False): 14,
}


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    archs = GRID_ARCHS[:1] if quick else GRID_ARCHS
    clusters = enumerate_clusters(pod_counts=(1, 2) if quick else (1, 2, 4))
    cache = PlanCostCache()
    ex_cache = PlanCostCache()
    total_evals = total_space = 0
    decode_pruned = {}                  # arch_id -> pruned under job_cost
    for arch_id in archs:
        arch = get_config(arch_id)
        for shape_id in GRID_SHAPES:
            shape = SHAPES[shape_id]
            for objective, slo in OBJECTIVES:
                stats = ResourceSearchStats()
                t0 = time.perf_counter()
                dec = optimize_resources(arch, shape, clusters,
                                         objective=objective, slo=slo,
                                         cache=cache, stats=stats)
                us = (time.perf_counter() - t0) * 1e6
                ex = optimize_resources(arch, shape, clusters,
                                        objective=objective, slo=slo,
                                        search="exhaustive", cache=ex_cache)
                match = (dec[0].cluster_id == ex[0].cluster_id
                         and dec[0].decision.plan == ex[0].decision.plan)
                total_evals += stats.plan_evals
                total_space += stats.exhaustive_plan_space
                if shape.mode == "decode" and objective == "job_cost":
                    decode_pruned[arch_id] = stats.clusters_pruned
                rows.append(
                    f"resource_opt.{arch_id}|{shape_id}|{objective},{us:.0f},"
                    f"win={dec[0].cluster_id}+{dec[0].decision.plan.describe()};"
                    f"T={dec[0].time * 1e3:.2f}ms;$={dec[0].cost_per_step:.5f};"
                    f"$job={dec[0].cost_per_job:.2f};"
                    f"evals={stats.plan_evals}/{stats.exhaustive_plan_space};"
                    f"{'MATCH' if match else 'MISMATCH'}")
    # --- topology gate: 3D-inclusive v5p grid, winner==exhaustive --------
    v5p_grid = enumerate_clusters(chips=["tpu_v5p"],
                                  pod_counts=(1, 2) if quick else (1, 2, 4))
    n_3d = sum(1 for c in v5p_grid if c.cid.endswith("-3d"))
    t3_stats = ResourceSearchStats()
    t3_cache = PlanCostCache()
    t3_match = True
    wins_3d = 0
    arch = get_config(archs[0])
    for shape_id in GRID_SHAPES:
        shape = SHAPES[shape_id]
        for objective in ("step_time", "cost", "job_cost"):
            dec = optimize_resources(arch, shape, v5p_grid,
                                     objective=objective,
                                     cache=t3_cache, stats=t3_stats)
            ex = optimize_resources(arch, shape, v5p_grid,
                                    objective=objective,
                                    search="exhaustive", cache=ex_cache)
            t3_match &= (dec[0].cluster_id == ex[0].cluster_id
                         and dec[0].decision.plan == ex[0].decision.plan)
            wins_3d += dec[0].cluster_id.endswith("-3d")
    t3_gate = (t3_match and n_3d >= 2 and wins_3d > 0
               and t3_stats.evals_ratio >= MIN_EVALS_RATIO)
    rows.append(
        f"resource_opt.torus3d,0,cells_3d={n_3d}/{len(v5p_grid)};"
        f"wins_3d={wins_3d}/6;"
        f"evals={t3_stats.plan_evals}/{t3_stats.exhaustive_plan_space}"
        f"({t3_stats.evals_ratio:.1f}x);claim={MIN_EVALS_RATIO:.0f}x;"
        f"{'MATCH' if t3_match else 'MISMATCH'};"
        f"{'PASS' if t3_gate else 'FAIL'}")

    # --- pipeline gate: pipeline-inclusive grid, frontier-dense train ----
    pp_grid = enumerate_clusters(chips=["tpu_v5p"], pod_counts=(1, 2, 4))
    pp_arch = get_config("qwen1.5-110b")
    pp_shape = SHAPES["train_4k"]
    pp_stats = ResourceSearchStats()
    pp_cache = PlanCostCache()
    pp_match = True
    pp_wins = 0
    for objective in ("step_time", "cost", "job_cost"):
        dec = optimize_resources(pp_arch, pp_shape, pp_grid,
                                 objective=objective,
                                 cache=pp_cache, stats=pp_stats)
        ex = optimize_resources(pp_arch, pp_shape, pp_grid,
                                objective=objective,
                                search="exhaustive", cache=ex_cache)
        pp_match &= (dec[0].cluster_id == ex[0].cluster_id
                     and dec[0].decision.plan == ex[0].decision.plan)
        if any(rd.decision is not None and rd.feasible
               and "-dcn" in rd.cluster_id
               and rd.decision.plan.pp_axes for rd in dec):
            pp_wins += 1
    pp_gate = (pp_match and pp_wins >= 3
               and pp_stats.evals_ratio >= MIN_EVALS_RATIO)
    rows.append(
        f"resource_opt.pipeline,0,clusters={len(pp_grid)};"
        f"pp_dcn_wins={pp_wins}/3;"
        f"evals={pp_stats.plan_evals}/{pp_stats.exhaustive_plan_space}"
        f"({pp_stats.evals_ratio:.1f}x);claim={MIN_EVALS_RATIO:.0f}x;"
        f"{'MATCH' if pp_match else 'MISMATCH'};"
        f"{'PASS' if pp_gate else 'FAIL'}")

    baselines = {a: PRE_JOB_COST_DECODE_PRUNED[a, quick] for a in archs}
    decode_gate = all(decode_pruned[a] > baselines[a] for a in archs)
    rows.append(
        "resource_opt.decode_pruning,0,"
        + ";".join(f"{a}={decode_pruned[a]}>base{baselines[a]}"
                   for a in archs)
        + f";clusters={len(clusters)};{'PASS' if decode_gate else 'FAIL'}")
    ratio = total_space / max(total_evals, 1)
    st = cache.stats()
    gate = (ratio >= MIN_EVALS_RATIO and st.hit_rate >= MIN_HIT_RATE)
    rows.append(
        f"resource_opt.cache,0,evals={total_evals}/{total_space};"
        f"ratio={ratio:.1f}x;claim={MIN_EVALS_RATIO:.0f}x;"
        f"hit_rate={st.hit_rate:.2f};min_hit_rate={MIN_HIT_RATE};"
        f"entries={st.entries};{'PASS' if gate else 'FAIL'}")
    return rows
