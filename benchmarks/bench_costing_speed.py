"""Paper §2: "generating runtime plans from HOP DAGs is rather efficient
(<0.5 ms for common DAG sizes), which makes the generation and costing of
runtime plans feasible."

Measures generate+cost time for (a) the LinReg DS plan (the paper's
"common DAG size") and (b) full LM train-step plans (hundreds of
instructions) — reported as us/plan.

Beyond the paper, this also gates the plan-search subsystem:

  * ``candidate_set`` — costing the full enumerated sharding-plan space
    for one (arch x shape) cell through the sub-plan cost cache vs. the
    uncached estimator (the seed path).  Both sides use the harness's
    warm-cache protocol; PASS requires the cached engine to be >=5x
    faster.
  * ``candidate_throughput`` — the lane-vector batched engine (one tree
    walk per structure signature, knob values as numpy lanes) on an
    expanded knob grid vs. the per-candidate uncached walk.  Reported as
    plans/sec; PASS requires >=10x over scalar, bit-exact totals and the
    identical winning plan.
  * ``beam_matches_exhaustive`` — the staged beam search must return the
    same winning plan as the exhaustive scan.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

from repro.configs import SHAPES, get_config
from repro.core import PlanCostCache, estimate
from repro.core.cluster import ClusterConfig, CPU_HOST, single_pod_config
from repro.core.linreg import SCENARIOS, build_linreg_program
from repro.core.planner import (OVERLAP_FRACTION, SearchStats, ShardingPlan,
                                build_step_program, choose_plan,
                                cost_candidates_batched, enumerate_plans)

PAPER_CC = ClusterConfig(chip=CPU_HOST, mesh_shape=(72,), mesh_axes=("data",))


def _time_us(fn, reps: int = 20) -> float:
    fn()                                     # warm caches
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = False) -> List[str]:
    rows = []
    sc = SCENARIOS["XL1"]
    us = _time_us(lambda: estimate(build_linreg_program(sc, PAPER_CC)[0],
                                   PAPER_CC))
    rows.append(f"costing_speed.linreg_generate_and_cost,{us:.1f},"
                f"paper_claim_us=500;{'PASS' if us < 500 else 'FAIL'}")

    cc = single_pod_config()
    plan = ShardingPlan(tp_axes=("model",), microbatches=2)
    lm_archs = ("qwen1.5-0.5b",) if quick else ("qwen1.5-0.5b",
                                                "deepseek-v3-671b")
    for arch_id in lm_archs:
        arch = get_config(arch_id)
        shape = SHAPES["train_4k"]
        us = _time_us(lambda: estimate(
            build_step_program(arch, shape, plan, cc), cc), reps=5)
        n_inst = sum(build_step_program(arch, shape, plan, cc)
                     .count_instructions().values())
        rows.append(f"costing_speed.lm_step.{arch_id},{us:.1f},"
                    f"instructions={n_inst}")

    # ---- candidate-set costing: cached engine vs. uncached (seed) path ----
    arch = get_config("qwen1.5-0.5b")
    shape = SHAPES["train_4k"]
    cands = enumerate_plans(arch, shape, cc)

    def cost_set(cache):
        return [estimate(build_step_program(arch, shape, p, cc), cc,
                         cache=cache)
                for p in cands]

    reps = 2 if quick else 5
    us_uncached = _time_us(lambda: cost_set(None), reps=reps)
    shared = PlanCostCache()
    us_cached = _time_us(lambda: cost_set(shared), reps=reps)
    base = cost_set(None)
    cached = cost_set(shared)
    exact = max(abs(a.total - b.total) for a, b in zip(base, cached))
    speedup = us_uncached / us_cached if us_cached > 0 else float("inf")
    st = shared.stats()
    rows.append(
        f"costing_speed.candidate_set,{us_cached:.1f},"
        f"n_plans={len(cands)};uncached_us={us_uncached:.1f};"
        f"speedup={speedup:.1f}x;max_abs_err={exact:.2g};"
        f"cache_hit_rate={st.hit_rate:.2f};claim=5x;"
        f"{'PASS' if speedup >= 5.0 and exact < 1e-9 else 'FAIL'}")

    # ---- batched lane-vector engine: plans/sec on an expanded grid --------
    # The enumerated space has only ~4 knob members per structure, where
    # numpy per-op overhead eats the win; the anytime-search workload the
    # engine exists for sweeps far wider grids.  Benchmark the honest
    # shape of that workload: one structure, 8 microbatch counts x 6
    # float grad-reduce dtypes = 48 lanes in one walk.
    big = dataclasses.replace(shape, global_batch=4096)
    vplan = ShardingPlan(name="dp+tp", batch_axes=("data",),
                        tp_axes=("model",))
    grid = [dataclasses.replace(vplan, microbatches=m, grad_reduce_dtype=g)
            for m in (2, 4, 8, 16, 32, 64, 128, 256)
            for g in ("float32", "bfloat16", "float16", "float64",
                      "float8_e4m3fn", "float8_e5m2")]
    # scalar baseline = the seed path per candidate: same overlap-adjusted
    # config the search's _cost_candidate walks with, no cache
    cc_p = cc.with_overlap(OVERLAP_FRACTION)
    reps_b = 1 if quick else 3
    us_scalar = _time_us(
        lambda: [estimate(build_step_program(arch, big, p, cc_p), cc_p)
                 for p in grid], reps=reps_b)
    us_batched = _time_us(lambda: cost_candidates_batched(arch, big, grid, cc),
                          reps=reps_b)
    scalar = [estimate(build_step_program(arch, big, p, cc_p), cc_p)
              for p in grid]
    batched = cost_candidates_batched(arch, big, grid, cc)
    err = max(abs(d.time - s.total) for d, s in zip(batched, scalar))
    best_i = min(range(len(grid)), key=lambda i: scalar[i].total)
    winner_ok = min(batched, key=lambda d: d.time).plan == grid[best_i]
    speedup = us_scalar / us_batched if us_batched > 0 else float("inf")
    plans_per_sec = len(grid) / (us_batched / 1e6)
    rows.append(
        f"costing_speed.candidate_throughput,{plans_per_sec:.0f},"
        f"n_plans={len(grid)};batched_us={us_batched:.0f};"
        f"scalar_us={us_scalar:.0f};speedup={speedup:.1f}x;"
        f"max_abs_err={err:.2g};"
        f"winner={'MATCH' if winner_ok else 'MISMATCH'};claim=10x;"
        f"{'PASS' if speedup >= 10.0 and err == 0.0 and winner_ok else 'FAIL'}")

    # ---- beam search returns the exhaustive winner ------------------------
    for arch_id in ("qwen1.5-0.5b", "gemma3-12b"):
        arch = get_config(arch_id)
        stats = SearchStats()
        t0 = time.perf_counter()
        bm = choose_plan(arch, shape, cc, top_k=1, search="beam",
                         stats=stats)[0]
        t_beam_us = (time.perf_counter() - t0) * 1e6
        ex = choose_plan(arch, shape, cc, top_k=1, search="exhaustive")[0]
        match = "MATCH" if bm.plan == ex.plan else "MISMATCH"
        rows.append(
            f"costing_speed.beam_matches_exhaustive.{arch_id},{t_beam_us:.0f},"
            f"winner={bm.plan.describe()};{stats.describe()};"
            f"n_space={len(enumerate_plans(arch, shape, cc))};{match}")
    return rows
