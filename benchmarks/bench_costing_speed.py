"""Paper §2: "generating runtime plans from HOP DAGs is rather efficient
(<0.5 ms for common DAG sizes), which makes the generation and costing of
runtime plans feasible."

Measures generate+cost time for (a) the LinReg DS plan (the paper's
"common DAG size") and (b) full LM train-step plans (hundreds of
instructions) — reported as us/plan.
"""
from __future__ import annotations

import time
from typing import List

from repro.configs import SHAPES, get_config
from repro.core import estimate
from repro.core.cluster import ClusterConfig, CPU_HOST, single_pod_config
from repro.core.linreg import SCENARIOS, build_linreg_program
from repro.core.planner import ShardingPlan, build_step_program

PAPER_CC = ClusterConfig(chip=CPU_HOST, mesh_shape=(72,), mesh_axes=("data",))


def _time_us(fn, reps: int = 20) -> float:
    fn()                                     # warm caches
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> List[str]:
    rows = []
    sc = SCENARIOS["XL1"]
    us = _time_us(lambda: estimate(build_linreg_program(sc, PAPER_CC)[0],
                                   PAPER_CC))
    rows.append(f"costing_speed.linreg_generate_and_cost,{us:.1f},"
                f"paper_claim_us=500;{'PASS' if us < 500 else 'FAIL'}")

    cc = single_pod_config()
    plan = ShardingPlan(tp_axes=("model",), microbatches=2)
    for arch_id in ("qwen1.5-0.5b", "deepseek-v3-671b"):
        arch = get_config(arch_id)
        shape = SHAPES["train_4k"]
        us = _time_us(lambda: estimate(
            build_step_program(arch, shape, plan, cc), cc), reps=5)
        n_inst = sum(build_step_program(arch, shape, plan, cc)
                     .count_instructions().values())
        rows.append(f"costing_speed.lm_step.{arch_id},{us:.1f},"
                    f"instructions={n_inst}")
    return rows
