"""Paper Table 1 + §2: LinReg DS plan generation across the five scenarios,
plus the LM-scale scenario sweep.

Emits one row per LinReg scenario: the selected execution type / physical
operators and the estimated cost — must reproduce the paper's plan
switches (XS: CP+tsmm; XL1: tsmm+ak+ & mapmm w/ partitioned broadcast;
XL2: cpmm Gram; XL3: cpmm for X^T y; XL4: both cpmm).

Then one ``sweep.<arch>|<shape>|<mesh>`` row per LM scenario-sweep cell
(see :mod:`repro.core.sweep`): the beam-searched best sharding plan, its
estimated step time / HBM, and the search+cache counters — all cells
costed through one shared sub-plan cache.
"""
from __future__ import annotations

import time
from typing import List

from repro.core import estimate
from repro.core.cluster import ClusterConfig, CPU_HOST, single_pod_config
from repro.core.linreg import (PAPER_BUDGETS, SCENARIOS, build_linreg_program,
                               tpu_budgets)
from repro.core.sweep import SweepEngine, sweep_rows

SWEEP_ARCHS = ("qwen1.5-0.5b", "gemma3-12b", "mamba2-1.3b")
SWEEP_SHAPES = ("train_4k", "decode_32k")
SWEEP_CLUSTERS = ("pod", "2pod")

PAPER_CC = ClusterConfig(chip=CPU_HOST, mesh_shape=(72,), mesh_axes=("data",),
                         dispatch_latency=20.0)

EXPECTED = {
    "XS": ("CP", "tsmm", "mm"),
    "XL1": ("DIST", "tsmm+ak+", "mapmm"),
    "XL2": ("DIST", "cpmm", "mapmm"),
    "XL3": ("DIST", "tsmm+ak+", "cpmm"),
    "XL4": ("DIST", "cpmm", "cpmm"),
}


def run(quick: bool = False) -> List[str]:
    rows = []
    for name, sc in SCENARIOS.items():
        t0 = time.perf_counter()
        prog, choice = build_linreg_program(sc, PAPER_CC, PAPER_BUDGETS)
        costed = estimate(prog, PAPER_CC)
        us = (time.perf_counter() - t0) * 1e6
        got = (choice.exec_type, choice.tsmm_op, choice.mm_op)
        match = "MATCH" if got == EXPECTED[name] else f"MISMATCH{EXPECTED[name]}"
        rows.append(
            f"scenarios.{name},{us:.1f},"
            f"exec={choice.exec_type};tsmm={choice.tsmm_op};mm={choice.mm_op};"
            f"party={choice.partition_y};C={costed.total:.2f}s;{match}")
    # TPU-instantiated budgets: decision structure under v5e constants
    cc = single_pod_config()
    for name in ("XS", "XL1", "XL2"):
        prog, choice = build_linreg_program(SCENARIOS[name], cc, tpu_budgets(cc))
        costed = estimate(prog, cc)
        rows.append(f"scenarios_tpu.{name},0,"
                    f"exec={choice.exec_type};tsmm={choice.tsmm_op};"
                    f"C={costed.total:.4f}s")

    # LM scenario sweep: every (arch x shape x mesh) cell through one
    # shared plan-cost cache, ranked fastest-first
    engine = SweepEngine(search="beam")
    cells = engine.sweep(SWEEP_ARCHS[:1] if quick else SWEEP_ARCHS,
                         SWEEP_SHAPES,
                         SWEEP_CLUSTERS[:1] if quick else SWEEP_CLUSTERS)
    rows.extend(sweep_rows(cells))
    st = engine.cache.stats()
    rows.append(f"sweep.cache,0,hits={st.hits};lookups={st.hits + st.misses};"
                f"hit_rate={st.hit_rate:.2f};entries={st.entries}")
    return rows
