"""Paper §3.4: "the estimated costs were within 2x of the actual
execution time."

We validate the same property on hardware we actually have: CPU-scale
LinReg DS programs are costed with the CPU cluster constants and then
EXECUTED in JAX; the benchmark reports est/actual per scenario and the
max deviation factor.  Constants are a-priori (no profiling runs — R1).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimate
from repro.core.cluster import cpu_host_config
from repro.core.linreg import CompilerBudgets, Scenario, build_linreg_program

# CPU-sized scenarios (same structure as Table 1, scaled to the container)
CPU_SCENARIOS = [
    Scenario("cpu-S", 20_000, 256, dtype="float64"),
    Scenario("cpu-M", 80_000, 384, dtype="float64"),
    Scenario("cpu-L", 160_000, 512, dtype="float64"),
]
BUDGETS = CompilerBudgets(local_mem=8e9, broadcast_mem=2e9, block_size=4096)


def _execute(sc: Scenario) -> float:
    """Run the actual LinReg DS computation; return wall seconds."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((sc.m, sc.n)), jnp.float64)
    y = jnp.asarray(rng.standard_normal((sc.m, 1)), jnp.float64)

    @jax.jit
    def linreg(x, y):
        a = x.T @ x + 0.001 * jnp.eye(sc.n, dtype=x.dtype)
        b = x.T @ y
        return jnp.linalg.solve(a, b)

    linreg(x, y).block_until_ready()          # compile once
    t0 = time.perf_counter()
    linreg(x, y).block_until_ready()
    return time.perf_counter() - t0


def run(quick: bool = False) -> List[str]:
    cc = cpu_host_config()
    rows = []
    worst = 1.0
    scenarios = CPU_SCENARIOS[:1] if quick else CPU_SCENARIOS
    for sc in scenarios:
        prog, _ = build_linreg_program(sc, cc, BUDGETS)
        costed = estimate(prog, cc)
        # compare compute-side estimate vs in-memory execution (inputs are
        # already device-resident in the measured fn — drop the IO term)
        est = costed.breakdown.compute + costed.breakdown.collective
        actual = _execute(sc)
        ratio = est / actual if actual > 0 else float("inf")
        dev = max(ratio, 1 / ratio)
        worst = max(worst, dev)
        rows.append(f"accuracy.{sc.name},0,"
                    f"est={est*1e3:.1f}ms;actual={actual*1e3:.1f}ms;"
                    f"ratio={ratio:.2f}")
    rows.append(f"accuracy.worst_factor,0,{worst:.2f};paper_claim=2.0;"
                f"{'PASS' if worst <= 2.0 else 'FAIL'}")
    return rows
