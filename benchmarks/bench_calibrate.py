"""Calibration harvest + fit + drift gate (ROADMAP item: close the
estimate↔reality loop).

Harvests ``(peak-rate features, measured wall seconds)`` pairs on the
CPU host — matmul microbenchmarks spanning the three MXU shape classes,
a streaming op for the HBM fraction, the §3.4 LinReg accuracy scenarios
(reusing :mod:`benchmarks.bench_accuracy`), and the two cheap-to-compile
smoke architectures lowered through :func:`repro.core.hlo_cost
.lower_and_cost` — then least-squares a
:class:`repro.core.calibration.CalibrationProfile` and re-estimates
every validation cell under ``cc.with_calibration(profile)``.

Rows:
  * ``calib.fit``            — fitted terms / residual / sample counts
  * ``calib.profile``        — the fitted factors themselves
  * ``calib.drift.<cell>``   — est/measured ratio, uncalibrated vs
                               calibrated, per validation cell
  * ``calib.drift``          — the gate: median |ratio − 1| must
                               strictly improve under the fitted profile
                               and every calibrated ratio must sit
                               inside a generous sanity band (out-of-
                               band means the measurement path, not the
                               workload, drifted — fail the job).

Samples whose HLO walk hit unknown dtypes (``CompiledCost
.unknown_dtypes``) are marked polluted and rejected by the fitter.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimate
from repro.core.calibration import (HBM_KEY, CalibrationSample, fit_profile,
                                    features_from_totals, mxu_key,
                                    shape_class)
from repro.core.cluster import cpu_host_config
from repro.core.hlo_cost import lower_and_cost
from repro.core.linreg import build_linreg_program

from benchmarks.bench_accuracy import BUDGETS, CPU_SCENARIOS, _execute

# Calibrated ratios outside this band fail the gate: the profile was
# fitted from these very measurements, so a wildly off ratio means the
# measurement path itself is broken (polluted payloads, a dead timer),
# not that the hardware is slow.
RATIO_BAND = (0.25, 4.0)

# Square-matmul sides spanning the small / medium / large shape classes
# (2n^3 FLOPs: ~3.4e7 / ~9.1e8 / ~1.3e10 against the 1e8/1e10 breaks).
MATMUL_SIDES = (256, 768, 1856)
MATMUL_SIDES_QUICK = (256, 768)

# The two cheap-to-compile families (tests/test_models_smoke.FAST_ARCHS)
# — the smoke-arch grid the drift rows cover.
SMOKE_ARCHS = ("qwen1.5-0.5b", "mamba2-1.3b")


def _mesh1():
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices("cpu")[:1]), ("data",))


def _time_compiled(compiled, args, reps: int) -> float:
    """Median wall seconds of one dispatch (first call excluded)."""
    jax.block_until_ready(compiled(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _matmul_sample(n: int, cc, reps: int) -> Tuple[CalibrationSample, float]:
    """One n x n @ n x n float32 matmul; returns (sample, measured)."""
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    compiled, cost = lower_and_cost(f"matmul{n}", lambda a, b: a @ b,
                                    (x, x), _mesh1())
    measured = _time_compiled(compiled, (x, x), reps)
    # Compiled modules report bf16-dominated MXU work (cc.chip.peak is
    # dtype-degenerate on the CPU host anyway) — key the feature the way
    # CompiledCost.time_breakdown will consult the fitted profile.
    feats = {mxu_key("bfloat16", shape_class(cost.flops_per_device)):
             cost.flops_per_device / cc.chip.peak("bfloat16"),
             HBM_KEY: cost.bytes_per_device / cc.chip.hbm_bw}
    return CalibrationSample(
        features=feats, measured_seconds=measured,
        fixed_seconds=cc.dispatch_latency, label=f"matmul{n}",
        polluted=bool(cost.unknown_dtypes)), measured


def _stream_sample(cc, reps: int) -> CalibrationSample:
    """A bandwidth-bound elementwise op: pins the HBM fraction."""
    n = 48 * 2 ** 20                      # 192 MB in, 192 MB out
    x = jnp.ones((n,), jnp.float32)
    compiled, cost = lower_and_cost("stream", lambda a: a * 1.0001 + 1.0,
                                    (x,), _mesh1())
    measured = _time_compiled(compiled, (x,), reps)
    return CalibrationSample(
        features={HBM_KEY: cost.bytes_per_device / cc.chip.hbm_bw},
        measured_seconds=measured, fixed_seconds=cc.dispatch_latency,
        label="stream", polluted=bool(cost.unknown_dtypes))


def _linreg_cell(sc, cc) -> Tuple[CalibrationSample, float, float]:
    """One §3.4 LinReg scenario: (sample, est_seconds_fn-able, measured).

    Returns the sample plus (uncalibrated estimate, measured); the
    calibrated estimate is recomputed by the caller under the fitted cc.
    """
    prog, _ = build_linreg_program(sc, cc, BUDGETS)
    costed = estimate(prog, cc)
    est = costed.breakdown.compute + costed.breakdown.collective
    actual = _execute(sc)
    sample = CalibrationSample(
        features=features_from_totals(costed.totals, cc),
        measured_seconds=actual, estimated_seconds=est,
        label=f"linreg:{sc.name}")
    return sample, est, actual


def _linreg_estimate(sc, cc) -> float:
    prog, _ = build_linreg_program(sc, cc, BUDGETS)
    costed = estimate(prog, cc)
    return costed.breakdown.compute + costed.breakdown.collective


def _arch_cell(arch_id: str, cc, reps: int):
    """Lower one smoke arch's loss step on a 1-device CPU mesh, time it,
    and return (sample, CompiledCost, measured)."""
    from repro.configs import get_config
    from repro.models.model import build_model

    cfg = dataclasses.replace(get_config(arch_id).reduced(), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    fs = model.frontend_shape(B)
    if fs is not None:
        batch["frontend"] = jax.random.normal(jax.random.PRNGKey(2), fs,
                                              jnp.float32)
    compiled, cost = lower_and_cost(
        arch_id, lambda p, b: model.loss(p, b)[0], (params, batch), _mesh1())
    measured = _time_compiled(compiled, (params, batch), reps)
    feats = {mxu_key("bfloat16", shape_class(cost.flops_per_device)):
             cost.flops_per_device / cc.chip.peak("bfloat16"),
             HBM_KEY: cost.bytes_per_device / cc.chip.hbm_bw}
    sample = CalibrationSample(
        features=feats, measured_seconds=measured,
        fixed_seconds=cc.dispatch_latency, label=f"arch:{arch_id}",
        polluted=bool(cost.unknown_dtypes))
    return sample, cost, measured


def _arch_estimate(cost, cc) -> float:
    bd = cost.time_breakdown(cc)
    return bd.compute + bd.collective


def _median_abs_dev(ratios: List[float]) -> float:
    devs = sorted(abs(r - 1.0) for r in ratios)
    n = len(devs)
    return devs[n // 2] if n % 2 else 0.5 * (devs[n // 2 - 1] + devs[n // 2])


def run(quick: bool = False) -> List[str]:
    cc = cpu_host_config()
    reps = 3 if quick else 5
    rows: List[str] = []
    samples: List[CalibrationSample] = []
    # cell name -> (re-estimate under a given cc, measured seconds)
    cells: Dict[str, Tuple] = {}

    # ---- harvest: microbenchmarks (fit-only, not validation cells) ----
    for n in (MATMUL_SIDES_QUICK if quick else MATMUL_SIDES):
        s, _ = _matmul_sample(n, cc, reps)
        samples.append(s)
    samples.append(_stream_sample(cc, reps))

    # ---- harvest: LinReg accuracy scenarios (bench_accuracy reuse) ----
    for sc in (CPU_SCENARIOS[:1] if quick else CPU_SCENARIOS):
        s, _, actual = _linreg_cell(sc, cc)
        samples.append(s)
        cells[sc.name] = (lambda c, sc=sc: _linreg_estimate(sc, c), actual)

    # ---- harvest: the two cheap jit smoke archs -----------------------
    for arch_id in SMOKE_ARCHS:
        s, cost, measured = _arch_cell(arch_id, cc, reps)
        samples.append(s)
        cells[arch_id] = (lambda c, cost=cost: _arch_estimate(cost, c),
                          measured)

    # ---- fit ----------------------------------------------------------
    fit = fit_profile(samples, chip_name=cc.chip.name)
    rows.append(f"calib.fit,0,terms={len(fit.factors)};"
                f"residual={fit.residual:.3f};samples={fit.n_samples};"
                f"rejected={fit.n_rejected}")
    rows.append(f"calib.profile,0,{fit.profile.describe()}")
    cc_cal = cc.with_calibration(fit.profile)

    # ---- validate: per-cell drift rows + the gate ---------------------
    unc, cal = [], []
    in_band = True
    for name, (est_fn, measured) in cells.items():
        r_unc = est_fn(cc) / measured
        r_cal = est_fn(cc_cal) / measured
        unc.append(r_unc)
        cal.append(r_cal)
        in_band &= RATIO_BAND[0] <= r_cal <= RATIO_BAND[1]
        rows.append(f"calib.drift.{name},0,"
                    f"ratio_uncal={r_unc:.3f};ratio_cal={r_cal:.3f}")
    med_unc = _median_abs_dev(unc)
    med_cal = _median_abs_dev(cal)
    ok = in_band and med_cal < med_unc
    rows.append(f"calib.drift,0,median_uncal={med_unc:.3f};"
                f"median_cal={med_cal:.3f};"
                f"band=[{RATIO_BAND[0]:.2f},{RATIO_BAND[1]:.2f}];"
                f"{'PASS' if ok else 'FAIL'}")
    return rows
