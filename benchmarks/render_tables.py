"""Render EXPERIMENTS.md tables from the dry-run artifacts.

Usage: PYTHONPATH=src:. python -m benchmarks.render_tables [dryrun|roofline|perf]
"""
from __future__ import annotations

import glob
import json
import os
import sys

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "artifacts")
PEAK = 197e12
HBM_BUDGET = 16e9 * 0.9

ARCH_ORDER = ["whisper-small", "pixtral-12b", "zamba2-2.7b",
              "phi3.5-moe-42b-a6.6b", "deepseek-v3-671b", "stablelm-12b",
              "qwen1.5-4b", "gemma3-12b", "qwen1.5-0.5b", "mamba2-1.3b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh=None, tag=""):
    out = {}
    for p in glob.glob(os.path.join(ARTIFACT_DIR, "dryrun_*.json")):
        d = json.load(open(p))
        if mesh and d.get("mesh") != mesh:
            continue
        if d.get("tag", "") != tag:
            continue
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def fmt_ms(x):
    return f"{x*1e3:.1f}" if x is not None else "-"


def dryrun_table():
    arts = load()
    print("| arch | shape | mesh | status | plan | peak HBM/dev | fits | "
          "compile s |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            for m in ("single", "multi"):
                d = arts.get((a, s, m))
                if d is None:
                    continue
                if d["status"] == "skip":
                    print(f"| {a} | {s} | {m} | SKIP | — | — | — | — |")
                    continue
                ma = d["memory_analysis"]
                used = (ma["argument_bytes"] + ma["temp_bytes"]
                        + ma["output_bytes"])
                fits = "yes" if used <= HBM_BUDGET else "**no**"
                print(f"| {a} | {s} | {m} | {d['status']} | "
                      f"{d['plan'].split('[')[0]} | {used/1e9:.1f} GB | "
                      f"{fits} | {d.get('compile_s', 0):.0f} |")


def roofline_table(mesh="single"):
    arts = load(mesh=mesh)
    print("| arch | shape | compute s | memory s | collective s | dominant |"
          " MODEL_FLOPS | useful | MFU@bound | next lever |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = arts.get((a, s, mesh))
            if d is None or d["status"] != "ok":
                if d is not None and d["status"] == "skip":
                    print(f"| {a} | {s} | — | — | — | SKIP | — | — | — | "
                          f"{d['why'][:40]} |")
                continue
            r = d["roofline"]
            bound = r["roofline_bound_s"]
            mf = d.get("model_flops") or 0
            chips = 512 if mesh == "multi" else 256
            mfu = mf / (chips * PEAK * bound) if bound else 0
            ufr = d.get("useful_flops_ratio")
            dom = r["dominant"].replace("_s", "")
            lever = {"compute": "more useful-flop fraction / MXU util",
                     "memory": "fuse fp32 intermediates (flash kernel)",
                     "collective": "compress/overlap collectives"}[dom]
            print(f"| {a} | {s} | {r['compute_s']:.4f} | {r['memory_s']:.4f}"
                  f" | {r['collective_s']:.4f} | {dom} | {mf:.2e} | "
                  f"{(1/ufr if ufr else 0):.2f} | {mfu:.3f} | {lever} |")


def perf_rows(tag_prefix="h"):
    arts = [d for d in
            (json.load(open(p)) for p in
             glob.glob(os.path.join(ARTIFACT_DIR, "dryrun_*.json")))
            if d.get("tag", "").startswith(tag_prefix) and d["status"] == "ok"]
    for d in sorted(arts, key=lambda x: (x["arch"], x["shape"], x["tag"])):
        r = d["roofline"]
        print(f"{d['arch']} x {d['shape']} [{d['tag']}] plan={d['plan']}: "
              f"compute={fmt_ms(r['compute_s'])}ms "
              f"mem={fmt_ms(r['memory_s'])}ms "
              f"coll={fmt_ms(r['collective_s'])}ms dom={r['dominant']}")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("dryrun", "all"):
        print("## Dry-run matrix\n")
        dryrun_table()
    if which in ("roofline", "all"):
        print("\n## Roofline (single-pod)\n")
        roofline_table("single")
    if which in ("perf", "all"):
        print("\n## Perf iterations\n")
        perf_rows()
