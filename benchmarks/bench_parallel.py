"""Parallel grid search + persistent plan-cost cache gates (PR-10).

Three claims, two of them CI gates:

  * ``resource_opt.parallel`` — a ``jobs=4`` sweep of the full bench grid
    returns a ranked table *byte-identical* to the serial sweep, and
    ``optimize_resources(jobs=4)`` returns the serial decision table
    byte-for-byte (incumbent pruning included).  The >=2.5x wall-clock
    speedup half of the gate is enforced only when the machine actually
    has >= 4 usable cores (CI's 4-vCPU runners do; a 1-core container
    cannot speed anything up and reports the measured ratio
    informationally instead of failing on physics).
  * ``resource_opt.warmstart`` — a second sweep seeded from the persisted
    cache snapshot replays >= 50% of its lookups as hits and returns
    identical winners.
  * ``parallel.affinity`` — informational: serial hit rate of the
    arch-outermost (cache-affinity) visit order vs the old
    clusters-outermost order.  Cache keys embed the cluster fingerprint,
    so cross-cluster sharing is ~nil and the delta is expected to be ~0
    for an unbounded cache — the row documents that honestly; the
    affinity order exists for *sharding* (whole (arch, shape) groups land
    on one worker) and for bounded caches, not for serial hit rate.

The grid is NOT shrunk under ``--quick``: the speedup gate is only
meaningful at full-grid scale (a tiny grid is all pool-startup overhead),
and the whole module costs well under the bench-smoke budget.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import List, Sequence

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core.costmodel import PlanCostCache
from repro.core.parallel import default_jobs
from repro.core.resource import (ResourceSearchStats, enumerate_clusters,
                                 optimize_resources)
from repro.core.sweep import CLUSTERS, SweepEngine

JOBS = 4
MIN_SPEEDUP = 2.5        # enforced when the host has >= JOBS usable cores
MIN_WARM_HIT_RATE = 0.5

# The full bench grid: every arch x (train + prefill + decode + a serving
# workload) x every named cluster.  ~300 cells, ~25s serial — big enough
# that a 4-worker pool's startup cost is noise against the work.
GRID_ARCHS = tuple(ARCH_IDS)
GRID_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "chat_2k")
GRID_CLUSTERS = tuple(CLUSTERS)

AFFINITY_ARCHS = ("qwen1.5-0.5b", "gemma3-12b", "mamba2-1.3b",
                  "qwen1.5-110b")
AFFINITY_SHAPES = ("train_4k", "decode_32k")


def _canon_sweep(cells) -> str:
    """Byte-comparable ranked table: full-precision floats via repr, no
    timing or cache counters (those legitimately differ across runs)."""
    out = []
    for c in cells:
        if c.skipped:
            out.append(f"{c.key},SKIP,{c.skipped}")
            continue
        d = c.decision
        out.append(f"{c.key},{d.plan.describe()},{d.time!r},"
                   f"{d.hbm_est!r},{d.feasible}")
    return "\n".join(out)


def _canon_resource(decisions) -> str:
    out = []
    for rd in decisions:
        if rd.pruned:
            out.append(f"{rd.cluster_id},PRUNED,{rd.pruned}")
            continue
        d = rd.decision
        out.append(f"{rd.cluster_id},{d.plan.describe()},{d.time!r},"
                   f"{rd.floor_time!r},{d.feasible}")
    return "\n".join(out)


def _hit_rate_in_order(specs: Sequence) -> float:
    engine = SweepEngine(search="beam")
    for arch, shape, cluster in specs:
        engine.cost_cell(arch, shape, cluster)
    return engine.cache.stats().hit_rate


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    ncpu = default_jobs()

    # ---- serial baseline -------------------------------------------------
    serial_engine = SweepEngine(search="beam")
    t0 = time.perf_counter()
    serial = serial_engine.sweep(GRID_ARCHS, GRID_SHAPES, GRID_CLUSTERS)
    t_serial = time.perf_counter() - t0
    serial_canon = _canon_sweep(serial)
    n_cells = len(serial)
    rows.append(f"parallel.sweep_serial,{t_serial * 1e6:.0f},"
                f"cells={n_cells};"
                f"cache={serial_engine.cache.stats().hit_rate:.2f}")
    # the full-grid cache is large; keep only the canonical table around
    del serial_engine, serial

    # ---- jobs=4 sweep: byte-identical table, measured speedup ------------
    fd, cache_path = tempfile.mkstemp(prefix="bench-plancache-",
                                      suffix=".pkl")
    os.close(fd)
    try:
        par_engine = SweepEngine(search="beam", jobs=JOBS,
                                 cache_path=cache_path)
        t0 = time.perf_counter()
        par = par_engine.sweep(GRID_ARCHS, GRID_SHAPES, GRID_CLUSTERS)
        t_par = time.perf_counter() - t0
        sweep_identical = _canon_sweep(par) == serial_canon
        speedup = t_serial / max(t_par, 1e-9)
        rows.append(
            f"parallel.sweep_jobs{JOBS},{t_par * 1e6:.0f},"
            f"speedup={speedup:.2f}x;workers={len(par_engine.last_worker_stats)};"
            f"{'MATCH' if sweep_identical else 'MISMATCH'}")
        del par_engine, par    # sweep() already persisted to cache_path

        # ---- optimize_resources(jobs=4): byte-identical decisions --------
        arch = get_config("qwen1.5-0.5b")
        shape = SHAPES["train_4k"]
        cands = enumerate_clusters()
        r_serial = optimize_resources(arch, shape, cands,
                                      objective="job_cost",
                                      stats=ResourceSearchStats())
        t0 = time.perf_counter()
        r_par = optimize_resources(arch, shape, cands, objective="job_cost",
                                   stats=ResourceSearchStats(), jobs=JOBS)
        t_rpar = time.perf_counter() - t0
        resource_identical = _canon_resource(r_par) == _canon_resource(
            r_serial)

        enforce_speedup = ncpu >= JOBS
        gate = (sweep_identical and resource_identical
                and (speedup >= MIN_SPEEDUP or not enforce_speedup))
        rows.append(
            f"resource_opt.parallel,{t_rpar * 1e6:.0f},"
            f"speedup={speedup:.2f}x;claim={MIN_SPEEDUP}x;ncpu={ncpu};"
            f"gate={'enforced' if enforce_speedup else 'informational'};"
            f"sweep={'MATCH' if sweep_identical else 'MISMATCH'};"
            f"resources={'MATCH' if resource_identical else 'MISMATCH'};"
            f"{'PASS' if gate else 'FAIL'}")

        # ---- warm start from the persisted snapshot ----------------------
        # jobs=1: this leg measures persistence (replay instead of
        # re-walk), not the pool — and it keeps one cache in RAM instead
        # of one per worker.
        warm_engine = SweepEngine(search="beam", cache_path=cache_path)
        seeded = warm_engine.cache.entries
        t0 = time.perf_counter()
        warm = warm_engine.sweep(GRID_ARCHS, GRID_SHAPES, GRID_CLUSTERS)
        t_warm = time.perf_counter() - t0
        traffic = warm_engine.traffic_stats()
        warm_identical = _canon_sweep(warm) == serial_canon
        warm_gate = (warm_identical
                     and traffic.hit_rate >= MIN_WARM_HIT_RATE
                     and seeded > 0)
        rows.append(
            f"resource_opt.warmstart,{t_warm * 1e6:.0f},"
            f"hit_rate={traffic.hit_rate:.2f};claim={MIN_WARM_HIT_RATE};"
            f"seeded={seeded};speedup_vs_cold={t_serial / max(t_warm, 1e-9):.2f}x;"
            f"{'MATCH' if warm_identical else 'MISMATCH'};"
            f"{'PASS' if warm_gate else 'FAIL'}")
    finally:
        os.unlink(cache_path)

    # ---- affinity order: serial hit-rate delta (informational) ----------
    new_order = [(a, s, c) for a in AFFINITY_ARCHS for s in AFFINITY_SHAPES
                 for c in GRID_CLUSTERS]
    old_order = [(a, s, c) for c in GRID_CLUSTERS for a in AFFINITY_ARCHS
                 for s in AFFINITY_SHAPES]
    hit_new = _hit_rate_in_order(new_order)
    hit_old = _hit_rate_in_order(old_order)
    rows.append(
        f"parallel.affinity,0,hit_arch_outer={hit_new:.4f};"
        f"hit_cluster_outer={hit_old:.4f};delta={hit_new - hit_old:+.4f};"
        f"cells={len(new_order)}")
    return rows
