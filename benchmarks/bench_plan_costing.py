"""Paper Figures 4 & 5: costed runtime plans with per-instruction
[IO, compute] annotations, for scenario XS (pure CP) and XL1 (hybrid
DIST plan) — plus the same treatment for an LM train-step plan.
"""
from __future__ import annotations

import time
from typing import List

from repro.configs import SHAPES, get_config
from repro.core import estimate, explain
from repro.core.cluster import ClusterConfig, CPU_HOST, single_pod_config
from repro.core.linreg import SCENARIOS, build_linreg_program
from repro.core.planner import choose_plan, build_step_program

PAPER_CC = ClusterConfig(chip=CPU_HOST, mesh_shape=(72,), mesh_axes=("data",),
                         dispatch_latency=20.0)


def run(quick: bool = False) -> List[str]:
    rows = []
    for name in ("XS", "XL1"):
        prog, _ = build_linreg_program(SCENARIOS[name], PAPER_CC)
        t0 = time.perf_counter()
        costed = estimate(prog, PAPER_CC)
        us = (time.perf_counter() - t0) * 1e6
        text = explain(costed)
        print(f"\n===== Costed plan, scenario {name} (paper Fig. "
              f"{'4' if name == 'XS' else '5'}) =====")
        print(text)
        dominant = max(("io", "compute", "collective", "latency"),
                       key=lambda k: getattr(costed.breakdown, k))
        rows.append(f"plan_costing.{name},{us:.1f},"
                    f"C={costed.total:.2f}s;dominant={dominant}")

    # LM-step analytical plan (the same machinery at LM scale)
    cc = single_pod_config()
    arch = get_config("qwen1.5-0.5b")
    dec = choose_plan(arch, SHAPES["train_4k"], cc, top_k=1)[0]
    prog = build_step_program(arch, SHAPES["train_4k"], dec.plan, cc)
    costed = estimate(prog, cc)
    print("\n===== Costed LM train-step plan (qwen1.5-0.5b, train_4k) =====")
    print(explain(costed, max_depth=2))
    rows.append(f"plan_costing.lm_step,0,C={costed.total*1e3:.1f}ms;"
                f"plan={dec.plan.name}")
    return rows
