"""Cost-based plan autotuning (the paper's optimizers in action).

For one (arch x shape x mesh) cell: enumerate the sharding-plan space,
rank analytically with C(P, cc), then show how the ranking responds to a
cluster change (elastic replanning = just re-costing, paper R3).

Run:  PYTHONPATH=src python examples/autotune_plan.py [--arch phi3.5-moe-42b-a6.6b]
"""
import argparse

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core.cluster import multi_pod_config, single_pod_config
from repro.core.planner import choose_plan, enumerate_plans


def rank(arch, shape, cc, label, k=5):
    print(f"\n== {label}: {len(enumerate_plans(arch, shape, cc))} candidates ==")
    for d in choose_plan(arch, shape, cc, top_k=k):
        print(f"  {d.plan.describe():66s} T={d.time*1e3:9.1f}ms "
              f"hbm={d.hbm_est/1e9:6.1f}GB feas={d.feasible}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3.5-moe-42b-a6.6b",
                    choices=ARCH_IDS)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    args = ap.parse_args()
    arch = get_config(args.arch)
    shape = SHAPES[args.shape]

    rank(arch, shape, single_pod_config(), "single pod (16x16)")
    rank(arch, shape, multi_pod_config(), "two pods (2x16x16, DCN between)")

    # sensitivity: what if the DCN were 4x faster? (R3: resource awareness)
    import dataclasses
    cc = multi_pod_config()
    fast_chip = dataclasses.replace(cc.chip, dcn_bw=cc.chip.dcn_bw * 4)
    rank(arch, shape, dataclasses.replace(cc, chip=fast_chip),
         "two pods, 4x DCN")


if __name__ == "__main__":
    main()
