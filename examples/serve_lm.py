"""Batched serving example (deliverable b): prefill + decode with KV
caches via the ServeEngine's continuous-batching core, on a reduced model.

Two runs of the same traffic: static batching (every request admitted in
one round — the degenerate continuous schedule), then a 2-slot continuous
pool that must refill lanes as requests finish — the executable twin of
the costed slot-refill schedules in ``repro.core.serving``.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.runtime.serve_engine import EngineConfig, Request, ServeEngine


def main():
    arch = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                               dtype="float32")
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=list(rng.integers(1, arch.vocab_size, size=n)),
                max_new_tokens=12)
        for n in (8, 12, 16, 16)
    ]

    # -- static batching: one admission round, lockstep decode ----------
    engine = ServeEngine(model, params,
                         EngineConfig(max_len=96, batching="static"))
    outs = engine.generate(requests)
    for i, c in enumerate(outs):
        print(f"req{i}: |prompt|={len(c.prompt):2d} "
              f"decode {c.decode_time_s * 1e3:4.0f}ms -> {c.tokens}")
    print(f"\nstatic batch of {len(requests)}: "
          f"prefill {outs[0].prefill_time_s * 1e3:.0f}ms, "
          f"stats {engine.stats}")

    # same requests again — greedy decoding is deterministic
    outs2 = engine.generate(requests)
    assert [c.tokens for c in outs] == [c.tokens for c in outs2]
    print("determinism check passed")

    # -- continuous batching: 2 slots over 4 requests --------------------
    pool = ServeEngine(model, params,
                       EngineConfig(max_len=96, batching="continuous",
                                    slots=2))
    for r in requests:
        pool.submit(r)
    done = pool.run()
    assert len(done) == len(requests)
    print(f"\ncontinuous, slots=2: {pool.stats['admission_rounds']} "
          f"admission rounds, {pool.stats['decode_steps']} decode steps, "
          f"{pool.stats['wasted_slot_steps']} wasted slot-steps")
    for c in done:
        print(f"req{c.rid}: prefill {c.prefill_time_s * 1e3:4.0f}ms "
              f"decode {c.decode_time_s * 1e3:4.0f}ms "
              f"({len(c.tokens)} tokens)")


if __name__ == "__main__":
    main()
