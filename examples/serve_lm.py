"""Batched serving example (deliverable b): prefill + decode with KV
caches via the ServeEngine, on a reduced model.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.runtime.serve_engine import Request, ServeEngine


def main():
    arch = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                               dtype="float32")
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=96, temperature=0.0)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=list(rng.integers(1, arch.vocab_size, size=n)),
                max_new_tokens=12)
        for n in (8, 12, 16, 16)
    ]
    outs = engine.generate(requests)
    for i, c in enumerate(outs):
        print(f"req{i}: |prompt|={len(c.prompt):2d} -> {c.tokens}")
    print(f"\nbatch of {len(requests)}: prefill {outs[0].prefill_time_s*1e3:.0f}ms, "
          f"12 decode steps {outs[0].decode_time_s*1e3:.0f}ms")

    # same requests again — greedy decoding is deterministic
    outs2 = engine.generate(requests)
    assert [c.tokens for c in outs] == [c.tokens for c in outs2]
    print("determinism check passed")


if __name__ == "__main__":
    main()
