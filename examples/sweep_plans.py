"""Scenario sweep: best sharding plan for every (arch x shape x mesh) cell.

The paper's point is that generating and costing runtime plans is cheap
enough to do for *every* alternative an optimizer can enumerate; the sweep
engine extends that to every *scenario* an operator can imagine.  All cells
share one sub-plan cost cache, so each additional scenario costs less than
the one before it — watch the per-cell cache columns fill with hits.

Run:
  PYTHONPATH=src python examples/sweep_plans.py
  PYTHONPATH=src python examples/sweep_plans.py \
      --archs qwen1.5-0.5b gemma3-12b --shapes train_4k decode_32k \
      --clusters pod 2pod --search beam
"""
import argparse
import time

from repro.configs import ARCH_IDS, SHAPES
from repro.core.sweep import CLUSTERS, SweepEngine, format_table


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--archs", nargs="+", default=["qwen1.5-0.5b",
                                                   "gemma3-12b",
                                                   "mamba2-1.3b"],
                    choices=ARCH_IDS, metavar="ARCH")
    ap.add_argument("--shapes", nargs="+", default=list(SHAPES),
                    choices=list(SHAPES), metavar="SHAPE")
    ap.add_argument("--clusters", nargs="+", default=["pod"],
                    choices=list(CLUSTERS), metavar="CLUSTER")
    ap.add_argument("--search", default="beam",
                    choices=["beam", "exhaustive"])
    args = ap.parse_args()

    engine = SweepEngine(search=args.search)
    t0 = time.perf_counter()
    cells = engine.sweep(args.archs, args.shapes, args.clusters)
    dt = time.perf_counter() - t0

    print(format_table(cells))
    st = engine.cache.stats()
    costed = sum(c.stats.costed for c in cells if c.stats)
    print(f"\n{len(cells)} scenarios, {costed} candidate plans costed in "
          f"{dt * 1e3:.0f}ms ({args.search} search); shared cache: "
          f"{st.hits} hits / {st.hits + st.misses} lookups "
          f"({st.hit_rate:.0%}), {st.entries} entries")


if __name__ == "__main__":
    main()
