"""Scenario sweep: best sharding plan for every (arch x shape x mesh) cell.

The paper's point is that generating and costing runtime plans is cheap
enough to do for *every* alternative an optimizer can enumerate; the sweep
engine extends that to every *scenario* an operator can imagine.  All cells
share one sub-plan cost cache, so each additional scenario costs less than
the one before it — watch the per-cell cache columns fill with hits.

Run:
  PYTHONPATH=src python examples/sweep_plans.py
  PYTHONPATH=src python examples/sweep_plans.py \
      --archs qwen1.5-0.5b gemma3-12b --shapes train_4k decode_32k \
      --clusters pod 2pod --search beam
  PYTHONPATH=src python examples/sweep_plans.py \
      --clusters v5p-pod v5p-3d   # same v5p pod, 2D flat vs native 3D
                                  # torus (2 links/axis, "depth" roles)
  PYTHONPATH=src python examples/sweep_plans.py \
      --archs qwen1.5-110b --shapes train_4k \
      --clusters v5p-dcn v5p-dcn-3d   # pipeline-over-DCN: the 110B dense
                                      # train cell only fits with pp
                                      # stages over the pod axis
  PYTHONPATH=src python examples/sweep_plans.py --resources \
      --objective cost      # sweep the full enumerated cluster grid —
                            # including the v5p 3D-torus cells — and rank
                            # (arch x shape x cluster) cells, then print
                            # each workload's winning cluster
  PYTHONPATH=src python examples/sweep_plans.py \
      --jobs 4 --cache-file /tmp/plans.cache   # cost cells over a
                            # 4-worker pool (identical ranked table) and
                            # persist the plan-cost cache: the next run
                            # starts warm and replays instead of re-walking
"""
import argparse
import time

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.core.resource import (DEFAULT_STEPS_PER_JOB, OBJECTIVES,
                                 enumerate_clusters)
from repro.core.sweep import CLUSTERS, SweepEngine, format_table


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--archs", nargs="+", default=["qwen1.5-0.5b",
                                                   "gemma3-12b",
                                                   "mamba2-1.3b"],
                    choices=ARCH_IDS, metavar="ARCH")
    ap.add_argument("--shapes", nargs="+", default=list(SHAPES),
                    choices=list(SHAPES), metavar="SHAPE")
    ap.add_argument("--clusters", nargs="+", default=["pod"],
                    choices=list(CLUSTERS), metavar="CLUSTER")
    ap.add_argument("--resources", action="store_true",
                    help="sweep the enumerated cluster grid (chip x pods x "
                         "mesh x ICI/DCN) instead of --clusters, and report "
                         "each workload's winning cluster")
    ap.add_argument("--objective", default="step_time",
                    choices=list(OBJECTIVES) + ["device_seconds"])
    ap.add_argument("--slo-ms", type=float, default=None)
    ap.add_argument("--steps-per-job", type=int,
                    default=DEFAULT_STEPS_PER_JOB,
                    help="job length priced by --objective job_cost")
    ap.add_argument("--search", default="beam",
                    choices=["beam", "exhaustive", "batched"])
    ap.add_argument("--jobs", type=int, default=1,
                    help="cost cells over N spawn workers (ranked table is "
                         "identical to --jobs 1)")
    ap.add_argument("--cache-file", default=None,
                    help="persist the plan-cost cache here: loaded at "
                         "startup (ignored when stale — the snapshot is "
                         "fingerprinted against the cost-model version) "
                         "and re-saved after the sweep")
    args = ap.parse_args()

    engine = SweepEngine(search=args.search, jobs=args.jobs,
                         cache_path=args.cache_file)
    clusters = (enumerate_clusters() if args.resources
                else list(args.clusters))
    t0 = time.perf_counter()
    cells = engine.sweep(args.archs, args.shapes, clusters)
    dt = time.perf_counter() - t0

    print(format_table(cells))
    if args.resources:
        slo = args.slo_ms / 1e3 if args.slo_ms is not None else None
        print(f"\nresource winners (objective={args.objective}):")
        for arch in args.archs:
            for shape in args.shapes:
                ok, why = shape_applicable(get_config(arch), SHAPES[shape])
                if not ok:
                    print(f"  {arch} x {shape}: {why}")
                    continue
                try:
                    decisions, stats = engine.optimize_cell(
                        arch, shape, clusters, objective=args.objective,
                        slo=slo, steps_per_job=args.steps_per_job)
                except ValueError as e:
                    print(f"  {arch} x {shape}: {e}")
                    continue
                print(f"  {arch} x {shape}: {decisions[0].describe()} "
                      f"[{stats.describe()}]")
    # traffic_stats() aggregates worker-local lookups after a parallel
    # sweep; for --jobs 1 it is exactly the engine cache's own counters.
    st = engine.traffic_stats()
    costed = sum(c.stats.costed for c in cells if c.stats)
    workers = f" over {args.jobs} workers" if args.jobs > 1 else ""
    print(f"\n{len(cells)} scenarios, {costed} candidate plans costed in "
          f"{dt * 1e3:.0f}ms ({args.search} search{workers}); cache: "
          f"{st.hits} hits / {st.hits + st.misses} lookups "
          f"({st.hit_rate:.0%}), {engine.cache.entries} merged entries")
    if args.cache_file:
        print(f"cache saved to {args.cache_file}")


if __name__ == "__main__":
    main()
