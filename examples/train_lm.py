"""End-to-end training driver (deliverable b): train a reduced-family LM
for a few hundred steps on CPU with the full production stack — cost-based
plan selection, sharded data pipeline, AdamW, async checkpointing, resume,
straggler monitor.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses
import json
import tempfile

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.cluster import cpu_host_config
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.runtime.train_loop import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    arch = dataclasses.replace(get_config(args.arch).reduced(),
                               dtype="float32")
    shape = ShapeConfig("cpu_train", seq_len=64, global_batch=16,
                        mode="train")
    mesh = make_host_mesh()
    cc = cpu_host_config().with_mesh(tuple(mesh.devices.shape),
                                     tuple(mesh.axis_names))
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    tcfg = TrainerConfig(steps=args.steps, log_every=20,
                         checkpoint_every=100, ckpt_dir=ckpt)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    trainer = Trainer(arch, shape, cc, mesh, opt_cfg=opt, tcfg=tcfg)
    print(f"plan: {trainer.plan.describe()}  params="
          f"{arch.n_params/1e6:.1f}M  ckpt={ckpt}")
    result = trainer.run(on_metrics=lambda m: print(json.dumps(m)))
    hist = result["history"]
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({args.steps} steps); straggler verdict: "
          f"{trainer.monitor.detect().action}")


if __name__ == "__main__":
    main()
