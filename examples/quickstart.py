"""Quickstart: cost a runtime plan, read the EXPLAIN, let the planner pick.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import SHAPES, get_config
from repro.core import estimate, explain, single_pod_config
from repro.core.planner import build_step_program, choose_plan

def main():
    cc = single_pod_config()                 # 256-chip v5e pod (16x16)
    arch = get_config("qwen1.5-4b")
    shape = SHAPES["train_4k"]

    # 1) ask the cost-based planner for the best sharding plan
    decisions = choose_plan(arch, shape, cc, top_k=5)
    print("== plan ranking (C(P, cc), HBM estimate) ==")
    for d in decisions:
        mark = "*" if d is decisions[0] else " "
        print(f" {mark} {d.plan.describe():64s} "
              f"T={d.time*1e3:8.1f}ms  hbm={d.hbm_est/1e9:5.1f}GB  "
              f"feasible={d.feasible}")

    # 2) generate + cost the winner's runtime plan, SystemML-EXPLAIN style
    best = decisions[0]
    prog = build_step_program(arch, shape, best.plan, cc)
    costed = estimate(prog, cc.with_overlap(0.7))
    print("\n== costed runtime plan (depth 2) ==")
    print(explain(costed, max_depth=2))

if __name__ == "__main__":
    main()
