"""The paper's running example, end to end.

1. Reproduces §2: generated runtime plans for the five Table-1 scenarios
   (plan switches: tsmm vs mapmm vs cpmm, CP vs DIST, broadcast partition).
2. Actually EXECUTES a CPU-sized LinReg DS instance using the tsmm Pallas
   kernel (the paper's flagship physical operator) and verifies beta
   against numpy lstsq.
3. Compares estimated vs measured wall time (paper §3.4's 2x claim).

Run:  PYTHONPATH=src python examples/linreg_ds.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimate, explain
from repro.core.cluster import ClusterConfig, CPU_HOST, cpu_host_config
from repro.core.linreg import (CompilerBudgets, SCENARIOS, Scenario,
                               build_linreg_program)
from repro.kernels import ops

PAPER_CC = ClusterConfig(chip=CPU_HOST, mesh_shape=(72,), mesh_axes=("data",),
                         dispatch_latency=20.0)


def show_scenarios():
    print("== §2: generated plans across Table-1 scenarios ==")
    for name, sc in SCENARIOS.items():
        prog, choice = build_linreg_program(sc, PAPER_CC)
        costed = estimate(prog, PAPER_CC)
        print(f"  {name:4s} X:{sc.m}x{sc.n}  exec={choice.exec_type:4s} "
              f"Gram={choice.tsmm_op:9s} mm={choice.mm_op:6s} "
              f"partition_y={choice.partition_y}  C={costed.total:9.2f}s")
    prog, _ = build_linreg_program(SCENARIOS["XS"], PAPER_CC)
    print("\n== costed plan, scenario XS (paper Fig. 4) ==")
    print(explain(estimate(prog, PAPER_CC)))


def execute_small():
    print("\n== executing LinReg DS (CPU-sized) with the tsmm kernel ==")
    sc = Scenario("exec", 8192, 256, dtype="float64")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((sc.m, sc.n)), jnp.float32)
    beta_true = jnp.asarray(rng.standard_normal((sc.n, 1)), jnp.float32)
    y = x @ beta_true + 0.01 * jnp.asarray(
        rng.standard_normal((sc.m, 1)), jnp.float32)

    t0 = time.perf_counter()
    a = ops.tsmm(x, bm=512, bn=128)              # Pallas half-compute Gram
    a = a + 0.001 * jnp.eye(sc.n)
    b = x.T @ y
    beta = jnp.linalg.solve(a, b)
    wall = time.perf_counter() - t0

    ref = np.linalg.lstsq(np.asarray(x), np.asarray(y), rcond=None)[0]
    err = float(np.abs(np.asarray(beta) - ref).max())
    fit = float(np.abs(np.asarray(beta) - np.asarray(beta_true)).max())
    print(f"  solved {sc.m}x{sc.n} in {wall*1e3:.1f}ms (interpret-mode kernel)"
          f"  | max|beta - lstsq| = {err:.2e}  max|beta - true| = {fit:.3f}")

    cc = cpu_host_config()
    prog, _ = build_linreg_program(
        sc, cc, CompilerBudgets(local_mem=8e9, broadcast_mem=2e9,
                                block_size=4096))
    costed = estimate(prog, cc)
    print(f"  cost model estimate (compute side): "
          f"{costed.breakdown.compute*1e3:.1f}ms")


if __name__ == "__main__":
    show_scenarios()
    execute_small()
