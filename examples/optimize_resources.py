"""Resource optimizer: which cluster should this workload run on?

Enumerates cluster candidates (chip type x pod count x mesh layout x
ICI/DCN topology — including the v5p 3D-torus layouts, whose wrapped
rings double per-axis ICI bandwidth on full-cube axes and whose third
"depth" axis carries its own parallelism role, plus DCN multi-slice
grids whose pod axis can carry *pipeline stages*: try
``--arch qwen1.5-110b --shape train_4k`` to watch a frontier-dense model
fit nowhere except a pipelined multi-slice), co-searches the
sharding-plan space on each
through one shared sub-plan cost cache, and ranks them under your
objective — fastest step, cheapest step ($/step via
ChipSpec.cost_per_chip_hour), cheapest *job* ($/job with startup,
per-arch checkpoint-restore and expected-preemption overheads amortized
over --steps-per-job steps), or cheapest config meeting a step-time SLO.

``--shape`` also accepts a *serving workload* (chat_2k, rag_32k): the
optimizer then co-searches (pool layout x slot count x per-pool plan)
serving schedules under their traffic model — including disaggregated
prefill/decode pool pairs with the KV handoff priced on the DCN hop —
under ``--objective ttft_p99`` (cheapest fleet meeting the p99 TTFT SLO)
or ``tokens_per_dollar``.

Run:
  PYTHONPATH=src python examples/optimize_resources.py
  PYTHONPATH=src python examples/optimize_resources.py \
      --arch gemma3-12b --shape train_4k --objective cost
  PYTHONPATH=src python examples/optimize_resources.py \
      --arch qwen1.5-0.5b --shape decode_32k --objective job_cost \
      --steps-per-job 50000
  PYTHONPATH=src python examples/optimize_resources.py \
      --arch qwen1.5-0.5b --shape decode_32k --objective slo --slo-ms 50
  PYTHONPATH=src python examples/optimize_resources.py \
      --arch gemma3-12b --shape chat_2k --objective ttft_p99
"""
import argparse
import time

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core.resource import (DEFAULT_STEPS_PER_JOB, OBJECTIVES,
                                 ResourceSearchStats, enumerate_clusters,
                                 format_decisions, optimize_resources)
from repro.core.serving import (enumerate_serving_clusters,
                                format_serving_decisions)
from repro.core.workload import SERVE_WORKLOADS, SERVING_OBJECTIVES


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=ARCH_IDS)
    ap.add_argument("--shape", default="train_4k",
                    choices=list(SHAPES) + list(SERVE_WORKLOADS))
    ap.add_argument("--objective", default=None,
                    choices=(list(OBJECTIVES) + ["device_seconds"]
                             + list(SERVING_OBJECTIVES)),
                    help="default: step_time, or tokens_per_dollar for a "
                         "serving workload")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="step-time target in ms (objective=slo) or p99 "
                         "TTFT target (objective=ttft_p99)")
    ap.add_argument("--steps-per-job", type=int,
                    default=DEFAULT_STEPS_PER_JOB,
                    help="job length priced by objective=job_cost")
    ap.add_argument("--chips", nargs="+", default=None,
                    metavar="CHIP", help="restrict the chip table")
    ap.add_argument("--pod-counts", nargs="+", type=int, default=(1, 2, 4))
    ap.add_argument("--search", default="beam",
                    choices=["beam", "exhaustive"])
    args = ap.parse_args()

    serving = args.shape in SERVE_WORKLOADS
    if serving:
        clusters = enumerate_serving_clusters(
            chips=args.chips, pod_counts=tuple(args.pod_counts))
        shape = SERVE_WORKLOADS[args.shape]
        objective = args.objective or "tokens_per_dollar"
    else:
        clusters = enumerate_clusters(chips=args.chips,
                                      pod_counts=tuple(args.pod_counts))
        shape = SHAPES[args.shape]
        objective = args.objective or "step_time"
    slo = args.slo_ms / 1e3 if args.slo_ms is not None else None
    stats = ResourceSearchStats()
    t0 = time.perf_counter()
    decisions = optimize_resources(
        get_config(args.arch), shape, clusters,
        objective=objective, slo=slo, search=args.search,
        steps_per_job=args.steps_per_job, stats=stats)
    dt = time.perf_counter() - t0

    print(f"{args.arch} x {args.shape}, objective={objective}"
          + (f" (slo={args.slo_ms}ms)" if slo else ""))
    if serving:
        print(format_serving_decisions(decisions))
    else:
        print(format_decisions(decisions, slo=slo))
    print(f"\nwinner: {decisions[0].describe()}")
    print(f"search: {stats.describe()} in {dt * 1e3:.0f}ms "
          f"({args.search}); exhaustive scan would cost "
          f"{stats.exhaustive_plan_space} plan evaluations")


if __name__ == "__main__":
    main()
