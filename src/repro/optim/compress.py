"""Gradient compression for cross-pod reduction (distributed-opt trick).

Two schemes the planner can select (`grad_reduce_dtype` knob):
  * bf16 cast-reduce — halves DCN payload, no state;
  * int8 per-tensor affine quantization with **error feedback** — quarters
    the payload; the residual buffer re-injects quantization error next
    step so convergence is preserved (Seide et al. / EF-SGD style).

The collective itself is whatever the sharding plan generates (psum across
"pod"/"data"); these helpers transform the payload around it.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any           # same tree as grads, fp32


def init_error_feedback(grads_like: Any) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.abs(x).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Any, ef: EFState, scheme: str = "int8_ef"
                   ) -> Tuple[Any, EFState]:
    """Returns (compressed-then-decompressed grads, new EF state).

    The round-trip happens *before* the cross-pod psum so every pod
    contributes identical quantization semantics; the EF residual keeps
    what was lost.  scheme: "none" | "bf16" | "int8_ef".
    """
    if scheme == "none":
        return grads, ef
    if scheme == "bf16":
        return jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads), ef

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), g32 - deq

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tree.unflatten([o[0] for o in outs]),
            EFState(tree.unflatten([o[1] for o in outs])))


def payload_bytes(grads: Any, scheme: str) -> float:
    """What the wire sees — used by the cost model's collective term."""
    total = sum(g.size for g in jax.tree.leaves(grads))
    per = {"none": 4.0, "bf16": 2.0, "int8_ef": 1.0}[scheme]
    return total * per
