"""AdamW (pure pytree, optax-free) + schedules + global-norm clipping.

Supports reduced-precision first/second moments (a distributed-memory
trick the planner's HBM model prices) and decoupled weight decay.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"      # "bfloat16" halves optimizer HBM
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(cfg: AdamWConfig, params: Any) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply(cfg: AdamWConfig, state: AdamWState, grads: Any, params: Any
          ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        upd32 = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32)
        p32 = p.astype(jnp.float32) - lr * (upd32 + decay)
        return p32.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tree.unflatten([o[0] for o in out])
    new_m = tree.unflatten([o[1] for o in out])
    new_v = tree.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
