"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import attention_dense
from repro.models.mamba import ssd_chunked


def tsmm_ref(x: jax.Array, reg: float = 0.0) -> jax.Array:
    """Full Gram matrix X^T X (+ reg*I)."""
    g = jnp.einsum("mk,mn->kn", x.astype(jnp.float32),
                   x.astype(jnp.float32))
    if reg:
        g = g + reg * jnp.eye(x.shape[1], dtype=jnp.float32)
    return g.astype(x.dtype)


def matmul_epilogue_ref(x, w, bias=None, *, epilogue: Optional[str] = None,
                        out_dtype=None) -> jax.Array:
    """Unfused oracle: fp32 matmul, then the elementwise epilogue."""
    acc = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if epilogue == "bias":
        acc = acc + bias.astype(jnp.float32)[None, :]
    elif epilogue == "silu":
        acc = jax.nn.silu(acc)
    elif epilogue == "gelu":
        acc = jax.nn.gelu(acc)
    elif epilogue == "layernorm":
        mu = jnp.mean(acc, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(acc - mu), axis=-1, keepdims=True)
        acc = (acc - mu) * jax.lax.rsqrt(var + 1e-6)
    elif epilogue is not None:
        raise ValueError(f"unknown epilogue {epilogue!r}")
    return acc.astype(x.dtype if out_dtype is None else out_dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jax.Array:
    return attention_dense(q, k, v, causal=causal, window=window, scale=scale)


def ssd_scan_ref(x, dt, A_log, B, C, D, *, chunk: int = 256,
                 ) -> Tuple[jax.Array, jax.Array]:
    """Chunked-SSD oracle (validated against the sequential recurrence)."""
    return ssd_chunked(x, dt, A_log, B, C, D, chunk=chunk)
