"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import attention_dense
from repro.models.mamba import ssd_chunked


def tsmm_ref(x: jax.Array) -> jax.Array:
    """Full Gram matrix X^T X."""
    return jnp.einsum("mk,mn->kn", x.astype(jnp.float32),
                      x.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jax.Array:
    return attention_dense(q, k, v, causal=causal, window=window, scale=scale)


def ssd_scan_ref(x, dt, A_log, B, C, D, *, chunk: int = 256,
                 ) -> Tuple[jax.Array, jax.Array]:
    """Chunked-SSD oracle (validated against the sequential recurrence)."""
    return ssd_chunked(x, dt, A_log, B, C, D, chunk=chunk)
