"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (CPU container); on TPU pass
``interpret=False`` (or set REPRO_PALLAS_COMPILE=1).
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import matmul_epilogue as _mme
from repro.kernels import ssd_scan as _ssd
from repro.kernels import tsmm as _tsmm

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def tsmm(x: jax.Array, *, bm: int = 512, bn: int = 256, reg: float = 0.0,
         interpret: Optional[bool] = None) -> jax.Array:
    """Symmetric Gram matrix X^T X (+ reg*I) via the half-compute kernel.

    The kernel writes only upper-triangular tiles; the strict lower
    triangle is mirrored here (diagonal blocks are internally symmetric).
    ``reg`` fuses the LinReg DS ridge shift into the diagonal-tile flush.
    """
    up = _tsmm.tsmm_upper(x, bm=bm, bn=bn, reg=reg,
                          interpret=_INTERPRET if interpret is None else interpret)
    upper = jnp.triu(up)
    return upper + jnp.triu(up, 1).T


def matmul_epilogue(x: jax.Array, w: jax.Array, bias=None, *,
                    epilogue: Optional[str] = None, out_dtype=None,
                    bm: int = 256, bn: int = 256, bk: int = 256,
                    interpret: Optional[bool] = None) -> jax.Array:
    """``epilogue(x @ w)`` with the elementwise tail fused into the flush.

    Realizes the planner's ``fusion="full"`` matmul variants: epilogue in
    {None, "bias", "silu", "gelu", "layernorm"}, with ``out_dtype`` cast
    sinking (the fp32 accumulator is narrowed during the single write).
    """
    return _mme.matmul_epilogue(
        x, w, bias, epilogue=epilogue, out_dtype=out_dtype,
        bm=bm, bn=bn, bk=bk,
        interpret=_INTERPRET if interpret is None else interpret)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    bq: int = 512, bk: int = 512,
                    interpret: Optional[bool] = None) -> jax.Array:
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, scale=scale, bq=bq, bk=bk,
        interpret=_INTERPRET if interpret is None else interpret)


def ssd_scan(x, dt, A_log, B, C, D, *, chunk: int = 256,
             interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """Mamba2 SSD scan via the Pallas kernel (matches models.mamba API).

    x: [B,S,H,P]; dt: [B,S,H]; A_log: [H]; B/C: [B,S,G,N]; D: [H].
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    dt32 = jnp.maximum(dt.astype(jnp.float32), 1e-6)
    a = -jnp.exp(A_log.astype(jnp.float32))
    log_a = dt32 * a                                   # [B,S,H]
    xbar = x * dt32[..., None].astype(x.dtype)
    bmat = jnp.repeat(B, rep, axis=2).reshape(b, s, h, n)
    cmat = jnp.repeat(C, rep, axis=2).reshape(b, s, h, n)
    y, state = _ssd.ssd_scan_kernel(
        xbar, log_a, bmat, cmat, chunk=chunk,
        interpret=_INTERPRET if interpret is None else interpret)
    y = y + x * D.astype(x.dtype)[None, None, :, None]
    return y, state
