"""Pallas TPU kernel: blockwise online-softmax attention (flash-style).

Compute hot-spot of the prefill_32k / train_4k cells.  Grid is
(B, Hq, nq, nk) with the KV axis minormost & sequential; the running
max / normalizer / accumulator live in fp32 VMEM scratch across KV steps.
Causal and sliding-window bands skip whole KV blocks via ``pl.when``
(the TPU analogue of warp-level early-exit in GPU flash kernels).

GQA is handled in the index maps (kv head = q head // group), so no
K/V replication is materialized in HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams; accept either
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * bq
    q_hi = q_lo + bq - 1                      # highest query position
    k_lo = ki * bk
    k_hi = k_lo + bk - 1

    may_contribute = True
    if causal:
        may_contribute &= k_lo <= q_hi
    if window is not None:
        may_contribute &= k_hi > q_lo - window

    @pl.when(may_contribute)
    def _update():
        q = q_ref[0, 0]                        # [bq, d]
        k = k_ref[0, 0]                        # [bk, d]
        v = v_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq, bk]
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                    # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new) * mask
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * alpha
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    bq: int = 512, bk: int = 512,
                    interpret: bool = True) -> jax.Array:
    """q: [B,Hq,S,D], k/v: [B,Hkv,S,D] -> [B,Hq,S,D]."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0
    nq, nk = sq // bq, skv // bk
    scale = scale if scale is not None else d ** -0.5

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, h, qi, ki: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, h, qi, ki: (bi, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bi, h, qi, ki: (bi, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, h, qi, ki: (bi, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out
