"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Compute hot-spot of the mamba2/zamba2 cells.  One grid step processes one
(batch, head, chunk) tile; the chunk axis is minormost & sequential so the
inter-chunk SSM state lives in an fp32 VMEM scratch tile [P, N] carried
across chunk steps — the TPU analogue of the register-resident state in the
CUDA SSD kernel.

All chunk-local math is expressed as MXU matmuls:
  * inclusive cumsum of log-decays  -> lower-triangular ones matmul,
  * intra-chunk mixing  Y_diag = ((C B^T) * L) Xbar,
  * state emission       S_c    = (decay_to_end * Xbar)^T B,
  * state consumption    Y_off  = decay_in * (C S_prev^T).

Inputs are pre-scaled outside (xbar = x * dt, log_a = dt * A), and the
D-residual is applied in the wrapper — the kernel is the pure scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams; accept either
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _ssd_kernel(xbar_ref, loga_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, chunk: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xb = xbar_ref[0, :, 0, :].astype(jnp.float32)      # [l, p]
    la = loga_ref[0, :, 0].astype(jnp.float32)         # [l]
    bm = b_ref[0, :, 0, :].astype(jnp.float32)         # [l, n]
    cm = c_ref[0, :, 0, :].astype(jnp.float32)         # [l, n]
    l_len = chunk

    # inclusive cumsum via lower-triangular ones matmul (MXU-friendly)
    ii = jax.lax.broadcasted_iota(jnp.int32, (l_len, l_len), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l_len, l_len), 1)
    tri_incl = (jj <= ii).astype(jnp.float32)          # [l, l]
    cum = jax.lax.dot_general(tri_incl, la[:, None],
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)[:, 0]

    # decay matrices
    seg = cum[:, None] - cum[None, :]                  # L[i,j]=exp(sum j+1..i)
    lmask = (jj <= ii).astype(jnp.float32)
    lmat = jnp.exp(jnp.where(jj <= ii, seg, 0.0)) * lmask

    # intra-chunk
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot_general(scores * lmat, xb, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # cross-chunk: consume state entering this chunk
    state = state_ref[...]                             # [p, n] fp32
    decay_in = jnp.exp(cum)[:, None]                   # [l, 1]
    y_off = decay_in * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())),           # [l, n] x [p, n]^T
        preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # update state: S = exp(cum[-1]) * S + (decay_to_end * xbar)^T B
    total = cum[l_len - 1]
    decay_to_end = jnp.exp(total - cum)[:, None]       # [l, 1]
    emit = jax.lax.dot_general(xb * decay_to_end, bm,
                               (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # [p, n]
    state_ref[...] = jnp.exp(total) * state + emit

    @pl.when(ci == nc - 1)
    def _flush():
        state_out_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_kernel(xbar: jax.Array, log_a: jax.Array, bmat: jax.Array,
                    cmat: jax.Array, *, chunk: int = 256,
                    interpret: bool = True):
    """xbar: [B,S,H,P] (dt-scaled); log_a: [B,S,H]; bmat/cmat: [B,S,H,N]
    (groups pre-broadcast to heads).  Returns (y_core [B,S,H,P],
    final_state [B,H,P,N] fp32) — caller adds the D*x residual.
    """
    b, s, h, p = xbar.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nc=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), xbar.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xbar, log_a, bmat, cmat)
    return y, state
