"""Pallas TPU kernels for the perf-critical compute layers.

  * tsmm            — the paper's transpose-self matmul (half-compute Gram,
                      optional fused ridge epilogue X^T X + reg*I)
  * flash_attention — blockwise online-softmax attention (prefill hot-spot)
  * ssd_scan        — Mamba2 SSD chunked scan (ssm/hybrid hot-spot)
  * matmul_epilogue — blocked matmul with fused bias/silu/gelu/layernorm
                      epilogue + cast sinking (the fusion="full" variants)

Each has a jit'd wrapper in ops.py and a pure-jnp oracle in ref.py;
validated in interpret mode on CPU, targeted at TPU via BlockSpec tiling.
"""
from repro.kernels import ops, ref
