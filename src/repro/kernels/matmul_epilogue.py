"""Pallas TPU kernel: blocked matmul with a fused epilogue.

This is the kernel realization of the planner's ``fusion="full"`` matmul
variants (see ``linalg_ops._matmul`` and COST_MODEL.md "Costing fusion
plans"): the epilogue — bias add, SiLU/GELU activation, or row layernorm
— is applied to the fp32 accumulator tile *before* the single HBM write,
so the B·M·N intermediate never round-trips through HBM.  The analytical
profile charges exactly the traffic this kernel performs: the fused plan
saves ``cells x (write + read)`` bytes versus materializing the matmul
output and running the elementwise op as a second pass.

Cast sinking rides the same flush: ``out_dtype`` narrows (or widens) the
result during the accumulator write, which is how the serving head's
"fp32 logits" materialization is folded away under ``fusion="full"``.

Grid layout: (M/bm, N/bn, K/bk) with K minormost and sequential
("arbitrary") so the fp32 VMEM scratch accumulator is revisited legally;
the M and N axes are parallel.  The layernorm epilogue normalizes over
the full N row and therefore requires a single block along N (bn == n).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams; accept either
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

EPILOGUES = (None, "bias", "silu", "gelu", "layernorm")
_LN_EPS = 1e-6


def _epilogue_f32(acc: jax.Array, epilogue: Optional[str],
                  bias: Optional[jax.Array]) -> jax.Array:
    """Apply the epilogue in fp32 (mirrors ``ref.matmul_epilogue_ref``)."""
    if epilogue is None:
        return acc
    if epilogue == "bias":
        return acc + bias
    if epilogue == "silu":
        return jax.nn.silu(acc)
    if epilogue == "gelu":
        return jax.nn.gelu(acc)
    if epilogue == "layernorm":
        mu = jnp.mean(acc, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(acc - mu), axis=-1, keepdims=True)
        return (acc - mu) * jax.lax.rsqrt(var + _LN_EPS)
    raise ValueError(f"unknown epilogue {epilogue!r}")


def _mm_epi_kernel(*refs, k_steps: int, epilogue: Optional[str]):
    if epilogue == "bias":
        x_ref, w_ref, b_ref, out_ref, acc_ref = refs
    else:
        x_ref, w_ref, out_ref, acc_ref = refs
        b_ref = None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _flush():
        acc = acc_ref[...]
        bias = b_ref[...].astype(jnp.float32) if b_ref is not None else None
        out_ref[...] = _epilogue_f32(acc, epilogue, bias).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "epilogue", "out_dtype", "bm", "bn", "bk", "interpret"))
def matmul_epilogue(x: jax.Array, w: jax.Array,
                    bias: Optional[jax.Array] = None, *,
                    epilogue: Optional[str] = None,
                    out_dtype: Optional[jnp.dtype] = None,
                    bm: int = 256, bn: int = 256, bk: int = 256,
                    interpret: bool = True) -> jax.Array:
    """``epilogue(x @ w)`` written once, in ``out_dtype``.

    x: [m, k]; w: [k, n]; bias: [n] (required iff epilogue == "bias").
    Block sizes must tile the operands exactly; layernorm needs bn == n.
    """
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, (x.shape, w.shape)
    if epilogue not in EPILOGUES:
        raise ValueError(f"unknown epilogue {epilogue!r}")
    if epilogue == "layernorm":
        assert bn == n, ("layernorm epilogue normalizes the full row; "
                         f"need bn == n, got bn={bn} n={n}")
    if (epilogue == "bias") != (bias is not None):
        raise ValueError("bias operand required iff epilogue == 'bias'")
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, \
        (x.shape, w.shape, bm, bn, bk)
    mb, nb, kk = m // bm, n // bn, kdim // bk
    out_dtype = x.dtype if out_dtype is None else out_dtype

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    args = [x, w]
    if epilogue == "bias":
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        args.append(bias.reshape(1, n))

    fn = pl.pallas_call(
        functools.partial(_mm_epi_kernel, k_steps=kk, epilogue=epilogue),
        grid=(mb, nb, kk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    return fn(*args)
