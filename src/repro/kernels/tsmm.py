"""Pallas TPU kernel: tsmm — transpose-self matmul  G = X^T X.

The paper's flagship physical operator: "exploit the unary input
characteristic and the known result symmetry which allows to do only half
the computation" (§2).  SystemML's CPU tsmm skips the lower triangle
element-wise; the TPU-native adaptation skips **whole MXU output tiles**:

  * the (n/bn x n/bn) grid of output blocks is linearized to only the
    upper-triangular pairs (i <= j) — T = nb(nb+1)/2 grid steps instead of
    nb^2.  The (i, j) pair for each step is scalar-prefetched (the splash-
    attention trick), so BlockSpec index_maps stay O(1);
  * each step accumulates X_i^T X_j over the m-dimension grid axis into an
    fp32 VMEM scratch tile, writing the bf16/f32 result tile once at the
    last m-step (HBM write traffic = half the Gram matrix, once);
  * the strict lower triangle is never computed nor written — the ops.py
    wrapper mirrors it in one cheap transpose.

Grid layout: (T, K) with K = m/bm minormost & sequential ("arbitrary"), so
the output tile revisit pattern is legal; T is parallel.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams; accept either
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _upper_pairs(nb: int) -> Tuple[np.ndarray, np.ndarray]:
    """Linearized upper-triangular block pairs (i <= j)."""
    ii, jj = [], []
    for i in range(nb):
        for j in range(i, nb):
            ii.append(i)
            jj.append(j)
    return np.asarray(ii, np.int32), np.asarray(jj, np.int32)


def _tsmm_kernel(i_ref, j_ref, xi_ref, xj_ref, out_ref, acc_ref, *,
                 k_steps: int, reg: float):
    s = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xi = xi_ref[...]                       # [bm, bn]
    xj = xj_ref[...]                       # [bm, bn]
    acc_ref[...] += jax.lax.dot_general(
        xi, xj, (((0,), (0,)), ((), ())),  # contract over m: X_i^T @ X_j
        preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _flush():
        acc = acc_ref[...]
        if reg != 0.0:                     # static: compiled away when 0
            bn = acc.shape[0]
            eye = (jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
                   == jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1))
            on_diag = i_ref[s] == j_ref[s]
            acc = acc + jnp.where(eye & on_diag, jnp.float32(reg), 0.0)
        out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "reg", "interpret"))
def tsmm_upper(x: jax.Array, *, bm: int = 512, bn: int = 256,
               reg: float = 0.0, interpret: bool = True) -> jax.Array:
    """Upper-triangular blocks of X^T X + reg*I (lower-left tiles zero).

    x: [m, n] with m % bm == 0 and n % bn == 0.  ``reg`` is the ridge
    epilogue of the paper's LinReg DS solve (G = X^T X + lambda*I): the
    diagonal shift is fused into the accumulator flush of the diagonal
    blocks, so G is still written exactly once.
    """
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (x.shape, bm, bn)
    nb, kk = n // bn, m // bm
    ii, jj = _upper_pairs(nb)
    t = len(ii)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t, kk),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda s, k, ii, jj: (k, ii[s])),
            pl.BlockSpec((bm, bn), lambda s, k, ii, jj: (k, jj[s])),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda s, k, ii, jj: (ii[s], jj[s])),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
    )
    fn = pl.pallas_call(
        functools.partial(_tsmm_kernel, k_steps=kk, reg=reg),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, n), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )
    return fn(jnp.asarray(ii), jnp.asarray(jj), x, x)
