"""Sharded, fault-tolerant checkpointing (orbax-free, stdlib + numpy).

Layout (one directory per step)::

    ckpt_dir/
      step_000123/
        MANIFEST.json        # tree structure, shapes, dtypes, crc32s, step
        host0000_leaf0000.npy ...
      LATEST                 # atomic pointer file

Properties required at 1000-node scale:
  * each host writes only its addressable shard rows (here: process 0
    writes all — the shard math is keyed off ``host_index``/``num_hosts``
    so multi-host behaves identically);
  * atomic commit — data written to ``.tmp-<step>``, fsynced, then
    ``rename``d; LATEST updated last.  A crash never leaves a readable but
    partial checkpoint;
  * integrity — every leaf carries a crc32; restore verifies;
  * **elastic restore** — the manifest stores global shapes, so a restore
    onto a different mesh/plan just re-``device_put``s with new shardings
    (re-sharding is the runtime's job, the store is layout-agnostic);
  * async — ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a background thread, overlapping the next train steps.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

try:                                    # bundled with jax
    import ml_dtypes
    _CUSTOM_DTYPES = {
        "bfloat16": (np.uint16, ml_dtypes.bfloat16),
        "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
        "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
    }
except ImportError:                     # pragma: no cover
    _CUSTOM_DTYPES = {}

_SEP = "/"


def _to_savable(arr: np.ndarray):
    """numpy can't persist ml_dtypes natively — store the raw bit view."""
    name = str(arr.dtype)
    if name in _CUSTOM_DTYPES:
        return arr.view(_CUSTOM_DTYPES[name][0]), name
    return arr, name


def _from_savable(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _CUSTOM_DTYPES and str(arr.dtype) != logical_dtype:
        return arr.view(_CUSTOM_DTYPES[logical_dtype][1])
    return arr


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any, *, host_index: int = 0,
         extra_meta: Optional[Dict] = None) -> str:
    """Synchronous sharded save with atomic commit.  Returns final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp-{step:08d}-{host_index}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest: Dict[str, Any] = {"step": step, "leaves": {},
                                "meta": extra_meta or {}}
    for i, (key, leaf) in enumerate(_flatten_with_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        savable, logical = _to_savable(arr)
        fname = f"host{host_index:04d}_leaf{i:04d}.npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, savable, allow_pickle=False)
        with open(fpath, "rb") as f:
            crc = zlib.crc32(f.read())
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape),
            "dtype": logical, "crc32": crc,
        }
    mpath = os.path.join(tmp, "MANIFEST.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                    # atomic commit
    latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a daemon thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, **kw) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save(self.ckpt_dir, step, host_tree, **kw)
                self._gc()
            except BaseException as e:       # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            return int(f.read().strip().split("_")[-1])
    except (FileNotFoundError, ValueError):
        return None


def restore(ckpt_dir: str, tree_like: Any, *, step: Optional[int] = None,
            shardings: Any = None, verify: bool = True) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional pytree (same structure) of NamedShardings for
    elastic re-placement onto a *different* mesh than the one that saved.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)

    keys = [k for k, _ in _flatten_with_paths(tree_like)]
    shard_list = (jax.tree.leaves(shardings) if shardings is not None
                  else [None] * len(keys))
    leaves = []
    for key, shard in zip(keys, shard_list):
        ent = manifest["leaves"].get(key)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        fpath = os.path.join(path, ent["file"])
        if verify:
            with open(fpath, "rb") as f:
                if zlib.crc32(f.read()) != ent["crc32"]:
                    raise IOError(f"checksum mismatch for {key} in {path}")
        arr = _from_savable(np.load(fpath, allow_pickle=False), ent["dtype"])
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.numpy.asarray(arr))
    tree_def = jax.tree.structure(tree_like)
    return jax.tree.unflatten(tree_def, leaves), step
