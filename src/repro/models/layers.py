"""Shared model primitives (pure JAX, GSPMD-friendly einsum formulations).

Everything here is written so the XLA partitioner can shard it cleanly
under the plans produced by :mod:`repro.core.planner`:

  * attention is *chunked* (online-softmax over KV blocks) so prefill at
    32k/500k never materializes an S x S score tensor — the pure-jnp
    analogue of the Pallas flash kernel in :mod:`repro.kernels`;
  * sliding-window layers visit a statically-bounded band of KV chunks,
    so local layers cost O(S * (W + C)) flops, not O(S^2);
  * MoE uses GShard-style capacity dispatch einsums (all-to-all friendly);
  * MLA implements DeepSeek's low-rank q/kv compression with the absorbed
    (MQA-over-latent) decode path (see transformer.py).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import costing_mode

NEG_INF = -1e30

# ---------------------------------------------------------------------------
# Small pieces
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps))
            * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, D]; positions broadcastable to x.shape[:-1]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., S, D/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _band_mask(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: Optional[int]) -> jax.Array:
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    return mask


def attention_dense(q, k, v, *, causal: bool = True,
                    q_offset=0, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    out_dim: Optional[int] = None) -> jax.Array:
    """Direct softmax attention (oracle + small-S path).

    q: [B, Hq, Sq, Dk], k: [B, Hkv, Skv, Dk], v: [B, Hkv, Skv, Dv].
    ``q_offset``: absolute position of q[0] relative to k[0] (decode).
    Supports Dk != Dv (MLA absorbed path).
    """
    b, hq, sq, dk = q.shape
    _, hkv, skv, dv = v.shape
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    qg = q.reshape(b, hkv, g, sq, dk)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(skv)
    mask = _band_mask(q_pos, k_pos, causal, window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m) * mask[None, None, None]
    l = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p / l, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, dv).astype(q.dtype)


def attention_chunked(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      scale: Optional[float] = None) -> jax.Array:
    """Online-softmax attention over KV chunks (flash-style, pure jnp).

    Query chunks are a static python loop; each visits only the KV chunks
    its causal/window band can intersect, via a lax.scan with dynamic
    slicing.  Peak memory O(q_chunk * kv_chunk) scores per head.
    """
    b, hq, sq, dk = q.shape
    _, hkv, skv, dv = v.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(dk)
    if sq <= 2048 and skv <= 2048:
        return attention_dense(q, k, v, causal=causal, window=window, scale=scale)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # pad ragged tails (e.g. MTP's S-1 sequences); padded keys are masked
    # off via the k_pos < skv check, padded queries are sliced off.
    pad_q = (-sq) % q_chunk
    pad_k = (-skv) % kv_chunk
    sq_p, skv_p = sq + pad_q, skv + pad_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    valid_kv = skv
    nq, nk = sq_p // q_chunk, skv_p // kv_chunk
    g = hq // hkv
    qr = q.reshape(b, hkv, g, nq, q_chunk, dk)

    def kv_step(qi, qc, carry, j, masked: bool):
        """masked=False for blocks fully inside the causal/window band —
        skips mask broadcast/select/compare entirely (they dominated the
        per-layer HBM bytes; see EXPERIMENTS.md §Perf)."""
        m, l, acc = carry
        kj = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, axis=2)
        # bf16 operands -> fp32 accumulation on the MXU: no convert ops
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, kj,
                       preferred_element_type=jnp.float32) * scale
        if masked:
            q_pos = qi * q_chunk + jnp.arange(q_chunk)
            k_pos = j * kv_chunk + jnp.arange(kv_chunk)
            mask = _band_mask(q_pos, k_pos, causal, window)
            mask &= (k_pos < valid_kv)[None, :]          # padded keys
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        if masked:
            p = p * mask[None, None, None]
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(q.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    def _interior_range(qi, lo, kv_hi):
        """KV-chunk indices fully inside the band (no masking needed)."""
        q_lo, q_hi = qi * q_chunk, (qi + 1) * q_chunk - 1
        int_lo, int_hi = lo, kv_hi
        if causal:
            # block fully past? need k_hi = j*kc+kc-1 <= q_lo
            int_hi = min(int_hi, (q_lo + 1) // kv_chunk)
        if window is not None:
            # fully inside window: k_lo = j*kc >= q_hi - window + 1
            int_lo = max(int_lo, -(-(q_hi - window + 1) // kv_chunk))
        if pad_k:
            int_hi = min(int_hi, skv // kv_chunk)   # padded tail needs mask
        return int_lo, max(int_hi, int_lo)

    outs = []
    for qi in range(nq):                       # static unroll over q blocks
        kv_hi = min(nk, -(-((qi + 1) * q_chunk) // kv_chunk)) if causal else nk
        lo = max(0, (qi * q_chunk - (window or 0)) // kv_chunk) if window else 0
        qc = qr[:, :, :, qi]
        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dv), jnp.float32)
        carry = (m0, l0, a0)
        int_lo, int_hi = _interior_range(qi, lo, kv_hi)
        # boundary blocks BEFORE the interior (window edge)
        for j in range(lo, int_lo):
            carry, _ = kv_step(qi, qc, carry, j, True)
        if int_hi > int_lo:                    # unmasked interior sweep
            carry, _ = jax.lax.scan(
                lambda c, j, _qi=qi, _qc=qc: kv_step(_qi, _qc, c, j, False),
                carry, jnp.arange(int_lo, int_hi),
                unroll=True if costing_mode.unroll_scans() else 1)
        for j in range(max(int_hi, int_lo), kv_hi):   # diagonal/tail blocks
            carry, _ = kv_step(qi, qc, carry, j, True)
        m, l, acc = carry
        outs.append(acc / jnp.maximum(l[..., None], 1e-30))
    o = jnp.stack(outs, axis=3)                # [b,hkv,g,nq,qc,dv]
    o = o.reshape(b, hkv, g, sq_p, dv).reshape(b, hq, sq_p, dv)
    return o[:, :, :sq].astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              q_offset=0, scale: Optional[float] = None,
              use_kernel: bool = False) -> jax.Array:
    """Dispatch: dense for small/decode, chunked for long prefill/train.

    ``use_kernel=True`` routes to the Pallas flash kernel (TPU target;
    interpret-mode on CPU) — see repro.kernels.ops.
    """
    sq, skv = q.shape[2], k.shape[2]
    if use_kernel and sq > 1 and q.shape[-1] == v.shape[-1]:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    scale=scale)
    if sq == 1 or (sq <= 2048 and skv <= 2048):
        return attention_dense(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, scale=scale)
    # flash-style recompute-in-backward: without this, the kv-chunk scan
    # saves every per-step softmax residual for the backward pass and the
    # compiled plan's temp memory explodes (observed 73 GB/device at
    # train_4k — see EXPERIMENTS.md §Perf iteration 1).
    chunked = jax.checkpoint(
        functools.partial(attention_chunked, causal=causal, window=window,
                          scale=scale))
    return chunked(q, k, v)


# ---------------------------------------------------------------------------
# FFN / MoE
# ---------------------------------------------------------------------------


def ffn(x: jax.Array, params: Dict[str, jax.Array], gated: bool,
        act: str = "silu") -> jax.Array:
    """Dense MLP. gated: SwiGLU (w_gate, w_up, w_down); else (w_up, w_down)."""
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    if gated:
        h = actf(dense(x, params["w_gate"])) * dense(x, params["w_up"])
    else:
        h = actf(dense(x, params["w_up"], params.get("b_up")))
    out = dense(h, params["w_down"], params.get("b_down"))
    return out


def moe_ffn(x: jax.Array, params: Dict[str, jax.Array], *, top_k: int,
            capacity_factor: float, gated: bool,
            group_size: int = 4096) -> Tuple[jax.Array, jax.Array]:
    """GShard-style capacity-based MoE with token grouping.

    x: [T, d].  params: w_router [d, E]; w_gate/w_up [E, d, ff]; w_down
    [E, ff, d].  Returns (out [T, d], aux_loss scalar).

    Tokens are split into groups of ``group_size`` (per-device blocks in
    GShard) so the dispatch one-hot is [G, Tg, E, Cg] with Cg ~ Tg*k/E —
    linear in T, and the form GSPMD turns into all-to-alls under expert
    sharding.  Capacity (and hence dropping) is per-group.
    """
    t, d = x.shape
    e = params["w_router"].shape[-1]
    tg = min(group_size, t)
    if t % tg != 0:                                  # fall back: one group
        tg = t
    g = t // tg
    capacity = max(int(capacity_factor * top_k * tg / e), 1)
    xg = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["w_router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # [G, Tg, k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert queue (per group)
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)    # [G, Tg, k, E]
    flat = onehot.reshape(g, tg * top_k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(g, tg, top_k, e)
    pos = jnp.einsum("gtke,gtke->gtk", pos, onehot)            # [G, Tg, k]
    keep = pos < capacity
    gate_vals = gate_vals * keep

    pos_cap = jnp.where(keep, pos, 0).astype(jnp.int32)
    disp = (onehot * keep[..., None]).astype(jnp.float32)      # [G, Tg, k, E]
    pos_onehot = jax.nn.one_hot(pos_cap, capacity, dtype=jnp.float32)
    dispatch = jnp.einsum("gtke,gtkc->gtec", disp, pos_onehot)  # [G,Tg,E,C]
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gate_vals, disp, pos_onehot)

    xe = jnp.einsum("gtd,gtec->gecd", xg.astype(jnp.float32), dispatch)
    xe = xe.astype(x.dtype)                                    # [G, E, C, d]
    actf = jax.nn.silu
    if gated:
        h = actf(jnp.einsum("gecd,edf->gecf", xe, params["w_gate"].astype(x.dtype)))
        h = h * jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(x.dtype))
    else:
        h = actf(jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(x.dtype)))
    ye = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(x.dtype))
    out = jnp.einsum("gecd,gtec->gtd", ye.astype(jnp.float32), combine)

    # load-balance aux loss (Switch-style), averaged over groups
    density = onehot.sum(2).mean(1)                            # [G, E]
    density_proxy = probs.mean(1)
    aux = (density * density_proxy).sum(-1).mean() * e
    return out.reshape(t, d).astype(x.dtype), aux.astype(jnp.float32)
