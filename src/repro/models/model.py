"""Model facade: ``build_model(cfg)`` -> init / loss / prefill / decode.

This is the single entry point the launcher, dry-run, tests and examples
use; arch-specific wiring lives in transformer.py / mamba.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------- params
    def init(self, rng) -> T.Params:
        return T.init_params(self.cfg, rng)

    def init_shapes(self, rng=None) -> Any:
        return jax.eval_shape(lambda: T.init_params(
            self.cfg, jax.random.PRNGKey(0)))

    # ------------------------------------------------------------ training
    def loss(self, params, batch, *, remat: str = "none",
             use_kernel: bool = False, capacity_factor=None):
        return T.loss_fn(self.cfg, params, batch, remat=remat,
                         use_kernel=use_kernel,
                         capacity_factor=capacity_factor)

    def forward(self, params, tokens, frontend=None, *, remat="none",
                use_kernel: bool = False):
        return T.forward(self.cfg, params, tokens, frontend, remat=remat,
                         use_kernel=use_kernel)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, max_len: int) -> T.Cache:
        return T.init_cache(self.cfg, batch, max_len)

    def cache_shapes(self, batch: int, max_len: int) -> Any:
        return jax.eval_shape(partial(T.init_cache, self.cfg, batch, max_len))

    def prefill(self, params, tokens, cache, frontend=None, *,
                use_kernel: bool = False, capacity_factor=None):
        return T.prefill(self.cfg, params, tokens, cache, frontend,
                         use_kernel=use_kernel, capacity_factor=capacity_factor)

    def decode_step(self, params, token, cache, *, use_kernel: bool = False,
                    capacity_factor=None):
        return T.decode_step(self.cfg, params, token, cache,
                             use_kernel=use_kernel,
                             capacity_factor=capacity_factor)

    # ------------------------------------------------------------- helpers
    def frontend_shape(self, batch: int) -> Optional[Tuple[int, ...]]:
        cfg = self.cfg
        if cfg.frontend == "none" or not cfg.frontend_seq:
            return None
        return (batch, cfg.frontend_seq, cfg.d_model)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
