"""Costing mode: fully unroll inner lax.scans while lowering components.

XLA's HloCostAnalysis visits a while-loop body once, so any scan-based
inner loop (chunked attention KV sweep, SSD inter-chunk recurrence)
under-reports FLOPs/bytes by its trip count.  When components are lowered
for *costing* (never for execution), we fully unroll those scans so the
generated HLO carries the true op counts.  Runtime behaviour is untouched
— the flag defaults to off and is only set inside component_cost.
"""
from __future__ import annotations

import contextlib

_UNROLL = False


def unroll_scans() -> bool:
    return _UNROLL


@contextlib.contextmanager
def costing_unroll():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev
