"""Decoder-LM / enc-dec / MoE / MLA / hybrid transformer assembly.

One implementation covers all 10 assigned architectures, driven entirely by
:class:`repro.configs.base.ArchConfig`:

  * homogeneous decoder stacks run as ONE ``lax.scan`` over stacked layer
    params (compact HLO — essential for the 512-device dry-run compiles);
  * gemma3's 5-local:1-global pattern scans over *cycles* (pattern period)
    so every layer keeps a static window — local layers get ring-buffer KV
    caches of size W, global layers full-length caches;
  * deepseek: MLA attention (low-rank q/kv, decoupled rope) with the
    absorbed MQA-over-latent decode path, 3 dense + 58 MoE layers as two
    scans, optional MTP head;
  * zamba2: 9 segments of (6 scanned mamba2 layers + shared attention
    block, params alternating between 2 shared sets);
  * whisper: encoder (non-causal) + decoder (causal self + cross) with the
    audio frontend stubbed as precomputed frame embeddings.

Params are plain pytrees of jnp arrays; leaves of scanned stacks carry a
leading layer axis.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import costing_mode
from repro.models import layers as L
from repro.models import mamba as M

Params = Dict[str, Any]
Cache = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _norm_init(rng, shape, scale, dtype):
    return (jax.random.normal(rng, shape) * scale).astype(dtype)


def attn_init(rng, cfg: ArchConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.head_dim_
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 8)
    std = d ** -0.5
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "w_dq": _norm_init(ks[0], (d, m.q_lora_rank), std, dtype),
            "q_norm": jnp.zeros((m.q_lora_rank,), jnp.float32),
            "w_uq": _norm_init(ks[1], (m.q_lora_rank, nh * m.qk_head_dim),
                               m.q_lora_rank ** -0.5, dtype),
            "w_dkv": _norm_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                                std, dtype),
            "kv_norm": jnp.zeros((m.kv_lora_rank,), jnp.float32),
            "w_ukv": _norm_init(ks[3], (m.kv_lora_rank,
                                        nh * (m.qk_nope_head_dim + m.v_head_dim)),
                                m.kv_lora_rank ** -0.5, dtype),
            "w_o": _norm_init(ks[4], (nh * m.v_head_dim, d),
                              (nh * m.v_head_dim) ** -0.5, dtype),
        }
    p = {
        "w_q": _norm_init(ks[0], (d, nh * hd), std, dtype),
        "w_k": _norm_init(ks[1], (d, nkv * hd), std, dtype),
        "w_v": _norm_init(ks[2], (d, nkv * hd), std, dtype),
        "w_o": _norm_init(ks[3], (nh * hd, d), (nh * hd) ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((nh * hd,), dtype)
        p["b_k"] = jnp.zeros((nkv * hd,), dtype)
        p["b_v"] = jnp.zeros((nkv * hd,), dtype)
    return p


def mlp_init(rng, cfg: ArchConfig, d_ff: int, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    std = d ** -0.5
    p = {"w_up": _norm_init(ks[0], (d, d_ff), std, dtype),
         "w_down": _norm_init(ks[1], (d_ff, d), d_ff ** -0.5, dtype)}
    if cfg.gated_mlp:
        p["w_gate"] = _norm_init(ks[2], (d, d_ff), std, dtype)
    return p


def moe_init(rng, cfg: ArchConfig, dtype) -> Params:
    mc = cfg.moe
    d, e, f = cfg.d_model, mc.n_experts, mc.d_ff_expert
    ks = jax.random.split(rng, 5)
    std = d ** -0.5
    p = {
        "w_router": _norm_init(ks[0], (d, e), std, jnp.float32),
        "w_up": _norm_init(ks[1], (e, d, f), std, dtype),
        "w_down": _norm_init(ks[2], (e, f, d), f ** -0.5, dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _norm_init(ks[3], (e, d, f), std, dtype)
    if mc.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, mc.n_shared_experts * f, dtype)
    return p


def block_init(rng, cfg: ArchConfig, *, moe: bool, cross: bool, dtype) -> Params:
    ks = jax.random.split(rng, 4)
    d = cfg.d_model
    p: Params = {"ln1": jnp.zeros((d,), jnp.float32),
                 "ln2": jnp.zeros((d,), jnp.float32),
                 "attn": attn_init(ks[0], cfg, dtype)}
    if moe:
        p["moe"] = moe_init(ks[1], cfg, dtype)
    else:
        d_ff = cfg.d_ff if cfg.d_ff else 4 * d
        if cfg.moe is not None and cfg.moe.d_ff_dense:
            d_ff = cfg.moe.d_ff_dense
        p["mlp"] = mlp_init(ks[1], cfg, d_ff, dtype)
    if cross:
        p["ln_cross"] = jnp.zeros((d,), jnp.float32)
        p["cross"] = attn_init(ks[2], cfg, dtype)
    return p


def _stack(rng, n: int, init_fn) -> Params:
    ps = [init_fn(k) for k in jax.random.split(rng, n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *ps)


def init_params(cfg: ArchConfig, rng) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 10)
    d = cfg.d_model
    params: Params = {
        "embed": _norm_init(ks[0], (cfg.vocab_size, d), 1.0, dtype),
        "final_norm": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _norm_init(ks[1], (d, cfg.vocab_size), d ** -0.5, dtype)

    fam = cfg.family
    if fam == "ssm":
        params["blocks"] = _stack(ks[2], cfg.n_layers,
                                  lambda k: dict(
                                      ln=jnp.zeros((d,), jnp.float32),
                                      mamba=M.mamba_block_init(k, d, cfg.ssm, dtype)))
    elif fam == "hybrid":
        params["blocks"] = _stack(ks[2], cfg.n_layers,
                                  lambda k: dict(
                                      ln=jnp.zeros((d,), jnp.float32),
                                      mamba=M.mamba_block_init(k, d, cfg.ssm, dtype)))
        params["shared_attn"] = [
            block_init(k, cfg, moe=False, cross=False, dtype=dtype)
            for k in jax.random.split(ks[3], cfg.hybrid.n_shared_attn_blocks)]
    elif cfg.enc_dec is not None:
        params["enc_blocks"] = _stack(
            ks[2], cfg.enc_dec.n_encoder_layers,
            lambda k: block_init(k, cfg, moe=False, cross=False, dtype=dtype))
        params["enc_norm"] = jnp.zeros((d,), jnp.float32)
        params["blocks"] = _stack(
            ks[3], cfg.n_layers,
            lambda k: block_init(k, cfg, moe=False, cross=True, dtype=dtype))
    elif cfg.moe is not None:
        nd = cfg.moe.first_dense_layers
        if nd:
            params["dense_blocks"] = _stack(
                ks[2], nd, lambda k: block_init(k, cfg, moe=False, cross=False,
                                                dtype=dtype))
        params["blocks"] = _stack(
            ks[3], cfg.n_layers - nd,
            lambda k: block_init(k, cfg, moe=True, cross=False, dtype=dtype))
        if cfg.mtp_depth:
            params["mtp"] = {
                "proj": _norm_init(ks[4], (2 * d, d), (2 * d) ** -0.5, dtype),
                "block": block_init(ks[5], cfg, moe=False, cross=False, dtype=dtype),
                "norm": jnp.zeros((d,), jnp.float32),
            }
    elif cfg.window_pattern is not None:
        period = len(cfg.window_pattern)
        n_cycles = cfg.n_layers // period
        assert n_cycles * period == cfg.n_layers
        params["cycles"] = _stack(
            ks[2], n_cycles,
            lambda k: [block_init(kk, cfg, moe=False, cross=False, dtype=dtype)
                       for kk in jax.random.split(k, period)])
    else:
        params["blocks"] = _stack(
            ks[2], cfg.n_layers,
            lambda k: block_init(k, cfg, moe=False, cross=False, dtype=dtype))
    return params


# ---------------------------------------------------------------------------
# Attention sublayer apply (dense QKV path + caches)
# ---------------------------------------------------------------------------


def gqa_attention(cfg: ArchConfig, p: Params, x: jax.Array, *,
                  positions: jax.Array, window: Optional[int],
                  causal: bool = True,
                  kv_cache: Optional[Dict[str, jax.Array]] = None,
                  kv_source: Optional[jax.Array] = None,
                  use_kernel: bool = False,
                  ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Standard GQA attention.  x: [B,S,d].

    kv_cache: {"k","v": [B,Hkv,S_c,hd], "kpos": [S_c]} — ring or full.
    kv_source: cross-attention source (whisper); disables rope+cache-write.
    """
    b, s, d = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = L.dense(x, p["w_q"], p.get("b_q")).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    src = kv_source if kv_source is not None else x
    sk = src.shape[1]
    k = L.dense(src, p["w_k"], p.get("b_k")).reshape(b, sk, nkv, hd).transpose(0, 2, 1, 3)
    v = L.dense(src, p["w_v"], p.get("b_v")).reshape(b, sk, nkv, hd).transpose(0, 2, 1, 3)

    new_cache = None
    if kv_source is not None:
        out = L.attention_dense(q, k, v, causal=False)
    else:
        q = L.apply_rope(q, positions[:, None, :].repeat(nh, 1), cfg.rope_theta)
        k = L.apply_rope(k, positions[:, None, :].repeat(nkv, 1), cfg.rope_theta)
        if kv_cache is None:
            out = L.attention(q, k, v, causal=causal, window=window,
                              use_kernel=use_kernel)
        else:
            ck, cv, kpos = kv_cache["k"], kv_cache["v"], kv_cache["kpos"]
            cap = ck.shape[2]
            if s == 1:                                     # decode
                pos = positions[0, 0]
                slot = pos % cap
                ck = jax.lax.dynamic_update_slice_in_dim(ck, k, slot, axis=2)
                cv = jax.lax.dynamic_update_slice_in_dim(cv, v, slot, axis=2)
                kpos = jax.lax.dynamic_update_slice_in_dim(
                    kpos, pos[None].astype(kpos.dtype), slot, axis=0)
                valid = (kpos >= 0) & (kpos <= pos)
                if window is not None:
                    valid &= kpos > pos - window
                scores_mask = valid[None, None, None, :]
                out = _masked_dense_attention(q, ck, cv, scores_mask)
            else:                                          # prefill
                if s >= cap:
                    ck = k[:, :, s - cap:]
                    cv = v[:, :, s - cap:]
                    kpos = positions[0, s - cap:].astype(jnp.int32)
                else:
                    ck = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, axis=2)
                    cv = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, axis=2)
                    kpos = jax.lax.dynamic_update_slice_in_dim(
                        kpos, positions[0].astype(jnp.int32), 0, axis=0)
                out = L.attention(q, k, v, causal=causal, window=window,
                                  use_kernel=use_kernel)
            new_cache = {"k": ck, "v": cv, "kpos": kpos}
    out = out.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    return L.dense(out, p["w_o"]), new_cache


def _masked_dense_attention(q, k, v, mask) -> jax.Array:
    b, hq, sq, dk = q.shape
    _, hkv, skv, dv = v.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(dk)
    qg = q.reshape(b, hkv, g, sq, dk)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(mask[:, :, None], s, L.NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m) * mask[:, :, None]
    l = jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p / l, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, dv).astype(q.dtype)


def mla_attention(cfg: ArchConfig, p: Params, x: jax.Array, *,
                  positions: jax.Array,
                  kv_cache: Optional[Dict[str, jax.Array]] = None,
                  use_kernel: bool = False,
                  ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """DeepSeek MLA.  Cache holds the compressed latent (c_kv + k_rope)."""
    m = cfg.mla
    b, s, d = x.shape
    nh = cfg.n_heads
    r, rd = m.kv_lora_rank, m.qk_rope_head_dim
    dn, dv_ = m.qk_nope_head_dim, m.v_head_dim

    cq = L.rms_norm(L.dense(x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = L.dense(cq, p["w_uq"]).reshape(b, s, nh, m.qk_head_dim).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, positions[:, None, :].repeat(nh, 1), cfg.rope_theta)

    ckv_full = L.dense(x, p["w_dkv"])                      # [B,S,r+rd]
    c_kv = L.rms_norm(ckv_full[..., :r], p["kv_norm"], cfg.norm_eps)
    k_rope = ckv_full[..., None, r:]                       # [B,S,1,rd]
    k_rope = L.apply_rope(k_rope.transpose(0, 2, 1, 3),
                          positions[:, None, :], cfg.rope_theta)  # [B,1,S,rd]

    scale = 1.0 / math.sqrt(m.qk_head_dim)
    new_cache = None
    if kv_cache is not None and s == 1:
        # ---- absorbed decode: MQA over the latent cache ----
        pos = positions[0, 0]
        cc, ckr = kv_cache["ckv"], kv_cache["krope"]       # [B,S_c,r],[B,S_c,rd]
        cc = jax.lax.dynamic_update_slice_in_dim(cc, c_kv, pos, axis=1)
        ckr = jax.lax.dynamic_update_slice_in_dim(
            ckr, k_rope[:, 0], pos, axis=1)
        w_ukv = p["w_ukv"].reshape(r, nh, dn + dv_)
        w_uk, w_uv = w_ukv[..., :dn], w_ukv[..., dn:]
        q_lat = jnp.einsum("bhsd,rhd->bhsr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32)).astype(x.dtype)
        q_full = jnp.concatenate([q_lat, q_rope], axis=-1)  # [B,H,1,r+rd]
        k_full = jnp.concatenate([cc, ckr], axis=-1)[:, None]  # [B,1,S,r+rd]
        v_lat = cc[:, None]                                 # [B,1,S,r]
        kmask = (jnp.arange(cc.shape[1]) <= pos)[None, None, None, :]
        # _masked_dense_attention scales by 1/sqrt(r+rd); MLA's true scale is
        # 1/sqrt(qk_head_dim) — fold the correction into q.
        corr = math.sqrt(q_full.shape[-1]) * scale
        o_lat = _masked_dense_attention(q_full * corr, k_full, v_lat, kmask)
        out = jnp.einsum("bhsr,rhd->bshd", o_lat.astype(jnp.float32),
                         w_uv.astype(jnp.float32))
        out = out.reshape(b, s, nh * dv_).astype(x.dtype)
        new_cache = {"ckv": cc, "krope": ckr}
    else:
        kv = L.dense(c_kv, p["w_ukv"]).reshape(b, s, nh, dn + dv_)
        k_nope = kv[..., :dn].transpose(0, 2, 1, 3)
        v = kv[..., dn:].transpose(0, 2, 1, 3)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope, (b, nh, s, rd)).astype(k_nope.dtype)], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = L.attention(qf, k, v, causal=True, scale=scale, use_kernel=use_kernel)
        out = o.transpose(0, 2, 1, 3).reshape(b, s, nh * dv_)
        if kv_cache is not None:                           # prefill fills cache
            cc = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["ckv"], c_kv, 0, axis=1)
            ckr = jax.lax.dynamic_update_slice_in_dim(
                kv_cache["krope"], k_rope[:, 0], 0, axis=1)
            new_cache = {"ckv": cc, "krope": ckr}
    return L.dense(out, p["w_o"]), new_cache


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def cross_attention(cfg: ArchConfig, p: Params, x: jax.Array,
                    k: jax.Array, v: jax.Array) -> jax.Array:
    """Cross-attn with precomputed K/V [B,Hkv,S_enc,hd]."""
    b, s, d = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim_
    q = L.dense(x, p["w_q"], p.get("b_q")).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    o = L.attention_dense(q, k, v, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, nh * hd)
    return L.dense(o, p["w_o"])


def cross_kv(cfg: ArchConfig, p: Params, src: jax.Array):
    b, sk, _ = src.shape
    nkv, hd = cfg.n_kv_heads, cfg.head_dim_
    k = L.dense(src, p["w_k"], p.get("b_k")).reshape(b, sk, nkv, hd).transpose(0, 2, 1, 3)
    v = L.dense(src, p["w_v"], p.get("b_v")).reshape(b, sk, nkv, hd).transpose(0, 2, 1, 3)
    return k, v


def block_apply(cfg: ArchConfig, p: Params, x: jax.Array, *,
                positions: jax.Array, window: Optional[int],
                causal: bool = True, moe: bool = False,
                kv_cache: Optional[Dict] = None,
                cross_state: Optional[Tuple] = None,
                capacity_factor: Optional[float] = None,
                use_kernel: bool = False):
    """One transformer block. Returns (x, new_cache, aux_loss)."""
    b, s, d = x.shape
    h_in = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        attn_out, new_cache = mla_attention(cfg, p["attn"], h_in,
                                            positions=positions,
                                            kv_cache=kv_cache,
                                            use_kernel=use_kernel)
    else:
        attn_out, new_cache = gqa_attention(cfg, p["attn"], h_in,
                                            positions=positions, window=window,
                                            causal=causal, kv_cache=kv_cache,
                                            use_kernel=use_kernel)
    x = x + attn_out
    if cross_state is not None:
        ck, cv = cross_state
        x = x + cross_attention(cfg, p["cross"],
                                L.rms_norm(x, p["ln_cross"], cfg.norm_eps), ck, cv)
    h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if moe:
        mc = cfg.moe
        out2d, aux = L.moe_ffn(h2.reshape(b * s, d), p["moe"],
                               top_k=mc.top_k,
                               capacity_factor=capacity_factor or mc.capacity_factor,
                               gated=cfg.gated_mlp)
        out = out2d.reshape(b, s, d)
        if mc.n_shared_experts:
            out = out + L.ffn(h2, p["moe"]["shared"], cfg.gated_mlp)
    else:
        out = L.ffn(h2, p["mlp"], cfg.gated_mlp,
                    act="silu" if cfg.gated_mlp else "gelu")
    return x + out, new_cache, aux


def mamba_layer_apply(cfg: ArchConfig, p: Params, x: jax.Array,
                      cache: Optional[Dict] = None, use_kernel: bool = False):
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    out, new_cache = M.mamba_block_apply(p["mamba"], h, cfg.ssm, cache,
                                         use_kernel=use_kernel)
    return x + out, new_cache, jnp.zeros((), jnp.float32)


def _remat_wrap(fn, remat: str):
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def scan_stack(stacked: Params, x: jax.Array, body_fn, cache=None,
               remat: str = "none"):
    """Scan a homogeneous layer stack.  body_fn(p, h, c) -> (h, c, aux)."""
    if cache is None:
        def body(h, p):
            h2, _, aux = body_fn(p, h, None)
            return h2, aux
        body = _remat_wrap(body, remat)
        x, auxs = jax.lax.scan(body, x, stacked)
        return x, None, auxs.sum()

    def body(h, pc):
        p, c = pc
        h2, c2, aux = body_fn(p, h, c)
        return h2, (c2, aux)

    x, (cache2, auxs) = jax.lax.scan(body, x, (stacked, cache))
    return x, cache2, auxs.sum()


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Cache:
    """Concrete zero-filled decode cache (eval_shape-able for the dry-run)."""
    dtype = jnp.dtype(cfg.dtype)
    nkv, hd = cfg.n_kv_heads, cfg.head_dim_

    def kvc(n_layers, cap):
        return {"k": jnp.zeros((n_layers, batch, nkv, cap, hd), dtype),
                "v": jnp.zeros((n_layers, batch, nkv, cap, hd), dtype),
                "kpos": jnp.full((n_layers, cap), -1, jnp.int32)}

    cache: Cache = {"pos": jnp.zeros((), jnp.int32)}
    fam = cfg.family
    if fam in ("ssm", "hybrid"):
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        conv_ch = di + 2 * s.n_groups * s.state_size
        cache["mamba"] = {
            "conv": jnp.zeros((cfg.n_layers, batch, s.conv_width - 1, conv_ch), dtype),
            "state": jnp.zeros((cfg.n_layers, batch, s.n_heads(cfg.d_model),
                                s.head_dim, s.state_size), jnp.float32),
        }
        if fam == "hybrid":
            n_app = cfg.n_layers // cfg.hybrid.attn_every
            cache["attn"] = kvc(n_app, max_len)
    elif cfg.enc_dec is not None:
        cache["self"] = kvc(cfg.n_layers, max_len)
        cache["cross_k"] = jnp.zeros((cfg.n_layers, batch, nkv,
                                      cfg.enc_dec.encoder_seq, hd), dtype)
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    elif cfg.mla is not None:
        m = cfg.mla
        nd = cfg.moe.first_dense_layers if cfg.moe else 0
        for name, n in (("dense", nd), ("moe", cfg.n_layers - nd)):
            if n:
                cache[name] = {
                    "ckv": jnp.zeros((n, batch, max_len, m.kv_lora_rank), dtype),
                    "krope": jnp.zeros((n, batch, max_len, m.qk_rope_head_dim), dtype),
                }
    elif cfg.window_pattern is not None:
        period = len(cfg.window_pattern)
        n_cycles = cfg.n_layers // period
        for i, w in enumerate(cfg.window_pattern):
            cap = max_len if w is None else min(w, max_len)
            cache[f"p{i}"] = {
                "k": jnp.zeros((n_cycles, batch, nkv, cap, hd), dtype),
                "v": jnp.zeros((n_cycles, batch, nkv, cap, hd), dtype),
                "kpos": jnp.full((n_cycles, cap), -1, jnp.int32)}
    elif cfg.moe is not None:
        nd = cfg.moe.first_dense_layers
        if nd:
            cache["dense"] = kvc(nd, max_len)
        cache["moe"] = kvc(cfg.n_layers - nd, max_len)
    else:
        cache["self"] = kvc(cfg.n_layers, max_len)
    return cache


# ---------------------------------------------------------------------------
# Forward / prefill / decode
# ---------------------------------------------------------------------------


def _stack_runner(cfg: ArchConfig, params: Params, x: jax.Array,
                  positions: jax.Array, cache: Optional[Cache],
                  remat: str, use_kernel: bool, capacity_factor=None):
    """Run the arch-specific layer stack. Returns (x, new_cache, aux)."""
    fam = cfg.family
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Cache = {} if cache is not None else None

    if fam == "ssm":
        def body(p, h, c):
            return mamba_layer_apply(cfg, p, h, c, use_kernel)
        x, c2, aux = scan_stack(params["blocks"], x, body,
                                cache["mamba"] if cache else None, remat)
        if cache is not None:
            new_cache["mamba"] = c2
        aux_total += aux

    elif fam == "hybrid":
        every = cfg.hybrid.attn_every
        n_seg = cfg.n_layers // every
        mamba_stack = jax.tree.map(
            lambda a: a.reshape((n_seg, every) + a.shape[1:]), params["blocks"])
        mcaches, acaches = [], []

        def body(p, h, c):
            return mamba_layer_apply(cfg, p, h, c, use_kernel)
        for seg in range(n_seg):
            seg_params = jax.tree.map(lambda a: a[seg], mamba_stack)
            seg_cache = (jax.tree.map(lambda a: a[seg * every:(seg + 1) * every],
                                      cache["mamba"]) if cache else None)
            x, c2, aux = scan_stack(seg_params, x, body, seg_cache, remat)
            aux_total += aux
            if cache is not None:
                mcaches.append(c2)
            shared = params["shared_attn"][seg % len(params["shared_attn"])]
            a_cache = (jax.tree.map(lambda a: a[seg], cache["attn"])
                       if cache is not None else None)
            blk = _remat_wrap(
                lambda h_, ac_, _sh=shared: block_apply(
                    cfg, _sh, h_, positions=positions, window=None,
                    kv_cache=ac_, use_kernel=use_kernel)[:2],
                remat if cache is None else "none")
            if cache is None:
                x2, _ = blk(x, None)
                x = x2
            else:
                x, ac2 = blk(x, a_cache)
                acaches.append(ac2)
        if cache is not None:
            new_cache["mamba"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *mcaches)
            new_cache["attn"] = jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0), *acaches)

    elif cfg.enc_dec is not None:
        # decoder over x; cross K/V must already be in `cross_state`
        raise RuntimeError("enc_dec handled in forward()/decode_step directly")

    elif cfg.moe is not None:
        nd = cfg.moe.first_dense_layers
        cname = {True: "dense", False: "moe"}
        for moe_flag, pname in ((False, "dense_blocks"), (True, "blocks")):
            if pname not in params:
                continue
            key = cname[not moe_flag] if False else ("moe" if moe_flag else "dense")
            def body(p, h, c, _moe=moe_flag):
                return block_apply(cfg, p, h, positions=positions, window=None,
                                   moe=_moe, kv_cache=c,
                                   capacity_factor=capacity_factor,
                                   use_kernel=use_kernel)
            x, c2, aux = scan_stack(params[pname], x, body,
                                    cache[key] if cache else None, remat)
            aux_total += aux
            if cache is not None:
                new_cache[key] = c2

    elif cfg.window_pattern is not None:
        period = len(cfg.window_pattern)
        kv_len = positions.shape[-1] if cache is None else None

        def cycle_body(h, pc):
            cyc_params, cyc_caches = pc
            new_c = []
            aux = jnp.zeros((), jnp.float32)
            for i, w in enumerate(cfg.window_pattern):
                p_i = [jax.tree.map(lambda a: a, cp) for cp in [cyc_params]][0][i]
                c_i = cyc_caches[i] if cyc_caches is not None else None
                h, c2, a = block_apply(cfg, p_i, h, positions=positions,
                                       window=w, kv_cache=c_i,
                                       use_kernel=use_kernel)
                aux += a
                new_c.append(c2 if c2 is not None else 0)
            return h, (tuple(new_c) if cyc_caches is not None else None, aux)

        cyc_stack = params["cycles"]
        if cache is None:
            def body(h, p):
                h2, (_, aux) = cycle_body(h, (p, None))
                return h2, aux
            body = _remat_wrap(body, remat)
            x, auxs = jax.lax.scan(body, x, cyc_stack)
            aux_total += auxs.sum()
        else:
            caches_in = tuple(cache[f"p{i}"] for i in range(period))
            def body(h, pc):
                h2, (cs, aux) = cycle_body(h, pc)
                return h2, (cs, aux)
            x, (cs_out, auxs) = jax.lax.scan(body, x, (cyc_stack, caches_in))
            aux_total += auxs.sum()
            for i in range(period):
                new_cache[f"p{i}"] = cs_out[i]
    else:
        def body(p, h, c):
            return block_apply(cfg, p, h, positions=positions, window=None,
                               kv_cache=c, use_kernel=use_kernel)
        x, c2, aux = scan_stack(params["blocks"], x, body,
                                cache["self"] if cache else None, remat)
        aux_total += aux
        if cache is not None:
            new_cache["self"] = c2
    return x, new_cache, aux_total


def _head(cfg: ArchConfig, params: Params, x: jax.Array) -> jax.Array:
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"]).astype(jnp.float32)
    return jnp.einsum("bsd,dv->bsv", h, params["lm_head"]).astype(jnp.float32)


def run_encoder(cfg: ArchConfig, params: Params, frontend: jax.Array,
                remat: str = "none", use_kernel: bool = False) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings [B,F,d]."""
    b, f, _ = frontend.shape
    positions = jnp.broadcast_to(jnp.arange(f), (b, f))

    def body(p, h, c):
        return block_apply(cfg, p, h, positions=positions, window=None,
                           causal=False, use_kernel=use_kernel)
    x, _, _ = scan_stack(params["enc_blocks"], frontend, body, None, remat)
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward_hidden(cfg: ArchConfig, params: Params, tokens: jax.Array,
                   frontend: Optional[jax.Array] = None, *,
                   remat: str = "none", use_kernel: bool = False,
                   capacity_factor: Optional[float] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Trunk only: returns (pre-head hidden [B,S_total,d], aux_loss)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.enc_dec is not None:
        assert frontend is not None, "enc-dec arch needs frontend embeddings"
        enc_out = run_encoder(cfg, params, frontend, remat, use_kernel)
    elif cfg.frontend != "none" and frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
    stot = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(stot), (b, stot))

    if cfg.enc_dec is not None:
        def body(p, h, c):
            ck, cv = cross_kv(cfg, p["cross"], enc_out)
            return block_apply(cfg, p, h, positions=positions, window=None,
                               kv_cache=c, cross_state=(ck, cv),
                               use_kernel=use_kernel)
        x, _, aux = scan_stack(params["blocks"], x, body, None, remat)
    else:
        x, _, aux = _stack_runner(cfg, params, x, positions, None, remat,
                                  use_kernel, capacity_factor)
    return x, aux


def forward(cfg: ArchConfig, params: Params, tokens: jax.Array,
            frontend: Optional[jax.Array] = None, *, remat: str = "none",
            use_kernel: bool = False,
            capacity_factor: Optional[float] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits [B,S_total,V], aux_loss)."""
    x, aux = forward_hidden(cfg, params, tokens, frontend, remat=remat,
                            use_kernel=use_kernel,
                            capacity_factor=capacity_factor)
    logits = _head(cfg, params, x)
    return logits, aux


def mtp_hidden(cfg: ArchConfig, params: Params, h_main: jax.Array,
               tokens: jax.Array) -> jax.Array:
    """DeepSeek MTP trunk: hidden predicting t+2 from h[t] + emb(token[t+1])."""
    p = params["mtp"]
    b, s = tokens.shape
    h = L.rms_norm(h_main[:, :-1], p["norm"], cfg.norm_eps)
    nxt = jnp.take(params["embed"], tokens[:, 1:], axis=0)
    x = jnp.einsum("bsd,df->bsf", jnp.concatenate([h, nxt], -1),
                   p["proj"].astype(h.dtype))
    positions = jnp.broadcast_to(jnp.arange(s - 1), (b, s - 1))
    x, _, _ = block_apply(cfg, p["block"], x, positions=positions, window=None)
    return x


def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array], *,
            remat: str = "none", use_kernel: bool = False,
            aux_weight: float = 0.01, mtp_weight: float = 0.1,
            capacity_factor: Optional[float] = None,
            ce_chunk: int = 2048):
    """Next-token CE (+ MoE aux + MTP).  batch: tokens [B,S] (+frontend).

    The CE head is **chunked + rematerialized**: logits are computed per
    token-chunk inside jax.checkpoint, so the [T, vocab] fp32 tensor never
    materializes — peak head memory is [ce_chunk, vocab].  (This fixed a
    73 GB/device temp the compiled-plan memory analysis exposed; see
    EXPERIMENTS.md §Perf.)
    """
    tokens = batch["tokens"]
    frontend = batch.get("frontend")
    hidden, aux = forward_hidden(cfg, params, tokens, frontend, remat=remat,
                                 use_kernel=use_kernel,
                                 capacity_factor=capacity_factor)
    offset = 0
    if cfg.frontend != "none" and cfg.enc_dec is None and frontend is not None:
        offset = frontend.shape[1]
    h = hidden[:, offset:offset + tokens.shape[1] - 1]
    tgt = tokens[:, 1:]
    ce = _chunked_ce(cfg, params, h, tgt, ce_chunk)
    total = ce + aux_weight * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_depth and "mtp" in params:
        h_m = hidden[:, offset:offset + tokens.shape[1]]
        mtp_h = mtp_hidden(cfg, params, h_m, tokens)      # [B, S-1, d]
        mtp_ce = _chunked_ce(cfg, params, mtp_h[:, :-1], tokens[:, 2:],
                             ce_chunk)
        total = total + mtp_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    return total, metrics


def _chunked_ce(cfg: ArchConfig, params: Params, h: jax.Array,
                targets: jax.Array, chunk: int) -> jax.Array:
    """Mean next-token CE with a rematerialized, time-chunked head.

    Chunks along the TIME axis with batch kept leading, so every chunk
    stays batch-sharded under GSPMD.  (The first version reshaped the
    sharded token dim into the scan axis — the partitioner then had to
    replicate each chunk, generating two [T, vocab]-sized all-reduces of
    637 GB each at train_4k/multi-pod.  See EXPERIMENTS.md §Perf.)
    """
    b, s, d = h.shape
    c = max(min(chunk // max(b, 1), s), 1)
    pad = (-s) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    n = (s + pad) // c
    hr = h.reshape(b, n, c, d).transpose(1, 0, 2, 3)      # [n, b, c, d]
    tr = targets.reshape(b, n, c).transpose(1, 0, 2)      # [n, b, c]

    def chunk_loss(hc, tc):
        logits = _head(cfg, params, hc)                   # [b, c, V] fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(tc, 0)[..., None],
                                 axis=-1)[..., 0]
        return jnp.where(tc >= 0, logz - ll, 0.0).sum()

    def body(acc, xt):
        hc, tc = xt
        return acc + jax.checkpoint(chunk_loss)(hc, tc), None

    total, _ = jax.lax.scan(
        body, jnp.zeros((), jnp.float32), (hr, tr),
        unroll=True if costing_mode.unroll_scans() else 1)
    return total / (b * s)


def prefill(cfg: ArchConfig, params: Params, tokens: jax.Array,
            cache: Cache, frontend: Optional[jax.Array] = None, *,
            use_kernel: bool = False,
            capacity_factor: Optional[float] = None) -> Tuple[jax.Array, Cache]:
    """Fill the decode cache from a prompt; returns (last-token logits, cache)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    offset = 0
    if cfg.enc_dec is not None:
        assert frontend is not None
        enc_out = run_encoder(cfg, params, frontend, "none", use_kernel)
    elif cfg.frontend != "none" and frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
        offset = frontend.shape[1]
    stot = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(stot), (b, stot))
    new_cache: Cache = {"pos": jnp.asarray(stot, jnp.int32)}

    if cfg.enc_dec is not None:
        # compute & store cross K/V once
        def body(h, pc):
            p, c = pc
            ck, cv = cross_kv(cfg, p["cross"], enc_out)
            h2, c2, _ = block_apply(cfg, p, h, positions=positions, window=None,
                                    kv_cache=c, cross_state=(ck, cv),
                                    use_kernel=use_kernel)
            return h2, (c2, ck, cv)
        x, (self_c, cks, cvs) = jax.lax.scan(
            body, x, (params["blocks"], cache["self"]))
        new_cache["self"] = self_c
        new_cache["cross_k"], new_cache["cross_v"] = cks, cvs
    else:
        x, c2, _ = _stack_runner(cfg, params, x, positions, cache, "none",
                                 use_kernel, capacity_factor)
        new_cache.update(c2)
    logits = _head(cfg, params, x[:, -1:])
    return logits[:, 0], new_cache


def decode_step(cfg: ArchConfig, params: Params, token: jax.Array,
                cache: Cache, *, use_kernel: bool = False,
                capacity_factor: Optional[float] = None
                ) -> Tuple[jax.Array, Cache]:
    """One decoding step.  token: [B] int32.  Returns (logits [B,V], cache)."""
    b = token.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    new_cache: Cache = {"pos": pos + 1}

    if cfg.enc_dec is not None:
        def body(h, pc):
            p, c, ck, cv = pc
            h2, c2, _ = block_apply(cfg, p, h, positions=positions, window=None,
                                    kv_cache=c, cross_state=(ck, cv),
                                    use_kernel=use_kernel)
            return h2, c2
        x, self_c = jax.lax.scan(
            body, x, (params["blocks"], cache["self"],
                      cache["cross_k"], cache["cross_v"]))
        new_cache["self"] = self_c
        new_cache["cross_k"], new_cache["cross_v"] = cache["cross_k"], cache["cross_v"]
    else:
        x, c2, _ = _stack_runner(cfg, params, x, positions, cache, "none",
                                 use_kernel, capacity_factor)
        new_cache.update(c2)
    logits = _head(cfg, params, x)
    return logits[:, 0], new_cache
