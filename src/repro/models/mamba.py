"""Mamba2 (SSD — state-space duality) block, pure JAX.

Implements the chunked SSD algorithm of arXiv:2405.21060 §6 (the "fully
recomputed" dual form): intra-chunk quadratic attention-like term + an
inter-chunk state recurrence (lax.scan over chunks).  A Pallas kernel for
the same computation lives in repro.kernels.ssd_scan; this module is the
oracle and the GSPMD path.

Shapes follow the paper: x [B,S,H,P], dt [B,S,H], A_log [H], B/C [B,S,G,N].
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import costing_mode


def segsum(log_a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} log_a[..., k].

    log_a: [..., L] -> [..., L, L] lower-triangular (j <= i), -inf above.
    """
    L = log_a.shape[-1]
    x = jnp.cumsum(log_a, axis=-1)
    diff = x[..., :, None] - x[..., None, :]          # sum_{j+1..i} for i>=j
    ii = jnp.arange(L)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A_log: jax.Array,
                B: jax.Array, C: jax.Array, D: jax.Array,
                chunk: int = 256,
                init_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    dt = jnp.maximum(dt.astype(jnp.float32), 1e-6)
    A = -jnp.exp(A_log.astype(jnp.float32))           # [H], negative
    log_a = (dt * A)                                   # [B,S,H] log decay
    xbar = x.astype(jnp.float32) * dt[..., None]       # dt-scaled input

    # chunked views
    xc = xbar.reshape(b, nc, chunk, h, p)
    lac = log_a.reshape(b, nc, chunk, h)
    Bc = B.astype(jnp.float32).reshape(b, nc, chunk, g, n)
    Cc = C.astype(jnp.float32).reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)                   # [b,nc,l,h,n]
    Ch = jnp.repeat(Cc, rep, axis=3)

    # ---- intra-chunk (quadratic, attention-like) ----
    Lmat = jnp.exp(segsum(lac.transpose(0, 1, 3, 2)))  # [b,nc,h,l,l]
    scores = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)  # [b,nc,h,l,s]
    y_diag = jnp.einsum("bchls,bchls,bcshp->bclhp",
                        scores, Lmat, xc)

    # ---- chunk states ----
    a_cum = jnp.cumsum(lac, axis=2)                    # [b,nc,l,h]
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bh, decay_to_end, xc)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])          # [b,nc,h]
    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((b, h, p, n), jnp.float32))

    def step(prev, inp):
        dec, st = inp                                  # [b,h], [b,h,p,n]
        new = prev * dec[..., None, None] + st
        return new, prev                               # emit state ENTERING chunk

    final_state, prev_states = jax.lax.scan(
        step, s0, (chunk_decay.transpose(1, 0, 2),
                   states.transpose(1, 0, 2, 3, 4)),
        unroll=True if costing_mode.unroll_scans() else 1)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [b,nc,h,p,n]

    # ---- off-diagonal (cross-chunk) output ----
    state_decay_in = jnp.exp(a_cum)                    # decay from chunk start
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Ch, prev_states, state_decay_in)

    y = (y_diag + y_off).reshape(b, s, h, p)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state: jax.Array, x_t: jax.Array, dt_t: jax.Array,
                    A_log: jax.Array, B_t: jax.Array, C_t: jax.Array,
                    D: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSD update.

    state [B,H,P,N]; x_t [B,H,P]; dt_t [B,H]; B_t/C_t [B,G,N].
    Returns (y [B,H,P], new_state).
    """
    b, h, p, n = state.shape
    g = B_t.shape[1]
    rep = h // g
    dt_t = jnp.maximum(dt_t.astype(jnp.float32), 1e-6)
    a = jnp.exp(dt_t * -jnp.exp(A_log.astype(jnp.float32)))       # [B,H]
    Bh = jnp.repeat(B_t.astype(jnp.float32), rep, axis=1)         # [B,H,N]
    Ch = jnp.repeat(C_t.astype(jnp.float32), rep, axis=1)
    xb = x_t.astype(jnp.float32) * dt_t[..., None]                # [B,H,P]
    new_state = state * a[..., None, None] + xb[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + x_t.astype(jnp.float32) * D.astype(jnp.float32)[None, :, None]
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba2 block (in_proj -> conv -> SSD -> gate -> out_proj)
# ---------------------------------------------------------------------------


def mamba_block_init(rng, d_model: int, ssm, dtype) -> Dict[str, jax.Array]:
    di = ssm.d_inner(d_model)
    h = ssm.n_heads(d_model)
    g, n, w = ssm.n_groups, ssm.state_size, ssm.conv_width
    conv_ch = di + 2 * g * n
    k1, k2, k3 = jax.random.split(rng, 3)
    std = d_model ** -0.5
    return {
        "w_in": (jax.random.normal(k1, (d_model, 2 * di + 2 * g * n + h)) * std).astype(dtype),
        "conv_w": (jax.random.normal(k2, (w, conv_ch)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
        "w_out": (jax.random.normal(k3, (di, d_model)) * di ** -0.5).astype(dtype),
    }


def _causal_conv(xc: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  xc [B,S,C]; w [W,C]; state [B,W-1,C]."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((xc.shape[0], width - 1, xc.shape[2]), xc.dtype)
    xpad = jnp.concatenate([state, xc], axis=1)
    out = sum(xpad[:, i:i + xc.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    new_state = xpad[:, -(width - 1):, :]
    return jax.nn.silu(out + b[None, None, :]), new_state


def mamba_block_apply(params: Dict[str, jax.Array], x: jax.Array, ssm,
                      cache: Optional[Dict[str, jax.Array]] = None,
                      use_kernel: bool = False,
                      ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """x: [B, S, d_model].  cache: {"conv": [B,W-1,C], "state": [B,H,P,N]}."""
    bsz, s, d = x.shape
    di = ssm.d_inner(d)
    h = ssm.n_heads(d)
    g, n = ssm.n_groups, ssm.state_size

    proj = dense_(x, params["w_in"])                   # [B,S,2di+2gn+h]
    z, xin, Bx, Cx, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xin, Bx, Cx], axis=-1)
    conv_state = cache.get("conv") if cache else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], conv_state)
    xin, Bx, Cx = jnp.split(conv_out, [di, di + g * n], axis=-1)
    xh = xin.reshape(bsz, s, h, ssm.head_dim)
    Bh = Bx.reshape(bsz, s, g, n)
    Ch = Cx.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])

    if cache is not None and s == 1:
        y, new_state = ssd_decode_step(cache["state"], xh[:, 0], dt[:, 0],
                                       params["A_log"], Bh[:, 0], Ch[:, 0],
                                       params["D"])
        y = y[:, None]                                  # [B,1,H,P]
    else:
        init = cache["state"] if cache is not None else None
        if use_kernel:
            from repro.kernels import ops as kops
            y, new_state = kops.ssd_scan(xh, dt, params["A_log"], Bh, Ch,
                                         params["D"], chunk=ssm.chunk_size)
        else:
            y, new_state = ssd_chunked(xh, dt, params["A_log"], Bh, Ch,
                                       params["D"], chunk=ssm.chunk_size,
                                       init_state=init)
    y = y.reshape(bsz, s, di)
    from repro.models.layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm_scale"])
    out = dense_(y, params["w_out"])
    new_cache = ({"conv": new_conv, "state": new_state}
                 if cache is not None else None)
    return out, new_cache


def dense_(x, w):
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
