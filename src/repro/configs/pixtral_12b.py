"""pixtral-12b [vlm]: Pixtral-ViT frontend stubbed; Mistral-NeMo backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409].  Patch embeddings arrive precomputed.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,               # NeMo-style fixed head dim (32*128 != d_model)
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e6,
    frontend="vision_stub",
    frontend_seq=1024,          # 1024 image patches prepended
)
