"""Architecture + shape configuration schema.

One :class:`ArchConfig` per assigned architecture (see ``configs/<id>.py``),
plus the four assigned input-shape sets.  Configs are pure data — models,
planner, dry-run and cost model all read from here.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    d_ff_dense: int = 0              # ff width of the dense (non-MoE) layers
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style multi-head latent attention."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim

    @property
    def cache_dim(self) -> int:
        # decode caches the compressed c_kv + the shared rope key
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block parameters."""

    state_size: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: SSM backbone + shared attention block every N layers."""

    attn_every: int = 6
    n_shared_attn_blocks: int = 2   # distinct shared param sets, alternated


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 12
    encoder_seq: int = 1500          # whisper: 30 s audio -> 1500 frames


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    gated_mlp: bool = True           # SwiGLU-style
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # sliding-window pattern: window size per layer position in a repeating
    # cycle; None entry = global attention.  gemma3: 5 local : 1 global.
    window_pattern: Optional[Tuple[Optional[int], ...]] = None
    local_window: int = 1024
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    enc_dec: Optional[EncDecConfig] = None
    frontend: str = "none"           # none | audio_stub | vision_stub
    frontend_seq: int = 0            # encoder frames / image patches
    mtp_depth: int = 0               # deepseek multi-token prediction heads
    dtype: str = "bfloat16"

    # ------------------------------------------------------------- derived
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_window(self, layer_idx: int, seq_len: int) -> int:
        """Effective attention window for a layer (seq_len = global)."""
        if self.window_pattern is None:
            return seq_len
        w = self.window_pattern[layer_idx % len(self.window_pattern)]
        return seq_len if w is None else min(w, seq_len)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid / mostly-local attention."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window_pattern is not None

    # -- parameter counts (used for 6ND MODEL_FLOPS and memory checks) ----
    def param_counts(self) -> Dict[str, float]:
        return _param_counts_cached(self)

    def _param_counts_impl(self) -> Dict[str, float]:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim_
        counts: Dict[str, float] = {}
        counts["embed"] = v * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> float:
            if self.mla:
                m = self.mla
                p = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * m.qk_head_dim
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                p += self.n_heads * m.v_head_dim * d
                return p
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            b = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
            return q + kv + o + b

        def mlp_params(width: float) -> float:
            return (3 if self.gated_mlp else 2) * d * width

        def ssm_params() -> float:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            # in_proj (z,x,B,C,dt) + conv + out_proj + A,D
            in_proj = d * (2 * di + 2 * s.n_groups * s.state_size + nh)
            conv = s.conv_width * (di + 2 * s.n_groups * s.state_size)
            return in_proj + conv + di * d + 2 * nh

        layer_total = 0.0
        active_total = 0.0
        for layer in range(self.n_layers):
            if self.family == "ssm":
                lp = ssm_params()
                la = lp
            elif self.family == "hybrid":
                lp = ssm_params()
                la = lp
            elif self.moe is not None:
                a = attn_params()
                if layer < self.moe.first_dense_layers:
                    m = mlp_params(self.moe.d_ff_dense or ff)
                    lp, la = a + m, a + m
                else:
                    per_expert = mlp_params(self.moe.d_ff_expert)
                    routed = self.moe.n_experts * per_expert
                    shared = self.moe.n_shared_experts * per_expert
                    router = d * self.moe.n_experts
                    lp = a + routed + shared + router
                    la = a + self.moe.top_k * per_expert + shared + router
            else:
                lp = attn_params() + mlp_params(ff)
                la = lp
            layer_total += lp
            active_total += la

        # zamba2 shared attention blocks (params counted once, applied often)
        if self.hybrid is not None:
            shared = (attn_params() + mlp_params(ff)) * self.hybrid.n_shared_attn_blocks
            layer_total += shared
            n_applications = self.n_layers // self.hybrid.attn_every
            active_total += (attn_params() + mlp_params(ff)) * n_applications

        if self.enc_dec is not None:
            # encoder layers + decoder cross-attention
            enc = (attn_params() + mlp_params(ff)) * self.enc_dec.n_encoder_layers
            cross = attn_params() * self.n_layers
            layer_total += enc + cross
            active_total += enc + cross

        counts["layers"] = layer_total
        counts["layers_active"] = active_total
        counts["total"] = counts["embed"] + layer_total
        counts["active"] = counts["embed"] + active_total
        return counts

    @property
    def n_params(self) -> float:
        return self.param_counts()["total"]

    @property
    def n_active_params(self) -> float:
        return self.param_counts()["active"]

    # -- smoke-test reduction ---------------------------------------------
    def reduced(self) -> "ArchConfig":
        """A tiny config of the same family for CPU smoke tests."""
        kw: Dict = {}
        kw["n_layers"] = min(self.n_layers, 4 if self.family in ("ssm", "hybrid") else 2)
        kw["d_model"] = 64
        kw["n_heads"] = 4
        kw["n_kv_heads"] = min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4
        kw["head_dim"] = 16
        kw["d_ff"] = 128 if self.d_ff else 0
        kw["vocab_size"] = 256
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4), top_k=min(self.moe.top_k, 2),
                d_ff_expert=64, d_ff_dense=128 if self.moe.d_ff_dense else 0,
                first_dense_layers=min(self.moe.first_dense_layers, 1))
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, state_size=16, head_dim=16,
                                            chunk_size=32)
        if self.hybrid:
            kw["hybrid"] = dataclasses.replace(self.hybrid, attn_every=2,
                                               n_shared_attn_blocks=1)
        if self.enc_dec:
            kw["enc_dec"] = dataclasses.replace(self.enc_dec, n_encoder_layers=2,
                                                encoder_seq=16)
        if self.window_pattern is not None:
            kw["window_pattern"] = (8, None)     # 1 local : 1 global
            kw["local_window"] = 8
            kw["n_layers"] = 4                   # 2 cycles of period 2
        if self.frontend_seq:
            kw["frontend_seq"] = 8
        return dataclasses.replace(self, **kw)


@functools.lru_cache(maxsize=None)
def _param_counts_cached(cfg: "ArchConfig") -> Dict[str, float]:
    return cfg._param_counts_impl()


# ---------------------------------------------------------------------------
# Input shapes (assigned): seq_len x global_batch per mode
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("skip: pure full-attention arch — 500k dense-KV decode "
                       "is infeasible (see DESIGN.md §5)")
    return True, ""
