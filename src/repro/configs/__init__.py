"""Architecture/shape registry: ``get_config("<arch-id>")``, ``SHAPES``."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (ArchConfig, EncDecConfig, HybridConfig,
                                MLAConfig, MoEConfig, ShapeConfig, SHAPES,
                                SSMConfig, shape_applicable)

_MODULES = {
    "whisper-small": "repro.configs.whisper_small",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe",
    "deepseek-v3-671b": "repro.configs.deepseek_v3",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "qwen1.5-4b": "repro.configs.qwen15_4b",
    "qwen1.5-110b": "repro.configs.qwen15_110b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "qwen1.5-0.5b": "repro.configs.qwen15_0p5b",
    "mamba2-1.3b": "repro.configs.mamba2_1p3b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(name: str) -> ArchConfig:
    import importlib

    if name not in _MODULES:
        raise KeyError(f"unknown arch '{name}'; available: {ARCH_IDS}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_IDS}


__all__ = [
    "ArchConfig", "MoEConfig", "MLAConfig", "SSMConfig", "HybridConfig",
    "EncDecConfig", "ShapeConfig", "SHAPES", "ARCH_IDS", "get_config",
    "all_configs", "shape_applicable",
]
