"""mamba2-1.3b [ssm]: attention-free SSD (state-space duality).

48L d_model=2048 vocab=50280 ssm_state=128 [arXiv:2405.21060].
d_inner = 2*d_model = 4096, head_dim 64 -> 64 SSD heads.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,                   # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    gated_mlp=False,
    ssm=SSMConfig(state_size=128, head_dim=64, expand=2, chunk_size=256),
)
