"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention blocks.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000 ssm_state=64
[arXiv:2411.15242].  Two shared attn+MLP blocks alternate every 6 layers.
"""
from repro.configs.base import ArchConfig, HybridConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(state_size=64, head_dim=64, expand=2, chunk_size=256),
    hybrid=HybridConfig(attn_every=6, n_shared_attn_blocks=2),
)
