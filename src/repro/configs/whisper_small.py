"""whisper-small [audio]: enc-dec, conv frontend stubbed (precomputed frames).

12L d_model=768 12H (GQA kv=12) d_ff=3072 vocab=51865 [arXiv:2212.04356].
Backbone only — `input_specs()` feeds precomputed 1500 frame embeddings.
"""
from repro.configs.base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    gated_mlp=False,            # whisper uses plain GELU MLP
    qkv_bias=True,              # whisper attention has q/v bias
    enc_dec=EncDecConfig(n_encoder_layers=12, encoder_seq=1500),
    frontend="audio_stub",
    frontend_seq=1500,
)
