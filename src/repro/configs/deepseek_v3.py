"""deepseek-v3-671b [moe]: MLA, 1 shared + 256 routed top-8, MTP.

61L d_model=7168 128H d_ff=2048(expert) vocab=129280 [arXiv:2412.19437].
First 3 layers dense (d_ff 18432); MLA q_lora 1536 / kv_lora 512 /
qk 128+64 rope / v 128; one MTP head.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,              # MLA: KV latent shared; kv=128 per assignment
    head_dim=128,
    d_ff=2048,
    vocab_size=129280,
    rope_theta=10000.0,
    moe=MoEConfig(
        n_experts=256, top_k=8, d_ff_expert=2048,
        n_shared_experts=1, first_dense_layers=3, d_ff_dense=18432,
    ),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    mtp_depth=1,
)
