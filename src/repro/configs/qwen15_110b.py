"""qwen1.5-110b [dense]: 80L d_model=8192 64H (kv=8, GQA) d_ff=49152
vocab=152064 [hf:Qwen/Qwen1.5-110B].

The frontier-dense scenario: ~111B parameters is deliberately *past* what
tensor/FSDP sharding alone can fit on one or two pod slices (weights +
fp32 grads + Adam state blow the per-device HBM budget at every 2D role),
which is exactly the cell family pipeline parallelism opens — per-stage
resident state drops ~S-fold when the layer stack is split over a "pp"
axis (see ``repro.core.planner``).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
)
