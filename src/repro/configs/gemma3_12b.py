"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144; 5:1 local:global attention, 128k context
[hf:google/gemma-3-12b-pt].

window_pattern encodes the 5 local (1024-window) : 1 global cycle — one
homogeneous scanned layer body (window == seq for global layers).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    rope_theta=1e6,
    window_pattern=(1024, 1024, 1024, 1024, 1024, None),
    local_window=1024,
)
