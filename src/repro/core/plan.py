"""Runtime-plan IR (paper §2/§3.1).

A runtime plan ``P`` is a hierarchy of *program blocks* ``b ∈ B`` and
*instructions* ``inst ∈ I``.  This mirrors SystemML's runtime program:

    PROGRAM
      MAIN PROGRAM
        GENERIC (lines 1-3)      <- GenericBlock([instructions...])
        IF / FOR / WHILE / PARFOR / FUNCTION blocks, arbitrarily nested

Instruction kinds map SystemML's onto the TPU world:

  * meta      — createvar / cpvar / rmvar (symbol-table maintenance, ~free)
  * datagen   — rand / seq / iota (produces a tensor, no input IO)
  * compute   — a logical op (opcode from :mod:`repro.core.linalg_ops`),
                CP (single device) or DIST (sharded across mesh axes)
  * io        — explicit state transfer: disk<->host<->hbm read/write
                (persistent reads, checkpoint writes, host staging)
  * collective— all_reduce / all_gather / reduce_scatter / all_to_all /
                permute over named mesh axes (the MR-shuffle analogue)
  * p2p       — point-to-point send/recv between neighbor positions on a
                mesh axis (pipeline stage boundaries; one link, no ring)
  * jitcall   — one compiled XLA executable; its cost comes from the
                *generated plan* (``hlo_cost``) rather than op formulas.
                This is the paper's headline object: costing what the
                compiler actually produced.

Plans are pure data — generation is cheap (paper: <0.5 ms) and costing is a
single recursive pass (:mod:`repro.core.costmodel`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.symbols import MemState, TensorStat

# ---------------------------------------------------------------------------
# Instructions
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Instruction:
    """Base class; concrete kinds below."""

    def describe(self) -> str:  # pragma: no cover - overridden
        return self.__class__.__name__


@dataclasses.dataclass
class CreateVar(Instruction):
    name: str
    stat: TensorStat

    def describe(self) -> str:
        return f"createvar {self.name} {list(self.stat.shape)} {self.stat.dtype} {self.stat.state.value}"


@dataclasses.dataclass
class CpVar(Instruction):
    src: str
    dst: str

    def describe(self) -> str:
        return f"cpvar {self.src} {self.dst}"


@dataclasses.dataclass
class RmVar(Instruction):
    names: Tuple[str, ...]

    def describe(self) -> str:
        return "rmvar " + " ".join(self.names)


@dataclasses.dataclass
class DataGen(Instruction):
    opcode: str              # "rand" | "seq" | "iota" | "zeros"
    output: str
    stat: TensorStat

    def describe(self) -> str:
        return f"{self.opcode} {self.output} {list(self.stat.shape)}"


@dataclasses.dataclass
class Compute(Instruction):
    """A logical operation; ``exec_type`` selects CP vs distributed.

    ``shard_axes`` names the mesh axes whose product divides the work
    (the paper's effective degree of parallelism for MR jobs).
    """

    opcode: str
    inputs: Tuple[str, ...]
    output: str
    exec_type: str = "CP"                 # "CP" | "DIST"
    shard_axes: Tuple[str, ...] = ()
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def describe(self) -> str:
        et = self.exec_type if not self.shard_axes else f"{self.exec_type}[{','.join(self.shard_axes)}]"
        return f"{et} {self.opcode} {' '.join(self.inputs)} -> {self.output}"


@dataclasses.dataclass
class IO(Instruction):
    """State transfer for one variable (pays bandwidth of the slower leg)."""

    op: str                  # "read" | "write"
    var: str
    src: MemState = MemState.DISK
    dst: MemState = MemState.HBM
    # When writing, serialized bytes may differ from in-memory (M' vs M).
    serialized: bool = True

    def describe(self) -> str:
        return f"{self.op} {self.var} {self.src.value}->{self.dst.value}"


@dataclasses.dataclass
class Collective(Instruction):
    """all_reduce / all_gather / reduce_scatter / all_to_all / permute."""

    kind: str
    var: str
    axes: Tuple[str, ...]          # mesh axes participating
    output: Optional[str] = None   # defaults to in-place semantics
    # Optional explicit payload override (bytes per device); else derived
    # from the symbol table entry for ``var``.
    bytes_override: Optional[float] = None

    def describe(self) -> str:
        return f"{self.kind}[{','.join(self.axes)}] {self.var}"


@dataclasses.dataclass
class P2P(Instruction):
    """Point-to-point send/recv between *neighbor* positions on a mesh axis.

    The wire primitive of pipeline parallelism: a stage hands its boundary
    activations (or, on the backward path, their gradients) to the adjacent
    stage.  Unlike a :class:`Collective`, a p2p transfer rides exactly one
    link of the axis fabric — it never benefits from the wrapped-ring
    doubling of ``ClusterConfig.axis_bandwidth`` — and it moves its payload
    once (no ring phases).  Priced by :func:`repro.core.linalg_ops.p2p_cost`
    at ``ClusterConfig.p2p_bw(axis)``.
    """

    var: str
    axis: str                      # mesh axis the transfer crosses
    # Optional explicit payload override (bytes per device); else derived
    # from the symbol table entry for ``var``.
    bytes_override: Optional[float] = None

    def describe(self) -> str:
        return f"p2p[{self.axis}] {self.var}"


@dataclasses.dataclass
class JitCall(Instruction):
    """One compiled executable, costed from its generated HLO.

    ``compiled_cost`` is a :class:`repro.core.hlo_cost.CompiledCost` —
    FLOPs / HBM bytes / per-collective bytes extracted from the compiled
    module.  ``reads``/``writes`` hook it into live-variable state so IO
    before/after the call is accounted exactly once.
    """

    name: str
    compiled_cost: Any
    reads: Tuple[str, ...] = ()
    writes: Tuple[str, ...] = ()
    donated: Tuple[str, ...] = ()

    def describe(self) -> str:
        return f"jitcall {self.name} reads={list(self.reads)} writes={list(self.writes)}"


# ---------------------------------------------------------------------------
# Program blocks (control flow — paper Eq (1))
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GenericBlock:
    label: str
    children: List[Union[Instruction, "Block"]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ForBlock:
    label: str
    iterations: Optional[int]              # None => unknown, use N-hat
    body: List[Union[Instruction, "Block"]] = dataclasses.field(default_factory=list)
    predicate: List[Instruction] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class WhileBlock:
    label: str
    body: List[Union[Instruction, "Block"]] = dataclasses.field(default_factory=list)
    predicate: List[Instruction] = dataclasses.field(default_factory=list)
    iterations: Optional[int] = None       # almost always unknown


@dataclasses.dataclass
class ParForBlock:
    """Task-parallel loop: time scales by ceil(N / k) (paper Eq (1))."""

    label: str
    iterations: Optional[int]
    parallelism: int
    body: List[Union[Instruction, "Block"]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class IfBlock:
    label: str
    branches: List[List[Union[Instruction, "Block"]]] = dataclasses.field(default_factory=list)
    weights: Optional[Sequence[float]] = None   # None => uniform
    predicate: List[Instruction] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class PipelinedLoopBlock:
    """A software-pipelined microbatch loop (GPipe-style schedule).

    ``stages`` holds S per-stage bodies; every one of the M microbatches
    flows through all S stages, but *different* microbatches occupy
    different stages concurrently, so the loop's time is not N x body:

        T = fill/drain + steady state
          = sum_s T_s           (one microbatch rippling through the pipe)
          + (M - 1) * max_s T_s (every further microbatch behind the
                                 slowest stage)

    which degenerates **bit-exactly** to the sequential :class:`ForBlock`
    semantics at S=1 (``T_first + (M-1) * T_warm``).  Work totals are NOT
    overlapped: every microbatch runs every stage, so totals aggregate as
    ``sum_s first_s + (M-1) * sum_s warm_s`` — exactly the sequential
    weights (pipelining hides time, it never removes work).

    Stage-boundary activation traffic belongs *inside* the stage bodies as
    :class:`P2P` instructions, so it pipelines (and caches) with the stage
    that pays it.
    """

    label: str
    microbatches: int              # M; the loop's trip count
    stages: List[List[Union[Instruction, "Block"]]] = dataclasses.field(
        default_factory=list)      # S per-stage bodies, pipeline order


@dataclasses.dataclass
class FunctionBlock:
    """Named function body; calls are CallInst; recursion guarded by stack."""

    name: str
    body: List[Union[Instruction, "Block"]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class Call(Instruction):
    func: str

    def describe(self) -> str:
        return f"call {self.func}"


Block = Union[GenericBlock, ForBlock, WhileBlock, ParForBlock, IfBlock,
              PipelinedLoopBlock, FunctionBlock]


@dataclasses.dataclass
class Program:
    """Top-level runtime plan ``P``."""

    name: str
    blocks: List[Union[Instruction, Block]] = dataclasses.field(default_factory=list)
    functions: Dict[str, FunctionBlock] = dataclasses.field(default_factory=dict)
    # Variables that exist before the program runs (persistent inputs).
    inputs: Dict[str, TensorStat] = dataclasses.field(default_factory=dict)

    def functions_signature(self) -> Tuple:
        """Hashable identity of the function table (part of the cache key:
        two programs may bind the same function name to different bodies)."""
        return tuple(sorted((name, node_signature(fb))
                            for name, fb in self.functions.items()))

    def count_instructions(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}

        def walk(nodes):
            for n in nodes:
                if isinstance(n, Instruction):
                    k = type(n).__name__
                    counts[k] = counts.get(k, 0) + 1
                elif isinstance(n, GenericBlock):
                    walk(n.children)
                elif isinstance(n, (ForBlock, WhileBlock, ParForBlock, FunctionBlock)):
                    walk(getattr(n, "predicate", []) or [])
                    walk(n.body)
                elif isinstance(n, IfBlock):
                    walk(n.predicate)
                    for br in n.branches:
                        walk(br)
                elif isinstance(n, PipelinedLoopBlock):
                    for stage in n.stages:
                        walk(stage)

        walk(self.blocks)
        for f in self.functions.values():
            walk(f.body)
        return counts


# ---------------------------------------------------------------------------
# Hashable plan signatures (cost-memoization keys)
# ---------------------------------------------------------------------------
#
# ``node_signature`` gives every plan node a structural identity: two nodes
# with equal signatures cost identically under the same symbol-table state
# and cluster config.  Signatures are computed once per node object and
# cached on the instance — plan nodes must not be mutated after costing
# begins (they never are: generation builds a plan, costing only reads it).


def _attrs_sig(attrs: Dict[str, Any]) -> Tuple:
    return tuple(sorted(attrs.items()))


def node_signature(node) -> Tuple:
    sig = getattr(node, "_sig", None)
    if sig is None:
        sig = _compute_signature(node)
        node._sig = sig
    return sig


def _sig_list(nodes) -> Tuple:
    return tuple(node_signature(n) for n in nodes)


def _compute_signature(node) -> Tuple:
    if isinstance(node, CreateVar):
        return ("cv", node.name, node.stat.sig)
    if isinstance(node, CpVar):
        return ("cp", node.src, node.dst)
    if isinstance(node, RmVar):
        return ("rm", node.names)
    if isinstance(node, DataGen):
        return ("dg", node.opcode, node.output, node.stat.sig)
    if isinstance(node, Compute):
        return ("c", node.opcode, node.inputs, node.output, node.exec_type,
                node.shard_axes, _attrs_sig(node.attrs))
    if isinstance(node, IO):
        return ("io", node.op, node.var, node.src.value, node.dst.value,
                node.serialized)
    if isinstance(node, Collective):
        return ("co", node.kind, node.var, node.axes, node.output,
                node.bytes_override)
    if isinstance(node, P2P):
        return ("p2p", node.var, node.axis, node.bytes_override)
    if isinstance(node, JitCall):
        return ("jit", node.name, node.reads, node.writes, node.donated,
                _compiled_cost_sig(node.compiled_cost))
    if isinstance(node, Call):
        return ("call", node.func)
    if isinstance(node, GenericBlock):
        return ("g", node.label, _sig_list(node.children))
    if isinstance(node, ForBlock):
        return ("for", node.label, node.iterations,
                _sig_list(node.predicate), _sig_list(node.body))
    if isinstance(node, WhileBlock):
        return ("while", node.label, node.iterations,
                _sig_list(node.predicate), _sig_list(node.body))
    if isinstance(node, ParForBlock):
        return ("parfor", node.label, node.iterations, node.parallelism,
                _sig_list(node.body))
    if isinstance(node, IfBlock):
        return ("if", node.label,
                tuple(node.weights) if node.weights else None,
                _sig_list(node.predicate),
                tuple(_sig_list(br) for br in node.branches))
    if isinstance(node, PipelinedLoopBlock):
        return ("pipe", node.label, node.microbatches,
                tuple(_sig_list(stage) for stage in node.stages))
    if isinstance(node, FunctionBlock):
        return ("fn", node.name, _sig_list(node.body))
    raise TypeError(f"unsignable plan node {type(node)}")


def _compiled_cost_sig(cost) -> Tuple:
    """Content signature for a JitCall's CompiledCost (pure-data record)."""
    colls = tuple((c.kind, c.operand_bytes, c.result_bytes, c.group_size)
                  for c in getattr(cost, "collectives", ()))
    return (getattr(cost, "name", ""), getattr(cost, "flops_per_device", 0.0),
            getattr(cost, "bytes_per_device", 0.0),
            getattr(cost, "num_devices", 1),
            getattr(cost, "dispatch_count", 1), colls)
