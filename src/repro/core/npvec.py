"""Scalar-preserving numpy polymorphism for the vectorized cost walk.

The batched costing engine (docs/COST_MODEL.md §Vectorized evaluation)
threads numpy arrays — one lane per knob-grid member — through the same
closed-form cost expressions the scalar walk evaluates.  Most of those
expressions (``+ - * / //`` chains) are array-polymorphic for free; the
helpers here cover the handful of spots where Python builtins are not:

  * ``max``/``min`` raise on arrays (truth-value ambiguity) — :func:`pmax`
    and :func:`pmin` substitute ``np.maximum``/``np.minimum`` only when an
    operand is an ndarray, so every scalar call site keeps the builtin
    bit-for-bit (the golden-sweep byte-identity gate rides on this);
  * ``int(x)``/``float(x)`` casts on shape dims and payloads —
    :func:`dim_int` / :func:`as_payload` skip the cast for array lanes;
  * branchy predicates (``if n > 1``) need one answer for the whole lane
    vector — :func:`uniform_bool` requires the predicate to agree across
    lanes and raises :class:`HeterogeneousLanes` otherwise, which the
    batched driver catches to split the group back to scalar costing.

Elementwise float64 numpy arithmetic uses the same IEEE-754 double
operations as Python floats, so a vectorized expression evaluated over K
lanes is bit-identical to K scalar evaluations of the same expression —
the property the batched engine's bit-exactness proofs rest on
(tests/test_properties.py).
"""
from __future__ import annotations

import numpy as np

ndarray = np.ndarray


class HeterogeneousLanes(Exception):
    """A lane vector straddles a structural branch (e.g. some lanes have
    ``n > 1`` and some ``n == 1``): the group shares no single program
    structure and must be costed scalar."""


def is_vec(x) -> bool:
    return isinstance(x, np.ndarray)


def pmax(a, b):
    """``max(a, b)`` that is ``np.maximum`` when either side is an array.

    Scalar calls take the builtin path untouched — identical objects out,
    identical tie behavior — so pre-batching cost paths stay bit-exact.
    """
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return np.maximum(a, b)
    return max(a, b)


def pmin(a, b):
    """``min(a, b)`` with the same scalar-preserving contract as :func:`pmax`."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return np.minimum(a, b)
    return min(a, b)


def dim_int(x):
    """``int(x)`` for scalar tensor dims; array dims pass through.

    Array lanes keep integer dtype when they already are integral (the
    ``//`` chains that produce them yield int64), so downstream byte math
    matches the scalar ``int`` path value-for-value.
    """
    if isinstance(x, np.ndarray):
        return x
    return int(x)


def dim_ceil(x):
    """``int(x + 0.999)`` (the resident-bytes dim rounding) for scalars;
    the truncating ``astype(int64)`` — same value for positive lanes —
    when ``x`` is an array."""
    if isinstance(x, np.ndarray):
        return (x + 0.999).astype(np.int64)
    return int(x + 0.999)


def as_payload(x):
    """``float(x)`` for scalar byte payloads; float64 lanes pass through."""
    if isinstance(x, np.ndarray):
        return x.astype(np.float64) if x.dtype != np.float64 else x
    return float(x)


def uniform_bool(pred) -> bool:
    """Collapse an elementwise predicate to one bool, requiring every lane
    to agree.  Scalar bools pass through; a straddling vector raises
    :class:`HeterogeneousLanes` (the batched driver then falls back to
    scalar costing for the group, keeping the engine sound by construction
    rather than by hope)."""
    if isinstance(pred, np.ndarray):
        if pred.size == 0:
            return False
        first = bool(pred.flat[0])
        if not (pred == first).all():
            raise HeterogeneousLanes("lanes disagree on a structural branch")
        return first
    return bool(pred)


def lane_count(*xs) -> int:
    """Number of lanes across a set of possibly-vector values (1 if all
    scalar).  Raises on mismatched vector lengths — vectors built from one
    knob grid always agree."""
    k = 1
    for x in xs:
        if isinstance(x, np.ndarray):
            if k != 1 and x.shape[0] != k:
                raise ValueError(f"lane mismatch: {x.shape[0]} vs {k}")
            k = x.shape[0]
    return k


def lane(x, j: int) -> float:
    """Extract lane ``j`` of a possibly-vector value as a Python float.
    Scalars broadcast (every lane sees the same value) — exactly how the
    scalar walk would have charged them."""
    if isinstance(x, np.ndarray):
        return float(x[j])
    return float(x)


def fmt(x, spec: str = "") -> str:
    """Format a possibly-vector value for labels/notes: scalars honor the
    format spec, vectors render as their compact repr (display only — the
    cost fields themselves stay numeric)."""
    if isinstance(x, np.ndarray):
        return np.array2string(x, separator=",", threshold=8)
    return format(x, spec)
