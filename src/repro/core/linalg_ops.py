"""White-box per-op FLOP/byte formulas (paper §3.3).

SystemML's cost model "consists of dozens of these white-box cost functions
for all existing instructions" — e.g.::

    FLOP(tsmm_left) = MMD_corr * m * n^2 * s        (dense)

Each formula here maps input :class:`TensorStat` s + attributes to an
:class:`OpProfile`: floating point ops, HBM read/write traffic, the output's
TensorStat, and a utilization class ("mxu" for matmul-shaped work, "vpu" for
elementwise/reduction work).  The cost model turns a profile into time via
the roofline ``max(flops/peak·util, bytes/hbm_bw)`` — the paper's
"maximum of main-memory IO and instruction-specific floating point
operations", with MXU/VPU taking the role of the 1-FLOP/cycle CPU.

Formulas count *multiply-add as 2 FLOPs* to stay commensurable with XLA's
``cost_analysis()`` (which counts fused multiply-add as 2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Sequence, Tuple, Union

import numpy as np

from repro.core.cluster import dtype_bytes
from repro.core.npvec import HeterogeneousLanes, as_payload, dim_int, pmax
from repro.core.symbols import MemState, TensorStat

# Operation-specific corrections (the paper's MMD_corr / MMS_corr analogues).
TSMM_CORR = 0.5          # symmetric output: half the computation
SOLVE_CHOL_CORR = 1.0 / 3.0

# Fused-epilogue flop charges per output cell — MUST stay equal to the
# standalone elementwise ops they replace (``silu``/``gelu``/``layernorm``
# below), so folding an epilogue into its producing matmul changes HBM
# traffic and *nothing else*: the fused-vs-materialized cost delta is
# exactly the intermediate's round trip (see docs/COST_MODEL.md
# §Costing fusion plans).
EPILOGUE_FLOPS_PER_CELL = {"bias": 1.0, "silu": 6.0, "gelu": 8.0,
                           "layernorm": 6.0}

# Materialized attention scores and the softmax over them run in fp32
# (XLA upcasts bf16 logits before the reduction), so the unfused score
# round trip is priced at accumulator width.
ATTN_SCORE_ACC_BYTES = 4.0


@dataclasses.dataclass
class OpProfile:
    flops: float
    read_bytes: float
    write_bytes: float
    out: TensorStat
    util: str = "mxu"            # "mxu" | "vpu"

    @property
    def bytes(self) -> float:
        return self.read_bytes + self.write_bytes


OpFn = Callable[..., OpProfile]
REGISTRY: Dict[str, OpFn] = {}


def register(name: str):
    def deco(fn: OpFn) -> OpFn:
        REGISTRY[name] = fn
        return fn
    return deco


def profile(opcode: str, inputs: Sequence[TensorStat], **attrs) -> OpProfile:
    if opcode not in REGISTRY:
        raise KeyError(f"no cost function registered for opcode '{opcode}'")
    return REGISTRY[opcode](*inputs, **attrs)


def _bytes(st: TensorStat) -> float:
    return st.bytes_in_memory()


def _out(shape, like: TensorStat, dtype=None, sparsity=1.0) -> TensorStat:
    # dim_int keeps knob-grid lane vectors (batched cost walk) intact.
    return TensorStat(tuple(dim_int(x) for x in shape), dtype or like.dtype,
                      sparsity=sparsity, state=MemState.HBM, shards=like.shards)


# ---------------------------------------------------------------------------
# Matrix multiplication family (the paper's ba+*, tsmm, mapmm, cpmm)
# ---------------------------------------------------------------------------


@register("matmul")
def _matmul(a: TensorStat, b: TensorStat, **attrs) -> OpProfile:
    """General (batched) matmul: [..., m, k] x [..., k, n].

    Fusion variants (the costed plan dimension — see docs/COST_MODEL.md
    §Costing fusion plans):

      * ``epilogue="bias"|"silu"|"gelu"|"layernorm"`` folds the named
        elementwise tail into the matmul flush: its flops ride the matmul
        (same per-cell charge as the standalone op) but the intermediate
        never round-trips HBM — the caller simply does not emit the
        separate op.  ``epi_cols`` narrows the epilogue to the first
        ``epi_cols`` output columns (a gated MLP applies the activation to
        d_ff of its 3*d_ff fused projection).
      * ``sink_cast_bytes=<width>`` sinks a dtype cast into the output
        write: the result leaves the MXU accumulator at ``width`` bytes
        per cell instead of the input dtype's, replacing a materialized
        read-modify-write ``cast`` op downstream.
    """
    *ba, m, k = a.shape
    *bb, k2, n = b.shape
    assert k == k2, f"matmul contraction mismatch {a.shape} x {b.shape}"
    batch = pmax(math.prod(ba) if ba else 1, math.prod(bb) if bb else 1)
    # sparse inputs scale flops by sparsity (paper's s / s^2 terms)
    s = a.sparsity * b.sparsity
    flops = 2.0 * batch * m * n * k * s
    out = _out(tuple(ba or bb) + (m, n), a)
    reads = _bytes(a) + _bytes(b)
    writes = _bytes(out)
    epi = attrs.get("epilogue")
    if epi:
        cols = attrs.get("epi_cols", n)
        flops = flops + EPILOGUE_FLOPS_PER_CELL[epi] * batch * m * cols
        if epi == "bias":
            reads = reads + n * dtype_bytes(a.dtype)
    sink = attrs.get("sink_cast_bytes")
    if sink is not None:
        writes = out.cells * as_payload(sink)
    return OpProfile(flops, reads, writes, out, "mxu")


@register("tsmm")
def _tsmm(x: TensorStat, **attrs) -> OpProfile:
    """Transpose-self matmul X^T X — symmetric output, half the compute.

    FLOP(tsmm_left) = TSMM_CORR * 2 * m * n^2 * s   (dense; paper Eq (2),
    doubled because we count mul+add separately like XLA does).
    """
    m, n = x.shape
    flops = TSMM_CORR * 2.0 * m * n * n * (x.sparsity if x.sparsity >= 0.4 else x.sparsity ** 2)
    out = _out((n, n), x)
    # read X once; write only the upper triangle then mirror (~n^2 writes)
    return OpProfile(flops, _bytes(x), _bytes(out), out, "mxu")


@register("transpose")
def _transpose(x: TensorStat, **attrs) -> OpProfile:
    out = _out(tuple(reversed(x.shape)), x, sparsity=x.sparsity)
    return OpProfile(0.0, _bytes(x), _bytes(out), out, "vpu")


@register("solve")
def _solve(a: TensorStat, b: TensorStat, **attrs) -> OpProfile:
    """Dense SPD solve via Cholesky: n^3/3 + 2 n^2 rhs."""
    n = a.shape[0]
    rhs = b.shape[1] if len(b.shape) > 1 else 1
    flops = SOLVE_CHOL_CORR * 2.0 * n ** 3 + 2.0 * 2.0 * n * n * rhs
    out = _out((n, rhs), b)
    return OpProfile(flops, _bytes(a) + _bytes(b), _bytes(out), out, "mxu")


# ---------------------------------------------------------------------------
# Elementwise / reduction / data movement
# ---------------------------------------------------------------------------


def _pick_big(ins: Sequence[TensorStat]) -> TensorStat:
    """The largest input by cells — ``max(ins, key=cells)`` made lane-safe.

    When some cell counts are knob-grid lane vectors, replay the builtin
    max's first-of-ties scan per lane; every lane must elect the same input
    (else the group's programs differ structurally per lane and the batched
    driver must fall back to scalar costing)."""
    if len(ins) == 1:
        return ins[0]
    try:
        return max(ins, key=lambda s: s.cells)
    except ValueError:  # truth-value ambiguity: at least one lane vector
        cells = [np.asarray(s.cells, dtype=np.float64) for s in ins]
        best = np.array(np.broadcast_to(cells[0], np.broadcast(*cells).shape))
        sel = np.zeros(best.shape, dtype=np.int64)
        for i in range(1, len(cells)):
            gt = cells[i] > best
            sel = np.where(gt, i, sel)
            best = np.maximum(best, cells[i])
        first = int(sel.flat[0])
        if not (sel == first).all():
            raise HeterogeneousLanes("lanes elect different elementwise "
                                     "broadcast shapes")
        return ins[first]


def _ew(arity: int, flops_per_cell: float = 1.0):
    def fn(*ins: TensorStat, **attrs) -> OpProfile:
        big = _pick_big(ins)
        out = _out(big.shape, big)
        reads = sum(_bytes(i) for i in ins)
        return OpProfile(flops_per_cell * big.cells, reads, _bytes(out), out, "vpu")
    return fn


REGISTRY["add"] = _ew(2)
REGISTRY["sub"] = _ew(2)
REGISTRY["mul"] = _ew(2)
REGISTRY["div"] = _ew(2, 4.0)
REGISTRY["unary"] = _ew(1)          # exp/tanh/gelu etc (approx 1 "flop"/cell
REGISTRY["gelu"] = _ew(1, 8.0)      # transcendental-heavy
REGISTRY["silu"] = _ew(1, 6.0)


@register("reduce")
def _reduce(x: TensorStat, **attrs) -> OpProfile:
    axes = attrs.get("axes")
    if axes is None:
        out_shape: Tuple[int, ...] = ()
    else:
        out_shape = tuple(d for i, d in enumerate(x.shape) if i not in set(axes))
    out = _out(out_shape, x)
    return OpProfile(as_payload(x.cells), _bytes(x), _bytes(out), out, "vpu")


@register("rdiag")
def _rdiag(v: TensorStat, **attrs) -> OpProfile:
    n = v.shape[0]
    out = _out((n, n), v, sparsity=1.0 / max(n, 1))
    return OpProfile(0.0, _bytes(v), out.bytes_serialized(), out, "vpu")


@register("concat")
def _concat(*ins: TensorStat, **attrs) -> OpProfile:
    axis = attrs.get("axis", -1)
    shape = list(ins[0].shape)
    shape[axis] = sum(i.shape[axis] for i in ins)
    out = _out(shape, ins[0])
    reads = sum(_bytes(i) for i in ins)
    return OpProfile(0.0, reads, _bytes(out), out, "vpu")


@register("softmax")
def _softmax(x: TensorStat, **attrs) -> OpProfile:
    out = _out(x.shape, x)
    return OpProfile(5.0 * x.cells, _bytes(x), _bytes(out), out, "vpu")


@register("layernorm")
def _layernorm(x: TensorStat, **attrs) -> OpProfile:
    out = _out(x.shape, x)
    return OpProfile(6.0 * x.cells, _bytes(x), _bytes(out), out, "vpu")


@register("embedding")
def _embedding(ids: TensorStat, table: TensorStat, **attrs) -> OpProfile:
    d = table.shape[-1]
    out = _out(tuple(ids.shape) + (d,), table)
    # gather reads only the selected rows
    reads = _bytes(ids) + out.bytes_in_memory()
    return OpProfile(0.0, reads, _bytes(out), out, "vpu")


# ---------------------------------------------------------------------------
# Attention / MoE / SSM composite ops (white-box composites used by the
# analytical planner; the generated-plan path gets exact numbers from HLO)
# ---------------------------------------------------------------------------


def avg_keys_per_query(sq: int, skv: int, window, causal: bool) -> float:
    """Exact average number of keys each query attends to.

    Queries occupy the last ``sq`` positions of a ``skv``-long context
    (decode/suffix convention): query i sees ``min(skv - sq + i + 1, w)``
    keys under a causal mask with window ``w`` (``w = skv`` when
    unwindowed).  The closed-form average prices windowed *and* causal
    attention correctly where the window overhangs the sequence start —
    the legacy profile's all-or-nothing ``frac=0.5`` granted no causal
    discount there at all.
    """
    w = min(window, skv) if window else skv
    if not causal:
        return float(w)
    lo, hi = skv - sq + 1, skv          # visible-key counts, pre-clamp
    if w >= hi:
        return (lo + hi) / 2.0
    if w <= lo:
        return float(w)
    # queries with <= w visible keys average (lo+w)/2; the rest clamp at w
    return ((w - lo + 1) * (lo + w) / 2.0 + (hi - w) * w) / sq


@register("attention")
def _attention(q: TensorStat, k: TensorStat, v: TensorStat, **attrs) -> OpProfile:
    """Scaled dot-product attention, optionally windowed/causal.

    q: [B, Hq, Sq, D], k/v: [B, Hkv, Skv, D].  ``window`` limits keys per
    query (sliding window); causal halves the score work.

    The ``fused`` attr selects the fusion variant (the costed plan
    dimension).  Absent — the legacy profile: flash-style fusion assumed
    unconditionally (reads only q+k+v) and the coarse all-or-nothing
    causal discount; every pre-fusion baseline rides on this path
    bit-identically.  ``fused=True`` — the flash plan, priced with the
    exact averaged keys-per-query discount.  ``fused=False`` — the
    *materialized* plan: same flops, plus the B*Hq*Sq*Skv score matrix's
    HBM round trip (fp32 scores written + read by softmax, probs written
    + read by the AV matmul at input width).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    window = attrs.get("window")
    eff_kv = min(skv, window) if window else skv
    causal = attrs.get("causal", False)
    out = _out((b, hq, sq, d), q)
    reads = _bytes(q) + _bytes(k) + _bytes(v)
    if "fused" not in attrs:
        frac = 0.5 if (causal and eff_kv == skv and sq == skv) else 1.0
        score_flops = 2.0 * b * hq * sq * eff_kv * d * frac
        av_flops = 2.0 * b * hq * sq * eff_kv * d * frac
        softmax_flops = 5.0 * b * hq * sq * eff_kv * frac
        return OpProfile(score_flops + av_flops + softmax_flops, reads,
                         _bytes(out), out, "mxu")
    avg = avg_keys_per_query(sq, skv, window, causal)
    score_flops = 2.0 * b * hq * sq * avg * d
    av_flops = 2.0 * b * hq * sq * avg * d
    softmax_flops = 5.0 * b * hq * sq * avg
    writes = _bytes(out)
    if not attrs["fused"]:
        # The materialized plan pays the full rectangular score matrix
        # (masked entries are computed-and-discarded, not skipped).
        score_cells = b * hq * sq * skv
        bpe = dtype_bytes(q.dtype)
        reads = reads + score_cells * (ATTN_SCORE_ACC_BYTES + bpe)
        writes = writes + score_cells * (ATTN_SCORE_ACC_BYTES + bpe)
    return OpProfile(score_flops + av_flops + softmax_flops, reads,
                     writes, out, "mxu")


@register("moe_ffn")
def _moe_ffn(x: TensorStat, w_up: TensorStat, **attrs) -> OpProfile:
    """Routed expert FFN: tokens x d -> top-k of E experts, gated MLP.

    w_up: [E, d, ff].  Expected compute scales with k/E "sparsity" — the
    paper's sparse-size math reused for expert load.
    """
    tokens = math.prod(x.shape[:-1])
    d = x.shape[-1]
    e, _, ff = w_up.shape
    k = attrs.get("top_k", 2)
    gated = 3.0 if attrs.get("gated", True) else 2.0
    flops = gated * 2.0 * tokens * k * d * ff
    out = _out(x.shape, x)
    reads = _bytes(x) + e * d * ff * gated * dtype_bytes(w_up.dtype)
    return OpProfile(flops, reads, _bytes(out), out, "mxu")


@register("ssd_scan")
def _ssd_scan(x: TensorStat, **attrs) -> OpProfile:
    """Mamba2 SSD chunked scan: [B, S, H, P] with state size N per head.

    Chunked dual form: intra-chunk (quadratic in chunk), inter-chunk state
    passing — flops ≈ 2*B*S*H*P*(chunk + 2N).
    """
    b, s, h, p = x.shape
    n = attrs.get("state", 128)
    chunk = attrs.get("chunk", 256)
    flops = 2.0 * b * s * h * p * (chunk + 2 * n)
    out = _out(x.shape, x)
    # ceil, not floor: a sequence shorter than one chunk still carries its
    # state once (floor costed s < chunk at ZERO state bytes).  Written as
    # -(-s // chunk) to stay lane-vector safe.
    n_chunks = -(-s // max(chunk, 1))
    state_bytes = b * h * p * n * dtype_bytes(x.dtype) * n_chunks
    return OpProfile(flops, _bytes(x) + state_bytes, _bytes(out), out, "mxu")


@register("cast")
def _cast(x: TensorStat, **attrs) -> OpProfile:
    """Materialized dtype cast: one read-modify-write over the buffer.

    ``from_bytes``/``to_bytes`` override the element widths (the input
    stat may stand in for a buffer of another dtype — e.g. the fp32
    gradient accumulator addressed through the ``params`` variable).  The
    fused alternative is no instruction at all: ``sink_cast_bytes`` on the
    producing matmul writes the target width straight out of the
    accumulator, so this op's whole profile IS the fusion delta.
    """
    cells = as_payload(x.cells)
    from_b = attrs.get("from_bytes", dtype_bytes(x.dtype))
    to_b = attrs.get("to_bytes", dtype_bytes(x.dtype))
    out = _out(x.shape, x)
    return OpProfile(1.0 * cells, cells * from_b, cells * to_b, out, "vpu")


@register("cross_entropy")
def _xent(logits: TensorStat, **attrs) -> OpProfile:
    out = _out((), logits)
    return OpProfile(8.0 * logits.cells, _bytes(logits), 4.0, out, "vpu")


@register("adamw_update")
def _adamw(p: TensorStat, **attrs) -> OpProfile:
    # read p, g, m, v; write p, m, v — ~14 flops/param
    out = _out(p.shape, p)
    b = _bytes(p)
    return OpProfile(14.0 * p.cells, 4 * b, 3 * b, out, "vpu")


# ---------------------------------------------------------------------------
# Collective payload/time formulas (ring algorithms on a torus axis)
# ---------------------------------------------------------------------------


def collective_wire(kind: str, bytes_per_device: float,
                    axis_size: Union[int, Sequence[int]]
                    ) -> Tuple[float, int]:
    """(wire bytes per device, hop count) for one collective over a mesh
    axis — or, given a tuple of sizes, over several axes of a torus mesh
    phased hierarchically (the 3D-mesh form).

    Ring formulas (bytes are the *per-device* payload B):
      all_gather / reduce_scatter: (n-1)/n * B_total_or_shard semantics —
        we take B as the per-device INPUT payload:
          all_gather:      each device ends with n*B; wire bytes (n-1)*B
          reduce_scatter:  input n*B-ish handled by caller; here B is the
                           per-device input, wire bytes (n-1)/n * B
      all_reduce = reduce_scatter + all_gather = 2*(n-1)/n * B
      all_to_all: (n-1)/n * B
      permute: B, 1 hop

    Multi-axis semantics mirror the cost estimator's per-axis phasing: the
    wire volumes and hops of each axis add, and a hierarchical all_gather
    grows the payload by each axis it crosses.  A size-1 axis contributes
    nothing, so the 3D form degenerates *bit-exactly* to the 2D answer
    when the third axis has size 1 (property-tested in
    ``tests/test_torus3d.py``).

    The wire volume is the bandwidth-bound part of the collective's cost
    (time = wire/link_bw + hops*phase_latency); the cost estimator also
    accumulates it into :class:`repro.core.costmodel.ProgramTotals`, where
    it feeds the resource optimizer's sound collective floors.
    """
    if not isinstance(axis_size, (int, float)):
        wire, hops = 0.0, 0
        for w, h in collective_phases(kind, bytes_per_device, axis_size):
            wire += w
            hops += h
        return wire, hops
    n = max(int(axis_size), 1)
    if n == 1:
        return 0.0, 0
    b = as_payload(bytes_per_device)
    if kind == "all_reduce":
        return 2.0 * (n - 1) / n * b, 2 * (n - 1)
    if kind == "all_gather":
        return (n - 1) * b, n - 1
    if kind == "reduce_scatter":
        return (n - 1) / n * b, n - 1
    if kind == "all_to_all":
        return (n - 1) / n * b, n - 1
    if kind in ("permute", "collective_permute"):
        return b, 1
    raise KeyError(f"unknown collective kind '{kind}'")


def collective_phases(kind: str, bytes_per_device: float,
                      axis_sizes: Sequence[int]):
    """Yield ``(wire bytes, hops)`` for each axis phase of a multi-axis
    collective, applying the hierarchical payload-growth rule between
    phases (an all_gather's payload multiplies by every axis it crosses).

    The single source of the phasing semantics: the cost estimator's
    per-axis pricing loop (``CostEstimator._cost_collective``, which needs
    each phase separately because axes carry different bandwidths) and the
    tuple form of :func:`collective_wire` both consume it, so the two can
    never drift apart."""
    payload = as_payload(bytes_per_device)
    for n in axis_sizes:
        yield collective_wire(kind, payload, int(n))
        if kind == "all_gather":
            # rebind, never *=: a lane-vector payload aliases the caller's
            # array (bytes_override / a TensorStat's cached bytes), and an
            # in-place multiply would corrupt it for every later walk
            payload = payload * max(int(n), 1)


def p2p_wire(bytes_per_device: float, axis_size: int) -> Tuple[float, int]:
    """(wire bytes per device, hop count) for a neighbor-to-neighbor
    send/recv along a mesh axis — the pipeline stage-boundary primitive.

    The payload crosses exactly one link once (no ring phases, no payload
    growth), so the wire volume is the payload itself and the hop count is
    1.  A size-1 axis has no neighbor: the transfer is a no-op (0 bytes,
    0 hops), which is what makes an S=1 "pipeline" degenerate bit-exactly
    to the sequential loop.
    """
    if int(axis_size) <= 1:
        return 0.0, 0
    return as_payload(bytes_per_device), 1


def p2p_cost(bytes_per_device: float, axis_size: int,
             link_bw: float, phase_latency: float) -> float:
    """Time for one stage-boundary send/recv: ``payload / link_bw +
    phase_latency`` across one link.

    Unlike :func:`collective_cost` there is no ``links`` parameter: a p2p
    transfer rides a single directed link of the fabric, so the wrapped-
    ring doubling a 3D torus grants collectives (both ring directions
    usable) never applies — price it at the *single-link* rate
    (``ClusterConfig.p2p_bw``), not ``axis_bandwidth``.
    """
    wire, hops = p2p_wire(bytes_per_device, axis_size)
    if not hops:
        return 0.0
    return wire / link_bw + hops * phase_latency


def collective_cost(kind: str, bytes_per_device: float,
                    axis_size: Union[int, Sequence[int]],
                    link_bw: float, phase_latency: float,
                    links: int = 1) -> float:
    """Time for one collective over an axis of ``axis_size`` devices:
    ``wire_bytes / (link_bw * links) + hops * phase_latency`` with the
    ring-algorithm wire volumes of :func:`collective_wire`.  ``links`` is
    the per-axis link count of the torus geometry (2 on a 3D-torus axis,
    1 on the flat model — see ``ClusterConfig.axis_bandwidth``)."""
    wire, hops = collective_wire(kind, bytes_per_device, axis_size)
    if not hops:
        return 0.0
    return wire / (link_bw * max(int(links), 1)) + hops * phase_latency
