"""Cluster characteristics ``cc`` — the hardware side of ``C(P, cc)``.

The paper's cost model (R3) is parameterized by cluster characteristics:
memory budgets, degrees of parallelism k_l/k_m/k_r, IO bandwidth multipliers
(HDFS/local disk), and a CPU frequency with a 1-FLOP/cycle assumption.

The TPU analogue is a white-box table of per-chip peak compute, the memory
hierarchy bandwidths (HBM / VMEM / host DRAM / PCIe / disk), the ICI fabric,
and fixed latency constants (dispatch, collective phase setup).  All values
are *constants*, not profiles — preserving the paper's R1 (analytical model,
no profiling runs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

from repro.core.calibration import CalibrationProfile

# ---------------------------------------------------------------------------
# Per-chip hardware descriptions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """A single accelerator chip (the unit the mesh is built from)."""

    name: str
    # Peak dense matmul throughput by dtype (FLOP/s).
    peak_flops: Dict[str, float]
    # HBM capacity (bytes) and bandwidth (bytes/s).
    hbm_bytes: float
    hbm_bw: float
    # Fast on-chip memory (VMEM) — relevant for Pallas BlockSpec budgeting.
    vmem_bytes: float
    # Per-link ICI bandwidth (bytes/s, one direction) and number of links
    # usable per mesh axis (a 2D torus exposes 1 link per axis direction
    # here; the planner multiplies by axis count when both axes carry the
    # same collective).
    ici_bw_per_link: float
    ici_links_per_axis: int = 1
    # How many torus dimensions this chip generation's ICI fabric builds.
    # v5e/v6e slices are 2D tori; v5p slices are 3D tori (each chip has six
    # ICI ports, two per axis).  Mapping a *3D* logical mesh onto a 3D torus
    # gives every mesh axis a wrapped physical ring with both link
    # directions usable — 2 links per axis — while the flat 2D model (one
    # effective link per axis, the calibrated behavior every existing mesh
    # uses) is kept for 2D meshes on any chip.  The resource optimizer only
    # emits 3D mesh candidates when ``ici_torus_dims >= 3``.
    ici_torus_dims: int = 2
    # Side length of the building-block cube the fabric is assembled from
    # (v4/v5p slices compose 4x4x4 cubes behind optical switches).  An axis
    # of a 3D slice only closes into a wrapped ring — earning the 2-link
    # torus rate — when its extent is a whole number of cube faces, i.e. a
    # multiple of this; any other extent is an open line (1 link).
    ici_cube_dim: int = 4
    # Host-side paths.
    pcie_bw: float = 32e9          # host <-> device
    host_dram_bw: float = 100e9    # host memory
    disk_bw: float = 1.0e9         # persistent storage (checkpoints, data)
    # Data-center network between pods (bytes/s per host NIC).
    dcn_bw: float = 25e9 / 8 * 8   # 25 GB/s effective per pod-slice edge
    # Largest single ICI-connected slice this chip generation builds; beyond
    # it, scaling crosses DCN (the resource optimizer enumerates both).
    ici_domain: int = 256
    # On-demand $/chip-hour — the resource optimizer's $-cost proxy
    # (device-seconds weighted by price).  Analytical constant like the
    # rest of the table; 0.0 means "free" and disables cost ranking.
    cost_per_chip_hour: float = 0.0

    def peak(self, dtype: str) -> float:
        key = _canon_dtype(dtype)
        if key in self.peak_flops:
            return self.peak_flops[key]
        # Unknown dtype: fall back to fp32 rate.
        return self.peak_flops.get("float32", min(self.peak_flops.values()))


def _canon_dtype(dtype) -> str:
    s = str(dtype)
    for k in ("bfloat16", "float32", "float16", "int8", "float64", "float8"):
        if k in s:
            return k
    return s


# TPU v5e — the assignment's target numbers: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s per ICI link.
TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops={
        "bfloat16": 197e12,
        "float16": 197e12,
        "int8": 394e12,
        "float8": 394e12,
        "float32": 49.25e12,   # 1/4 rate through the MXU
        "float64": 2.0e12,     # emulated; effectively "don't"
    },
    hbm_bytes=16e9,
    hbm_bw=819e9,
    vmem_bytes=128 * 2 ** 20,
    ici_bw_per_link=50e9,
    ici_links_per_axis=1,
    ici_domain=256,
    cost_per_chip_hour=1.20,
)

# TPU v5p — the training-class sibling: ~2.3x the bf16 rate, ~6x the HBM,
# bigger ICI domain, at a materially higher price point.  The interesting
# resource decisions (is a smaller count of fat chips cheaper than a pod of
# thin ones?) need exactly this contrast in the table.
TPU_V5P = ChipSpec(
    name="tpu_v5p",
    peak_flops={
        "bfloat16": 459e12,
        "float16": 459e12,
        "int8": 918e12,
        "float8": 918e12,
        "float32": 114.75e12,
        "float64": 4.0e12,
    },
    hbm_bytes=95e9,
    hbm_bw=2765e9,
    vmem_bytes=128 * 2 ** 20,
    ici_bw_per_link=90e9,
    ici_links_per_axis=1,
    ici_domain=1024,           # v5p slices scale far further over ICI (3D torus)
    ici_torus_dims=3,          # six ICI ports per chip: 2 per torus axis
    cost_per_chip_hour=4.20,
)

# TPU v6e (Trillium) — ~4.7x the v5e bf16 rate and 2x its HBM bandwidth at
# ~2.2x the price: usually the fastest *and* the cheapest per step, unless
# the workload is HBM-capacity bound (32 GB/chip).
TPU_V6E = ChipSpec(
    name="tpu_v6e",
    peak_flops={
        "bfloat16": 918e12,
        "float16": 918e12,
        "int8": 1836e12,
        "float8": 1836e12,
        "float32": 229.5e12,
        "float64": 4.0e12,
    },
    hbm_bytes=32e9,
    hbm_bw=1640e9,
    vmem_bytes=128 * 2 ** 20,
    ici_bw_per_link=90e9,
    ici_links_per_axis=1,
    ici_domain=256,
    cost_per_chip_hour=2.70,
)

# A CPU "chip" used ONLY by the accuracy benchmark (paper §3.4): the cost
# model's fidelity is validated against wall time on the machine we actually
# have.  Single core (the container), DGEMM-ish peak, DRAM bandwidth.
CPU_HOST = ChipSpec(
    name="cpu_host",
    peak_flops={
        "float32": 5.0e10,     # ~2.5GHz x 8-wide FMA x 2 on one core, derated
        "float64": 2.5e10,
        "bfloat16": 5.0e10,
    },
    hbm_bytes=32e9,
    hbm_bw=1.2e10,             # effective single-core stream bandwidth
    vmem_bytes=32 * 2 ** 20,   # L2-ish
    ici_bw_per_link=1e10,
    pcie_bw=1e12,              # host==device: transfers are memcpy-free-ish
    disk_bw=0.5e9,
    ici_domain=1,
    cost_per_chip_hour=0.10,
)

# The chip table the resource optimizer enumerates over (cpu_host excluded:
# it exists for the accuracy benchmark, not as a serving/training target).
CHIPS: Dict[str, ChipSpec] = {
    "tpu_v5e": TPU_V5E,
    "tpu_v5p": TPU_V5P,
    "tpu_v6e": TPU_V6E,
}


# ---------------------------------------------------------------------------
# Cluster config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Everything the cost model may consult about the execution substrate.

    ``mesh_shape``/``mesh_axes`` describe the device mesh the plan targets
    (e.g. (16, 16) x ("data", "model") for one v5e pod, (2, 16, 16) x
    ("pod", "data", "model") for the multi-pod config).  The "pod" axis is
    assumed to cross DCN, all other axes ride ICI.
    """

    chip: ChipSpec = TPU_V5E
    mesh_shape: Tuple[int, ...] = (16, 16)
    mesh_axes: Tuple[str, ...] = ("data", "model")
    # Per-mesh-axis ICI link counts, aligned with ``mesh_axes``.  Empty
    # (the default) means one effective link per axis — the flat model
    # every pre-torus mesh was calibrated with, kept bit-identical.  A 3D
    # logical mesh laid out on a 3D torus (v5p) sets 2 for each ICI axis:
    # the wrapped physical ring exposes both link directions, doubling the
    # per-axis bandwidth.  DCN ("pod") axes ignore the link count.
    torus_links: Tuple[int, ...] = ()

    # --- latency constants (the paper's job/task-latency analogues) ---
    dispatch_latency: float = 35e-6        # per jit-call launch
    collective_phase_latency: float = 1e-6  # per hop of a phased collective
    host_callback_latency: float = 1e-3

    # --- efficiency corrections (the paper's MMD_corr analogues) ---
    matmul_util: float = 0.75      # achievable fraction of MXU peak, large mms
    small_matmul_util: float = 0.30
    vpu_util: float = 0.80         # elementwise ops vs HBM roofline
    hbm_eff: float = 0.85          # achievable fraction of peak HBM bw
    ici_eff: float = 0.90
    dcn_eff: float = 0.80

    # fraction of collective time that can hide under compute when the plan
    # enables overlap (microbatched accumulation / async collectives).
    overlap_fraction: float = 0.0

    # Fitted corrections for this chip type (repro.core.calibration) —
    # achieved fractions measured by benchmarks/bench_calibrate.py.  None
    # (the default) keeps the hand-set constants above bit-identical;
    # every consulting property below checks ``calibration is None``
    # first, so the uncalibrated path never changes.
    calibration: Optional[CalibrationProfile] = None

    # --- memory budgets (the paper's memory-budget analogue) ---
    hbm_budget_fraction: float = 0.9   # usable HBM fraction (runtime reserve)

    # --- control-flow defaults (paper §3.2) ---
    default_loop_iterations: int = 16   # N-hat for unknown while/for bounds
    default_branch_weights: Tuple[float, ...] = ()  # empty => uniform

    # --- job-level pricing constants (resource optimizer, $/job) ---
    # Analytical constants like everything else in this table (R1): they
    # never touch the per-step cost walk, only the job-level amortization
    # in ``repro.core.resource.job_seconds`` / ``job_dollars``.
    job_startup_seconds: float = 180.0     # provision + weight load + compile
    # Constant override for the checkpoint-restore term of job pricing.
    # ``None`` (the default) derives restore time from the architecture's
    # checkpoint bytes over the disk+PCIe path, sharded across chips
    # (:func:`repro.core.resource.checkpoint_restore_seconds`); callers
    # with no architecture in hand fall back to
    # :data:`DEFAULT_CHECKPOINT_RESTORE_SECONDS`.  Set a float to pin the
    # old constant-seconds behavior.
    checkpoint_restore_seconds: Optional[float] = None
    # Expected preemptions per chip-hour (large slices are preempted more
    # often in absolute terms: the rate scales with chip count).
    preemption_rate_per_chip_hour: float = 1e-4
    checkpoint_interval_steps: int = 1000  # work at risk between checkpoints

    # ----- derived -----
    @property
    def num_chips(self) -> int:
        return int(math.prod(self.mesh_shape))

    def axis_size(self, axis: str) -> int:
        try:
            return self.mesh_shape[self.mesh_axes.index(axis)]
        except ValueError:
            return 1

    @property
    def hbm_budget(self) -> float:
        return self.chip.hbm_bytes * self.hbm_budget_fraction

    def peak_flops_total(self, dtype: str = "bfloat16") -> float:
        return self.chip.peak(dtype) * self.num_chips

    # Effective bandwidths -------------------------------------------------
    @property
    def hbm_bw_eff(self) -> float:
        cal = self.calibration
        if cal is not None and cal.hbm_fraction is not None:
            return self.chip.hbm_bw * cal.hbm_fraction
        return self.chip.hbm_bw * self.hbm_eff

    @property
    def ici_bw_eff(self) -> float:
        cal = self.calibration
        if cal is not None and cal.ici_fraction is not None:
            return self.chip.ici_bw_per_link * cal.ici_fraction
        return self.chip.ici_bw_per_link * self.ici_eff

    @property
    def dcn_bw_eff(self) -> float:
        cal = self.calibration
        if cal is not None and cal.dcn_fraction is not None:
            return self.chip.dcn_bw * cal.dcn_fraction
        return self.chip.dcn_bw * self.dcn_eff

    # MXU efficiency -------------------------------------------------------
    def mxu_util(self, dtype: str, flops: float) -> float:
        """Achievable MXU fraction for one matmul of ``flops`` in
        ``dtype``.  Uncalibrated: the log-linear ramp from
        ``small_matmul_util`` (<=1e8 FLOPs) to ``matmul_util`` (>=1e10) —
        smooth, so estimated time stays monotone in problem size (a step
        function made bigger ops 'faster').  A calibration profile with a
        fitted (dtype, shape-class) entry replaces the ramp value for
        that class; uncovered classes keep the ramp.

        ``flops`` may be a knob-grid lane vector (the batched cost walk):
        the ramp is then evaluated per lane with the same float64 ops the
        scalar branch uses; a calibration profile classifies per lane, so
        calibrated vectors fall back to elementwise scalar calls."""
        import numpy as np
        if isinstance(flops, np.ndarray):
            if self.calibration is not None:
                return np.array([self.mxu_util(dtype, float(f))
                                 for f in flops], dtype=np.float64)
            lo, hi = 1e8, 1e10
            frac = (np.log10(flops) - 8.0) / 2.0
            ramp = self.small_matmul_util + frac * (self.matmul_util
                                                    - self.small_matmul_util)
            return np.where(flops <= lo, self.small_matmul_util,
                            np.where(flops >= hi, self.matmul_util, ramp))
        cal = self.calibration
        if cal is not None:
            f = cal.mxu_util(dtype, flops)
            if f is not None:
                return f
        lo, hi = 1e8, 1e10
        if flops <= lo:
            return self.small_matmul_util
        if flops >= hi:
            return self.matmul_util
        frac = (math.log10(flops) - 8.0) / 2.0
        return self.small_matmul_util + frac * (self.matmul_util
                                                - self.small_matmul_util)

    def mxu_util_ceiling(self, dtype: str) -> float:
        """The most generous MXU fraction ANY op of ``dtype`` can earn —
        what a sound cluster floor must price FLOPs at.  Uncalibrated this
        is ``max(matmul_util, small_matmul_util)`` (the ramp's endpoints
        bound it); a calibrated profile's per-class table raises or lowers
        it, but classes the table does not cover still fall back to the
        ramp, so the uncalibrated ceiling stays folded in."""
        ceiling = max(self.matmul_util, self.small_matmul_util)
        cal = self.calibration
        if cal is not None:
            return cal.mxu_ceiling(dtype, ceiling)
        return ceiling

    def overlap(self, fabric: str) -> float:
        """Effective overlap fraction for one fabric (``"ici"``/``"dcn"``).
        The *gate* stays with the plan: ``overlap_fraction == 0`` (plan
        did not enable overlap) always yields 0.  When the plan enables
        overlap, a calibrated per-fabric achieved overlap replaces the
        enabled value; uncalibrated both fabrics get ``overlap_fraction``
        unchanged."""
        if self.overlap_fraction == 0.0:
            return 0.0
        cal = self.calibration
        if cal is not None:
            o = cal.overlap_ici if fabric == "ici" else cal.overlap_dcn
            if o is not None:
                return o
        return self.overlap_fraction

    def link_class(self, axis: str) -> str:
        """``"dcn"`` for the pod axis (crosses the data-center network),
        ``"ici"`` for every other mesh axis.  The single source of truth
        for axis->fabric mapping: :meth:`link_bw` and the cost estimator's
        collective-volume accounting both route through it."""
        return "dcn" if axis == "pod" else "ici"

    def link_bw(self, axis: str) -> float:
        """Per-device *single-link* interconnect bandwidth along a mesh
        axis (fabric selection only; see :meth:`axis_bandwidth` for the
        topology-aware rate collectives are actually priced at)."""
        return (self.dcn_bw_eff if self.link_class(axis) == "dcn"
                else self.ici_bw_eff)

    def axis_links(self, axis: str) -> int:
        """ICI links usable along a mesh axis: the ``torus_links`` entry
        aligned with ``mesh_axes`` (1 when unset — the flat model).  DCN
        axes always report 1 (link counts describe the torus fabric)."""
        if self.link_class(axis) == "dcn" or not self.torus_links:
            return 1
        try:
            return max(int(self.torus_links[self.mesh_axes.index(axis)]), 1)
        except (ValueError, IndexError):
            return 1

    def axis_bandwidth(self, axis: str) -> float:
        """Per-device interconnect bandwidth along a mesh axis, link count
        included: ``link_bw(axis) * axis_links(axis)``.  On a 3D-torus mesh
        each ICI axis rides a wrapped physical ring with both directions
        usable (2 links), doubling the flat per-axis rate; every 2D mesh
        keeps the calibrated 1-link rate bit-identical."""
        return self.link_bw(axis) * self.axis_links(axis)

    def p2p_bw(self, axis: str) -> float:
        """Point-to-point path: per-device bandwidth of ONE link along a
        mesh axis — what a pipeline stage boundary's send/recv rides.  A
        neighbor transfer uses a single directed link, so the wrapped-ring
        doubling of :meth:`axis_bandwidth` (a ring-collective property)
        never applies; on a DCN ("pod") axis this is the inter-slice
        network path, which is exactly what makes pipeline-over-DCN the
        interesting plan family (one activation hop per microbatch instead
        of a ring collective's phased volume)."""
        return self.link_bw(axis)

    @property
    def max_ici_links(self) -> int:
        """The most links any ICI mesh axis exposes — the *most generous*
        per-axis rate, which is what the resource optimizer's cluster
        floors must price ICI wire at to stay sound."""
        return max((self.axis_links(a) for a in self.mesh_axes
                    if self.link_class(a) == "ici"), default=1)

    def with_mesh(self, shape: Tuple[int, ...], axes: Tuple[str, ...],
                  torus_links: Optional[Tuple[int, ...]] = None
                  ) -> "ClusterConfig":
        """Re-mesh, resetting ``torus_links`` unless new ones are given —
        link counts describe a specific axis layout and must never leak
        onto a differently-shaped mesh."""
        return dataclasses.replace(
            self, mesh_shape=tuple(shape), mesh_axes=tuple(axes),
            torus_links=tuple(torus_links) if torus_links else ())

    def with_overlap(self, fraction: float) -> "ClusterConfig":
        # The calibration profile rides along (dataclasses.replace keeps
        # every other field), so an overlap-enabled copy of a calibrated
        # config still consults the fitted per-fabric overlap values.
        return dataclasses.replace(self, overlap_fraction=float(fraction))

    def with_calibration(self, profile: Optional[CalibrationProfile]
                         ) -> "ClusterConfig":
        """Attach (or with ``None`` detach) a fitted calibration profile."""
        return dataclasses.replace(self, calibration=profile)

    def fingerprint(self) -> Tuple:
        """Hashable identity over every field the cost model may consult —
        part of the sub-plan memoization key.  Cached on the instance (the
        dataclass is frozen, so the fields can never drift)."""
        fp = getattr(self, "_fp", None)
        if fp is None:
            chip = self.chip
            fp = (chip.name, tuple(sorted(chip.peak_flops.items())),
                  chip.hbm_bytes, chip.hbm_bw, chip.vmem_bytes,
                  chip.ici_bw_per_link, chip.ici_links_per_axis, chip.pcie_bw,
                  chip.host_dram_bw, chip.disk_bw, chip.dcn_bw,
                  chip.ici_domain, chip.ici_torus_dims, chip.ici_cube_dim,
                  chip.cost_per_chip_hour,
                  self.mesh_shape, self.mesh_axes, self.torus_links,
                  self.dispatch_latency,
                  self.collective_phase_latency, self.host_callback_latency,
                  self.matmul_util, self.small_matmul_util, self.vpu_util,
                  self.hbm_eff, self.ici_eff, self.dcn_eff,
                  self.overlap_fraction, self.hbm_budget_fraction,
                  self.default_loop_iterations,
                  tuple(self.default_branch_weights),
                  self.job_startup_seconds, self.checkpoint_restore_seconds,
                  self.preemption_rate_per_chip_hour,
                  self.checkpoint_interval_steps,
                  # calibrated and uncalibrated costs must never share a
                  # PlanCostCache entry
                  None if self.calibration is None
                  else self.calibration.fingerprint())
            object.__setattr__(self, "_fp", fp)
        return fp


# Fallback for job pricing when neither a constant override nor an
# architecture (to derive checkpoint bytes from) is available.
DEFAULT_CHECKPOINT_RESTORE_SECONDS = 60.0


# Canonical configs used throughout the repo ---------------------------------

def single_pod_config(**kw) -> ClusterConfig:
    return ClusterConfig(mesh_shape=(16, 16), mesh_axes=("data", "model"), **kw)


def torus_3d_config(mesh_shape: Tuple[int, int, int] = (4, 4, 4),
                    chip: ChipSpec = TPU_V5P, **kw) -> ClusterConfig:
    """A 3D-torus mesh cell: three ICI axes ("data", "model", "depth"),
    each a wrapped ring with both link directions usable (2 links/axis).
    Defaults to one v5p pod slice as a 4x4x4 cube."""
    if len(mesh_shape) != 3:
        raise ValueError(f"3D torus needs a 3-axis mesh, got {mesh_shape}")
    if chip.ici_torus_dims < 3:
        raise ValueError(f"{chip.name} builds {chip.ici_torus_dims}D tori; "
                         "a 3D mesh needs ici_torus_dims >= 3")
    return ClusterConfig(chip=chip, mesh_shape=tuple(mesh_shape),
                         mesh_axes=("data", "model", "depth"),
                         torus_links=(2, 2, 2), **kw)


def multi_pod_config(**kw) -> ClusterConfig:
    return ClusterConfig(
        mesh_shape=(2, 16, 16), mesh_axes=("pod", "data", "model"), **kw
    )


def single_chip_config(**kw) -> ClusterConfig:
    """The 'CP' execution-type analogue: one chip, no collectives."""
    return ClusterConfig(mesh_shape=(1,), mesh_axes=("data",), **kw)


def cpu_host_config(**kw) -> ClusterConfig:
    """For the paper-§3.4 accuracy benchmark on this container."""
    return ClusterConfig(
        chip=CPU_HOST,
        mesh_shape=(1,),
        mesh_axes=("data",),
        dispatch_latency=50e-6,
        matmul_util=0.60,
        **kw,
    )


DTYPE_BYTES = {
    "bfloat16": 2, "float16": 2, "float32": 4, "float64": 8,
    "int8": 1, "uint8": 1, "int16": 2, "int32": 4, "int64": 8,
    "uint32": 4, "bool": 1, "float8_e4m3fn": 1, "float8_e5m2": 1,
}


_DTYPE_BYTES_CACHE: dict = {}


def dtype_bytes(dtype) -> int:
    s = str(dtype)
    hit = _DTYPE_BYTES_CACHE.get(s)
    if hit is not None:
        return hit
    out = 4
    for k, v in DTYPE_BYTES.items():
        if k in s:
            out = v
            break
    _DTYPE_BYTES_CACHE[s] = out
    return out
