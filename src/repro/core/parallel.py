"""Process-parallel plan search over mergeable plan-cost caches.

The grid loops (``SweepEngine.sweep``, ``optimize_resources``,
``optimize_serving``) are embarrassingly parallel *between* cells and
candidates, and the :class:`~repro.core.costmodel.PlanCostCache` is
mergeable (keys embed every input to a walk, see COST_MODEL.md).  This
module combines the two:

  * work is sharded deterministically in **cache-affinity order** —
    specs are grouped by an affinity key (arch x shape for sweeps) and
    whole groups are greedy-packed onto shards heaviest-first, so
    structure-sharing cells land on one worker and shard loads balance;
  * each **spawn**-based worker costs its shard against a local cache
    seeded from a snapshot of the driver's cache, then returns its
    results plus :meth:`~repro.core.costmodel.PlanCostCache.export_delta`
    (only the entries it recorded, not the seed);
  * the driver merges deltas back into the long-lived engine cache in
    shard order — merge is order-independent, the fixed order just keeps
    entry iteration deterministic.

Workers are plain importable functions (the ``spawn`` start method
re-imports this module in the child — never define pool workers in
``__main__``).  ``fork`` is deliberately not used: jax-adjacent parents
may hold unforkable state, and spawn children import ``repro.core``
without jax in ~0.2s.

Two parallel shapes are offered:

  * :func:`sweep_shards` — sweep cells are independent, so workers return
    their costed cells directly and the driver just reassembles the grid.
  * :func:`warm_shards` — ``optimize_resources``/``optimize_serving``
    prune against a shared incumbent, which is visit-order dependent; a
    parallel run therefore only *warms the cache* on candidate shards and
    the caller re-runs the unchanged serial search against the warm cache.
    Replays are exact, so the serial pass reproduces the serial ranked
    table bit-for-bit while every expensive plan walk is a cache hit.
"""
from __future__ import annotations

import multiprocessing
import os
import tempfile
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.costmodel import CacheDelta, CacheStats, PlanCostCache

__all__ = ["default_jobs", "shard_specs", "sweep_shards", "warm_shards"]


def default_jobs() -> int:
    """Usable CPU count (cgroup/affinity aware where the OS exposes it)."""
    try:
        return max(len(os.sched_getaffinity(0)), 1)
    except (AttributeError, OSError):
        return os.cpu_count() or 1


def shard_specs(specs: Sequence, jobs: int,
                key: Optional[Callable] = None,
                weight: Optional[Callable] = None) -> List[List]:
    """Deterministically shard ``specs`` onto at most ``jobs`` shards.

    Specs with the same affinity ``key`` always share a shard (cache
    affinity: they are the ones that can share plan-cost entries), and
    groups are packed heaviest-first onto the least-loaded shard
    (``weight`` per spec, default 1) so one expensive group does not
    serialize the pool.  Ties break on first-appearance order, making the
    sharding a pure function of the spec list.
    """
    jobs = max(int(jobs), 1)
    if weight is None:
        weight = lambda s: 1.0     # noqa: E731
    order: List = []
    groups: Dict = {}
    for i, s in enumerate(specs):
        k = key(s) if key is not None else i   # no key: one group per spec
        if k not in groups:
            groups[k] = []
            order.append(k)
        groups[k].append(s)
    ranked = sorted(range(len(order)),
                    key=lambda i: (-sum(weight(s) for s in groups[order[i]]),
                                   i))
    shards: List[List] = [[] for _ in range(min(jobs, len(order)))]
    loads = [0.0] * len(shards)
    for i in ranked:
        k = order[i]
        j = min(range(len(shards)), key=lambda j: (loads[j], j))
        shards[j].extend(groups[k])
        loads[j] += sum(weight(s) for s in groups[k])
    return [s for s in shards if s]


# --------------------------------------------------------------- plumbing
def _snapshot(cache: Optional[PlanCostCache]) -> Optional[str]:
    if cache is None or not cache.entries:
        return None
    fd, path = tempfile.mkstemp(prefix="plancache-", suffix=".pkl")
    os.close(fd)
    cache.save(path)
    return path


def _pool_map(worker: Callable, jobs_args: List[Tuple]) -> List:
    ctx = multiprocessing.get_context("spawn")
    with ctx.Pool(processes=len(jobs_args)) as pool:
        return pool.map(worker, jobs_args)


# ------------------------------------------------------------ sweep cells
def _sweep_worker(args: Tuple):
    (widx, indexed_specs, search, beam_width, max_entries, snapshot) = args
    from repro.core.sweep import SweepEngine
    cache = PlanCostCache(max_entries=max_entries)
    if snapshot:
        cache.load_from(snapshot)
    cache.mark()    # the delta must exclude the seed entries
    engine = SweepEngine(search=search, beam_width=beam_width, cache=cache)
    cells = []
    for pos, (arch, shape, cluster) in indexed_specs:
        cell = engine.cost_cell(arch, shape, cluster)
        cell.worker = widx
        cells.append((pos, cell))
    # lean: the driver deserializes every worker's delta serially, so the
    # wire delta carries only block entries (see export_delta docstring)
    return widx, cells, cache.export_delta(lean=True)


def sweep_shards(specs: Sequence[Tuple], jobs: int, *,
                 search: str, beam_width: int,
                 max_entries: Optional[int] = None,
                 seed_cache: Optional[PlanCostCache] = None,
                 seed_path: Optional[str] = None,
                 key: Optional[Callable] = None,
                 weight: Optional[Callable] = None,
                 ) -> Tuple[List, List[CacheDelta], List[CacheStats]]:
    """Cost ``(arch, shape, cluster)`` sweep specs across a worker pool.

    Returns ``(cells, deltas, worker_stats)`` with cells in the input spec
    order (cell costing is cache-state independent, so the assembled grid
    is identical to a serial pass).  The caller merges the deltas.

    ``seed_path`` seeds workers from an existing snapshot file instead of
    re-serializing ``seed_cache`` — pass it when the cache is unchanged
    since it was loaded from that very file.
    """
    indexed = list(enumerate(specs))
    shards = shard_specs(
        indexed, jobs,
        key=None if key is None else (lambda p: key(p[1])),
        weight=None if weight is None else (lambda p: weight(p[1])))
    snapshot = seed_path if seed_path else _snapshot(seed_cache)
    try:
        results = _pool_map(_sweep_worker, [
            (i, shard, search, beam_width, max_entries, snapshot)
            for i, shard in enumerate(shards)])
    finally:
        if snapshot and not seed_path:
            os.unlink(snapshot)
    results.sort(key=lambda r: r[0])
    cells: List = [None] * len(indexed)
    for _widx, shard_cells, _delta in results:
        for pos, cell in shard_cells:
            cells[pos] = cell
    deltas = [delta for _, _, delta in results]
    return cells, deltas, [d.stats for d in deltas]


# ------------------------------------------------- resource/serving warm
def _warm_worker(args: Tuple):
    (widx, kind, arch, shape, cands, kwargs, snapshot) = args
    cache = PlanCostCache()
    if snapshot:
        cache.load_from(snapshot)
    cache.mark()
    if kind == "serving":
        from repro.core.serving import optimize_serving
        optimize_serving(arch, shape, cands, cache=cache, **kwargs)
    else:
        from repro.core.resource import optimize_resources
        optimize_resources(arch, shape, cands, cache=cache, **kwargs)
    return widx, cache.export_delta(lean=True)


def warm_shards(kind: str, arch, shape, cands: Sequence, kwargs: dict,
                jobs: int, cache: PlanCostCache,
                key: Optional[Callable] = None,
                weight: Optional[Callable] = None) -> List[CacheStats]:
    """Warm ``cache`` for a resource/serving co-search by running the
    search itself on candidate shards in parallel and merging back only
    the cache deltas.  Each worker prunes against its own shard-local
    incumbent — decisions are discarded, so per-shard pruning differences
    cannot leak into the caller's serial pass.  Returns per-worker
    lookup-traffic stats."""
    shards = shard_specs(cands, jobs, key=key, weight=weight)
    snapshot = _snapshot(cache)
    try:
        results = _pool_map(_warm_worker, [
            (i, kind, arch, shape, shard, kwargs, snapshot)
            for i, shard in enumerate(shards)])
    finally:
        if snapshot:
            os.unlink(snapshot)
    results.sort(key=lambda r: r[0])
    for _, delta in results:
        cache.merge(delta)
    return [delta.stats for _, delta in results]
