"""Scenario sweep engine: cost a grid of (config x shape x cluster).

The ROADMAP's north star — "as fast as the hardware allows, as many
scenarios as you can imagine" — needs plan costing cheap enough to run for
*every* scenario an operator can dream up, not just the one in front of
them.  This module turns the plan-search stack into exactly that: a grid
of (architecture x input shape x cluster config) cells, each resolved to
its best sharding plan by :func:`repro.core.planner.choose_plan`, all
sharing one :class:`repro.core.costmodel.PlanCostCache` so sub-plans that
repeat across scenarios (per-layer loop bodies, shared program prefixes,
same-arch candidates under different knobs) are costed exactly once.

The output is a ranked table — fastest feasible step time first, OOM
cells sunk to the bottom, skipped cells (assignment rules) last — plus
per-cell search statistics so regressions in pruning or cache behavior
are visible in benchmarks and CI.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.configs import SHAPES, get_config, shape_applicable
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.cluster import (TPU_V5P, TPU_V6E, ClusterConfig,
                                multi_pod_config, single_pod_config,
                                torus_3d_config)
from repro.core.costmodel import CacheStats, PlanCostCache
from repro.core.planner import PlanDecision, SearchStats, choose_plan
from repro.core.resource import (DEFAULT_STEPS_PER_JOB, ClusterCandidate,
                                 ResourceDecision, ResourceSearchStats,
                                 optimize_resources, torus_links_for)
from repro.core.workload import (SERVE_WORKLOADS, Objective, ServeWorkload,
                                 TrainWorkload)

# Named cluster shorthands accepted anywhere a cluster is given (pure
# dataclass constants — building them never touches jax device state).
CLUSTERS: Dict[str, ClusterConfig] = {
    "pod": single_pod_config(),
    "2pod": multi_pod_config(),
    "v5p-pod": ClusterConfig(chip=TPU_V5P, mesh_shape=(8, 8),
                             mesh_axes=("data", "model")),
    "v6e-pod": ClusterConfig(chip=TPU_V6E, mesh_shape=(16, 16),
                             mesh_axes=("data", "model")),
    # One v5p pod slice laid out as its native 3D torus: three ICI axes
    # ("data", "model", "depth"), wrapped rings with 2 links per axis.
    "v5p-3d": torus_3d_config((4, 4, 4)),
    # Four v5p slices joined over DCN — the pipeline-over-DCN scenario:
    # the "pod" axis can carry pipeline stages whose boundaries pay one
    # p2p activation hop per microbatch instead of pod-phased collectives,
    # and per-stage resident state drops S-fold (which is what lets
    # frontier-dense training fit here at all).
    "v5p-dcn": ClusterConfig(chip=TPU_V5P, mesh_shape=(4, 8, 8),
                             mesh_axes=("pod", "data", "model")),
    # The 4-axis family: pod over a full 3D inner torus (wrapped rings on
    # every full-cube inner axis, derived by the same rule the candidate
    # enumeration uses).
    "v5p-dcn-3d": ClusterConfig(
        chip=TPU_V5P, mesh_shape=(4, 4, 4, 4),
        mesh_axes=("pod", "data", "model", "depth"),
        torus_links=torus_links_for(("pod", "data", "model", "depth"),
                                    TPU_V5P, (4, 4, 4, 4))),
}


@dataclasses.dataclass
class SweepCell:
    """One costed scenario: the chosen plan plus search observability."""

    arch_id: str
    shape_id: str
    cluster_id: str
    decision: Optional[PlanDecision]     # None when the cell was skipped
    stats: Optional[SearchStats]
    elapsed_s: float = 0.0
    skipped: str = ""                    # non-empty: why the cell was skipped

    @property
    def key(self) -> str:
        return f"{self.arch_id}|{self.shape_id}|{self.cluster_id}"

    @property
    def time(self) -> float:
        return self.decision.time if self.decision else float("inf")

    @property
    def feasible(self) -> bool:
        return bool(self.decision and self.decision.feasible)


class SweepEngine:
    """Costs scenario grids through one shared sub-plan cache.

    The engine is long-lived by design: successive :meth:`sweep` calls
    (new shapes, a what-if cluster, one more architecture) keep hitting
    the same cache, so the marginal cost of a new scenario drops toward
    the cache-replay floor rather than paying full plan-walk price.

    ``search`` selects the per-cell plan search: ``"beam"`` (default),
    ``"exhaustive"``, or ``"batched"`` — the vectorized engine that walks
    each structure signature once with the whole knob grid as lane
    vectors and prunes provably-dominated groups by their role floors
    (see :func:`repro.core.planner.choose_plan`); its winners are
    bit-identical to the exhaustive scan, so swapping it in never moves a
    sweep's golden results.
    """

    def __init__(self, search: str = "beam", beam_width: int = 4,
                 cache: Optional[PlanCostCache] = None):
        self.search = search
        self.beam_width = beam_width
        self.cache = cache if cache is not None else PlanCostCache()

    def cost_cell(self, arch: Union[str, ArchConfig],
                  shape: Union[str, ShapeConfig, ServeWorkload],
                  cluster: Union[str, ClusterConfig],
                  top_k: int = 1) -> SweepCell:
        arch_id, arch = _resolve_arch(arch)
        shape_id, shape = _resolve_shape(shape)
        cluster_id, cc = _resolve_cluster(cluster)
        h0, m0 = self.cache.hits, self.cache.misses
        if isinstance(shape, ServeWorkload):
            # A serving cell: the best costed schedule of this traffic on
            # this cluster, reported as the winning decode-pool decision
            # (feasible additionally requires a *stable* schedule).  No
            # shape_applicable gate — workloads declare their own context.
            from repro.core import serving
            t0 = time.perf_counter()
            decision, stats = serving.serve_cell(
                arch, shape, cc, cluster_id=cluster_id, search=self.search,
                beam_width=self.beam_width, cache=self.cache)
            elapsed = time.perf_counter() - t0
            stats.cache = CacheStats(self.cache.hits - h0,
                                     self.cache.misses - m0,
                                     self.cache.entries)
            return SweepCell(arch_id, shape_id, cluster_id, decision, stats,
                             elapsed)
        ok, why = shape_applicable(arch, shape)
        if not ok:
            return SweepCell(arch_id, shape_id, cluster_id, None, None,
                             skipped=why)
        stats = SearchStats()
        t0 = time.perf_counter()
        decisions = choose_plan(arch, shape, cc, top_k=top_k,
                                search=self.search,
                                beam_width=self.beam_width,
                                cache=self.cache, stats=stats)
        elapsed = time.perf_counter() - t0
        # report this cell's marginal cache traffic, not the shared totals
        stats.cache = CacheStats(self.cache.hits - h0,
                                 self.cache.misses - m0, self.cache.entries)
        return SweepCell(arch_id, shape_id, cluster_id, decisions[0], stats,
                         elapsed)

    def sweep(self, archs: Sequence[Union[str, ArchConfig]],
              shapes: Sequence[Union[str, ShapeConfig]],
              clusters: Sequence[Union[str, ClusterConfig]],
              ) -> List[SweepCell]:
        """Cost the full grid and return cells ranked fastest-first
        (feasible before OOM, skipped cells last)."""
        cells = [self.cost_cell(a, s, c)
                 for c in clusters for a in archs for s in shapes]
        return rank_cells(cells)

    def optimize_cell(self, arch: Union[str, ArchConfig],
                      shape: Union[str, ShapeConfig, TrainWorkload,
                                   ServeWorkload],
                      clusters: Optional[Sequence] = None,
                      objective: Union[str, Objective] = "step_time",
                      slo: Optional[float] = None,
                      steps_per_job: int = DEFAULT_STEPS_PER_JOB,
                      ) -> Tuple[List[ResourceDecision], ResourceSearchStats]:
        """The ``--resources`` dimension: instead of costing one fixed
        cluster, co-search the cluster grid for this (arch x shape) through
        the engine's shared sub-plan cache and return the ranked
        :class:`ResourceDecision` table plus search stats.
        ``steps_per_job`` sizes the job priced by ``objective="job_cost"``.
        Typed workloads and objectives pass straight through — a
        :class:`ServeWorkload` makes this the serving schedule co-search
        (:class:`~repro.core.serving.ServingDecision` rows)."""
        _, arch = _resolve_arch(arch)
        if not isinstance(shape, TrainWorkload):
            _, shape = _resolve_shape(shape)
        stats = ResourceSearchStats()
        decisions = optimize_resources(
            arch, shape, clusters, objective=objective, slo=slo,
            search=self.search, beam_width=self.beam_width,
            steps_per_job=steps_per_job, cache=self.cache, stats=stats)
        return decisions, stats


def rank_cells(cells: Sequence[SweepCell]) -> List[SweepCell]:
    return sorted(cells, key=lambda c: (bool(c.skipped), not c.feasible,
                                        c.time))


def format_table(cells: Sequence[SweepCell]) -> str:
    """Render ranked cells as a fixed-width table (examples / EXPLAIN)."""
    header = (f"{'#':>3} {'scenario':44s} {'step':>10} {'hbm/dev':>8} "
              f"{'feas':>4}  {'chosen plan':40s} {'search':22s}")
    lines = [header, "-" * len(header)]
    for i, c in enumerate(rank_cells(cells), 1):
        if c.skipped:
            lines.append(f"{i:>3} {c.key:44s} {'--':>10} {'--':>8} "
                         f"{'skip':>4}  {c.skipped[:64]}")
            continue
        d = c.decision
        lines.append(
            f"{i:>3} {c.key:44s} {d.time * 1e3:9.1f}ms "
            f"{d.hbm_est / 1e9:7.1f}G {'y' if d.feasible else 'OOM':>4}  "
            f"{d.plan.describe():40s} {c.stats.describe():22s}")
    return "\n".join(lines)


def sweep_rows(cells: Sequence[SweepCell]) -> List[str]:
    """Benchmark-harness rows: ``sweep.<arch>|<shape>|<mesh>,us,derived``."""
    rows = []
    for c in rank_cells(cells):
        if c.skipped:
            rows.append(f"sweep.{c.key},0,SKIP;{c.skipped[:60]}")
            continue
        d = c.decision
        st = c.stats
        rows.append(
            f"sweep.{c.key},{c.elapsed_s * 1e6:.0f},"
            f"best={d.plan.describe()};T={d.time * 1e3:.2f}ms;"
            f"hbm={d.hbm_est / 1e9:.1f}GB;feas={d.feasible};"
            f"costed={st.costed};pruned={st.pruned_infeasible + st.pruned_dominated};"
            f"cache={st.cache.hits}/{st.cache.hits + st.cache.misses}")
    return rows


def _resolve_arch(arch) -> Tuple[str, ArchConfig]:
    if isinstance(arch, str):
        return arch, get_config(arch)
    return arch.name, arch


def _resolve_shape(shape) -> Tuple[str, Union[ShapeConfig, ServeWorkload]]:
    if isinstance(shape, str):
        if shape in SHAPES:
            return shape, SHAPES[shape]
        if shape in SERVE_WORKLOADS:
            return shape, SERVE_WORKLOADS[shape]
        raise KeyError(f"unknown shape {shape!r}; one of "
                       f"{sorted(SHAPES) + sorted(SERVE_WORKLOADS)}")
    return shape.name, shape


def _resolve_cluster(cluster) -> Tuple[str, ClusterConfig]:
    if isinstance(cluster, str):
        return cluster, CLUSTERS[cluster]
    if isinstance(cluster, ClusterCandidate):
        return cluster.cid, cluster.cc
    label = "x".join(str(s) for s in cluster.mesh_shape)
    return f"{cluster.chip.name}[{label}]", cluster
