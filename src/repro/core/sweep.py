"""Scenario sweep engine: cost a grid of (config x shape x cluster).

The ROADMAP's north star — "as fast as the hardware allows, as many
scenarios as you can imagine" — needs plan costing cheap enough to run for
*every* scenario an operator can dream up, not just the one in front of
them.  This module turns the plan-search stack into exactly that: a grid
of (architecture x input shape x cluster config) cells, each resolved to
its best sharding plan by :func:`repro.core.planner.choose_plan`, all
sharing one :class:`repro.core.costmodel.PlanCostCache` so sub-plans that
repeat across scenarios (per-layer loop bodies, shared program prefixes,
same-arch candidates under different knobs) are costed exactly once.

The output is a ranked table — fastest feasible step time first, OOM
cells sunk to the bottom, skipped cells (assignment rules) last — plus
per-cell search statistics so regressions in pruning or cache behavior
are visible in benchmarks and CI.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.configs import SHAPES, get_config, shape_applicable
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.cluster import (TPU_V5P, TPU_V6E, ClusterConfig,
                                multi_pod_config, single_pod_config,
                                torus_3d_config)
from repro.core.costmodel import CacheStats, PlanCostCache
from repro.core.planner import PlanDecision, SearchStats, choose_plan
from repro.core.resource import (DEFAULT_STEPS_PER_JOB, ClusterCandidate,
                                 ResourceDecision, ResourceSearchStats,
                                 optimize_resources, torus_links_for)
from repro.core.workload import (SERVE_WORKLOADS, Objective, ServeWorkload,
                                 TrainWorkload)

# Named cluster shorthands accepted anywhere a cluster is given (pure
# dataclass constants — building them never touches jax device state).
CLUSTERS: Dict[str, ClusterConfig] = {
    "pod": single_pod_config(),
    "2pod": multi_pod_config(),
    "v5p-pod": ClusterConfig(chip=TPU_V5P, mesh_shape=(8, 8),
                             mesh_axes=("data", "model")),
    "v6e-pod": ClusterConfig(chip=TPU_V6E, mesh_shape=(16, 16),
                             mesh_axes=("data", "model")),
    # One v5p pod slice laid out as its native 3D torus: three ICI axes
    # ("data", "model", "depth"), wrapped rings with 2 links per axis.
    "v5p-3d": torus_3d_config((4, 4, 4)),
    # Four v5p slices joined over DCN — the pipeline-over-DCN scenario:
    # the "pod" axis can carry pipeline stages whose boundaries pay one
    # p2p activation hop per microbatch instead of pod-phased collectives,
    # and per-stage resident state drops S-fold (which is what lets
    # frontier-dense training fit here at all).
    "v5p-dcn": ClusterConfig(chip=TPU_V5P, mesh_shape=(4, 8, 8),
                             mesh_axes=("pod", "data", "model")),
    # The 4-axis family: pod over a full 3D inner torus (wrapped rings on
    # every full-cube inner axis, derived by the same rule the candidate
    # enumeration uses).
    "v5p-dcn-3d": ClusterConfig(
        chip=TPU_V5P, mesh_shape=(4, 4, 4, 4),
        mesh_axes=("pod", "data", "model", "depth"),
        torus_links=torus_links_for(("pod", "data", "model", "depth"),
                                    TPU_V5P, (4, 4, 4, 4))),
}


@dataclasses.dataclass
class SweepCell:
    """One costed scenario: the chosen plan plus search observability."""

    arch_id: str
    shape_id: str
    cluster_id: str
    decision: Optional[PlanDecision]     # None when the cell was skipped
    stats: Optional[SearchStats]
    elapsed_s: float = 0.0
    skipped: str = ""                    # non-empty: why the cell was skipped
    worker: int = -1                     # pool worker that costed it (-1: driver)

    @property
    def key(self) -> str:
        return f"{self.arch_id}|{self.shape_id}|{self.cluster_id}"

    @property
    def time(self) -> float:
        return self.decision.time if self.decision else float("inf")

    @property
    def feasible(self) -> bool:
        return bool(self.decision and self.decision.feasible)


class SweepEngine:
    """Costs scenario grids through one shared sub-plan cache.

    The engine is long-lived by design: successive :meth:`sweep` calls
    (new shapes, a what-if cluster, one more architecture) keep hitting
    the same cache, so the marginal cost of a new scenario drops toward
    the cache-replay floor rather than paying full plan-walk price.

    ``search`` selects the per-cell plan search: ``"beam"`` (default),
    ``"exhaustive"``, or ``"batched"`` — the vectorized engine that walks
    each structure signature once with the whole knob grid as lane
    vectors and prunes provably-dominated groups by their role floors
    (see :func:`repro.core.planner.choose_plan`); its winners are
    bit-identical to the exhaustive scan, so swapping it in never moves a
    sweep's golden results.

    ``jobs`` > 1 costs sweep cells over a spawn-based worker pool
    (:mod:`repro.core.parallel`): workers get a snapshot of the engine
    cache, cost their cache-affinity shard locally, and the driver merges
    their deltas back — the ranked table is identical to a serial sweep
    because cell costing is cache-state independent.  ``cache_path``
    makes the cache persistent: loaded (if fresh — see
    :func:`repro.core.costmodel.cost_model_fingerprint`) at construction
    and re-saved after every sweep, so the next process starts warm.
    ``max_entries`` bounds the cache (clock-hand eviction, bit-exact).
    """

    def __init__(self, search: str = "beam", beam_width: int = 4,
                 cache: Optional[PlanCostCache] = None, jobs: int = 1,
                 cache_path: Optional[str] = None,
                 max_entries: Optional[int] = None):
        self.search = search
        self.beam_width = beam_width
        self.jobs = max(int(jobs), 1)
        self.cache_path = cache_path
        self.max_entries = max_entries
        self.cache = (cache if cache is not None
                      else PlanCostCache(max_entries=max_entries))
        self._persisted_seq = None   # cache._seq as of cache_path on disk
        if cache_path:
            preloaded = self.cache.entries
            loaded = self.cache.load_from(cache_path)
            if preloaded == 0 and loaded > 0:
                # memory now mirrors disk exactly — until something is
                # recorded, workers can seed from the file directly and
                # save_cache() has nothing new to write
                self._persisted_seq = self.cache._seq
        # Per-worker lookup traffic of the last parallel sweep; [] after a
        # serial sweep (the engine cache's own counters already tell all).
        self.last_worker_stats: List[CacheStats] = []

    def cost_cell(self, arch: Union[str, ArchConfig],
                  shape: Union[str, ShapeConfig, ServeWorkload],
                  cluster: Union[str, ClusterConfig],
                  top_k: int = 1) -> SweepCell:
        arch_id, arch = _resolve_arch(arch)
        shape_id, shape = _resolve_shape(shape)
        cluster_id, cc = _resolve_cluster(cluster)
        # Marginal attribution against this engine's own cache is sound
        # because an engine (driver or pool worker) owns its cache
        # exclusively — parallel sweeps give every worker a *local*
        # engine, so concurrent cells never interleave these counters.
        h0, m0 = self.cache.hits, self.cache.misses
        if isinstance(shape, ServeWorkload):
            # A serving cell: the best costed schedule of this traffic on
            # this cluster, reported as the winning decode-pool decision
            # (feasible additionally requires a *stable* schedule).  No
            # shape_applicable gate — workloads declare their own context.
            from repro.core import serving
            t0 = time.perf_counter()
            decision, stats = serving.serve_cell(
                arch, shape, cc, cluster_id=cluster_id, search=self.search,
                beam_width=self.beam_width, cache=self.cache)
            elapsed = time.perf_counter() - t0
            stats.cache = CacheStats(self.cache.hits - h0,
                                     self.cache.misses - m0,
                                     self.cache.entries)
            return SweepCell(arch_id, shape_id, cluster_id, decision, stats,
                             elapsed)
        ok, why = shape_applicable(arch, shape)
        if not ok:
            return SweepCell(arch_id, shape_id, cluster_id, None, None,
                             skipped=why)
        stats = SearchStats()
        t0 = time.perf_counter()
        decisions = choose_plan(arch, shape, cc, top_k=top_k,
                                search=self.search,
                                beam_width=self.beam_width,
                                cache=self.cache, stats=stats)
        elapsed = time.perf_counter() - t0
        # report this cell's marginal cache traffic, not the shared totals
        stats.cache = CacheStats(self.cache.hits - h0,
                                 self.cache.misses - m0, self.cache.entries)
        return SweepCell(arch_id, shape_id, cluster_id, decisions[0], stats,
                         elapsed)

    def sweep(self, archs: Sequence[Union[str, ArchConfig]],
              shapes: Sequence[Union[str, ShapeConfig]],
              clusters: Sequence[Union[str, ClusterConfig]],
              jobs: Optional[int] = None) -> List[SweepCell]:
        """Cost the full grid and return cells ranked fastest-first
        (feasible before OOM, skipped cells last).

        Cells are visited arch x shape outermost — the cache-affinity
        order: cells of one (arch, shape) stay adjacent and whole groups
        shard onto one worker.  The ranked output is sorted, so visit
        order never moves results.
        """
        jobs = self.jobs if jobs is None else max(int(jobs), 1)
        specs = [(a, s, c) for a in archs for s in shapes for c in clusters]
        if jobs > 1 and len(specs) > 1:
            cells = self._sweep_parallel(specs, jobs)
        else:
            self.last_worker_stats = []
            cells = [self.cost_cell(a, s, c) for a, s, c in specs]
        self.save_cache()
        return rank_cells(cells)

    def _sweep_parallel(self, specs: Sequence[Tuple], jobs: int
                        ) -> List[SweepCell]:
        from repro.core import parallel
        # When the cache is byte-for-byte what cache_path holds (freshly
        # loaded, nothing recorded since), seed workers straight from the
        # file instead of re-serializing ~the whole cache to a temp copy.
        clean = (self.cache_path is not None
                 and self._persisted_seq == self.cache._seq)
        cells, deltas, wstats = parallel.sweep_shards(
            specs, jobs, search=self.search, beam_width=self.beam_width,
            max_entries=self.max_entries, seed_cache=self.cache,
            seed_path=self.cache_path if clean else None,
            key=_spec_affinity, weight=_spec_weight)
        for delta in deltas:
            self.cache.merge(delta)
        self.last_worker_stats = wstats
        return cells

    def save_cache(self) -> None:
        """Persist the engine cache when ``cache_path`` is configured and
        anything was recorded since the last load/save (a fully-warm
        sweep rewrites nothing)."""
        if self.cache_path and self._persisted_seq != self.cache._seq:
            self.cache.save(self.cache_path)
            self._persisted_seq = self.cache._seq

    def traffic_stats(self) -> CacheStats:
        """Honest lookup traffic of the last sweep: the engine cache's own
        counters plus (after a parallel sweep) every worker's local-cache
        traffic, with ``entries`` reporting the merged engine cache."""
        st = self.cache.stats()
        for w in self.last_worker_stats:
            st = st + w
        return CacheStats(st.hits, st.misses, self.cache.entries,
                          st.evictions)

    def optimize_cell(self, arch: Union[str, ArchConfig],
                      shape: Union[str, ShapeConfig, TrainWorkload,
                                   ServeWorkload],
                      clusters: Optional[Sequence] = None,
                      objective: Union[str, Objective] = "step_time",
                      slo: Optional[float] = None,
                      steps_per_job: int = DEFAULT_STEPS_PER_JOB,
                      jobs: Optional[int] = None,
                      ) -> Tuple[List[ResourceDecision], ResourceSearchStats]:
        """The ``--resources`` dimension: instead of costing one fixed
        cluster, co-search the cluster grid for this (arch x shape) through
        the engine's shared sub-plan cache and return the ranked
        :class:`ResourceDecision` table plus search stats.
        ``steps_per_job`` sizes the job priced by ``objective="job_cost"``.
        Typed workloads and objectives pass straight through — a
        :class:`ServeWorkload` makes this the serving schedule co-search
        (:class:`~repro.core.serving.ServingDecision` rows)."""
        _, arch = _resolve_arch(arch)
        if not isinstance(shape, TrainWorkload):
            _, shape = _resolve_shape(shape)
        stats = ResourceSearchStats()
        decisions = optimize_resources(
            arch, shape, clusters, objective=objective, slo=slo,
            search=self.search, beam_width=self.beam_width,
            steps_per_job=steps_per_job, cache=self.cache, stats=stats,
            jobs=self.jobs if jobs is None else jobs)
        self.save_cache()
        return decisions, stats


def rank_cells(cells: Sequence[SweepCell]) -> List[SweepCell]:
    return sorted(cells, key=lambda c: (bool(c.skipped), not c.feasible,
                                        c.time))


def format_table(cells: Sequence[SweepCell]) -> str:
    """Render ranked cells as a fixed-width table (examples / EXPLAIN)."""
    header = (f"{'#':>3} {'scenario':44s} {'step':>10} {'hbm/dev':>8} "
              f"{'feas':>4}  {'chosen plan':40s} {'search':22s}")
    lines = [header, "-" * len(header)]
    for i, c in enumerate(rank_cells(cells), 1):
        if c.skipped:
            lines.append(f"{i:>3} {c.key:44s} {'--':>10} {'--':>8} "
                         f"{'skip':>4}  {c.skipped[:64]}")
            continue
        d = c.decision
        # cells costed on a pool worker report that worker's local cache
        # traffic — label them like sweep_rows does
        where = f" @w{c.worker}" if c.worker >= 0 else ""
        lines.append(
            f"{i:>3} {c.key:44s} {d.time * 1e3:9.1f}ms "
            f"{d.hbm_est / 1e9:7.1f}G {'y' if d.feasible else 'OOM':>4}  "
            f"{d.plan.describe():40s} {c.stats.describe():22s}{where}")
    return "\n".join(lines)


def sweep_rows(cells: Sequence[SweepCell]) -> List[str]:
    """Benchmark-harness rows: ``sweep.<arch>|<shape>|<mesh>,us,derived``.

    The ``cache=h/n`` fragment is the cell's marginal traffic against the
    cache of the engine that costed it; cells costed on a pool worker are
    labelled ``@w<N>`` because those numbers are against worker ``N``'s
    *local* cache, not the merged engine cache."""
    rows = []
    for c in rank_cells(cells):
        if c.skipped:
            rows.append(f"sweep.{c.key},0,SKIP;{c.skipped[:60]}")
            continue
        d = c.decision
        st = c.stats
        where = f"@w{c.worker}" if c.worker >= 0 else ""
        rows.append(
            f"sweep.{c.key},{c.elapsed_s * 1e6:.0f},"
            f"best={d.plan.describe()};T={d.time * 1e3:.2f}ms;"
            f"hbm={d.hbm_est / 1e9:.1f}GB;feas={d.feasible};"
            f"costed={st.costed};pruned={st.pruned_infeasible + st.pruned_dominated};"
            f"cache={st.cache.hits}/{st.cache.hits + st.cache.misses}{where}")
    return rows


def _spec_affinity(spec: Tuple) -> Tuple[str, str]:
    """Shard-affinity key for an ``(arch, shape, cluster)`` sweep spec:
    cells of one (arch, shape) share plan structure signatures, so they
    belong on one worker's cache."""
    arch_id, _ = _resolve_arch(spec[0])
    shape_id, _ = _resolve_shape(spec[1])
    return arch_id, shape_id


def _spec_weight(spec: Tuple) -> float:
    """Relative cost estimate for shard load-balancing: train and serving
    cells walk orders of magnitude more plan than single-token decode
    cells (measured ~10x on the golden grid)."""
    _, shape = _resolve_shape(spec[1])
    if isinstance(shape, ServeWorkload):
        return 8.0
    return 8.0 if getattr(shape, "mode", "train") == "train" else 1.0


def _resolve_arch(arch) -> Tuple[str, ArchConfig]:
    if isinstance(arch, str):
        return arch, get_config(arch)
    return arch.name, arch


def _resolve_shape(shape) -> Tuple[str, Union[ShapeConfig, ServeWorkload]]:
    if isinstance(shape, str):
        if shape in SHAPES:
            return shape, SHAPES[shape]
        if shape in SERVE_WORKLOADS:
            return shape, SERVE_WORKLOADS[shape]
        raise KeyError(f"unknown shape {shape!r}; one of "
                       f"{sorted(SHAPES) + sorted(SERVE_WORKLOADS)}")
    return shape.name, shape


def _resolve_cluster(cluster) -> Tuple[str, ClusterConfig]:
    if isinstance(cluster, str):
        return cluster, CLUSTERS[cluster]
    if isinstance(cluster, ClusterCandidate):
        return cluster.cid, cluster.cc
    label = "x".join(str(s) for s in cluster.mesh_shape)
    return f"{cluster.chip.name}[{label}]", cluster
