"""Costed serving schedules: traffic-aware continuous batching over C(P, cc).

The paper costs *generated runtime plans* so optimizers can size resources
for whole programs, control flow included.  This module applies that to
inference at fleet scale: a :class:`repro.core.workload.ServeWorkload`
(Poisson arrival rate + prompt/output length distributions) is compiled
into **costed serving schedules** built from the same plan IR and
estimator the training stack uses:

  * **Continuous batching** is a steady-state slot-refill loop.  The
    *capacity window* — the schedule interval in which every one of the
    ``B`` decode slots turns over once — is a real :class:`~repro.core.
    plan.Program`: a ``ForBlock`` of ``B`` prefill admissions on the
    prefill pool and a ``ForBlock`` of ``K = E[output len]`` decode steps
    on the decode pool, both priced by :func:`repro.core.costmodel.
    estimate` (first-iteration IO vs warm iterations, collectives,
    residents — the whole Eq-(1) machinery).

  * **Disaggregated prefill/decode pools** split a multi-slice cluster
    into a prefill pool and a decode pool; the per-request KV-cache
    handoff between them is priced as a :class:`~repro.core.plan.P2P`
    instruction on the joining axis — the PR-5 one-link path (never the
    torus-doubled collective rate).  The pool windows compose with the
    ``PipelinedLoopBlock`` schedule algebra: a colocated pool serializes
    (the S=1 fill-sum degeneracy), disjoint pools overlap in steady state
    (the M→∞ ``max`` of per-stage warm times).  At zero arrival rate and
    zero handoff bytes the disaggregated schedule's latency metrics are
    bit-exact equal to the colocated ones — the degeneracy
    tests/test_serving_cost.py pins.

  * **Traffic math** is analytical and monotone in every costed time, so
    the floor pruning of :func:`optimize_serving` stays sound (see
    docs/COST_MODEL.md).  With arrival rate λ and window time T over B
    slots, pool utilization is ρ = λ·T/B; queueing waits use the M/M/1
    mean-wait shape ρ/(1−ρ)·service with the exponential-tail p99
    multiplier ln(100); TTFT stacks queue wait + p99 prefill + handoff +
    one decode step.  ρ ≥ 1 means the schedule is unstable (infeasible).

  * **KV-paging pressure** rides in :func:`repro.core.planner.
    resident_components`: serving decode shapes carry ``kv_page_tokens``
    and a p99 ``max_context``, so slots reserve whole pages up to the
    tail context — an additive HBM-residency term plain decode shapes
    never see.

:func:`optimize_serving` runs the (cluster × plan × schedule) co-search:
candidates are (pool layout × slot count) pairs, pruned by sound
arrival-rate-scaled floors built from :func:`repro.core.resource.
cluster_floor_time`, with per-pool plans chosen by the staged beam.
``optimize_resources`` dispatches here whenever it is handed a
``ServeWorkload``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.cluster import ClusterConfig, single_chip_config
from repro.core.costmodel import PlanCostCache, estimate
from repro.core.dominance import DominancePool
from repro.core.plan import CreateVar, ForBlock, GenericBlock, P2P, Program
from repro.core.planner import (OVERLAP_FRACTION, PlanDecision, SearchStats,
                                ShardingPlan, build_step_program, choose_plan,
                                resident_components)
from repro.core.resource import (ClusterCandidate, ResourceSearchStats,
                                 _as_candidate, _plan_space_size,
                                 cluster_floor_time, enumerate_clusters,
                                 torus_links_for)
from repro.core.symbols import MemState, TensorStat
from repro.core.workload import (Objective, ServeWorkload, as_objective)

# p99 multiplier for an exponential queue-wait tail: P(W > t·E[W]) = e^-t.
LN100 = math.log(100.0)

# Slot-count grid for the schedule axis of the co-search: how many decode
# slots the continuous-batching loop keeps in flight.  Small enough to
# enumerate exhaustively per candidate; the HBM pre-filter and stability
# check sink the options a pool cannot carry.
SLOT_OPTS = (8, 32, 128)

# How "step_time" / "cost" / "slo" map onto serving semantics — the string
# objectives stay usable on a ServeWorkload and mean the obvious thing.
_SERVING_KIND = {
    "step_time": "step_time",            # fastest decode step (TPOT)
    "cost": "tokens_per_dollar",
    "job_cost": "tokens_per_dollar",
    "tokens_per_dollar": "tokens_per_dollar",
    "slo": "ttft_p99",
    "ttft_p99": "ttft_p99",
}


# ---------------------------------------------------------------------------
# Serving shapes (decode shapes that know about paging; prefill shapes)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingShape(ShapeConfig):
    """A decode ShapeConfig with the paged-KV fields the residency model
    consults: slots reserve whole ``kv_page_tokens`` pages up to the p99
    ``max_context`` (``resident_components``'s ``kv_paging`` term).  Plain
    decode shapes carry neither field and price exactly as before."""

    kv_page_tokens: int = 0
    max_context: int = 0


def decode_steps(wl: ServeWorkload) -> int:
    """Decode steps per capacity window: one full slot turnover emits the
    mean output length."""
    return max(int(round(wl.output_len.mean)), 1)


def decode_shape(wl: ServeWorkload, slots: int) -> ServingShape:
    """The steady-state decode step shape: ``slots`` sequences at the mean
    context (prompt + half-emitted output averages to mean context for a
    full turnover; we use the mean totals, matching the window's K steps),
    with tail-residency fields for the paging term."""
    ctx = max(int(round(wl.prompt_len.mean + wl.output_len.mean)), 1)
    tail = max(int(round(wl.prompt_len.p99 + wl.output_len.p99)), ctx)
    return ServingShape(f"{wl.name}:decode", ctx, max(int(slots), 1),
                        "decode", kv_page_tokens=wl.kv_page_tokens,
                        max_context=tail)


def prefill_shape(wl: ServeWorkload, p99: bool = False) -> ShapeConfig:
    """One request's prefill (admissions are per-request: batch 1)."""
    length = wl.prompt_len.p99 if p99 else wl.prompt_len.mean
    tag = ":prefill99" if p99 else ":prefill"
    return ShapeConfig(f"{wl.name}{tag}", max(int(round(length)), 1), 1,
                       "prefill")


# ---------------------------------------------------------------------------
# Serving candidates: colocated pools or disaggregated pool pairs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingCandidate:
    """One serving hardware layout: a prefill pool and a decode pool.

    Colocated candidates (``handoff_cc is None``) use one pool for both
    phases: the capacity window serializes the two phases and there is no
    handoff.  Disaggregated candidates split a multi-slice cluster into
    two concurrently-running pools (which may have identical configs — a
    1+1 pod split is still two pods); ``handoff_cc`` is the *joined* mesh
    whose ``handoff_axis`` the per-request KV handoff crosses as a
    one-link P2P."""

    cid: str
    prefill_cc: ClusterConfig
    decode_cc: ClusterConfig
    handoff_cc: Optional[ClusterConfig] = None
    handoff_axis: str = "pod"

    @property
    def colocated(self) -> bool:
        return self.handoff_cc is None

    @property
    def num_chips(self) -> int:
        if self.colocated:
            return self.decode_cc.num_chips
        return self.prefill_cc.num_chips + self.decode_cc.num_chips

    @property
    def handoff_lanes(self) -> int:
        """Parallel one-link paths the handoff stripes over: each sender
        pairs with a receiver, so the narrower pool sets the lane count."""
        return max(min(self.prefill_cc.num_chips, self.decode_cc.num_chips), 1)

    @property
    def dollars_per_hour(self) -> float:
        d = self.decode_cc.num_chips * self.decode_cc.chip.cost_per_chip_hour
        if not self.colocated:
            d += (self.prefill_cc.num_chips
                  * self.prefill_cc.chip.cost_per_chip_hour)
        return d


def as_serving_candidate(c) -> ServingCandidate:
    """Accept ServingCandidate | ClusterCandidate | ClusterConfig |
    (cid, cc) — anything a cluster grid already contains serves colocated."""
    if isinstance(c, ServingCandidate):
        return c
    cand = _as_candidate(c)
    return ServingCandidate(cand.cid, cand.cc, cand.cc)


def disaggregate(cand: Union[ClusterCandidate, ServingCandidate]
                 ) -> Optional[ServingCandidate]:
    """The prefill/decode split of a DCN multi-slice candidate: one pod
    becomes the prefill pool, the remaining ``p-1`` the decode pool, and
    the KV handoff crosses the joined mesh's ``pod`` axis (size >= 2, so
    the P2P is never the size-1 no-op).  Single-slice candidates have no
    boundary to split on and return ``None``."""
    if isinstance(cand, ServingCandidate):
        if not cand.colocated:
            return None
        cid, cc = cand.cid, cand.decode_cc
    else:
        cand = _as_candidate(cand)
        cid, cc = cand.cid, cand.cc
    if not cc.mesh_axes or cc.mesh_axes[0] != "pod" or cc.mesh_shape[0] < 2:
        return None
    p = cc.mesh_shape[0]
    inner_shape, inner_axes = cc.mesh_shape[1:], cc.mesh_axes[1:]
    prefill_cc = cc.with_mesh(
        inner_shape, inner_axes,
        torus_links_for(inner_axes, cc.chip, inner_shape))
    if p - 1 > 1:
        dmesh = (p - 1,) + inner_shape
        decode_cc = cc.with_mesh(
            dmesh, cc.mesh_axes, torus_links_for(cc.mesh_axes, cc.chip, dmesh))
    else:
        # 1+1 split: the decode pool is a second pod with the prefill
        # pool's config — physically distinct, so still disaggregated.
        decode_cc = prefill_cc
    return ServingCandidate(f"{cid}+pd", prefill_cc, decode_cc,
                            handoff_cc=cc, handoff_axis="pod")


def join_pools(prefill_cc: ClusterConfig,
               decode_cc: ClusterConfig) -> ClusterConfig:
    """The two-slice mesh a cross-pool KV handoff crosses: the decode
    pool's config with a size-2 ``pod`` axis prepended, so the P2P is
    DCN-classed (the receiver's NIC is the bottleneck end of the wire;
    the chips' DCN rates are fabric-set and identical anyway)."""
    mesh = (2,) + decode_cc.mesh_shape
    axes = ("pod",) + decode_cc.mesh_axes
    return decode_cc.with_mesh(
        mesh, axes, torus_links_for(axes, decode_cc.chip, mesh))


def cross_pool_pairs(cands: Sequence) -> List[ServingCandidate]:
    """Heterogeneous disaggregation: pair single-slice pools of *different
    chip families* as (prefill pool, decode pool), with the KV handoff
    crossing a synthesized joined mesh (:func:`join_pools`).

    This is where prefill/decode disaggregation genuinely earns its keep
    under the cost model: within one chip family every phase scales ~
    linearly with chips, so a same-chip split can never beat its colocated
    parent — but prefill is compute-bound (wants FLOPs/$) while decode
    streams weights (wants HBM-BW/$), and pods come in discrete sizes, so
    the cheapest *stable* fleet can be a compute-dense prefill pod feeding
    a cheaper bandwidth-dense decode pod."""
    singles = []
    for c in cands:
        sc = as_serving_candidate(c)
        if sc.colocated and "pod" not in sc.decode_cc.mesh_axes:
            singles.append(sc)
    out: List[ServingCandidate] = []
    for pf in singles:
        for dc in singles:
            if pf.decode_cc.chip.name == dc.decode_cc.chip.name:
                continue
            out.append(ServingCandidate(
                f"{pf.cid}>{dc.cid}", pf.prefill_cc, dc.decode_cc,
                handoff_cc=join_pools(pf.prefill_cc, dc.decode_cc),
                handoff_axis="pod"))
    return out


def enumerate_serving_clusters(chips=None, pod_counts: Sequence[int] = (1, 2, 4),
                               mesh_variants: int = 2,
                               base: Optional[ClusterConfig] = None,
                               cross_chip: bool = False
                               ) -> List[ServingCandidate]:
    """The serving cluster grid: every :func:`repro.core.resource.
    enumerate_clusters` candidate served colocated, plus the disaggregated
    prefill/decode split of every DCN multi-slice candidate, plus — with
    ``cross_chip=True`` — the heterogeneous single-slice pool pairs of
    :func:`cross_pool_pairs`."""
    out: List[ServingCandidate] = []
    for cand in enumerate_clusters(chips, pod_counts, mesh_variants, base):
        out.append(ServingCandidate(cand.cid, cand.cc, cand.cc))
        split = disaggregate(cand)
        if split is not None:
            out.append(split)
    if cross_chip:
        out.extend(cross_pool_pairs(out))
    return out


# ---------------------------------------------------------------------------
# Costed schedules
# ---------------------------------------------------------------------------


def kv_handoff_bytes(arch: ArchConfig, prompt_tokens: int) -> float:
    """Total KV-cache bytes one prefilled request hands to the decode pool
    — read off :func:`repro.core.planner.resident_components` (the single
    source of truth for cache residency) at batch 1 on a single chip, so
    the payload and the residency model can never disagree."""
    shape = ShapeConfig("kv_handoff", max(int(prompt_tokens), 1), 1, "decode")
    comps = resident_components(arch, shape, ShardingPlan(),
                                single_chip_config())
    return comps.get("kv_cache", 0.0)


def build_handoff_program(payload_bytes: float, axis: str) -> Program:
    """One request's KV handoff as a plan: a P2P send of ``payload_bytes``
    per device across ``axis`` — exactly one link of that axis's fabric
    (:meth:`ClusterConfig.p2p_bw`), DCN-classed when the axis is ``pod``."""
    stat = TensorStat(shape=(max(int(payload_bytes), 1),), dtype="int8",
                      state=MemState.HBM)
    blk = GenericBlock("kv handoff", [
        CreateVar("kv_block", stat),
        P2P("kv_block", axis=axis, bytes_override=float(payload_bytes)),
    ])
    return Program(name=f"kv_handoff[{axis}]", blocks=[blk])


def _window_program(step: Program, label: str, iterations: int) -> Program:
    """Wrap one step program in the schedule's steady-state loop — the
    slot-refill / decode-round window costed through the ForBlock walk
    (first iteration pays staging IO, warm iterations do not)."""
    return Program(name=f"{step.name}|{label}",
                   blocks=[ForBlock(label, max(int(iterations), 1),
                                    list(step.blocks))],
                   functions=dict(step.functions),
                   inputs=dict(step.inputs))


@dataclasses.dataclass(frozen=True)
class ServingScheduleCost:
    """The costed steady state of one (candidate × slot count) schedule.

    All times come from the estimator; the traffic-dependent metrics are
    analytical functions of them, each monotone non-decreasing in every
    time field and in the arrival rate (the floor-soundness requirement).
    """

    slots: int
    decode_steps: int            # K: decode steps per capacity window
    arrival_rate: float          # λ, requests/s
    output_tokens: float         # E[output len], tokens/request
    colocated: bool
    decode_step_time: float      # TPOT: one decode step over `slots`
    prefill_time: float          # one mean-prompt prefill
    prefill_time_p99: float      # one p99-prompt prefill
    handoff_time: float          # per-request KV handoff (0 colocated)
    decode_window_time: float    # K decode steps, costed via the loop IR
    prefill_window_time: float   # B admissions (+ B handoffs), ditto
    dollars_per_hour: float

    # -- schedule algebra -------------------------------------------------
    @property
    def window_time(self) -> float:
        """The capacity window under the PipelinedLoopBlock schedule
        algebra: a colocated pool runs its two phases back to back (the
        S=1 fill-sum degeneracy); disjoint pools overlap, so the steady
        state is the slowest pool (the M→∞ ``(M-1)·max`` term)."""
        if self.colocated:
            return self.prefill_window_time + self.decode_window_time
        return max(self.prefill_window_time, self.decode_window_time)

    # -- utilization (ρ = λ·T/B per pool) ---------------------------------
    @property
    def decode_rho(self) -> float:
        return self.arrival_rate * self.decode_window_time / self.slots

    @property
    def prefill_rho(self) -> float:
        return self.arrival_rate * self.prefill_window_time / self.slots

    @property
    def utilization(self) -> float:
        if self.colocated:
            return self.arrival_rate * self.window_time / self.slots
        return max(self.decode_rho, self.prefill_rho)

    @property
    def stable(self) -> bool:
        return self.utilization < 1.0

    # -- latency ----------------------------------------------------------
    @staticmethod
    def _queue_wait(rho: float, service: float) -> float:
        """M/M/1-shaped mean queue wait; diverges (→ inf) at saturation,
        keeping the metric monotone through the stability boundary."""
        if rho >= 1.0:
            return float("inf")
        return rho / (1.0 - rho) * service

    @property
    def ttft_mean(self) -> float:
        rho_p = self.utilization if self.colocated else self.prefill_rho
        rho_d = self.utilization if self.colocated else self.decode_rho
        wait = (self._queue_wait(rho_p, self.prefill_time + self.handoff_time)
                + self._queue_wait(rho_d, self.decode_step_time))
        return (wait + self.prefill_time + self.handoff_time
                + self.decode_step_time)

    @property
    def ttft_p99(self) -> float:
        """p99 TTFT: exponential-tail queue wait (ln 100 × mean) + p99
        prefill + handoff + the first decode step."""
        rho_p = self.utilization if self.colocated else self.prefill_rho
        rho_d = self.utilization if self.colocated else self.decode_rho
        wait = (self._queue_wait(rho_p, self.prefill_time + self.handoff_time)
                + self._queue_wait(rho_d, self.decode_step_time))
        return (LN100 * wait + self.prefill_time_p99 + self.handoff_time
                + self.decode_step_time)

    # -- throughput / $ ---------------------------------------------------
    @property
    def peak_tokens_per_second(self) -> float:
        """Capacity: the window emits slots × K tokens."""
        w = self.window_time
        return self.slots * self.decode_steps / w if w > 0 else 0.0

    @property
    def tokens_per_second(self) -> float:
        """Delivered throughput: demand-limited when stable, zero when the
        queue diverges."""
        return (self.arrival_rate * self.output_tokens if self.stable
                else 0.0)

    @property
    def cost_per_1k_tokens(self) -> float:
        tps = self.tokens_per_second
        if tps <= 0:
            return float("inf")
        return self.dollars_per_hour / 3600.0 / tps * 1000.0


def cost_serving_schedule(arch: ArchConfig, wl: ServeWorkload,
                          cand: ServingCandidate, slots: int,
                          decode_plan: ShardingPlan,
                          prefill_plan: ShardingPlan,
                          cache: Optional[PlanCostCache] = None,
                          handoff_bytes: Optional[float] = None
                          ) -> ServingScheduleCost:
    """Cost one schedule through the estimator: per-pool step programs,
    the windowed slot-refill loops, and the KV handoff P2P, all sharing
    ``cache`` so repeated sub-plans replay bit-exact.  ``handoff_bytes``
    overrides the per-request KV payload (``None`` reads it off the
    residency model; ``0.0`` makes the handoff free — the degeneracy
    tests pin against)."""
    cand = as_serving_candidate(cand)
    slots = max(int(slots), 1)
    dshape = decode_shape(wl, slots)
    pshape = prefill_shape(wl)
    p99shape = prefill_shape(wl, p99=True)
    k = decode_steps(wl)
    # Mirror planner._cost_candidate: programs are built and estimated
    # under the plan's overlap discount, so the schedule's step times are
    # bit-identical to the PlanDecision times choose_plan reported.
    dcc = cand.decode_cc.with_overlap(
        OVERLAP_FRACTION if decode_plan.overlap else 0.0)
    pcc = cand.prefill_cc.with_overlap(
        OVERLAP_FRACTION if prefill_plan.overlap else 0.0)
    dprog = build_step_program(arch, dshape, decode_plan, dcc)
    t_dec = estimate(dprog, dcc, cache=cache).total
    pprog = build_step_program(arch, pshape, prefill_plan, pcc)
    t_pre = estimate(pprog, pcc, cache=cache).total
    t_pre99 = estimate(build_step_program(arch, p99shape, prefill_plan, pcc),
                       pcc, cache=cache).total
    dwin = estimate(_window_program(dprog, f"decode steady x{k}", k),
                    dcc, cache=cache).total
    pwin = estimate(_window_program(pprog, f"slot refill x{slots}", slots),
                    pcc, cache=cache).total
    if cand.colocated:
        t_handoff = 0.0
    else:
        if handoff_bytes is None:
            handoff_bytes = kv_handoff_bytes(
                arch, int(round(wl.prompt_len.mean)))
        payload = handoff_bytes / cand.handoff_lanes
        if payload > 0:
            hcc = cand.handoff_cc.with_overlap(OVERLAP_FRACTION)
            t_handoff = estimate(build_handoff_program(payload,
                                                       cand.handoff_axis),
                                 hcc, cache=cache).total
        else:
            t_handoff = 0.0
        pwin += slots * t_handoff
    return ServingScheduleCost(
        slots=slots, decode_steps=k, arrival_rate=wl.arrival_rate,
        output_tokens=wl.output_len.mean, colocated=cand.colocated,
        decode_step_time=t_dec, prefill_time=t_pre, prefill_time_p99=t_pre99,
        handoff_time=t_handoff, decode_window_time=dwin,
        prefill_window_time=pwin, dollars_per_hour=cand.dollars_per_hour)


# ---------------------------------------------------------------------------
# Sound serving floors (arrival-rate-scaled, monotone)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServingFloor:
    """Lower bounds for one (candidate × slots) entry, each obtained by
    substituting :func:`cluster_floor_time` step floors into the monotone
    traffic formulas (queue waits and the handoff dropped — both
    nonnegative).  A window of N iterations costs at least N × the step
    floor (warm iterations keep the full roofline totals; only the
    first-use IO term shrinks, and the floor never charged IO)."""

    decode_step: float
    prefill_step: float
    prefill_step_p99: float
    utilization: float
    ttft_p99: float


def serving_floor(arch: ArchConfig, wl: ServeWorkload,
                  cand: ServingCandidate, slots: int) -> ServingFloor:
    cand = as_serving_candidate(cand)
    slots = max(int(slots), 1)
    df = cluster_floor_time(arch, decode_shape(wl, slots), cand.decode_cc)
    pf = cluster_floor_time(arch, prefill_shape(wl), cand.prefill_cc)
    pf99 = cluster_floor_time(arch, prefill_shape(wl, p99=True),
                              cand.prefill_cc)
    dwin_f = decode_steps(wl) * df
    pwin_f = slots * pf
    lam = wl.arrival_rate
    if cand.colocated:
        util = lam * (dwin_f + pwin_f) / slots
    else:
        util = lam * max(dwin_f, pwin_f) / slots
    return ServingFloor(decode_step=df, prefill_step=pf,
                        prefill_step_p99=pf99, utilization=util,
                        ttft_p99=pf99 + df)


# ---------------------------------------------------------------------------
# Decisions, ranking, pruning
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServingDecision:
    """One (candidate × slot count) outcome: its per-pool plans and costed
    schedule, or why the floor pruned it.  Mirrors
    :class:`repro.core.resource.ResourceDecision`'s surface (``cc`` /
    ``decision`` / ``time`` / ``feasible`` / ``describe``) so sweep cells
    and elastic replanning consume either interchangeably."""

    cluster_id: str
    cand: ServingCandidate
    workload: ServeWorkload
    objective: Objective
    slots: int
    schedule: Optional[ServingScheduleCost]
    decode_decision: Optional[PlanDecision]
    prefill_decision: Optional[PlanDecision]
    floor: Optional[ServingFloor] = None
    pruned: str = ""
    search: Optional[SearchStats] = None

    @property
    def cc(self) -> ClusterConfig:
        return self.cand.decode_cc

    @property
    def decision(self) -> Optional[PlanDecision]:
        return self.decode_decision

    @property
    def time(self) -> float:
        """The serving step-time analogue: one decode step (TPOT)."""
        return (self.schedule.decode_step_time if self.schedule
                else float("inf"))

    @property
    def fits(self) -> bool:
        return bool(self.decode_decision and self.decode_decision.feasible
                    and self.prefill_decision
                    and self.prefill_decision.feasible)

    @property
    def stable(self) -> bool:
        return bool(self.schedule and self.schedule.stable)

    @property
    def feasible(self) -> bool:
        return self.fits and self.stable

    @property
    def ttft_p99(self) -> float:
        return self.schedule.ttft_p99 if self.schedule else float("inf")

    @property
    def tokens_per_second(self) -> float:
        return (self.schedule.tokens_per_second
                if (self.schedule and self.fits) else 0.0)

    @property
    def dollars_per_hour(self) -> float:
        return self.cand.dollars_per_hour

    @property
    def cost_per_1k_tokens(self) -> float:
        if not self.fits or self.schedule is None:
            return float("inf")
        return self.schedule.cost_per_1k_tokens

    def meets(self, slo: Optional[float]) -> bool:
        return self.feasible and slo is not None and self.ttft_p99 <= slo

    def describe(self) -> str:
        if self.pruned:
            return f"{self.cluster_id}@B{self.slots}: pruned ({self.pruned})"
        s = self.schedule
        pools = ("colocated" if self.cand.colocated else
                 f"pd {self.cand.prefill_cc.num_chips}"
                 f"+{self.cand.decode_cc.num_chips}ch")
        return (f"{self.cluster_id}@B{self.slots} [{pools}] "
                f"tpot={s.decode_step_time * 1e3:.2f}ms "
                f"ttft99={self.ttft_p99 * 1e3:.0f}ms "
                f"util={s.utilization * 100:.0f}% "
                f"${self.cost_per_1k_tokens:.4f}/1k")


def canon_serving_objective(objective: Union[str, Objective],
                            slo: Optional[float],
                            wl: ServeWorkload) -> Objective:
    """Canonicalize to a serving objective kind; an unset TTFT SLO falls
    back to the workload's declared target."""
    obj = as_objective(objective, slo)
    kind = _SERVING_KIND.get(obj.kind)
    if kind is None:
        raise ValueError(f"objective {obj.kind!r} has no serving meaning")
    slo_v = obj.slo if obj.slo is not None else wl.ttft_slo
    if kind == "ttft_p99" and slo_v is None:
        raise ValueError("the ttft_p99 objective needs a target: pass "
                         "slo=... or set ServeWorkload.ttft_slo")
    return Objective(kind, slo=slo_v, steps_per_job=obj.steps_per_job)


def _rank_key(obj: Objective):
    def key(sd: ServingDecision) -> Tuple:
        if sd.pruned:
            return (1, sd.floor.utilization if sd.floor else 0.0,
                    sd.cluster_id, sd.slots)
        if obj.kind == "ttft_p99":
            vals: Tuple = (0 if sd.meets(obj.slo) else 1,
                           sd.dollars_per_hour, sd.ttft_p99, sd.time)
        elif obj.kind == "tokens_per_dollar":
            vals = (sd.cost_per_1k_tokens, sd.ttft_p99, sd.time)
        else:                                   # step_time → TPOT
            vals = (sd.time, sd.dollars_per_hour, sd.ttft_p99)
        return (0, 0 if sd.feasible else 1) + vals + (sd.cluster_id,
                                                      sd.slots)
    return key


def _visit_order_key(obj: Objective):
    """Most-promising-first ordering so the incumbent forms early."""
    def key(entry) -> Tuple:
        cand, slots, floor = entry
        dph = cand.dollars_per_hour
        if obj.kind == "ttft_p99":
            ok = floor.utilization < 1.0 and floor.ttft_p99 <= obj.slo
            return (0 if ok else 1, dph, floor.ttft_p99, cand.cid, slots)
        if obj.kind == "tokens_per_dollar":
            return (0 if floor.utilization < 1.0 else 1, dph,
                    floor.ttft_p99, cand.cid, slots)
        return (floor.decode_step, dph, cand.cid, slots)
    return key


def _floor_cannot_win(obj: Objective, wl: ServeWorkload,
                      incumbent: ServingDecision, cand: ServingCandidate,
                      floor: ServingFloor) -> bool:
    """Sound pruning test against a *feasible* incumbent, mirroring
    resource._floor_cannot_win: strict inequalities only, so exact ties
    are still costed and resolved by the deterministic tie-break.  Every
    floor metric lower-bounds its costed value (monotone substitution),
    and the $-rate terms are exact per candidate."""
    if floor.utilization >= 1.0:
        # Unstable at the floor => unstable at any costed plan => can
        # never enter the feasible group the incumbent sits in.
        return True
    dph = cand.dollars_per_hour
    if obj.kind == "ttft_p99":
        if incumbent.meets(obj.slo):
            return (floor.ttft_p99 > obj.slo
                    or dph > incumbent.dollars_per_hour)
        return (floor.ttft_p99 > obj.slo
                and dph > incumbent.dollars_per_hour)
    if obj.kind == "tokens_per_dollar":
        # Throughput is demand-limited (λ·E[out]) for every stable
        # schedule, so the $/token floor is the exact $-rate over demand.
        tps = wl.tokens_per_second
        if tps <= 0:
            return False
        floor_c1k = dph / 3600.0 / tps * 1000.0
        return floor_c1k > incumbent.cost_per_1k_tokens
    return floor.decode_step > incumbent.time


# ---------------------------------------------------------------------------
# The (cluster × plan × schedule) co-search
# ---------------------------------------------------------------------------


def optimize_serving(arch: ArchConfig, wl: ServeWorkload,
                     clusters: Optional[Sequence] = None,
                     objective: Union[str, Objective] = "tokens_per_dollar",
                     slo: Optional[float] = None, *,
                     search: str = "beam", beam_width: int = 4,
                     prune: Optional[bool] = None,
                     slot_opts: Sequence[int] = SLOT_OPTS,
                     cache: Optional[PlanCostCache] = None,
                     stats: Optional[ResourceSearchStats] = None,
                     jobs: int = 1) -> List[ServingDecision]:
    """Rank (pool layout × slot count) candidates with their best per-pool
    plans under a serving objective.  ``search="beam"`` prunes entries by
    the sound serving floors and plans by the staged beam;
    ``search="exhaustive"`` costs every (candidate × slots × plan) cell —
    the verification oracle.  Both return the identical winner (gated by
    benchmarks/bench_serving.py).  ``jobs`` > 1 warms the cache by running
    the search on candidate shards in parallel (decisions discarded, cache
    deltas merged), then the serial pass below re-runs warm — bit-identical
    to ``jobs=1`` (incumbent pruning is visit-order dependent)."""
    obj = canon_serving_objective(objective, slo, wl)
    if prune is None:
        prune = search == "beam"
    cands = [as_serving_candidate(c) for c in
             (clusters if clusters is not None
              else enumerate_serving_clusters())]
    if cache is None:
        cache = PlanCostCache()
    if stats is None:
        stats = ResourceSearchStats()
    if jobs > 1 and len(cands) > 1:
        from repro.core import parallel
        stats.worker_cache = parallel.warm_shards(
            "serving", arch, wl, cands,
            dict(objective=objective, slo=slo, search=search,
                 beam_width=beam_width, prune=prune,
                 slot_opts=tuple(slot_opts)),
            jobs, cache)
    pshape = prefill_shape(wl)
    entries = []
    for cand in cands:
        stats.exhaustive_plan_space += _plan_space_size(
            arch, pshape, cand.prefill_cc.mesh_shape,
            cand.prefill_cc.mesh_axes)
        for slots in slot_opts:
            dshape = decode_shape(wl, slots)
            stats.exhaustive_plan_space += _plan_space_size(
                arch, dshape, cand.decode_cc.mesh_shape,
                cand.decode_cc.mesh_axes)
            entries.append((cand, int(slots),
                            serving_floor(arch, wl, cand, slots)))
    stats.clusters_total += len(entries)
    if prune:
        entries.sort(key=_visit_order_key(obj))
    key = _rank_key(obj)
    pool = DominancePool(
        rank_key=key,
        cannot_win=(lambda bound, best: _floor_cannot_win(
            obj, wl, best, bound[0], bound[1])) if prune else None)
    pre_memo: Dict[str, Tuple[PlanDecision, int]] = {}
    out: List[ServingDecision] = []
    for cand, slots, floor in entries:
        if not pool.admit((cand, floor)):
            stats.clusters_pruned += 1
            out.append(ServingDecision(
                cand.cid, cand, wl, obj, slots, None, None, None,
                floor=floor,
                pruned=f"floor loses to {pool.best.cluster_id}"
                       f"@B{pool.best.slots}"))
            continue
        pstats = SearchStats()
        dec_best = choose_plan(arch, decode_shape(wl, slots), cand.decode_cc,
                               top_k=1, search=search, beam_width=beam_width,
                               cache=cache, stats=pstats)[0]
        memo = pre_memo.get(cand.cid)
        if memo is None:
            pre_best = choose_plan(arch, pshape, cand.prefill_cc, top_k=1,
                                   search=search, beam_width=beam_width,
                                   cache=cache, stats=pstats)[0]
            pre_memo[cand.cid] = (pre_best, pstats.costed)
        else:
            pre_best = memo[0]
        stats.plan_evals += pstats.costed
        stats.clusters_costed += 1
        sched = cost_serving_schedule(arch, wl, cand, slots, dec_best.plan,
                                      pre_best.plan, cache=cache)
        sd = ServingDecision(cand.cid, cand, wl, obj, slots, sched,
                             dec_best, pre_best, floor=floor, search=pstats)
        out.append(sd)
        if sd.feasible:
            pool.offer(sd)
    stats.cache = cache.stats()
    out.sort(key=key)
    return out


def serve_cell(arch: ArchConfig, wl: ServeWorkload, cc: ClusterConfig,
               cluster_id: Optional[str] = None, *, search: str = "beam",
               beam_width: int = 4, cache: Optional[PlanCostCache] = None
               ) -> Tuple[PlanDecision, SearchStats]:
    """One sweep-grid serving cell: the best schedule of this workload on
    this one cluster (served colocated), reported as the winning decode
    pool's :class:`PlanDecision` — feasibility tightened to require a
    *stable* schedule, not just an HBM fit — so sweep tables and golden
    cells consume serving cells exactly like step cells."""
    cand = as_serving_candidate((cluster_id, cc) if cluster_id else cc)
    rstats = ResourceSearchStats()
    decisions = optimize_serving(arch, wl, [cand],
                                 objective="tokens_per_dollar",
                                 search=search, beam_width=beam_width,
                                 cache=cache, stats=rstats)
    best = decisions[0]
    pd = dataclasses.replace(best.decode_decision, feasible=best.feasible)
    return pd, SearchStats(costed=rstats.plan_evals)


def format_serving_decisions(decisions: Sequence[ServingDecision]) -> str:
    """Fixed-width ranked table for examples / EXPLAIN output."""
    header = (f"{'#':>3} {'candidate':30} {'B':>4} {'chips':>6} "
              f"{'tpot':>9} {'ttft99':>9} {'util':>5} {'$/1k tok':>9} "
              f"{'feas':>4}  {'decode plan':36}")
    lines = [header, "-" * len(header)]
    for i, sd in enumerate(decisions, 1):
        if sd.pruned:
            lines.append(f"{i:>3} {sd.cluster_id:30} {sd.slots:>4} "
                         f"{sd.cand.num_chips:>6} {'--':>9} {'--':>9} "
                         f"{'--':>5} {'--':>9} {'cut':>4}  "
                         f"pruned: {sd.pruned[:40]}")
            continue
        s = sd.schedule
        feas = "y" if sd.feasible else ("sat" if sd.fits else "OOM")
        c1k = sd.cost_per_1k_tokens
        lines.append(
            f"{i:>3} {sd.cluster_id:30} {sd.slots:>4} "
            f"{sd.cand.num_chips:>6} {s.decode_step_time * 1e3:8.2f}m "
            f"{min(sd.ttft_p99, 9999) * 1e3:8.0f}m "
            f"{min(s.utilization, 9.99) * 100:4.0f}% "
            f"{min(c1k, 999.9):9.4f} {feas:>4}  "
            f"{sd.decode_decision.plan.describe():36}")
    return "\n".join(lines)
