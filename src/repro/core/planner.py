"""Cost-based plan selection (the optimizers the paper's model serves).

SystemML's compiler makes *execution-type* decisions (CP vs MR), *physical
operator* choices (tsmm / mapmm / cpmm), and *resource* decisions, all
evaluated through C(P, cc).  The TPU analogue optimizes a **sharding plan**
for each (architecture x input shape x mesh):

  * role of the mesh axes: tensor-parallel, expert-parallel, FSDP,
    pipeline-parallel (the layer stack split into stages along an axis —
    over ICI on a "depth" axis, or across DCN slices on the "pod" axis),
    or pure extra data-parallelism,
  * remat (activation checkpointing) policy: none / selective / full,
  * microbatch count (gradient accumulation — reinterpreted as the
    pipeline's M for pipelined roles),
  * gradient-reduction dtype (compression),
  * collective/compute overlap.

For every candidate plan we *generate* an analytical runtime plan — a
:class:`Program` of per-layer instructions and collectives, with the layer
stack expressed as a ForBlock exactly like the paper costs loops — and rank
by ``C(P, cc)`` subject to the HBM budget.  The winner is then validated by
compiling the real jitted step and costing the generated HLO
(:mod:`repro.core.hlo_cost`) — cost the *generated* plan, per the paper.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.cluster import ClusterConfig, dtype_bytes
from repro.core.costmodel import (CacheStats, CostedProgram, PlanCostCache,
                                  estimate, split_costed_lanes)
from repro.core.dominance import DominancePool
from repro.core.npvec import (HeterogeneousLanes, dim_ceil, dim_int, is_vec,
                              pmax, pmin, uniform_bool)
from repro.core.plan import (Collective, Compute, CreateVar, DataGen, ForBlock,
                             GenericBlock, IO, P2P, PipelinedLoopBlock,
                             Program)
from repro.core.symbols import MemState, TensorStat

# Fraction of collective time hidden under compute when a plan enables
# overlap (all enumerated plans do).  Candidate costing applies it via
# ``cc.with_overlap``; the resource optimizer's collective floors discount
# by the same constant, so a drift here cannot silently unsound the floors.
OVERLAP_FRACTION = 0.7

# The enumerated microbatch knob (train mode).  For pipelined roles the
# knob is reinterpreted as the schedule's M; its ceiling bounds how far a
# pipeline can amortize its (S-1) fill/drain bubbles, which is what the
# resource optimizer's pipeline-aware floor divides by
# (``cluster_floor_time``: time >= roofline/S * (1 + (S-1)/M)).
MICRO_OPTS = (1, 2, 4, 8)
MAX_MICROBATCHES = MICRO_OPTS[-1]

# The operator-fusion plan dimension (PAPERS.md arXiv 1801.00829 — fusion
# plans as a costed compiler decision).  "off" emits the legacy fusion-
# blind profiles bit-identically (every pre-fusion baseline rides on it);
# "none" is the honest *materialized* plan (unfused attention pays its
# score-matrix round trip, casts are explicit instructions); "full" is the
# fused plan (flash attention, act/norm epilogues folded into their
# producing matmuls, casts sunk into the output write).  The value of the
# knob is exactly the HBM-traffic delta ProgramTotals already tracks.
FUSION_OPTS = ("off", "none", "full")


def _fusion_space(fusion: str) -> List[str]:
    """The enumerated fusion settings: ``"search"`` opens the full knob,
    any single setting pins it (default ``"off"`` — the legacy space)."""
    if fusion == "search":
        return list(FUSION_OPTS)
    if fusion in FUSION_OPTS:
        return [fusion]
    raise ValueError(f"unknown fusion setting {fusion!r}; "
                     f"one of {FUSION_OPTS + ('search',)}")


# ---------------------------------------------------------------------------
# Sharding plan: the searchable decision vector
# ---------------------------------------------------------------------------


class VecKnob:
    """A per-lane knob vector standing in for one scalar ShardingPlan field
    during a batched build (``cost_candidates_batched``): lane ``j`` holds
    group member ``j``'s knob value.  ``microbatches`` lanes carry the
    counts themselves; ``grad_reduce_dtype`` lanes carry the *byte widths*
    (the only thing the program builder reads off the dtype)."""

    __slots__ = ("values", "display")

    def __init__(self, values, display: str = "vec"):
        self.values = np.asarray(values)
        self.display = display

    def __str__(self) -> str:
        return f"<{self.display}x{self.values.shape[0]}>"

    __repr__ = __str__


def _kv(x):
    """Unwrap a possibly-:class:`VecKnob` knob to its numeric value(s)."""
    return x.values if isinstance(x, VecKnob) else x


def _gd_bytes(gd) -> int:
    """Byte width of the grad-reduce dtype knob (per-lane when batched)."""
    return gd.values if isinstance(gd, VecKnob) else dtype_bytes(gd)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    name: str = "dp"
    batch_axes: Tuple[str, ...] = ("data",)
    tp_axes: Tuple[str, ...] = ()          # heads / ff sharding
    fsdp_axes: Tuple[str, ...] = ()        # ZeRO-3 param sharding
    ep_axes: Tuple[str, ...] = ()          # MoE expert sharding
    seq_axes: Tuple[str, ...] = ()         # sequence-parallel (long prefill)
    pp_axes: Tuple[str, ...] = ()          # pipeline stages over this axis
    remat: str = "none"                    # none | selective | full
    microbatches: int = 1
    grad_reduce_dtype: str = "float32"
    overlap: bool = True
    zero1: bool = True                     # shard optimizer state over data
    fusion: str = "off"                    # off | none | full (FUSION_OPTS)

    def degree(self, cc: ClusterConfig, axes: Tuple[str, ...]) -> int:
        d = 1
        for a in axes:
            d *= cc.axis_size(a)
        return d

    def eff_degree(self, cc: ClusterConfig, axes: Tuple[str, ...],
                   units: int) -> int:
        """Effective parallelism: the axes product only divides the work
        when it divides the unit count — otherwise GSPMD (and our sharding
        rules) replicate, and the honest degree is 1.  (A dp-pure plan
        'sharding' batch=32 over 256 chips actually replicates the whole
        model on every chip — caught by the generated-plan costing, see
        EXPERIMENTS.md §Perf cell 2.)"""
        d = self.degree(cc, axes)
        if is_vec(units):   # per-lane unit counts (batched build)
            if d <= 0:
                return np.ones_like(units)
            return np.where(units % d == 0, d, 1)
        return d if (d > 0 and units % d == 0) else 1

    def describe(self) -> str:
        bits = [f"batch={'x'.join(self.batch_axes) or '-'}"]
        if self.tp_axes:
            bits.append(f"tp={'x'.join(self.tp_axes)}")
        if self.fsdp_axes:
            bits.append(f"fsdp={'x'.join(self.fsdp_axes)}")
        if self.ep_axes:
            bits.append(f"ep={'x'.join(self.ep_axes)}")
        if self.seq_axes:
            bits.append(f"seq={'x'.join(self.seq_axes)}")
        if self.pp_axes:
            bits.append(f"pp={'x'.join(self.pp_axes)}")
        bits.append(f"remat={self.remat}")
        if isinstance(self.microbatches, VecKnob) or self.microbatches > 1:
            bits.append(f"ubatch={self.microbatches}")
        if (isinstance(self.grad_reduce_dtype, VecKnob)
                or self.grad_reduce_dtype != "float32"):
            bits.append(f"gdtype={self.grad_reduce_dtype}")
        if self.fusion != "off":           # "off" keeps legacy strings
            bits.append(f"fusion={self.fusion}")
        return f"{self.name}[{','.join(bits)}]"


# ---------------------------------------------------------------------------
# Analytical step-program generation (white-box, per layer, ForBlock)
# ---------------------------------------------------------------------------


def _ts(shape, dtype="bfloat16", shards=1, state=MemState.HBM, sparsity=1.0):
    # dim_int/pmax keep knob-grid lane vectors (batched build) intact; the
    # scalar path is the same int()/max() it has always been.
    return TensorStat(tuple(dim_int(x) for x in shape), dtype, sparsity, state,
                      pmax(dim_int(shards), 1))


def build_step_program(arch: ArchConfig, shape: ShapeConfig, plan: ShardingPlan,
                       cc: ClusterConfig) -> Program:
    """Generate the analytical runtime plan for one train/serve step.

    All tensor shapes are GLOBAL; ``shard_axes`` on each Compute divides the
    work by the product of those axes' sizes, and each TensorStat's
    ``shards`` divides its per-device bytes — the same discipline the paper
    uses when normalizing MR task costs by the effective degree of
    parallelism.
    """
    mode = shape.mode
    micro0 = _kv(plan.microbatches) if shape.mode == "train" else 1
    mb0 = pmax(shape.global_batch // micro0, 1)
    dp = plan.eff_degree(cc, plan.batch_axes, mb0)
    tp = plan.degree(cc, plan.tp_axes)
    fsdp = plan.degree(cc, plan.fsdp_axes)
    ep = plan.degree(cc, plan.ep_axes)
    sp = plan.eff_degree(cc, plan.seq_axes,
                         1 if mode == "decode" else shape.seq_len)
    # Pipeline stages: the layer stack is partitioned into S bodies along
    # the pp axis (train only — the schedule needs a microbatch stream).
    pp_s = plan.degree(cc, plan.pp_axes) if mode == "train" else 1
    d, hd = arch.d_model, arch.head_dim_
    nh, nkv = max(arch.n_heads, 1), max(arch.n_kv_heads, 1)
    dt = arch.dtype
    bpe = dtype_bytes(dt)
    micro = _kv(plan.microbatches) if mode == "train" else 1

    batch = shape.global_batch
    q_len = 1 if mode == "decode" else shape.seq_len
    kv_len = shape.seq_len
    # The fusion plan knob.  "off" must emit EXACTLY the legacy tree (no
    # new attrs, no new instructions): the frozen pre-fusion baselines are
    # byte-identical on that path.  Otherwise every composite op names its
    # variant: attention carries fused=True/False, matmuls grow epilogue /
    # cast-sinking attrs ("full") or the materialized intermediates stay
    # separate instructions ("none", plus explicit casts).
    fus = plan.fusion
    attn_attrs = {} if fus == "off" else {"fused": fus == "full"}
    proj_epi = {"epilogue": "layernorm"} if fus == "full" else {}
    mb_batch = pmax(batch // micro, 1)         # global batch per microbatch
    tokens = mb_batch * q_len                  # global tokens per microbatch
    act_axes = plan.batch_axes + plan.seq_axes # divide token work
    mm_axes = act_axes + plan.tp_axes          # divide matmul work
    act_sh = dp * sp                           # shards of [tokens, d] acts
    head_sh = dp * sp * tp                     # shards of head-split acts
    weight_shards = max(tp * fsdp, 1)

    prog = Program(name=f"{arch.name}/{shape.name}/{plan.describe()}")
    pc = arch.param_counts()
    # Pipeline stages hold only their own layers' weights resident — the
    # per-device param bytes divide by S on top of the tp x fsdp sharding.
    prog.inputs["params"] = _ts((int(pc["total"]),), dt,
                                shards=weight_shards * pp_s)
    prog.inputs["batch_tokens"] = _ts((mb_batch, q_len), "int32",
                                      shards=act_sh, state=MemState.HOST)

    setup = GenericBlock("setup (persistent residents)")
    # Materialize the persistent HBM residents (optimizer state, activation
    # stash, KV cache, ...) as variables, so the costed walk's peak-HBM is
    # never below the estimate_hbm pre-filter that shares this formula.
    # Components the program materializes itself are not double-counted:
    # "params" is a program input (sharded by tp*fsdp, i.e. never below the
    # component, which ep-shards MoE experts too), and the logits-like
    # component is emitted only net of the logits variable the loss/lm-head
    # block creates at the very point the peak is taken.
    comps = dict(resident_components(arch, shape, plan, cc))
    logits_like = "ce_head" if mode == "train" else "logits"
    if logits_like in comps:
        logits_var = (tokens * arch.vocab_size
                      * (4 if mode == "train" else bpe) / pmax(head_sh, 1))
        comps[logits_like] = pmax(comps[logits_like] - logits_var, 0.0)
    for comp_name, comp_bytes in comps.items():
        # lane vectors must agree on which components materialize
        # (uniform_bool raises to the batched driver's scalar fallback)
        if comp_name == "params" or uniform_bool(comp_bytes < 1.0):
            continue
        setup.children.append(CreateVar(f"resident_{comp_name}",
                                        _ts((dim_ceil(comp_bytes),), "int8")))
    setup.children.append(CreateVar("embed_table",
                                    _ts((arch.vocab_size, d), dt, weight_shards)))
    prog.blocks.append(setup)

    # Batch staging + embedding run once per *microbatch* (the micro loop
    # wraps body_blocks below), so a step's total embedding work is the
    # full global batch no matter how it is microbatched — emitting them
    # once with per-microbatch tokens would under-charge ubatch>1 plans
    # (and break the within-role monotonicity the cluster floors rest on).
    stage = GenericBlock("stage batch + embed (per microbatch)")
    stage.children.append(IO("read", "batch_tokens",
                             src=MemState.HOST, dst=MemState.HBM))
    stage.children.append(Compute("embedding", ("batch_tokens", "embed_table"),
                                  "h", exec_type="DIST", shard_axes=act_axes))

    # ------------------------------------------------------------ sublayers
    def emit_attention(ops: List, prefix: str, reps: int) -> None:
        def emit(opcode, ins, out, axes, **attrs):
            for r in range(reps):
                ops.append(Compute(opcode, ins, f"{prefix}{out}_{r}",
                                   exec_type="DIST", shard_axes=axes,
                                   attrs=attrs))

        ops.append(CreateVar(f"{prefix}x2d", _ts((tokens, d), dt, act_sh)))
        if arch.mla is not None:
            m = arch.mla
            ops.append(CreateVar(f"{prefix}w_dq", _ts((d, m.q_lora_rank), dt, weight_shards)))
            emit("matmul", (f"{prefix}x2d", f"{prefix}w_dq"), "cq", act_axes)
            ops.append(CreateVar(f"{prefix}cq", _ts((tokens, m.q_lora_rank), dt, act_sh)))
            ops.append(CreateVar(f"{prefix}w_uq",
                                 _ts((m.q_lora_rank, nh * m.qk_head_dim), dt, weight_shards)))
            emit("matmul", (f"{prefix}cq", f"{prefix}w_uq"), "q", mm_axes)
            ops.append(CreateVar(f"{prefix}w_dkv", _ts((d, m.cache_dim), dt, weight_shards)))
            emit("matmul", (f"{prefix}x2d", f"{prefix}w_dkv"), "ckv", act_axes)
            if mode == "decode":
                # absorbed MLA: q heads attend over the shared latent cache
                # (MQA-like: 1 kv "head" of width cache_dim)
                ops.append(CreateVar(f"{prefix}q4", _ts((mb_batch, nh, q_len, m.cache_dim), dt, head_sh)))
                ops.append(CreateVar(f"{prefix}kc", _ts((mb_batch, 1, kv_len, m.cache_dim), dt, dp)))
                ops.append(CreateVar(f"{prefix}vc", _ts((mb_batch, 1, kv_len, m.kv_lora_rank), dt, dp)))
                emit("attention", (f"{prefix}q4", f"{prefix}kc", f"{prefix}vc"),
                     "attn", mm_axes, causal=False, **attn_attrs)
                v_dim = m.kv_lora_rank
            else:
                kv_tokens = mb_batch * kv_len
                ops.append(CreateVar(f"{prefix}ckv_all", _ts((kv_tokens, m.kv_lora_rank), dt, act_sh)))
                ops.append(CreateVar(f"{prefix}w_ukv",
                                     _ts((m.kv_lora_rank, nh * (m.qk_nope_head_dim + m.v_head_dim)),
                                         dt, weight_shards)))
                emit("matmul", (f"{prefix}ckv_all", f"{prefix}w_ukv"), "kv", mm_axes)
                ops.append(CreateVar(f"{prefix}q4", _ts((mb_batch, nh, q_len, m.qk_head_dim), dt, head_sh)))
                ops.append(CreateVar(f"{prefix}k4", _ts((mb_batch, nh, kv_len, m.qk_head_dim), dt, head_sh)))
                ops.append(CreateVar(f"{prefix}v4", _ts((mb_batch, nh, kv_len, m.v_head_dim), dt, head_sh)))
                emit("attention", (f"{prefix}q4", f"{prefix}k4", f"{prefix}v4"),
                     "attn", mm_axes, causal=True, **attn_attrs)
                v_dim = m.v_head_dim
            ops.append(CreateVar(f"{prefix}ao", _ts((tokens, nh * v_dim), dt, head_sh)))
            ops.append(CreateVar(f"{prefix}w_o", _ts((nh * v_dim, d), dt, weight_shards)))
            emit("matmul", (f"{prefix}ao", f"{prefix}w_o"), "proj", mm_axes,
                 **proj_epi)
        else:
            ops.append(CreateVar(f"{prefix}w_qkv",
                                 _ts((d, (nh + 2 * nkv) * hd), dt, weight_shards)))
            emit("matmul", (f"{prefix}x2d", f"{prefix}w_qkv"), "qkv", mm_axes)
            window = arch.layer_window(0, kv_len) if arch.window_pattern else None
            ops.append(CreateVar(f"{prefix}q4", _ts((mb_batch, nh, q_len, hd), dt, head_sh)))
            kv_sh = dp * min(tp, nkv) if tp > 1 else dp
            ops.append(CreateVar(f"{prefix}k4", _ts((mb_batch, nkv, kv_len, hd), dt, kv_sh)))
            ops.append(CreateVar(f"{prefix}v4", _ts((mb_batch, nkv, kv_len, hd), dt, kv_sh)))
            emit("attention", (f"{prefix}q4", f"{prefix}k4", f"{prefix}v4"),
                 "attn", mm_axes, causal=(mode != "decode"), window=window,
                 **attn_attrs)
            ops.append(CreateVar(f"{prefix}ao", _ts((tokens, nh * hd), dt, head_sh)))
            ops.append(CreateVar(f"{prefix}w_o", _ts((nh * hd, d), dt, weight_shards)))
            emit("matmul", (f"{prefix}ao", f"{prefix}w_o"), "proj", mm_axes,
                 **proj_epi)
        if tp > 1:
            # TP output reduction (Megatron g-op): payload = local act slice
            ops.append(Collective("all_reduce", f"{prefix}proj_0", plan.tp_axes,
                                  bytes_override=tokens * d * bpe / act_sh))
        if fus != "full":
            # materialized post-attention norm: its own HBM round trip
            # ("full" folded it into the proj matmul's epilogue above)
            ops.append(CreateVar(f"{prefix}hn", _ts((tokens, d), dt, act_sh)))
            for r in range(reps):
                ops.append(Compute("layernorm", (f"{prefix}hn",),
                                   f"{prefix}n_{r}", exec_type="DIST",
                                   shard_axes=act_axes))

    def emit_ffn(ops: List, prefix: str, reps: int) -> None:
        def emit(opcode, ins, out, axes, **attrs):
            for r in range(reps):
                ops.append(Compute(opcode, ins, f"{prefix}{out}_{r}",
                                   exec_type="DIST", shard_axes=axes,
                                   attrs=attrs))

        if f"{prefix}x2d" not in [c.name for c in ops if isinstance(c, CreateVar)]:
            ops.append(CreateVar(f"{prefix}x2d", _ts((tokens, d), dt, act_sh)))
        if arch.moe is not None:
            mcfg = arch.moe
            ops.append(CreateVar(f"{prefix}w_router", _ts((d, mcfg.n_experts), dt, 1)))
            emit("matmul", (f"{prefix}x2d", f"{prefix}w_router"), "route", act_axes)
            if ep > 1:
                a2a = tokens * d * bpe * mcfg.top_k / (act_sh * max(tp, 1))
                ops.append(Collective("all_to_all", f"{prefix}x2d", plan.ep_axes,
                                      bytes_override=a2a))
            ops.append(CreateVar(f"{prefix}w_up",
                                 _ts((mcfg.n_experts, d, mcfg.d_ff_expert), dt,
                                     max(ep * tp, 1) * max(fsdp, 1))))
            emit("moe_ffn", (f"{prefix}x2d", f"{prefix}w_up"), "moe",
                 act_axes + plan.ep_axes + plan.tp_axes,
                 top_k=mcfg.top_k, gated=arch.gated_mlp)
            if mcfg.n_shared_experts:
                ops.append(CreateVar(f"{prefix}w_sh",
                                     _ts((d, (3 if arch.gated_mlp else 2)
                                          * mcfg.n_shared_experts * mcfg.d_ff_expert),
                                         dt, weight_shards)))
                emit("matmul", (f"{prefix}x2d", f"{prefix}w_sh"), "shex", mm_axes)
            if ep > 1:
                a2a = tokens * d * bpe * mcfg.top_k / (act_sh * max(tp, 1))
                ops.append(Collective("all_to_all", f"{prefix}moe_0", plan.ep_axes,
                                      bytes_override=a2a))
        elif arch.d_ff:
            width = (3 if arch.gated_mlp else 2) * arch.d_ff
            act = "silu" if arch.gated_mlp else "gelu"
            ops.append(CreateVar(f"{prefix}w_ff", _ts((d, width), dt, weight_shards)))
            if fus == "full":
                # activation folded into the up-projection's flush — the
                # (tokens, d_ff) intermediate never round-trips HBM
                emit("matmul", (f"{prefix}x2d", f"{prefix}w_ff"), "ffn",
                     mm_axes, epilogue=act, epi_cols=arch.d_ff)
                ops.append(CreateVar(f"{prefix}ffh",
                                     _ts((tokens, arch.d_ff), dt, head_sh)))
            else:
                emit("matmul", (f"{prefix}x2d", f"{prefix}w_ff"), "ffn", mm_axes)
                ops.append(CreateVar(f"{prefix}ffh",
                                     _ts((tokens, arch.d_ff), dt, head_sh)))
                emit(act, (f"{prefix}ffh",), "act", mm_axes)
            ops.append(CreateVar(f"{prefix}w_down", _ts((arch.d_ff, d), dt, weight_shards)))
            emit("matmul", (f"{prefix}ffh", f"{prefix}w_down"), "ffo", mm_axes)
            if tp > 1:
                ops.append(Collective("all_reduce", f"{prefix}ffo_0", plan.tp_axes,
                                      bytes_override=tokens * d * bpe / act_sh))

    def emit_ssm(ops: List, prefix: str, reps: int) -> None:
        def emit(opcode, ins, out, axes, **attrs):
            for r in range(reps):
                ops.append(Compute(opcode, ins, f"{prefix}{out}_{r}",
                                   exec_type="DIST", shard_axes=axes,
                                   attrs=attrs))

        s = arch.ssm
        di = s.d_inner(d)
        ops.append(CreateVar(f"{prefix}x2d", _ts((tokens, d), dt, act_sh)))
        ops.append(CreateVar(f"{prefix}w_in",
                             _ts((d, 2 * di + 2 * s.n_groups * s.state_size
                                  + s.n_heads(d)), dt, weight_shards)))
        emit("matmul", (f"{prefix}x2d", f"{prefix}w_in"), "xin", mm_axes)
        ops.append(CreateVar(f"{prefix}x4",
                             _ts((mb_batch, q_len, s.n_heads(d), s.head_dim), dt, head_sh)))
        # decode: single-step state update (memory bound), else chunked scan
        chunk = 1 if mode == "decode" else s.chunk_size
        emit("ssd_scan", (f"{prefix}x4",), "ssd", mm_axes,
             state=s.state_size, chunk=chunk)
        ops.append(CreateVar(f"{prefix}xdi", _ts((tokens, di), dt, head_sh)))
        ops.append(CreateVar(f"{prefix}w_out", _ts((di, d), dt, weight_shards)))
        emit("matmul", (f"{prefix}xdi", f"{prefix}w_out"), "out", mm_axes)
        if tp > 1:
            ops.append(Collective("all_reduce", f"{prefix}out_0", plan.tp_axes,
                                  bytes_override=tokens * d * bpe / act_sh))

    def layer_body(prefix: str, backward: bool, kind: str) -> List:
        """kind: 'attn+ffn' | 'ssm' | 'attn-shared'."""
        ops: List = []
        reps = 2 if backward else 1           # dgrad + wgrad ~= 2x fwd
        if kind == "ssm":
            emit_ssm(ops, prefix, reps)
        else:
            emit_attention(ops, prefix, reps)
            emit_ffn(ops, prefix, reps)
        if fsdp > 1:
            # gathered params are reused across microbatches (prefetch +
            # persist for the step), so amortize the payload by micro
            per_layer = (pc["layers"] / arch.n_layers * bpe / weight_shards
                         / pmax(micro, 1))
            ops.insert(0, Collective("all_gather", "params", plan.fsdp_axes,
                                     bytes_override=per_layer))
            if backward:
                ops.append(Collective("reduce_scatter", "params", plan.fsdp_axes,
                                      bytes_override=per_layer * fsdp))
        return ops

    main_kind = "ssm" if arch.family in ("ssm", "hybrid") else "attn+ffn"
    body_blocks: List = [stage]
    fwd = ForBlock(f"fwd layers x{arch.n_layers}", arch.n_layers,
                   body=layer_body("L_", False, main_kind))
    body_blocks.append(fwd)
    shared_fwd = None
    if arch.hybrid is not None:
        n_app = arch.n_layers // arch.hybrid.attn_every
        shared_fwd = ForBlock(f"shared attn blocks x{n_app}", n_app,
                              body=layer_body("A_", False, "attn-shared"))
        body_blocks.append(shared_fwd)
    enc_block = None
    if arch.enc_dec is not None:
        # encoder runs once per step over frontend_seq frames
        enc_tokens = mb_batch * arch.enc_dec.encoder_seq
        enc_block = ForBlock(
            f"encoder layers x{arch.enc_dec.n_encoder_layers}",
            arch.enc_dec.n_encoder_layers,
            body=[Compute("matmul", ("enc_x", "enc_w"), f"enc_{i}",
                          exec_type="DIST", shard_axes=mm_axes)
                  for i in range(2)])
        body_blocks.append(enc_block)
        prog.inputs["enc_x"] = _ts((enc_tokens, d), dt, act_sh)
        prog.inputs["enc_w"] = _ts((d, 4 * d + (3 if arch.gated_mlp else 2) * arch.d_ff),
                                   dt, weight_shards)

    if mode == "train":
        recompute = {"none": 0.0, "selective": 0.35, "full": 1.0}[plan.remat]
        # Per-microbatch loss: like staging/embedding, the loss head runs
        # once per microbatch, so its work scales with the full batch.
        loss = GenericBlock("loss (per microbatch)")
        loss.children.append(CreateVar("logits",
                                       _ts((tokens, arch.vocab_size), "float32", head_sh)))
        loss.children.append(Compute("cross_entropy", ("logits",), "loss",
                                     exec_type="DIST", shard_axes=mm_axes))
        body_blocks.append(loss)
        bwd_body = layer_body("B_", True, main_kind)
        if recompute > 0:
            extra = layer_body("R_", False, main_kind)
            bwd_body = extra[: int(len(extra) * recompute)] + bwd_body
        body_blocks.append(ForBlock(f"bwd layers x{arch.n_layers}",
                                    arch.n_layers, body=bwd_body))
        if arch.hybrid is not None:
            n_app = arch.n_layers // arch.hybrid.attn_every
            body_blocks.append(ForBlock(f"bwd shared attn x{n_app}", n_app,
                                        body=layer_body("AB_", True, "attn-shared")))

        tail = GenericBlock("grad reduce + update")
        grad_bytes = (pc["total"] * _gd_bytes(plan.grad_reduce_dtype)
                      / (weight_shards * pp_s))
        if arch.moe is not None and ep > 1:
            grad_bytes /= ep
        reduce_axes = tuple(a for a in plan.batch_axes if a not in plan.fsdp_axes)
        if fus == "none" and plan.degree(cc, reduce_axes) > 1:
            # Materialized grad-dtype cast: the fp32 accumulator (global
            # param count, addressed through the params variable) is read
            # and re-written at wire width before the reduce.  "full"
            # sinks this into the producing wgrad writes (no instruction,
            # no traffic — the fused matmul's sink_cast_bytes semantics);
            # "off" is the legacy tree, which never priced the cast.
            tail.children.append(Compute(
                "cast", ("params",), "grad_wire", exec_type="DIST",
                shard_axes=plan.fsdp_axes + plan.tp_axes + plan.pp_axes,
                attrs={"from_bytes": 4,
                       "to_bytes": _gd_bytes(plan.grad_reduce_dtype)}))
        if plan.degree(cc, reduce_axes) > 1 and fsdp == 1:
            tail.children.append(Collective("all_reduce", "params", reduce_axes,
                                            bytes_override=grad_bytes))
        elif fsdp > 1 and plan.degree(cc, reduce_axes) > 1:
            tail.children.append(Collective("reduce_scatter", "params", reduce_axes,
                                            bytes_override=grad_bytes))
        upd_shards = weight_shards * (dp if fsdp > 1 else 1)
        tail.children.append(Compute("adamw_update", ("params",), "params2",
                                     exec_type="DIST",
                                     shard_axes=plan.fsdp_axes + plan.tp_axes
                                     + plan.pp_axes + plan.batch_axes))
        if pp_s > 1:
            prog.blocks.append(_pipelined_stages(
                arch, plan, pp_s, micro, stage, loss, enc_block, shared_fwd,
                layer_body, main_kind, recompute,
                act_payload=tokens * d * bpe / act_sh))
        elif uniform_bool(micro > 1):
            prog.blocks.append(ForBlock(f"microbatches x{micro}", micro,
                                        body=body_blocks))
        else:
            prog.blocks.extend(body_blocks)
        prog.blocks.append(tail)
    else:
        prog.blocks.extend(body_blocks)
        head = GenericBlock("lm head")
        head.children.append(CreateVar("hout", _ts((tokens, d), dt, act_sh)))
        head.children.append(CreateVar("w_head", _ts((d, arch.vocab_size), dt, weight_shards)))
        # Serving logits leave the head in fp32 (sampling runs there — the
        # resident "logits" component is 4 B/cell).  "full" sinks the cast
        # into the matmul's output write; "none" materializes it as its
        # own round trip; "off" keeps the legacy tree, which never priced
        # the upcast at all.
        head_attrs = {"sink_cast_bytes": 4} if fus == "full" else {}
        head.children.append(Compute("matmul", ("hout", "w_head"), "logits",
                                     exec_type="DIST", shard_axes=mm_axes,
                                     attrs=head_attrs))
        if fus == "none":
            head.children.append(Compute("cast", ("logits",), "logits32",
                                         exec_type="DIST", shard_axes=mm_axes,
                                         attrs={"to_bytes": 4}))
        if tp > 1:
            head.children.append(Collective("all_gather", "logits", plan.tp_axes,
                                            bytes_override=tokens * arch.vocab_size
                                            * bpe / (act_sh * tp)))
        prog.blocks.append(head)
    return prog


def _pipelined_stages(arch: ArchConfig, plan: ShardingPlan, pp_s: int,
                      micro: int, stage: GenericBlock, loss: GenericBlock,
                      enc_block, shared_fwd, layer_body, main_kind: str,
                      recompute: float, act_payload: float
                      ) -> PipelinedLoopBlock:
    """Partition the train step's layer stack into S pipeline-stage bodies.

    Stage 0 owns batch staging + embedding (and the encoder, when one
    exists); the last stage owns the loss head (and any shared-attention
    blocks).  Every stage runs ``n_layers / S`` of the per-layer fwd + bwd
    work (remainder layers land on the earliest stages) and hands its
    boundary activations to the next stage — and, on the backward path,
    the activation gradients to the previous stage — as :class:`P2P`
    transfers over one link of the pp axis.  Identical interior stages
    share one structural signature, so the sub-plan cache costs them once.
    """
    pp_axis = plan.pp_axes[0]
    base_l, rem = divmod(arch.n_layers, pp_s)
    stages: List[List] = []
    for si in range(pp_s):
        layers_s = base_l + (1 if si < rem else 0)
        body: List = []
        if si == 0:
            body.append(stage)
            if enc_block is not None:
                body.append(enc_block)
        body.append(ForBlock(f"fwd layers x{layers_s}", layers_s,
                             body=layer_body("L_", False, main_kind)))
        if si < pp_s - 1:
            body.append(P2P("pp_fwd_act", pp_axis,
                            bytes_override=act_payload))
        else:
            if shared_fwd is not None:
                body.append(shared_fwd)
            body.append(loss)
        bwd_body = layer_body("B_", True, main_kind)
        if recompute > 0:
            extra = layer_body("R_", False, main_kind)
            bwd_body = extra[: int(len(extra) * recompute)] + bwd_body
        if si == pp_s - 1 and shared_fwd is not None:
            n_app = arch.n_layers // arch.hybrid.attn_every
            body.append(ForBlock(f"bwd shared attn x{n_app}", n_app,
                                 body=layer_body("AB_", True, "attn-shared")))
        body.append(ForBlock(f"bwd layers x{layers_s}", layers_s,
                             body=bwd_body))
        if si > 0:
            body.append(P2P("pp_bwd_grad", pp_axis,
                            bytes_override=act_payload))
        stages.append(body)
    return PipelinedLoopBlock(f"ubatch x{micro} over {pp_s} stages", micro,
                              stages)


# ---------------------------------------------------------------------------
# Memory estimate (white-box HBM budget check, pre-compile)
# ---------------------------------------------------------------------------


def resident_components(arch: ArchConfig, shape: ShapeConfig,
                        plan: ShardingPlan, cc: ClusterConfig
                        ) -> Dict[str, float]:
    """Persistent per-device HBM residents (bytes) for one step, by name.

    This is the single source of truth for the HBM-feasibility pre-filter
    (:func:`estimate_hbm` sums it) AND for the generated plan itself:
    :func:`build_step_program` materializes every non-params component as a
    resident variable, so the cost walk's ``peak_hbm_per_device`` is always
    at least ``estimate_hbm`` — the pre-filter can never reject a plan whose
    costed peak-HBM excursion fits (asserted by tests/test_planner.py).
    """
    pc = arch.param_counts()
    mb0 = pmax(shape.global_batch
               // (_kv(plan.microbatches) if shape.mode == "train" else 1), 1)
    dp = plan.eff_degree(cc, plan.batch_axes, mb0)
    tp = plan.degree(cc, plan.tp_axes)
    fsdp = plan.degree(cc, plan.fsdp_axes)
    ep = plan.degree(cc, plan.ep_axes)
    sp = plan.eff_degree(cc, plan.seq_axes,
                         1 if shape.mode == "decode" else shape.seq_len)
    # Pipeline stages are resident-state shards: a stage holds only its
    # own n_layers/S slice of weights, gradients and optimizer state —
    # the ~S-fold HBM drop that opens cells where no 2D role fits.
    pp = plan.degree(cc, plan.pp_axes) if shape.mode == "train" else 1
    bpe = dtype_bytes(arch.dtype)
    wsh = max(tp * fsdp * (ep if arch.moe else 1), 1)
    comp: Dict[str, float] = {"params": pc["total"] * bpe / (wsh * pp)}
    if shape.mode == "train":
        # adam m,v (fp32) + fp32 transients during the update, sharded like
        # params (+dp if fsdp); calibrated against compiled memory_analysis
        opt_shards = wsh * (dp if (fsdp > 1 or plan.zero1) else 1)
        comp["opt_state"] = 4 * pc["total"] * 4 / (pmax(opt_shards, wsh) * pp)
        # gradients: resident fp32 accumulator regardless of microbatching
        # (grad_reduce_dtype only changes the wire payload, not the buffer;
        # calibrated against compiled memory_analysis)
        comp["grads"] = pc["total"] * 4 / (wsh * pp)
        # activations saved for backward, per token per layer:
        #   replicated residual-stream parts (~d) + head/ff-sharded parts
        d = arch.d_model
        hd_total = max(arch.n_heads, 1) * arch.head_dim_
        if arch.moe is not None:
            ff_eff = arch.moe.top_k * arch.moe.d_ff_expert \
                + arch.moe.n_shared_experts * arch.moe.d_ff_expert
        elif arch.family in ("ssm", "hybrid"):
            ff_eff = arch.ssm.expand * d
        else:
            ff_eff = arch.d_ff
        fac = {"none": (5.0, 3.0), "selective": (2.0, 1.0),
               "full": (2.0, 0.0)}[plan.remat]
        per_tok = (fac[0] * d * bpe
                   + fac[1] * (hd_total + ff_eff) * bpe / max(tp, 1))
        tokens_dev = shape.tokens / pmax(dp * sp * _kv(plan.microbatches), 1)
        if pp > 1:
            # 1F1B-style schedule memory: a stage stashes activations for
            # its own n_layers/S layers, but keeps min(M, S) microbatches
            # in flight — for M >= S that is exactly the sequential
            # microbatched stash (the stage's S-fold layer cut times the
            # S in-flight microbatches cancel); weights/optimizer state
            # above still drop S-fold.
            comp["act_stash"] = (tokens_dev * (arch.n_layers / pp) * per_tok
                                 * pmin(_kv(plan.microbatches), pp))
        else:
            comp["act_stash"] = tokens_dev * arch.n_layers * per_tok
        # chunked-CE head: [ce_chunk, vocab] fp32 (+bwd copy), tp-sharded
        comp["ce_head"] = 2 * 2048 * arch.vocab_size * 4 / max(tp, 1)
    else:
        tokens_dev = shape.tokens / max(dp * sp, 1)
        if shape.mode == "decode":
            # KV cache dominates
            def kv_at(kv_len: float) -> float:
                """Per-layer cache residents at context ``kv_len`` (the SSM
                state is sequence-independent; hybrids scale only the
                attention share)."""
                if arch.mla:
                    return shape.global_batch / dp * kv_len * arch.mla.cache_dim
                if arch.family == "ssm":
                    s = arch.ssm
                    return (shape.global_batch / dp * s.n_heads(arch.d_model)
                            * s.head_dim * s.state_size)
                if arch.family == "hybrid":
                    s = arch.ssm
                    ssm_state = (shape.global_batch / dp
                                 * s.n_heads(arch.d_model) * s.head_dim
                                 * s.state_size)
                    n_attn = arch.n_layers // arch.hybrid.attn_every
                    kv = (shape.global_batch / dp * kv_len
                          * 2 * arch.n_kv_heads * arch.head_dim_
                          / max(tp, 1)) * n_attn / arch.n_layers
                    return ssm_state + kv
                kv_len_eff = kv_len
                if arch.window_pattern:
                    # local layers cache only the window
                    n_pat = len(arch.window_pattern)
                    w_sum = sum(min(w, kv_len) if w else kv_len
                                for w in arch.window_pattern) / n_pat
                    kv_len_eff = w_sum
                return (shape.global_batch / dp * kv_len_eff
                        * 2 * arch.n_kv_heads * arch.head_dim_ / max(tp, 1))

            cache = kv_at(shape.seq_len)
            comp["kv_cache"] = cache * arch.n_layers * bpe
            # Paged-KV allocator pressure (serving decode shapes only): each
            # slot reserves whole pages out to its p99 context, so the pool
            # must keep the page-rounded tail resident, not the mean.  Plain
            # decode shapes carry neither field and the term vanishes (and a
            # zero-byte component emits no resident variable — bit-exact).
            page = getattr(shape, "kv_page_tokens", 0)
            max_ctx = getattr(shape, "max_context", 0)
            if page and max_ctx:
                paged_len = math.ceil(max(max_ctx, shape.seq_len)
                                      / page) * page
                comp["kv_paging"] = (max(kv_at(paged_len) - cache, 0.0)
                                     * arch.n_layers * bpe)
            live_tokens = shape.global_batch / max(dp, 1)   # one token/seq
            comp["live_acts"] = live_tokens * arch.d_model * bpe * 4
            comp["logits"] = live_tokens * arch.vocab_size * 4 / max(tp, 1)
        else:
            comp["act_workspace"] = tokens_dev * arch.d_model * bpe * 8 / max(tp, 1)
    return comp


def estimate_hbm(arch: ArchConfig, shape: ShapeConfig, plan: ShardingPlan,
                 cc: ClusterConfig) -> float:
    """Per-device resident HBM (bytes): the feasibility pre-filter's bound."""
    return sum(resident_components(arch, shape, plan, cc).values())


# ---------------------------------------------------------------------------
# Enumeration + selection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PlanDecision:
    plan: ShardingPlan
    cost: CostedProgram
    hbm_est: float
    feasible: bool

    @property
    def time(self) -> float:
        return self.cost.total


@dataclasses.dataclass
class SearchStats:
    """Observability for one plan search: how many candidates were actually
    costed vs. pruned, and how well the sub-plan cache worked."""

    costed: int = 0
    pruned_infeasible: int = 0   # skipped: cannot fit HBM even when frugal
    pruned_dominated: int = 0    # skipped: a strictly better sibling exists
    cache: Optional[CacheStats] = None

    def describe(self) -> str:
        bits = [f"costed={self.costed}",
                f"pruned_oom={self.pruned_infeasible}",
                f"pruned_dom={self.pruned_dominated}"]
        if self.cache is not None:
            bits.append(f"cache_hits={self.cache.hits}/"
                        f"{self.cache.hits + self.cache.misses}")
        return " ".join(bits)


def _knob_space(shape: ShapeConfig) -> Tuple[List[str], List[int], List[str]]:
    """The non-role decision knobs: remat x microbatches x grad dtype.
    For pipelined roles the microbatch knob doubles as the schedule's M."""
    if shape.mode == "train":
        return (["none", "selective", "full"], list(MICRO_OPTS),
                ["float32", "bfloat16"])
    return (["none"], [1], ["float32"])


def _model_roles(arch: ArchConfig, shape: ShapeConfig,
                 cc: ClusterConfig) -> List[Dict]:
    """Role assignments for the non-batch mesh axes (search stage 1).

    On a 2D (+pod) mesh the single "model" axis carries one role.  On a 3D
    torus mesh ("data", "model", "depth") the two non-batch axes are
    assigned jointly: both tensor-parallel, tp on one with extra data /
    FSDP / expert / sequence parallelism on the other, or both folded into
    data-parallel replicas — every enumerated plan still belongs to
    exactly one role class, which is what keeps the resource optimizer's
    per-role cluster floors sound on the enlarged space.
    """
    axes = cc.mesh_axes
    has_model = "model" in axes
    has_depth = "depth" in axes

    def pp_ok(axis: str) -> bool:
        # A pipeline role needs a microbatch stream (train), at least two
        # stage positions on the axis, and enough layers to partition.
        s = cc.axis_size(axis)
        return shape.mode == "train" and s >= 2 and arch.n_layers >= s

    if has_depth:
        roles: List[Dict] = [
            dict(name="dp+tp2", tp=("model", "depth")),
            dict(name="dp+tp", tp=("model",), batch_extra=("depth",)),
            dict(name="tp+fsdp", tp=("model",), fsdp=("depth",)),
            dict(name="fsdp2", fsdp=("model", "depth")),
            dict(name="dp-pure", batch_extra=("model", "depth")),
        ]
        if arch.moe is not None:
            roles.append(dict(name="dp+ep+tp", ep=("depth",), tp=("model",)))
            roles.append(dict(name="dp+ep", ep=("model", "depth")))
        if shape.mode == "prefill":
            roles.append(dict(name="tp+seq", tp=("model",), seq=("depth",)))
        if pp_ok("depth"):
            roles.append(dict(name="pp+tp", pp=("depth",), tp=("model",)))
            roles.append(dict(name="dp+pp", pp=("depth",),
                              batch_extra=("model",)))
        if "pod" in axes and pp_ok("pod"):
            # pipeline-over-DCN across slices, 3D torus inside each stage
            roles.append(dict(name="pp-dcn+tp2", pp=("pod",),
                              tp=("model", "depth")))
        return roles
    roles = [dict(name="dp+tp", tp=("model",))]
    roles.append(dict(name="fsdp", fsdp=("model",)))
    roles.append(dict(name="dp-pure", batch_extra=("model",)))
    if arch.moe is not None and has_model:
        roles.append(dict(name="dp+ep", ep=("model",)))
        roles.append(dict(name="dp+ep+tp", ep=("model",), tp=("model",)))
    if shape.mode == "prefill":
        roles.append(dict(name="dp+seq", seq=("model",)))
    if "pod" in axes and pp_ok("pod"):
        # the headline family: pipeline-over-DCN across slices.  Stage
        # boundaries pay one p2p activation hop per microbatch instead of
        # the ring collective a pod-wide gradient reduce would phase over
        # DCN, and per-stage resident state drops S-fold.
        roles.append(dict(name="pp-dcn+tp", pp=("pod",), tp=("model",)))
        if has_model:
            roles.append(dict(name="pp-dcn+fsdp", pp=("pod",),
                              fsdp=("model",)))
    if not has_model:
        roles = [r for r in roles if r["name"] == "dp+tp"]
    return roles


def _batch_base(cc: ClusterConfig) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in cc.mesh_axes)


def _role_plan(role: Dict, cc: ClusterConfig, remat: str, micro: int,
               gd: str, fus: str = "off") -> ShardingPlan:
    has_model = "model" in cc.mesh_axes
    pp = tuple(role.get("pp", ()))
    return ShardingPlan(
        name=role["name"],
        # a pipeline axis carries stages, never batch — strip it from the
        # default (pod, data) batch base
        batch_axes=tuple(a for a in _batch_base(cc) + role.get("batch_extra", ())
                         if a not in pp),
        tp_axes=role.get("tp", ()) if has_model else (),
        fsdp_axes=role.get("fsdp", ()),
        ep_axes=role.get("ep", ()),
        seq_axes=role.get("seq", ()),
        pp_axes=pp,
        remat=remat, microbatches=micro, grad_reduce_dtype=gd, fusion=fus)


def _micro_valid(role: Dict, shape: ShapeConfig, cc: ClusterConfig,
                 micro: int) -> bool:
    if micro == 1:
        return True
    pp = role.get("pp", ())
    base = tuple(a for a in _batch_base(cc) + role.get("batch_extra", ())
                 if a not in pp)
    return shape.global_batch // (_deg(cc, base) * micro) >= 1


def _role_base_micro(role: Dict, shape: ShapeConfig, cc: ClusterConfig,
                     micro_opts: Sequence[int]) -> int:
    """The microbatch count a role's stage-1 beam representative is costed
    with.  Non-pipelined roles use 1 (the minimum-work knob); a pipelined
    role's natural operating point is the *largest* valid M — at M=1 its
    stages run back-to-back with zero overlap, which would unfairly sink
    an eventually-winning pipeline in the role beam."""
    if not role.get("pp"):
        return 1
    return max((m for m in micro_opts
                if _micro_valid(role, shape, cc, m)), default=1)


def enumerate_plans(arch: ArchConfig, shape: ShapeConfig,
                    cc: ClusterConfig,
                    fusion: str = "off") -> List[ShardingPlan]:
    """The full candidate sharding-plan space for the fixed mesh of ``cc``.

    ``fusion="search"`` widens the space by the fusion knob
    (:data:`FUSION_OPTS`); the default pins ``"off"``, keeping every
    pre-fusion candidate set (and its golden winners) unchanged."""
    remats, micro_opts, gdtypes = _knob_space(shape)
    fus_opts = _fusion_space(fusion)
    plans: List[ShardingPlan] = []
    for role in _model_roles(arch, shape, cc):
        for remat, micro, gd, fus in itertools.product(
                remats, micro_opts, gdtypes, fus_opts):
            if not _micro_valid(role, shape, cc, micro):
                continue
            plans.append(_role_plan(role, cc, remat, micro, gd, fus))
    # dedupe
    seen, out = set(), []
    for p in plans:
        key = dataclasses.astuple(p)
        if key not in seen:
            seen.add(key)
            out.append(p)
    return out


def _deg(cc: ClusterConfig, axes: Tuple[str, ...]) -> int:
    d = 1
    for a in axes:
        d *= cc.axis_size(a)
    return d


def reference_plans(arch: ArchConfig, shape: ShapeConfig,
                    cc: ClusterConfig,
                    fusion: str = "off") -> List[ShardingPlan]:
    """One minimum-work representative per axis-role class of
    :func:`enumerate_plans` — the basis of the resource optimizer's sound
    cluster floors (:func:`repro.core.resource.cluster_floor_time`).

    Every enumerated plan belongs to exactly one role (its mesh-axis
    assignment); within a role the knobs can only *add* charged work
    relative to this representative:

      * ``remat`` heavier than ``none`` re-emits forward ops (and, under
        FSDP, their gathers) into the backward pass;
      * ``microbatches > 1`` keeps global work and total collective volume
        the same at best, and inflates both when the smaller per-microbatch
        batch stops dividing the data axes (``eff_degree`` collapses to
        replication);
      * the widest ``grad_reduce_dtype`` payload is avoided by picking the
        narrowest enumerated dtype here.

    So the representative's charged per-device totals (flops, HBM bytes,
    collective wire volume — :class:`repro.core.costmodel.ProgramTotals`)
    lower-bound every plan in its role, and a minimum over roles
    lower-bounds the whole plan space.

    Pipelined roles keep micro=1 here too: the pipelined loop's *work*
    totals are microbatch-invariant (M transfers of payload/M, M loss
    heads over batch/M, ...), so M=1 stays the minimum-work member — but
    its *time* overlaps across stages, so the floor must not price the
    totals as one sequential roofline.  ``cluster_floor_time`` handles
    that with the pipeline-aware ``roofline / S * (1 + (S-1)/M)`` bound.

    **Fusion.**  With ``fusion="search"`` the knob breaks the "only adds
    work" monotonicity in one direction: ``fusion="full"`` *removes* HBM
    traffic relative to ``"off"``, so the off representative alone would
    not lower-bound fused members.  The fix is a second representative
    per role at ``fusion="full"`` — the traffic-minimal setting — and the
    floor consumer (``resource.role_floor_times``) takes the min over a
    role's representatives.  ``"none"`` only ever adds traffic on top of
    ``"off"`` (materialized intermediates, explicit casts), so the off
    rep covers it.
    """
    remats, _, gdtypes = _knob_space(shape)
    gd_min = min(gdtypes, key=dtype_bytes)
    fus_reps = ["off"]
    if "full" in _fusion_space(fusion):
        fus_reps.append("full")
    return [_role_plan(role, cc, remats[0], 1, gd_min, fus)
            for role in _model_roles(arch, shape, cc)
            for fus in fus_reps]


def _cost_candidate(arch: ArchConfig, shape: ShapeConfig, p: ShardingPlan,
                    cc: ClusterConfig, cache: Optional[PlanCostCache],
                    stats: SearchStats) -> PlanDecision:
    cc_p = cc.with_overlap(OVERLAP_FRACTION if p.overlap else 0.0)
    prog = build_step_program(arch, shape, p, cc_p)
    costed = estimate(prog, cc_p, cache=cache)
    hbm = estimate_hbm(arch, shape, p, cc_p)
    stats.costed += 1
    return PlanDecision(p, costed, hbm, hbm <= cc.hbm_budget)


def _rank_key(d: PlanDecision) -> Tuple:
    return (not d.feasible, d.time)


# ---------------------------------------------------------------------------
# Batched costing: one walk per structure signature
# ---------------------------------------------------------------------------


def _structure_key(plan: ShardingPlan, mode: str) -> Tuple:
    """The program-tree identity of a candidate: every ShardingPlan field
    that changes which nodes :func:`build_step_program` emits (axis roles,
    remat re-emission, micro>1's loop wrap, the pipelined/sequential split,
    overlap/zero1).  Candidates sharing a key differ only in the *values*
    of (microbatches, grad_reduce_dtype) — the same tree with different
    numbers — so one lane-vector walk costs them all.  The micro>1 flag is
    part of the key because it IS structure: the microbatch ForBlock (and
    the warm-branch shape of every loop walker) exists only on one side.
    ``fusion`` is structure too: each setting emits a different tree
    (separate-vs-folded epilogue ops, explicit casts, fused attrs)."""
    micro = plan.microbatches if mode == "train" else 1
    return (plan.name, plan.batch_axes, plan.tp_axes, plan.fsdp_axes,
            plan.ep_axes, plan.seq_axes, plan.pp_axes, plan.remat,
            plan.overlap, plan.zero1, micro > 1, plan.fusion)


def _cost_group_vectorized(arch: ArchConfig, shape: ShapeConfig,
                           members: Sequence[ShardingPlan],
                           cc: ClusterConfig) -> List[CostedProgram]:
    """Cost one structure group with a single lane-vector tree walk.

    The group's representative program is built once with
    :class:`VecKnob`-wrapped knob fields — lane ``j`` carries member
    ``j``'s (microbatches, grad-dtype bytes) — and costed with
    ``cache=None`` (lane vectors have no hashable read-set signatures; the
    vectorized walk IS the fast path, it does not also memoize).  Lane
    extraction then yields each member's scalar-walk numbers bit-exact
    (tests/test_properties.py asserts every field)."""
    base = members[0]
    micros = np.array([p.microbatches for p in members], dtype=np.int64)
    gdb = np.array([dtype_bytes(p.grad_reduce_dtype) for p in members],
                   dtype=np.int64)
    vec_plan = dataclasses.replace(
        base,
        microbatches=VecKnob(micros, "ubatch"),
        grad_reduce_dtype=VecKnob(gdb, "gdB"))
    cc_p = cc.with_overlap(OVERLAP_FRACTION if base.overlap else 0.0)
    prog = build_step_program(arch, shape, vec_plan, cc_p)
    costed = estimate(prog, cc_p, cache=None, terse_labels=True)
    return split_costed_lanes(costed, len(members))


def cost_candidates_batched(arch: ArchConfig, shape: ShapeConfig,
                            plans: Sequence[ShardingPlan], cc: ClusterConfig,
                            cache: Optional[PlanCostCache] = None,
                            stats: Optional[SearchStats] = None
                            ) -> List[PlanDecision]:
    """Cost ``plans`` with one tree walk per structure signature.

    Candidates are grouped by :func:`_structure_key`; each K>1 group is
    costed by one vectorized walk (:func:`_cost_group_vectorized`),
    singleton groups by the ordinary scalar walk (which still shares the
    sub-plan ``cache``).  Any group the vectorized walk cannot hold
    uniform (:class:`repro.core.npvec.HeterogeneousLanes`, or an
    array-blind code path) falls back to scalar costing member by member —
    the engine is exact by construction, never by hope.  Results come back
    in input order."""
    if stats is None:
        stats = SearchStats()
    groups: Dict[Tuple, List[int]] = {}
    for i, p in enumerate(plans):
        groups.setdefault(_structure_key(p, shape.mode), []).append(i)
    out: List[Optional[PlanDecision]] = [None] * len(plans)
    for idxs in groups.values():
        members = [plans[i] for i in idxs]
        costed = None
        if len(idxs) > 1:
            try:
                costed = _cost_group_vectorized(arch, shape, members, cc)
            except (HeterogeneousLanes, TypeError, ValueError):
                costed = None
        if costed is None:
            for i, p in zip(idxs, members):
                out[i] = _cost_candidate(arch, shape, p, cc, cache, stats)
            continue
        stats.costed += len(idxs)
        cc_p = cc.with_overlap(OVERLAP_FRACTION if members[0].overlap
                               else 0.0)
        for i, p, cp in zip(idxs, members, costed):
            hbm = estimate_hbm(arch, shape, p, cc_p)
            out[i] = PlanDecision(p, cp, hbm, hbm <= cc.hbm_budget)
    return out


class IncrementalCoster:
    """Incremental re-costing for single-knob plan mutations.

    Wraps one (arch, shape, cc) context around a shared
    :class:`PlanCostCache`: the first :meth:`cost` pays the full walk and
    populates the cache; a :meth:`recost` after mutating one knob re-walks
    only the dirty subtree — every block whose structural signature and
    read-set fingerprint survive the mutation replays from cache (e.g. a
    ``grad_reduce_dtype`` flip misses only the grad-reduce tail; a remat
    change misses the backward bodies but keeps the forward stack).  The
    result is the from-scratch answer bit-exact — the cache key semantics
    guarantee it, and tests/test_incremental.py asserts it per knob —
    ``marginal`` just reports how little was recomputed."""

    def __init__(self, arch: ArchConfig, shape: ShapeConfig,
                 cc: ClusterConfig,
                 cache: Optional[PlanCostCache] = None):
        self.arch = arch
        self.shape = shape
        self.cc = cc
        self.cache = cache if cache is not None else PlanCostCache()
        self.stats = SearchStats()
        self.marginal: Optional[CacheStats] = None

    def cost(self, plan: ShardingPlan,
             shape: Optional[ShapeConfig] = None) -> PlanDecision:
        """Cost ``plan`` (optionally under a shape override, e.g. a
        re-slotted decode shape) through the shared cache, recording the
        walk's *marginal* hits/misses in :attr:`marginal`."""
        h0, m0 = self.cache.hits, self.cache.misses
        d = _cost_candidate(self.arch, shape or self.shape, plan,
                            self.cc, self.cache, self.stats)
        self.marginal = CacheStats(self.cache.hits - h0,
                                   self.cache.misses - m0,
                                   self.cache.entries)
        return d

    def recost(self, base_plan: ShardingPlan,
               shape: Optional[ShapeConfig] = None,
               **mutation) -> PlanDecision:
        """Re-cost ``base_plan`` with the given knob fields replaced
        (``remat=...``, ``microbatches=...``, ``grad_reduce_dtype=...``)."""
        return self.cost(dataclasses.replace(base_plan, **mutation)
                         if mutation else base_plan, shape=shape)


def choose_plan(arch: ArchConfig, shape: ShapeConfig, cc: ClusterConfig,
                top_k: int = 5,
                candidates: Optional[Sequence[ShardingPlan]] = None,
                search: str = "beam", beam_width: int = 4,
                cache: Optional[PlanCostCache] = None,
                stats: Optional[SearchStats] = None,
                fusion: str = "off") -> List[PlanDecision]:
    """Pick the best sharding plans by ``C(P, cc)``; infeasible (OOM) sink.

    ``search="beam"`` (default) runs the staged beam search over the
    decision vector — axis roles, then remat/microbatch, then grad-dtype/
    overlap — pruning HBM-infeasible and dominated prefixes without costing
    them.  ``search="exhaustive"`` costs every enumerated candidate (the
    seed behavior; also used whenever an explicit ``candidates`` list is
    given with the default search).  ``search="batched"`` covers the SAME
    exhaustive space through the vectorized engine — one tree walk per
    structure signature (:func:`cost_candidates_batched`), streaming the
    structure groups through a role-floor dominance pool that, at
    ``top_k=1``, skips whole groups whose sound per-role floor already
    loses to the incumbent (the winner is provably unaffected; wider
    ``top_k`` disables the pruning so the full ranking stays exhaustive).
    Pass a shared :class:`PlanCostCache` to reuse sub-plan costs
    across calls (scenario sweeps); by default each call gets a private
    cache, which already dedupes the per-layer loop bodies shared between
    candidates.

    ``fusion="search"`` widens every strategy's space by the operator-
    fusion knob (beam expands it in stage 3; the batched engine's role
    floors turn fusion-aware automatically).  The default ``"off"``
    searches exactly the pre-fusion space.
    """
    if stats is None:
        stats = SearchStats()
    if cache is None:
        cache = PlanCostCache()
    if search == "batched":
        cands = (list(candidates) if candidates is not None
                 else enumerate_plans(arch, shape, cc, fusion=fusion))
        decisions = _batched_search(arch, shape, cc, top_k, cands, cache,
                                    stats)
        stats.cache = cache.stats()
        return decisions[:top_k]
    if candidates is not None or search == "exhaustive":
        cands = (list(candidates) if candidates is not None
                 else enumerate_plans(arch, shape, cc, fusion=fusion))
        decisions = [_cost_candidate(arch, shape, p, cc, cache, stats)
                     for p in cands]
        decisions.sort(key=_rank_key)
        stats.cache = cache.stats()
        return decisions[:top_k]
    if search != "beam":
        raise ValueError(f"unknown search strategy {search!r}")
    decisions = _beam_search(arch, shape, cc, top_k, beam_width, cache, stats,
                             fusion=fusion)
    stats.cache = cache.stats()
    return decisions


def _batched_search(arch: ArchConfig, shape: ShapeConfig, cc: ClusterConfig,
                    top_k: int, cands: List[ShardingPlan],
                    cache: PlanCostCache,
                    stats: SearchStats) -> List[PlanDecision]:
    """Exhaustive-space search through the vectorized engine.

    Structure groups stream in ascending role-floor order through a
    rank-key :class:`DominancePool`; at ``top_k == 1`` a group whose
    role's sound cluster floor (``resource.role_floor_times`` — a lower
    bound on every member's time, knobs included) strictly loses to a
    *feasible* incumbent is pruned without being costed: each member
    would rank behind the incumbent under ``_rank_key`` whether feasible
    (worse time) or not (feasibility sinks).  Ties are never pruned
    (strict inequality), so the returned winner is the exhaustive winner
    bit-for-bit.  With ``top_k > 1`` every group is costed — the tail of
    the ranking has no floor argument."""
    from repro.core import resource as _resource  # circular at import time
    # A candidate set with non-"off" fusion members needs fusion-aware
    # floors: "full" removes HBM traffic, so the off-only representative
    # would not lower-bound it (see reference_plans).  Derived from the
    # candidates themselves so explicit candidate lists stay sound.
    floor_fusion = ("off" if all(p.fusion == "off" for p in cands)
                    else "search")
    try:
        floors = _resource.role_floor_times(arch, shape, cc,
                                            fusion=floor_fusion)
    except Exception:
        floors = {}
    groups: Dict[Tuple, List[ShardingPlan]] = {}
    for p in cands:
        groups.setdefault(_structure_key(p, shape.mode), []).append(p)
    ordered = sorted(groups.items(),
                     key=lambda kv: floors.get(kv[0][0], 0.0))
    pool = DominancePool(
        rank_key=_rank_key,
        cannot_win=lambda floor_t, best: best.feasible and floor_t > best.time)
    decisions: List[PlanDecision] = []
    for key, members in ordered:
        floor_t = floors.get(key[0], 0.0)
        if top_k == 1 and not pool.admit(floor_t):
            stats.pruned_dominated += len(members)
            continue
        for d in cost_candidates_batched(arch, shape, members, cc, cache,
                                         stats):
            decisions.append(d)
            pool.offer(d)
    decisions.sort(key=_rank_key)
    return decisions


def _family_beam(ranked: List, width: int, is_pp) -> List:
    """The beam slice when pipelined roles share the space with
    sequential ones: the global top slice widened by the pipelined
    presence, UNION each family's own top ``width``.  The per-family
    guarantees mean neither family can crowd the other out of its slots
    no matter how the mixed ranking falls (a pipeline ranks on different
    knobs — its M, not its remat — so a low stage rank says little about
    either family's expanded best).  The widened global slice is extra
    exploration on exactly the meshes where pipelining enlarged the
    space: it admits entries past the calibrated width even when their
    *family* rank exceeds it — measured to matter when one role's
    microbatch variants flood the stage-2 ranking and the true winner
    (e.g. dp-pure, which only wins after its stage-3 grad-dtype
    expansion) sits just past both cuts.  With no pp entries this IS
    ``ranked[:width]``: every pre-pipeline search is bit-identical."""
    pp = [e for e in ranked if is_pp(e)]
    if not pp:
        return ranked[:width]
    seq = [e for e in ranked if not is_pp(e)]
    out = list(ranked[:width + min(len(pp), width)])
    chosen = set(map(id, out))
    for e in pp[:width] + seq[:width]:
        if id(e) not in chosen:
            chosen.add(id(e))
            out.append(e)
    return out


def _beam_search(arch: ArchConfig, shape: ShapeConfig, cc: ClusterConfig,
                 top_k: int, beam_width: int, cache: PlanCostCache,
                 stats: SearchStats,
                 fusion: str = "off") -> List[PlanDecision]:
    """Staged beam search over the sharding decision vector.

    Stage 1 — axis roles, costed with neutral knobs (remat=none, fp32
    grads, micro=1 — except pipelined roles, whose representative runs at
    the largest valid M: a pipeline at M=1 is all bubble and would be
    unfairly dropped from the beam).  A role whose *most frugal*
    completion (remat=full, max microbatches) still exceeds the HBM budget
    is an infeasible prefix and is dropped without expanding it — unless
    nothing fits, in which case all roles stay so the caller sees the
    honest OOM ranking.

    Stage 2 — remat x microbatch per surviving role.  For a fixed (role,
    micro) the cost model makes recompute strictly slower and strictly
    smaller, so every remat heavier than the lightest feasible one is
    dominated and skipped without costing.

    Stage 3 — grad-reduce dtype, the fusion knob, and collective overlap.
    overlap=False is dominated outright (the model can only discount
    collectives), so only the dtype x fusion grid is expanded.  With the
    default ``fusion="off"`` the grid collapses to the dtype axis and the
    search is bit-identical to the pre-fusion beam.
    """
    remats, micro_opts, gdtypes = _knob_space(shape)
    fus_opts = _fusion_space(fusion)
    budget = cc.hbm_budget

    # ---- stage 1: axis roles --------------------------------------------
    roles = _model_roles(arch, shape, cc)
    stage1: List[Tuple[Dict, PlanDecision]] = []
    kept: List[Tuple[Dict, PlanDecision]] = []
    base_micros: Dict[int, int] = {}     # id(role) -> stage-1 micro used
    for role in roles:
        base_micro = _role_base_micro(role, shape, cc, micro_opts)
        base_micros[id(role)] = base_micro
        d = _cost_candidate(arch, shape,
                            _role_plan(role, cc, remats[0], base_micro,
                                       gdtypes[0]),
                            cc, cache, stats)
        stage1.append((role, d))
        frugal_micro = max((m for m in micro_opts
                            if _micro_valid(role, shape, cc, m)), default=1)
        frugal = _role_plan(role, cc, remats[-1], frugal_micro, gdtypes[0])
        if estimate_hbm(arch, shape, frugal, cc) <= budget:
            kept.append((role, d))
        else:
            stats.pruned_infeasible += 1
    if not kept:           # nothing can fit: keep every prefix, rank honestly
        kept = stage1
    kept.sort(key=lambda rd: _rank_key(rd[1]))
    # Pipelined roles are a new family riding alongside the sequential
    # ones — the beam takes the top beam_width of EACH family (in rank
    # order), so neither can crowd the other out of its slots.  With no
    # pp roles in the space this is exactly kept[:beam_width]: every
    # pre-pipeline search is bit-identical.
    beam1 = _family_beam(kept, beam_width, lambda rd: bool(rd[0].get("pp")))

    # ---- stage 2: remat x microbatches ----------------------------------
    stage2: List[PlanDecision] = []
    oom_pairs: List[Tuple[Dict, int]] = []   # (role, micro) with no fit
    for role, base_d in beam1:
        for micro in micro_opts:
            if not _micro_valid(role, shape, cc, micro):
                continue
            picked = None
            for remat in remats:    # lightest-first: first fit dominates rest
                if picked is not None:
                    stats.pruned_dominated += 1
                    continue
                p = _role_plan(role, cc, remat, micro, gdtypes[0])
                if estimate_hbm(arch, shape, p, cc) > budget:
                    stats.pruned_infeasible += 1
                    continue
                if remat == remats[0] and micro == base_micros[id(role)]:
                    picked = base_d          # already costed in stage 1
                else:
                    picked = _cost_candidate(arch, shape, p, cc, cache, stats)
            if picked is not None:
                stage2.append(picked)
            else:
                oom_pairs.append((role, micro))
    if not any(d.feasible for d in stage2):
        # Nothing fits: rank the infeasible space honestly.  Among plans
        # that all OOM, the fastest has the lightest remat, so one
        # representative per (role, micro) reproduces the exhaustive order.
        for role, micro in oom_pairs:
            p = _role_plan(role, cc, remats[0], micro, gdtypes[0])
            if micro == base_micros[id(role)]:
                d = next(d for r, d in beam1 if r is role)
            else:
                d = _cost_candidate(arch, shape, p, cc, cache, stats)
            stage2.append(d)
    stage2.sort(key=_rank_key)
    beam2 = _family_beam(stage2, beam_width, lambda d: bool(d.plan.pp_axes))

    # ---- stage 3: grad dtype x fusion (+ overlap, dominated) ------------
    final: List[PlanDecision] = []
    for d in beam2:
        final.append(d)
        for gd, fus in itertools.product(gdtypes, fus_opts):
            if gd == d.plan.grad_reduce_dtype and fus == d.plan.fusion:
                continue
            p = dataclasses.replace(d.plan, grad_reduce_dtype=gd, fusion=fus)
            final.append(_cost_candidate(arch, shape, p, cc, cache, stats))
        # overlap=False is dominated outright (the model can only discount
        # collectives) and is not part of the enumerated space — not
        # expanded, and not counted against it either.
    final.sort(key=_rank_key)
    return final[:top_k]
