"""The cost estimator (paper §3): ``C(P, cc) = T-hat(P)``.

Single recursive pass over the runtime plan in execution order:

  * maintains the live-variable symbol table (sizes + memory state), so IO
    is paid exactly once by the first consumer (§3.2);
  * per-instruction time = latency + IO + compute, with compute =
    max(memory-bandwidth time, FLOP-model time) (§3.3);
  * aggregates over control flow with Eq (1): blocks sum children, loops
    scale by N-hat (first-iteration IO correction applied), parfor divides
    by parallelism, branches take a weighted sum, software-pipelined
    microbatch loops (:class:`repro.core.plan.PipelinedLoopBlock`) pay
    fill/drain plus ``(M-1) * max_stage`` steady state, function-call
    stacks prevent recursion cycles;
  * linearizes everything into one scalar, estimated execution time (R2).

Costs are *per-program-run* wall-clock seconds given a cluster config.

Sub-plan memoization (beyond the paper, in its spirit — §2 argues costing
must be cheap enough to sit inside enumerating optimizers): pass a
:class:`PlanCostCache` to :func:`estimate` and repeated sub-plans — the
per-layer ``ForBlock`` body, shared program prefixes, identical candidates'
common blocks — are costed once and replayed afterwards.  Cache keys are
(structural node signature, symbol-table read-set fingerprint, cluster
fingerprint), so a hit is *exact*: same cost, same symbol-table effects,
same peak-HBM excursion, same work totals.

Alongside the time breakdown, the same walk accumulates
:class:`ProgramTotals` — the charged per-device MXU FLOPs (by dtype), VPU
FLOPs, HBM bytes, and collective wire volume by link class (ICI vs DCN) —
aggregated with exactly the Eq (1) weights the costs use.  Consumers that
need the *work* a program does (the resource optimizer's sound cluster
floors, roofline reports) read it off the costed result instead of
re-walking the plan with hand-mirrored semantics; see
``docs/COST_MODEL.md``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import os
import pickle
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import linalg_ops
from repro.core.cluster import ClusterConfig
from repro.core.linalg_ops import (collective_phases, collective_wire,
                                   p2p_cost, p2p_wire)
from repro.core.npvec import (as_payload, dim_int, fmt, is_vec, lane,
                              lane_count, pmax, uniform_bool)
from repro.core.plan import (
    Block, Call, Collective, Compute, CpVar, CreateVar, DataGen, ForBlock,
    FunctionBlock, GenericBlock, IfBlock, Instruction, IO, JitCall, P2P,
    ParForBlock, PipelinedLoopBlock, Program, RmVar, WhileBlock,
    node_signature,
)
from repro.core.symbols import MemState, SymbolTable, TensorStat

TINY = 4.7e-9            # bookkeeping-instruction cost (paper Fig. 4 shows 4.7E-9s)
VPU_FRACTION = 0.10      # VPU throughput as a fraction of fp32 MXU peak


class ProgramTotals:
    """Charged work totals of one (sub-)walk — the estimator-native
    counterpart of :class:`CostBreakdown`.

    Where the breakdown holds *time*, the totals hold the quantities the
    time was computed from, aggregated with the same control-flow weights:

      * ``mxu_flops``   — per-device MXU FLOPs by input dtype (after the
                          shard division each Compute was charged with),
      * ``vpu_flops``   — per-device VPU FLOPs,
      * ``hbm_bytes``   — per-device HBM bytes on the compute roofline
                          (op reads+writes and datagen materialization;
                          first-use staging IO is *not* included — it is an
                          IO-term cost, not roofline work),
      * ``ici_bytes`` / ``dcn_bytes`` — collective wire volume per device
                          by link class, *before* the overlap discount.

    Instances are immutable by convention (``__add__``/``scaled`` return
    new objects; :data:`ZERO_TOTALS` is shared), which is what lets
    :class:`PlanCostCache` replay a cached sub-walk's totals bit-exact.
    """

    __slots__ = ("mxu_flops", "vpu_flops", "hbm_bytes", "ici_bytes",
                 "dcn_bytes")

    def __init__(self, mxu_flops: Optional[Dict[str, float]] = None,
                 vpu_flops: float = 0.0, hbm_bytes: float = 0.0,
                 ici_bytes: float = 0.0, dcn_bytes: float = 0.0):
        self.mxu_flops = mxu_flops if mxu_flops is not None else {}
        self.vpu_flops = vpu_flops
        self.hbm_bytes = hbm_bytes
        self.ici_bytes = ici_bytes
        self.dcn_bytes = dcn_bytes

    @property
    def collective_bytes(self) -> float:
        """Total collective wire volume per device (ICI + DCN)."""
        return self.ici_bytes + self.dcn_bytes

    def __add__(self, o: "ProgramTotals") -> "ProgramTotals":
        if self is ZERO_TOTALS:
            return o
        if o is ZERO_TOTALS:
            return self
        mxu = dict(self.mxu_flops)
        for dt, f in o.mxu_flops.items():
            mxu[dt] = mxu.get(dt, 0.0) + f
        return ProgramTotals(mxu, self.vpu_flops + o.vpu_flops,
                             self.hbm_bytes + o.hbm_bytes,
                             self.ici_bytes + o.ici_bytes,
                             self.dcn_bytes + o.dcn_bytes)

    def scaled(self, w: float) -> "ProgramTotals":
        if self is ZERO_TOTALS or (not is_vec(w) and w == 1.0):
            return self
        return ProgramTotals({dt: f * w for dt, f in self.mxu_flops.items()},
                             self.vpu_flops * w, self.hbm_bytes * w,
                             self.ici_bytes * w, self.dcn_bytes * w)

    def as_tuple(self) -> Tuple:
        """Hashable snapshot (sorted dtype pairs) for tests/fingerprints."""
        return (tuple(sorted(self.mxu_flops.items())), self.vpu_flops,
                self.hbm_bytes, self.ici_bytes, self.dcn_bytes)

    def __eq__(self, o) -> bool:
        return isinstance(o, ProgramTotals) and self.as_tuple() == o.as_tuple()

    def __hash__(self) -> int:
        return hash(self.as_tuple())

    def __repr__(self) -> str:
        mxu = sum(self.mxu_flops.values())
        return (f"ProgramTotals(mxu={mxu:.4g}F, vpu={self.vpu_flops:.4g}F, "
                f"hbm={self.hbm_bytes:.4g}B, ici={self.ici_bytes:.4g}B, "
                f"dcn={self.dcn_bytes:.4g}B)")


ZERO_TOTALS = ProgramTotals()


@dataclasses.dataclass
class CostBreakdown:
    """The linearized cost factors (R2): IO, compute, collectives, latency."""

    io: float = 0.0
    compute: float = 0.0
    collective: float = 0.0
    latency: float = 0.0

    @property
    def total(self) -> float:
        return self.io + self.compute + self.collective + self.latency

    def __add__(self, o: "CostBreakdown") -> "CostBreakdown":
        return CostBreakdown(self.io + o.io, self.compute + o.compute,
                             self.collective + o.collective, self.latency + o.latency)

    def scaled(self, w: float) -> "CostBreakdown":
        return CostBreakdown(self.io * w, self.compute * w,
                             self.collective * w, self.latency * w)


@dataclasses.dataclass
class CostedNode:
    """One plan node with its (aggregated) cost — feeds EXPLAIN output.

    ``totals`` carries the subtree's :class:`ProgramTotals`, aggregated with
    the same weights as ``cost`` (loops scale, branches weight, blocks sum),
    so a cached replay of the node reproduces both bit-exact.
    """

    label: str
    cost: CostBreakdown
    children: List["CostedNode"] = dataclasses.field(default_factory=list)
    note: str = ""
    totals: ProgramTotals = ZERO_TOTALS


@dataclasses.dataclass
class CostedProgram:
    """The result of :func:`estimate`: the annotated cost tree, the
    linearized scalar (R2), its four-way breakdown, the peak per-device
    HBM excursion, and the program's charged work totals."""

    root: CostedNode
    total: float
    breakdown: CostBreakdown
    peak_hbm_per_device: float
    totals: ProgramTotals = ZERO_TOTALS

    def __repr__(self) -> str:
        return (f"CostedProgram(total={self.total:.4g}s, io={self.breakdown.io:.4g}, "
                f"compute={self.breakdown.compute:.4g}, coll={self.breakdown.collective:.4g}, "
                f"lat={self.breakdown.latency:.4g}, peak_hbm={self.peak_hbm_per_device/1e9:.3g}GB)")


@dataclasses.dataclass(frozen=True)
class CacheStats:
    hits: int
    misses: int
    entries: int
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def __add__(self, other: "CacheStats") -> "CacheStats":
        """Aggregate lookup traffic across caches (driver + workers).

        hits/misses/evictions sum exactly; ``entries`` sums the reporting
        caches' sizes, which double-counts entries present in several
        worker caches — treat the aggregate's ``entries`` as an upper
        bound on the merged cache's size, or read the merged cache's own
        :meth:`PlanCostCache.stats` for the true count.
        """
        return CacheStats(self.hits + other.hits,
                          self.misses + other.misses,
                          self.entries + other.entries,
                          self.evictions + other.evictions)


class _CacheEntry:
    __slots__ = ("reads", "net", "hbm_delta", "max_rel_hbm", "node",
                 "seq", "ref")

    def __init__(self, reads, net, hbm_delta, max_rel_hbm, node):
        self.reads = reads           # name -> stat sig at first read (or None)
        self.net = net               # name -> final stat (None == removed)
        self.hbm_delta = hbm_delta   # net live-HBM change of the walk
        self.max_rel_hbm = max_rel_hbm
        self.node = node             # the CostedNode produced by the walk
        self.seq = 0                 # insertion tick (delta export watermark)
        self.ref = False             # clock-hand reference bit

    def __getstate__(self):
        # ``ref`` is replacement-policy state, not payload: a freshly
        # loaded entry starts cold.  ``seq`` is reassigned on insert.
        #
        # The wire form is deliberately lean: a parallel driver pays
        # deserialization *serially* for every worker delta, so entry
        # decode cost is on the speedup-critical path.  Two transforms:
        #
        #   * the node's subtree is elided — replay applies the recorded
        #     read/write deltas and the root's cost/totals, never the
        #     children, so costs stay bit-exact; only EXPLAIN depth of
        #     walks replayed from a snapshot shrinks (the root's note
        #     says so);
        #   * payload objects travel as primitive tuples (a TensorStat
        #     as its ``sig``, node cost/totals as field tuples) instead
        #     of pickled class instances — rebuilding from tuples in
        #     ``__setstate__`` is ~2x faster than generic object
        #     unpickling.
        node = self.node
        note = node.note
        if node.children:
            note = ((note + " " if note else "")
                    + "[subtree elided in snapshot]")
        t = node.totals
        tot = (None if t is ZERO_TOTALS else
               (t.mxu_flops, t.vpu_flops, t.hbm_bytes, t.ici_bytes,
                t.dcn_bytes))
        c = node.cost
        return (self.reads,
                {k: (None if v is None else v.sig)
                 for k, v in self.net.items()},
                self.hbm_delta, self.max_rel_hbm,
                (node.label, (c.io, c.compute, c.collective, c.latency),
                 note, tot))

    def __setstate__(self, state):
        reads, net_enc, hbm_delta, max_rel_hbm, node_enc = state
        net = {}
        for k, sig in net_enc.items():
            if sig is None:
                net[k] = None
            else:
                shape, dtype, sparsity, mem, shards = sig
                net[k] = TensorStat(shape, dtype, sparsity,
                                    MemState(mem), shards)
        label, (io, comp, coll, lat), note, tot = node_enc
        totals = (ZERO_TOTALS if tot is None else
                  ProgramTotals(tot[0], tot[1], tot[2], tot[3], tot[4]))
        node = CostedNode(label, CostBreakdown(io, comp, coll, lat), [],
                          note, totals)
        self.__init__(reads, net, hbm_delta, max_rel_hbm, node)

    def payload_sig(self):
        """Everything a hit replays, in comparable form.  Two entries
        under the same (key, read-set) must agree on this — the merge
        debug assert checks it."""
        net = tuple(sorted((k, None if v is None else v.sig)
                           for k, v in self.net.items()))
        cost = self.node.cost
        return (net, self.hbm_delta, self.max_rel_hbm,
                (cost.io, cost.compute, cost.collective, cost.latency))


#: On-disk container version — bump when CacheDelta's layout changes.
CACHE_FORMAT = 1

_COST_MODEL_FP: Optional[str] = None


def cost_model_fingerprint() -> str:
    """Version fingerprint of the *pricing semantics*: a hash over the
    source of every module whose code determines what a cached entry
    replays (cost formulas, op profiles, symbol-table effects, plan node
    signatures, cluster fingerprints, calibration application).  Persisted
    caches carry it, and :meth:`PlanCostCache.load_from` silently drops a
    snapshot whose fingerprint differs — a stale cache self-invalidates
    instead of replaying old economics.  Planner/search modules are
    deliberately excluded: program structure is already in the key.
    """
    global _COST_MODEL_FP
    if _COST_MODEL_FP is None:
        from repro.core import calibration as _m_cal
        from repro.core import cluster as _m_cluster
        from repro.core import linalg_ops as _m_lo
        from repro.core import npvec as _m_npvec
        from repro.core import plan as _m_plan
        from repro.core import symbols as _m_sym
        h = hashlib.sha256()
        for path in sorted(m.__file__ for m in
                           (_m_cal, _m_cluster, _m_lo, _m_npvec, _m_plan,
                            _m_sym)) + [__file__]:
            with open(path, "rb") as f:
                h.update(f.read())

        _COST_MODEL_FP = h.hexdigest()[:16]
    return _COST_MODEL_FP


@dataclasses.dataclass
class CacheDelta:
    """A portable slice of a :class:`PlanCostCache`: the serialized form
    both of a worker's freshly-recorded entries (:meth:`export_delta`) and
    of a full persisted snapshot (:meth:`save`).  ``stats`` carries the
    producing cache's lookup traffic so drivers can aggregate honest
    per-worker numbers via :meth:`CacheStats.__add__`."""

    fingerprint: str
    buckets: Dict[Tuple, List[_CacheEntry]]
    stats: CacheStats
    format: int = CACHE_FORMAT

    @property
    def entries(self) -> int:
        return sum(len(b) for b in self.buckets.values())


class PlanCostCache:
    """Sub-plan cost memoization, shared across :func:`estimate` calls.

    Maps (node signature, cluster/functions fingerprint, call stack) to a
    small list of entries, each guarded by the symbol-table read-set
    fingerprint its walk observed (the same block is typically seen in a
    handful of states: cold first iteration, warm iterations, ...).  One
    cache serves any number of programs and cluster configs — keys embed
    both — which is what lets a plan-enumerating optimizer or a scenario
    sweep share work across candidates.

    Because every input to a walk is embedded in (key, read-set), caches
    are *mergeable*: :meth:`export_delta` captures entries recorded since
    the last :meth:`mark`, :meth:`merge` folds a delta in (idempotent and
    order-independent — a collision can only carry an identical payload),
    and :meth:`save`/:meth:`load` persist snapshots across processes and
    runs, versioned by :func:`cost_model_fingerprint`.

    ``max_entries`` optionally bounds the cache with cheap clock-hand
    (second-chance) eviction; a bounded cache stays bit-exact — eviction
    only costs extra misses.
    """

    def __init__(self, max_entries: Optional[int] = None) -> None:
        if max_entries is not None and max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self._buckets: Dict[Tuple, List[_CacheEntry]] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.max_entries = max_entries
        self._n = 0          # live entry count (kept incrementally)
        self._seq = 0        # monotone insertion tick
        self._mark_seq = 0   # export_delta watermark
        self._hand: List[Tuple] = []   # clock hand: pending bucket keys

    @property
    def entries(self) -> int:
        return self._n

    def stats(self) -> CacheStats:
        return CacheStats(self.hits, self.misses, self._n, self.evictions)

    def clear(self) -> None:
        self._buckets.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._n = 0
        self._seq = 0
        self._mark_seq = 0
        self._hand = []

    # ------------------------------------------------- insertion/eviction
    def _insert(self, key: Tuple, entry: _CacheEntry) -> None:
        self._seq += 1
        entry.seq = self._seq
        entry.ref = False
        self._buckets.setdefault(key, []).append(entry)
        self._n += 1
        if self.max_entries is not None:
            while self._n > self.max_entries:
                self._evict_one()

    def _evict_one(self) -> None:
        """Clock-hand (second-chance) eviction: cycle bucket keys; a
        bucket whose tail entry was hit since the hand last passed gets
        its reference bit cleared and a second chance, otherwise the tail
        — the bucket's coldest entry, by move-to-front — is dropped."""
        while True:
            if not self._hand:
                self._hand = list(self._buckets.keys())
                self._hand.reverse()   # pop() scans in insertion order
            key = self._hand.pop()
            bucket = self._buckets.get(key)
            if not bucket:
                continue
            victim = bucket[-1]
            if victim.ref:
                victim.ref = False
                continue
            bucket.pop()
            if not bucket:
                del self._buckets[key]
            self._n -= 1
            self.evictions += 1
            return

    # --------------------------------------------------- delta export/merge
    def mark(self) -> None:
        """Set the :meth:`export_delta` watermark: only entries recorded
        *after* this call are exported.  Workers call it right after
        seeding from a snapshot so the delta excludes the seed."""
        self._mark_seq = self._seq

    def export_delta(self, lean: bool = False) -> CacheDelta:
        """Entries recorded since the last :meth:`mark` (or ever, if no
        mark), plus this cache's full lookup-traffic stats.

        ``lean=True`` keeps only *block* entries (walks with children) —
        the form pool workers ship back to a parallel driver.  Walks
        replay top-down, so an outer block hit absorbs every leaf lookup
        beneath it and a blocks-only delta replays an identical grid with
        a 100% hit rate; leaves are ~80% of a delta's entries but only
        matter on near-misses (a changed read fingerprint), where the
        consumer re-walks the cheap leaves and re-records them locally.
        Deserialization is the *serial* part of a parallel run, so the
        5-6x smaller wire delta is what the speedup gate buys with this.
        """
        buckets: Dict[Tuple, List[_CacheEntry]] = {}
        for key, bucket in self._buckets.items():
            fresh = [e for e in bucket
                     if e.seq > self._mark_seq
                     and (not lean or e.node.children)]
            if fresh:
                buckets[key] = fresh
        return CacheDelta(cost_model_fingerprint(), buckets, self.stats())

    def merge(self, delta: CacheDelta) -> int:
        """Fold a delta's entries in; returns the number actually added.

        Idempotent and order-independent: keys embed the node signature,
        cluster/functions fingerprint and call stack, and each entry is
        guarded by its read-set fingerprint — so when two caches both
        hold an (key, read-set) pair, both recorded the same deterministic
        walk and the payloads are identical (assert-checked in debug);
        the duplicate is simply skipped.
        """
        if delta.fingerprint != cost_model_fingerprint():
            raise ValueError(
                "cache delta was produced by a different cost-model "
                f"version ({delta.fingerprint} != {cost_model_fingerprint()})")
        added = 0
        for key, entries in delta.buckets.items():
            bucket = self._buckets.get(key)
            for e in entries:
                dup = None
                if bucket is not None:
                    for have in bucket:
                        if have.reads == e.reads:
                            dup = have
                            break
                if dup is not None:
                    assert dup.payload_sig() == e.payload_sig(), (
                        "cache merge collision with differing payloads — "
                        "key fingerprints no longer cover every walk input")
                    continue
                # Copy the shell so seq/ref stay local to this cache; the
                # payload objects themselves are immutable-by-convention.
                self._insert(key, _CacheEntry(e.reads, e.net, e.hbm_delta,
                                              e.max_rel_hbm, e.node))
                added += 1
                bucket = self._buckets.get(key)
        return added

    # ------------------------------------------------------- persistence
    def save(self, path: str) -> int:
        """Atomically snapshot every entry to ``path``; returns the entry
        count written.  The snapshot embeds the cost-model fingerprint."""
        delta = CacheDelta(cost_model_fingerprint(),
                           {k: list(b) for k, b in self._buckets.items()},
                           self.stats())
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            pickle.dump(delta, f, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)
        return self._n

    def load_from(self, path: str) -> int:
        """Merge a saved snapshot into this cache; returns entries added.
        Missing, unreadable, wrong-format or stale-fingerprint files all
        load as 0 entries — a stale cache self-invalidates, it never
        raises and never replays old economics."""
        try:
            with open(path, "rb") as f:
                delta = pickle.load(f)
        except Exception:
            return 0
        if not isinstance(delta, CacheDelta) or delta.format != CACHE_FORMAT:
            return 0
        if delta.fingerprint != cost_model_fingerprint():
            return 0
        if self._n == 0 and self.max_entries is None:
            # Fast adopt: freshly unpickled entries are exclusively ours
            # (no other cache aliases their seq/ref), and an empty cache
            # has no duplicates to guard against.
            added = 0
            for key, entries in delta.buckets.items():
                for e in entries:
                    self._seq += 1
                    e.seq = self._seq
                self._buckets[key] = entries
                added += len(entries)
            self._n = added
            return added
        return self.merge(delta)

    @classmethod
    def load(cls, path: str,
             max_entries: Optional[int] = None) -> "PlanCostCache":
        """A fresh cache seeded from ``path`` (empty if missing/stale)."""
        cache = cls(max_entries=max_entries)
        cache.load_from(path)
        return cache


# Node kinds worth memoizing: blocks (arbitrarily large sub-walks) and the
# instructions with non-trivial math (op profiling / collective formulas).
# Meta instructions (createvar & co) are cheaper to execute than to probe.
_CACHEABLE = (GenericBlock, ForBlock, WhileBlock, ParForBlock,
              PipelinedLoopBlock, Compute, Collective, P2P, JitCall)


class CostEstimator:
    """Walks a :class:`Program` and produces a :class:`CostedProgram`."""

    def __init__(self, cc: ClusterConfig, verbose: bool = False,
                 cache: Optional[PlanCostCache] = None,
                 terse_labels: bool = False):
        self.cc = cc
        self.verbose = verbose
        self.cache = cache
        # The batched (lane-vector) walk discards every label below the
        # root when the lanes are split back out, and formatting a lane
        # array into a node label costs more than costing the node —
        # terse_labels swaps describe() for the bare instruction kind.
        self.terse_labels = terse_labels

    # ------------------------------------------------------------------ API
    def estimate(self, program: Program) -> CostedProgram:
        """Walk ``program`` once and return its :class:`CostedProgram`
        (cost tree + scalar + breakdown + peak HBM + work totals)."""
        symtab = SymbolTable()
        for name, stat in program.inputs.items():
            symtab.createvar(name, stat)
        self._peak_hbm = symtab.live_hbm_bytes()
        self._functions = program.functions
        if self.cache is not None:
            self._ctx_fp = (self.cc.fingerprint(),
                            program.functions_signature())
        root = CostedNode(f"PROGRAM {program.name}", CostBreakdown())
        total = CostBreakdown()
        totals = ZERO_TOTALS
        for node in program.blocks:
            cn = self._cost_node(node, symtab, stack=())
            root.children.append(cn)
            total = total + cn.cost
            totals = totals + cn.totals
        root.cost = total
        root.totals = totals
        return CostedProgram(root, total.total, total, self._peak_hbm, totals)

    # ------------------------------------------------------- block walkers
    def _cost_node(self, node: Union[Instruction, Block], symtab: SymbolTable,
                   stack: Tuple[str, ...]) -> CostedNode:
        if self.cache is not None and isinstance(node, _CACHEABLE):
            return self._cost_cached(node, symtab, stack)
        return self._cost_node_direct(node, symtab, stack)

    def _cost_cached(self, node, symtab: SymbolTable,
                     stack: Tuple[str, ...]) -> CostedNode:
        cache = self.cache
        key = (node_signature(node), self._ctx_fp, stack)
        bucket = cache._buckets.get(key)
        if bucket is not None:
            for i, entry in enumerate(bucket):
                if symtab.matches(entry.reads):
                    cache.hits += 1
                    entry.ref = True     # second chance vs the clock hand
                    if i:            # move-to-front: states recur in runs
                        del bucket[i]
                        bucket.insert(0, entry)
                    peak = symtab.replay(entry.reads, entry.net,
                                         entry.hbm_delta, entry.max_rel_hbm)
                    if peak > self._peak_hbm:
                        self._peak_hbm = peak
                    return entry.node
        cache.misses += 1
        rec = symtab.begin_record()
        try:
            cn = self._cost_node_direct(node, symtab, stack)
            net = symtab.net_delta(rec)
            hbm_delta = symtab.live_hbm_bytes() - rec.start_hbm
        finally:
            symtab.end_record(rec)
        if not rec.poisoned:
            cache._insert(key, _CacheEntry(rec.reads, net, hbm_delta,
                                           rec.max_rel_hbm, cn))
        return cn

    def _cost_node_direct(self, node: Union[Instruction, Block],
                          symtab: SymbolTable,
                          stack: Tuple[str, ...]) -> CostedNode:
        if isinstance(node, Instruction):
            return self._cost_instruction(node, symtab, stack)
        if isinstance(node, GenericBlock):
            return self._sum_children(node.label, node.children, symtab, stack)
        if isinstance(node, (ForBlock, WhileBlock)):
            return self._cost_loop(node, symtab, stack)
        if isinstance(node, ParForBlock):
            return self._cost_parfor(node, symtab, stack)
        if isinstance(node, PipelinedLoopBlock):
            return self._cost_pipelined(node, symtab, stack)
        if isinstance(node, IfBlock):
            return self._cost_if(node, symtab, stack)
        if isinstance(node, FunctionBlock):
            return self._sum_children(f"FUNCTION {node.name}", node.body, symtab, stack)
        raise TypeError(f"unknown plan node {type(node)}")

    def _sum_children(self, label: str, children, symtab, stack) -> CostedNode:
        out = CostedNode(label, CostBreakdown())
        agg = CostBreakdown()
        totals = ZERO_TOTALS
        for c in children:
            cn = self._cost_node(c, symtab, stack)
            out.children.append(cn)
            agg = agg + cn.cost
            totals = totals + cn.totals
        out.cost = agg
        out.totals = totals
        return out

    def _cost_loop(self, node, symtab, stack) -> CostedNode:
        """T = N * T_pred + T_first + (N-1) * T_warm.

        The warm pass re-costs the body with the post-first-iteration symbol
        table — the paper's correction for "overestimated read costs in
        loops, where only the first iteration reads persistent inputs".
        """
        n = node.iterations if node.iterations is not None else self.cc.default_loop_iterations
        n = pmax(dim_int(n), 1)
        pred = self._sum_children("predicate", node.predicate, symtab, stack)
        first = self._sum_children("body[first]", node.body, symtab, stack)
        # lane vectors must agree on the warm-branch shape (uniform_bool
        # raises to the batched driver's scalar fallback otherwise)
        if uniform_bool(n > 1):
            warm = self._sum_children("body[warm]", node.body, symtab, stack)
            agg = pred.cost.scaled(n) + first.cost + warm.cost.scaled(n - 1)
            totals = (pred.totals.scaled(n) + first.totals
                      + warm.totals.scaled(n - 1))
        else:
            warm = None
            agg = pred.cost + first.cost
            totals = pred.totals + first.totals
        kind = "FOR" if isinstance(node, ForBlock) else "WHILE"
        label = f"{kind} {node.label} (N={n}{'' if node.iterations is not None else ' est'})"
        children = [pred, first] + ([warm] if warm else [])
        return CostedNode(label, agg, children, totals=totals)

    def _cost_parfor(self, node: ParForBlock, symtab, stack) -> CostedNode:
        n = node.iterations if node.iterations is not None else self.cc.default_loop_iterations
        k = max(int(node.parallelism), 1)
        w = math.ceil(max(int(n), 1) / k)
        first = self._sum_children("body[first]", node.body, symtab, stack)
        if w > 1:
            warm = self._sum_children("body[warm]", node.body, symtab, stack)
            agg = first.cost + warm.cost.scaled(w - 1)
            totals = first.totals + warm.totals.scaled(w - 1)
            children = [first, warm]
        else:
            agg = first.cost
            totals = first.totals
            children = [first]
        return CostedNode(f"PARFOR {node.label} (N={n}, k={k}, w={w})", agg,
                          children, totals=totals)

    def _cost_pipelined(self, node: PipelinedLoopBlock, symtab,
                        stack) -> CostedNode:
        """GPipe-style schedule: T = fill/drain + steady state.

        The cold pass (microbatch 1 rippling through every stage, paying
        first-use IO) sums the stages; every further microbatch hides
        behind the slowest *warm* stage:

            T = sum_s T_s[first] + (M - 1) * max_s T_s[warm]

        Work totals take the sequential weights — every microbatch still
        executes every stage — so ``totals = sum_s first_s +
        (M-1) * sum_s warm_s``: pipelining overlaps time, it never deletes
        work (this is what keeps the resource optimizer's floors honest).
        At S=1 both formulas reduce bit-exactly to the sequential loop's
        ``T_first + (N-1) * T_warm``.
        """
        m = pmax(dim_int(node.microbatches), 1)
        s = len(node.stages)
        if not s:      # no stages: an empty loop body, nothing to charge
            return CostedNode(f"PIPELINE {node.label} (S=0, M={m})",
                              CostBreakdown())
        firsts = [self._sum_children(f"stage[{i}][first]", body, symtab,
                                     stack)
                  for i, body in enumerate(node.stages)]
        fill = CostBreakdown()
        totals = ZERO_TOTALS
        for fn in firsts:
            fill = fill + fn.cost
            totals = totals + fn.totals
        children: List[CostedNode] = list(firsts)
        note = ""
        if uniform_bool(m > 1):
            warms = [self._sum_children(f"stage[{i}][warm]", body, symtab,
                                        stack)
                     for i, body in enumerate(node.stages)]
            children.extend(warms)
            crit, crit_cost = self._critical_stage(warms)
            warm_totals = ZERO_TOTALS
            for wn in warms:
                warm_totals = warm_totals + wn.totals
            agg = fill + crit_cost.scaled(m - 1)
            totals = totals + warm_totals.scaled(m - 1)
            note = (f"critical stage={fmt(crit)} "
                    f"bubble~(S-1)/M={fmt((s - 1) / m, '.3f')}")
        else:
            agg = fill
        label = f"PIPELINE {node.label} (S={s}, M={m})"
        return CostedNode(label, agg, children, note=note, totals=totals)

    @staticmethod
    def _critical_stage(warms: List[CostedNode]):
        """The slowest warm stage: ``argmax`` over stage totals, first max
        on ties (the builtin-max tie rule the scalar walk has always used;
        ``np.argmax`` matches it, asserted by the property suite).

        With lane-vector stage costs the critical stage is selected *per
        lane* and every :class:`CostBreakdown` field gathered along the
        winning stage, so one batched walk reproduces each lane's scalar
        pipeline time bit-exact even when lanes disagree on which stage
        dominates."""
        tots = [w.cost.total for w in warms]
        try:
            crit = max(range(len(warms)), key=lambda i: tots[i])
            return crit, warms[crit].cost
        except ValueError:   # truth-value ambiguity: lane vectors
            k = lane_count(*tots)
            stacked = np.stack(
                [np.broadcast_to(np.asarray(t, dtype=np.float64), (k,))
                 for t in tots])
            crit_lanes = np.argmax(stacked, axis=0)     # first max per lane

            def gather(field: str):
                vals = np.stack(
                    [np.broadcast_to(
                        np.asarray(getattr(w.cost, field), dtype=np.float64),
                        (k,)) for w in warms])
                return np.take_along_axis(vals, crit_lanes[None, :], axis=0)[0]

            cost = CostBreakdown(gather("io"), gather("compute"),
                                 gather("collective"), gather("latency"))
            return crit_lanes, cost

    def _cost_if(self, node: IfBlock, symtab, stack) -> CostedNode:
        pred = self._sum_children("predicate", node.predicate, symtab, stack)
        nb = max(len(node.branches), 1)
        weights = list(node.weights) if node.weights else [1.0 / nb] * nb
        branch_nodes, branch_tabs = [], []
        base = symtab.snapshot()
        agg = pred.cost
        totals = pred.totals
        for i, br in enumerate(node.branches):
            symtab.restore(base)
            bn = self._sum_children(f"branch[{i}] w={weights[i]:.2f}", br, symtab, stack)
            branch_nodes.append(bn)
            branch_tabs.append(symtab.snapshot())
            agg = agg + bn.cost.scaled(weights[i])
            totals = totals + bn.totals.scaled(weights[i])
        # pessimistic merge: a var is HBM-resident only if resident in every
        # branch that defines it; otherwise keep the colder state.
        merged = branch_tabs[0] if branch_tabs else base
        for tab in branch_tabs[1:]:
            for name, st in list(merged.items()):
                other = tab.get(name)
                if other is None:
                    del merged[name]
                elif other.state != st.state:
                    colder = st if st.state != MemState.HBM else other
                    merged[name] = dataclasses.replace(st, state=colder.state)
        symtab.restore(merged)
        return CostedNode(f"IF {node.label}", agg, [pred] + branch_nodes,
                          totals=totals)

    # ------------------------------------------------------- instructions
    def _cost_instruction(self, inst: Instruction, symtab: SymbolTable,
                          stack: Tuple[str, ...]) -> CostedNode:
        cc = self.cc
        if isinstance(inst, CreateVar):
            symtab.createvar(inst.name, dataclasses.replace(inst.stat))
            return self._leaf(inst, CostBreakdown(latency=TINY), symtab)
        if isinstance(inst, CpVar):
            symtab.cpvar(inst.src, inst.dst)
            return self._leaf(inst, CostBreakdown(latency=TINY), symtab)
        if isinstance(inst, RmVar):
            symtab.rmvar(*inst.names)
            return self._leaf(inst, CostBreakdown(latency=TINY), symtab)
        if isinstance(inst, DataGen):
            stat = dataclasses.replace(inst.stat, state=MemState.HBM)
            symtab.createvar(inst.output, stat)
            bytes_gen = stat.bytes_per_device()
            t = bytes_gen / cc.hbm_bw_eff
            return self._leaf(inst, CostBreakdown(compute=t), symtab,
                              totals=ProgramTotals(hbm_bytes=bytes_gen))
        if isinstance(inst, Compute):
            return self._cost_compute(inst, symtab)
        if isinstance(inst, IO):
            return self._cost_io(inst, symtab)
        if isinstance(inst, Collective):
            return self._cost_collective(inst, symtab)
        if isinstance(inst, P2P):
            return self._cost_p2p(inst, symtab)
        if isinstance(inst, JitCall):
            return self._cost_jitcall(inst, symtab)
        if isinstance(inst, Call):
            return self._cost_call(inst, symtab, stack)
        raise TypeError(f"unknown instruction {type(inst)}")

    def _leaf(self, inst: Instruction, cost: CostBreakdown,
              symtab: SymbolTable, note: str = "",
              totals: ProgramTotals = ZERO_TOTALS) -> CostedNode:
        self._peak_hbm = pmax(self._peak_hbm, symtab.live_hbm_bytes())
        label = (inst.__class__.__name__ if self.terse_labels
                 else inst.describe())
        return CostedNode(label, cost, note=note, totals=totals)

    # -- first-use IO (the "pays the read" rule) --------------------------
    def _stage_in(self, name: str, symtab: SymbolTable) -> float:
        st = symtab.get(name)
        if st is None or st.state == MemState.HBM:
            return 0.0
        t = 0.0
        per_dev = st.bytes_serialized() / pmax(1, st.shards)
        if st.state == MemState.DISK:
            t += per_dev / self.cc.chip.disk_bw
            t += per_dev / self.cc.chip.pcie_bw
        elif st.state == MemState.HOST:
            t += per_dev / self.cc.chip.pcie_bw
        symtab.touch_hbm(name)
        return t

    def _cost_compute(self, inst: Compute, symtab: SymbolTable) -> CostedNode:
        cc = self.cc
        io_t = sum(self._stage_in(n, symtab) for n in inst.inputs)
        stats = []
        for n in inst.inputs:
            st = symtab.get(n)
            if st is None:
                raise KeyError(f"compute '{inst.opcode}' reads undefined var '{n}'")
            stats.append(st)
        prof = linalg_ops.profile(inst.opcode, stats, **inst.attrs)

        n_shards = 1
        for ax in inst.shard_axes:
            n_shards *= cc.axis_size(ax)
        if inst.exec_type == "CP":
            n_shards = 1

        flops = prof.flops / n_shards
        bytes_moved = prof.bytes / n_shards
        dtype = stats[0].dtype if stats else "bfloat16"
        if prof.util == "mxu":
            util = cc.mxu_util(dtype, prof.flops)
            peak = cc.chip.peak(dtype) * util
        else:
            peak = cc.chip.peak("float32") * VPU_FRACTION
        t_flops = flops / peak
        t_mem = bytes_moved / cc.hbm_bw_eff
        compute_t = pmax(t_flops, t_mem)

        out_stat = dataclasses.replace(prof.out, shards=n_shards, state=MemState.HBM)
        symtab.createvar(inst.output, out_stat)
        note = ""
        if self.verbose:
            note = (f"flops={prof.flops:.3g}/shard{n_shards} "
                    f"t_flops={t_flops:.3g} t_mem={t_mem:.3g}")
        if prof.util == "mxu":
            totals = ProgramTotals(mxu_flops={dtype: flops},
                                   hbm_bytes=bytes_moved)
        else:
            totals = ProgramTotals(vpu_flops=flops, hbm_bytes=bytes_moved)
        return self._leaf(inst, CostBreakdown(io=io_t, compute=compute_t,
                                              latency=TINY), symtab, note,
                          totals=totals)

    def _cost_io(self, inst: IO, symtab: SymbolTable) -> CostedNode:
        st = symtab.get(inst.var)
        if st is None:
            raise KeyError(f"io on undefined var '{inst.var}'")
        per_dev = (st.bytes_serialized() if inst.serialized else st.bytes_in_memory())
        # not //=: per_dev may be an int64 lane vector, and in-place true
        # division cannot widen it to float64
        per_dev = per_dev / pmax(1, st.shards)
        t = 0.0
        legs = _path_legs(inst.src, inst.dst)
        for leg in legs:
            bw = {"disk": self.cc.chip.disk_bw, "pcie": self.cc.chip.pcie_bw,
                  "dram": self.cc.chip.host_dram_bw}[leg]
            t += per_dev / bw
        symtab.set_state(inst.var, inst.dst)
        return self._leaf(inst, CostBreakdown(io=t), symtab)

    def _cost_collective(self, inst: Collective, symtab: SymbolTable) -> CostedNode:
        cc = self.cc
        st = symtab.get(inst.var)
        if inst.bytes_override is not None:
            payload = as_payload(inst.bytes_override)
        elif st is not None:
            payload = st.bytes_per_device()
        else:
            raise KeyError(f"collective on undefined var '{inst.var}'")
        t = 0.0
        wire = {"ici": 0.0, "dcn": 0.0}
        t_fab = {"ici": 0.0, "dcn": 0.0}
        phases = collective_phases(inst.kind, payload,
                                   [cc.axis_size(ax) for ax in inst.axes])
        for ax, (w, hops) in zip(inst.axes, phases):
            # axis_bandwidth folds in the torus link count (2 per axis on a
            # 3D-torus mesh, 1 on the calibrated flat model)
            dt = w / cc.axis_bandwidth(ax) + hops * cc.collective_phase_latency
            t += dt
            cls = cc.link_class(ax)
            t_fab[cls] += dt
            wire[cls] += w
        o_ici, o_dcn = cc.overlap("ici"), cc.overlap("dcn")
        if o_ici == o_dcn:
            # one discount (always the uncalibrated case): keep the exact
            # pre-calibration accumulation order, bit-identical
            t *= (1.0 - o_ici)
        else:
            # calibrated per-fabric overlap: discount each fabric's share
            t = t_fab["ici"] * (1.0 - o_ici) + t_fab["dcn"] * (1.0 - o_dcn)
        if inst.output and st is not None:
            symtab.createvar(inst.output, dataclasses.replace(st))
        return self._leaf(inst, CostBreakdown(collective=t), symtab,
                          totals=ProgramTotals(ici_bytes=wire["ici"],
                                               dcn_bytes=wire["dcn"]))

    def _cost_p2p(self, inst: P2P, symtab: SymbolTable) -> CostedNode:
        """One stage-boundary send/recv: priced at the *single-link* p2p
        rate of the axis fabric (``cc.p2p_bw``), never at the torus-doubled
        ``axis_bandwidth`` a ring collective earns.  Size-1 axes are
        no-ops; wire volume lands in the same ICI/DCN totals the floors
        read, and the overlap discount applies exactly as for collectives
        (a pipeline hides its sends under the adjacent stage's compute)."""
        cc = self.cc
        st = symtab.get(inst.var)
        if inst.bytes_override is not None:
            payload = as_payload(inst.bytes_override)
        elif st is not None:
            payload = st.bytes_per_device()
        else:
            raise KeyError(f"p2p on undefined var '{inst.var}'")
        n = cc.axis_size(inst.axis)
        wire, _ = p2p_wire(payload, n)
        cls = cc.link_class(inst.axis)
        t = p2p_cost(payload, n, cc.p2p_bw(inst.axis),
                     cc.collective_phase_latency) * (1.0 - cc.overlap(cls))
        return self._leaf(inst, CostBreakdown(collective=t), symtab,
                          totals=ProgramTotals(
                              ici_bytes=wire if cls == "ici" else 0.0,
                              dcn_bytes=wire if cls == "dcn" else 0.0))

    def _cost_jitcall(self, inst: JitCall, symtab: SymbolTable) -> CostedNode:
        io_t = sum(self._stage_in(n, symtab) for n in inst.reads)
        cost_rec = inst.compiled_cost
        bd = cost_rec.time_breakdown(self.cc)
        for w in inst.writes:
            if w in symtab:
                symtab.touch_hbm(w)
        # Compiled HLO does not name mesh axes: collectives are attributed
        # to a fabric by group size (CollectiveStat.attribute_axis), and a
        # collective that demonstrably crossed the DCN pod axis takes the
        # DCN overlap discount; everything else rides ICI.
        cc = self.cc
        t_fab = {"ici": 0.0, "dcn": 0.0}
        wire = {"ici": 0.0, "dcn": 0.0}
        for c in getattr(cost_rec, "collectives", ()):
            ax = c.attribute_axis(cc)
            cls = cc.link_class(ax) if ax is not None else "ici"
            t_fab[cls] += c.time(cc, axis=ax)
            wire[cls] += collective_wire(c.kind, c.operand_bytes,
                                         c.group_size)[0]
        coll_t = (t_fab["ici"] * (1.0 - cc.overlap("ici"))
                  + t_fab["dcn"] * (1.0 - cc.overlap("dcn")))
        cost = CostBreakdown(io=io_t + bd.io, compute=bd.compute,
                             collective=coll_t,
                             latency=bd.latency + self.cc.dispatch_latency)
        # Compiled modules report bf16-dominated MXU work.
        totals = ProgramTotals(
            mxu_flops={"bfloat16": getattr(cost_rec, "flops_per_device", 0.0)},
            hbm_bytes=getattr(cost_rec, "bytes_per_device", 0.0),
            ici_bytes=wire["ici"], dcn_bytes=wire["dcn"])
        return self._leaf(inst, cost, symtab, totals=totals,
                          note=f"from compiled HLO: {cost_rec.summary()}")

    def _cost_call(self, inst: Call, symtab: SymbolTable,
                   stack: Tuple[str, ...]) -> CostedNode:
        if inst.func in stack:   # recursion guard (paper §3.2)
            return self._leaf(inst, CostBreakdown(latency=TINY), symtab,
                              note="recursive call — cycle cut")
        fn = self._functions.get(inst.func)
        if fn is None:
            raise KeyError(f"call to undefined function '{inst.func}'")
        node = self._sum_children(f"call {inst.func}", fn.body, symtab,
                                  stack + (inst.func,))
        node.cost = node.cost + CostBreakdown(latency=self.cc.dispatch_latency)
        return node


def _mxu_util(cc: ClusterConfig, flops: float,
              dtype: str = "bfloat16") -> float:
    """Achievable MXU fraction — delegates to ``cc.mxu_util`` (the ramp
    lives on :class:`ClusterConfig` now so calibration profiles can
    replace it per dtype and shape class)."""
    return cc.mxu_util(dtype, flops)


def _path_legs(src: MemState, dst: MemState) -> List[str]:
    order = {MemState.DISK: 0, MemState.HOST: 1, MemState.HBM: 2}
    legs_up = {(0, 1): ["disk"], (1, 2): ["pcie"], (0, 2): ["disk", "pcie"]}
    a, b = order[src], order[dst]
    if a == b:
        return []
    if a < b:
        return legs_up[(a, b)]
    return list(reversed(legs_up[(b, a)]))


def estimate(program: Program, cc: ClusterConfig,
             cache: Optional[PlanCostCache] = None,
             terse_labels: bool = False) -> CostedProgram:
    """``C(P, cc)`` — cost a runtime plan under a cluster config.

    One recursive pass in execution order (no profiling, R1) returning a
    :class:`CostedProgram`: the annotated cost tree (feed it to
    :func:`repro.core.explain.explain` for the paper's Fig 4/5 text form),
    the linearized scalar ``total`` (R2) with its
    io/compute/collective/latency :class:`CostBreakdown`, the peak
    per-device HBM excursion, and the charged :class:`ProgramTotals`.
    Re-cost the same plan under any other ``cc`` freely (R3).

    Pass one shared :class:`PlanCostCache` across calls to memoize
    repeated sub-plans (per-layer loop bodies, shared prefixes, common
    blocks of sibling candidates) — hits replay cost, totals, symbol-table
    effects and peak-HBM bit-exact.
    """
    return CostEstimator(cc, cache=cache,
                         terse_labels=terse_labels).estimate(program)


def split_costed_lanes(cp: CostedProgram, k: int) -> List[CostedProgram]:
    """Split a lane-vector :class:`CostedProgram` — one batched walk over a
    K-member knob grid — into K scalar results.

    Every numeric field (four breakdown terms, five work totals, peak HBM)
    is extracted per lane; fields the walk left scalar broadcast unchanged.
    Extraction is a float64 read, so each returned program carries exactly
    the numbers the scalar walk computes for that knob assignment (the
    property suite asserts this field-by-field).  The returned trees are
    root-only: the batched walk trades the per-node EXPLAIN annotations for
    throughput — cost a single candidate scalar when the tree matters.
    """
    outs: List[CostedProgram] = []
    bd, tt = cp.breakdown, cp.totals
    for j in range(k):
        b = CostBreakdown(lane(bd.io, j), lane(bd.compute, j),
                          lane(bd.collective, j), lane(bd.latency, j))
        t = ProgramTotals({dt: lane(f, j) for dt, f in tt.mxu_flops.items()},
                          lane(tt.vpu_flops, j), lane(tt.hbm_bytes, j),
                          lane(tt.ici_bytes, j), lane(tt.dcn_bytes, j))
        root = CostedNode(cp.root.label, b, totals=t)
        outs.append(CostedProgram(root, b.total, b,
                                  lane(cp.peak_hbm_per_device, j), t))
    return outs
