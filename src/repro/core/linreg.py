"""The paper's running example: closed-form linear regression (LinReg DS).

Reproduces §2's plan generation end-to-end: given a scenario (X: m x n,
y: m x 1) and cluster characteristics, generate the runtime plan the way
SystemML's compiler does —

  * execution-type selection: CP (single device) when memory estimates fit
    the local budget, DIST otherwise (paper: CP vs MR);
  * physical operator selection for X^T X:
      - ``tsmm``        : local transpose-self matmul (CP),
      - ``tsmm+ak+``    : partial Gram per row-block + all-reduce aggregation
                          (paper's map-side tsmm w/ ak+ final aggregation) —
                          requires full rows per device (n <= block size),
      - ``cpmm``        : 2D-sharded matmul w/ reduce-scatter (+extra
                          shuffle) when rows don't fit a block;
  * physical operator selection for X^T y:
      - ``mapmm``       : broadcast the small side (y) and psum — requires y
                          to fit the broadcast (per-device) budget,
      - ``cpmm``        : shard both sides otherwise;
  * the (y^T X)^T rewrite in CP mode (avoids materializing X^T — paper
    applies it in XS but NOT in XL1 where the transpose would not fit);
  * partitioned broadcast of y (paper's `partition` CP instruction).

The generated :class:`Program` is then costed by the ordinary estimator —
producing the paper's Figures 4/5 — and the scenario sweep reproduces the
plan switches of Table 1 / §2.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.core.cluster import ClusterConfig
from repro.core.plan import (Collective, Compute, CreateVar, DataGen,
                             GenericBlock, IfBlock, IO, Program, RmVar)
from repro.core.symbols import MemState, TensorStat


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Paper Table 1 rows."""

    name: str
    m: int                # rows of X
    n: int                # cols of X
    intercept: int = 0
    dtype: str = "float64"   # SystemML matrices are double

    @property
    def x_bytes(self) -> float:
        return self.m * self.n * 8.0

    @property
    def y_bytes(self) -> float:
        return self.m * 8.0


# The paper's five scenarios (Table 1): 80 MB ... 3.2 TB.
SCENARIOS: Dict[str, Scenario] = {
    "XS": Scenario("XS", 10**4, 10**3),
    "XL1": Scenario("XL1", 10**8, 10**3),
    "XL2": Scenario("XL2", 10**8, 2 * 10**3),
    "XL3": Scenario("XL3", 2 * 10**8, 10**3),
    "XL4": Scenario("XL4", 2 * 10**8, 2 * 10**3),
}


@dataclasses.dataclass(frozen=True)
class CompilerBudgets:
    """The constraint structure driving the paper's decisions.

    ``local_mem``     — CP memory budget (paper: 1,434 MB = 70% of 2 GB heap)
    ``broadcast_mem`` — per-task budget for the mapmm broadcast operand
    ``block_size``    — matrix block (tile) width; tsmm's map-side variant
                        "requires to see entire rows of the input matrix"
    """

    local_mem: float = 1434e6
    broadcast_mem: float = 1434e6
    block_size: int = 1000


PAPER_BUDGETS = CompilerBudgets()


def tpu_budgets(cc: ClusterConfig) -> CompilerBudgets:
    """The same decision structure instantiated with TPU constants:
    local budget = one chip's usable HBM; broadcast budget = HBM reserve;
    block size = lane-aligned tile bound for a single-pass row kernel."""
    return CompilerBudgets(local_mem=cc.hbm_budget,
                           broadcast_mem=cc.hbm_budget * 0.25,
                           block_size=8192)


@dataclasses.dataclass
class PlanChoice:
    exec_type: str          # "CP" | "DIST"
    tsmm_op: str            # "tsmm" | "tsmm+ak+" | "cpmm"
    mm_op: str              # "mm" | "mapmm" | "cpmm"
    yt_rewrite: bool        # (y^T X)^T rewrite applied?
    partition_y: bool


def select_operators(sc: Scenario, cc: ClusterConfig,
                     budgets: CompilerBudgets) -> PlanChoice:
    """The paper's §2 decision procedure, verbatim in structure."""
    xb, yb = sc.x_bytes, sc.y_bytes
    # memory estimate of the tsmm/transpose HOPs ~ input + output (+X^T)
    cp_fits = (2 * xb + sc.n * sc.n * 8 + 2 * yb) <= budgets.local_mem
    if cp_fits:
        return PlanChoice("CP", "tsmm", "mm", yt_rewrite=True, partition_y=False)
    # distributed: operator constraints
    tsmm_ok = sc.n <= budgets.block_size          # needs whole rows per pass
    mapmm_ok = yb <= budgets.broadcast_mem        # broadcast operand fits
    return PlanChoice(
        "DIST",
        "tsmm+ak+" if tsmm_ok else "cpmm",
        "mapmm" if mapmm_ok else "cpmm",
        yt_rewrite=False,                          # X^T materialized remotely
        partition_y=mapmm_ok,                      # paper partitions broadcast y
    )


def build_linreg_program(sc: Scenario, cc: ClusterConfig,
                         budgets: CompilerBudgets = PAPER_BUDGETS) -> Tuple[Program, PlanChoice]:
    """Generate the runtime plan for LinReg DS under a scenario + cluster."""
    choice = select_operators(sc, cc, budgets)
    dist = choice.exec_type == "DIST"
    n_dev = cc.num_chips if dist else 1
    shard_axes = tuple(cc.mesh_axes) if dist else ()
    dt = sc.dtype

    prog = Program(name=f"LinregDS-{sc.name}")
    # persistent inputs on "HDFS" (disk)
    prog.inputs["X"] = TensorStat((sc.m, sc.n), dt, state=MemState.DISK,
                                  shards=n_dev)
    prog.inputs["y"] = TensorStat((sc.m, 1), dt, state=MemState.DISK,
                                  shards=n_dev if not choice.partition_y else 1)

    b1 = GenericBlock("lines 1-3 (read inputs, scalars)")
    # createvar/cpvar bookkeeping mirrors Fig. 2
    b1.children.append(CpVarLike("pREADX", "X"))
    b1.children.append(CpVarLike("pREADy", "y"))
    prog.blocks.append(b1)

    # intercept branch (constant-folded away when intercept==0, Fig. 1)
    if sc.intercept == 1:
        br = GenericBlock("lines 4-7 (append intercept column)")
        br.children.append(DataGen("rand", "ones",
                                   TensorStat((sc.m, 1), dt, shards=n_dev)))
        br.children.append(Compute("concat", ("X", "ones"), "X",
                                   exec_type=choice.exec_type,
                                   shard_axes=shard_axes, attrs={"axis": 1}))
        prog.blocks.append(br)

    core = GenericBlock("lines 8-12 (normal equations + solve)")
    A = core.children.append
    # lambda*I via rand+rdiag (the paper's rewritten diag(matrix(lambda,...)))
    A(DataGen("rand", "_mVarI", TensorStat((sc.n, 1), dt)))
    A(Compute("rdiag", ("_mVarI",), "_mVarD", exec_type="CP"))

    if choice.partition_y:
        # CP partition instruction: stage y into block-partitioned form
        A(IO("read", "y", src=MemState.DISK, dst=MemState.HOST))
        A(IO("read", "y", src=MemState.HOST, dst=MemState.HBM))

    # ---- X^T X ----
    if choice.tsmm_op == "tsmm":
        A(Compute("tsmm", ("X",), "_mVarA", exec_type="CP"))
    elif choice.tsmm_op == "tsmm+ak+":
        A(Compute("tsmm", ("X",), "_mVarA", exec_type="DIST",
                  shard_axes=shard_axes))
        A(Collective("all_reduce", "_mVarA", shard_axes))
    else:  # cpmm: 2D sharding, X shuffled, reduce-scatter + gather
        A(Compute("transpose", ("X",), "_mVarXt", exec_type="DIST",
                  shard_axes=shard_axes))
        A(Compute("matmul", ("_mVarXt", "X"), "_mVarA", exec_type="DIST",
                  shard_axes=shard_axes))
        A(Collective("reduce_scatter", "_mVarA", shard_axes))
        A(Collective("all_gather", "_mVarA", shard_axes,
                     bytes_override=sc.n * sc.n * 8 / n_dev))

    # ---- X^T y ----
    if choice.exec_type == "CP":
        if choice.yt_rewrite:   # (y^T X)^T — avoids transposing X (Fig. 2)
            A(Compute("transpose", ("y",), "_mVarYt", exec_type="CP"))
            A(Compute("matmul", ("_mVarYt", "X"), "_mVarBt", exec_type="CP"))
            A(Compute("transpose", ("_mVarBt",), "_mVarB", exec_type="CP"))
        else:
            A(Compute("transpose", ("X",), "_mVarXt", exec_type="CP"))
            A(Compute("matmul", ("_mVarXt", "y"), "_mVarB", exec_type="CP"))
    elif choice.mm_op == "mapmm":
        # broadcast y (already partitioned), transpose X remotely — but
        # piggybacked into the SAME pass as tsmm (shared scan of X): we model
        # the shared scan by the symbol table: X is HBM-resident after tsmm.
        A(Compute("transpose", ("X",), "_mVarXt", exec_type="DIST",
                  shard_axes=shard_axes))
        A(Compute("matmul", ("_mVarXt", "y"), "_mVarB", exec_type="DIST",
                  shard_axes=shard_axes))
        A(Collective("all_reduce", "_mVarB", shard_axes))
    else:  # cpmm for X^T y
        A(Compute("transpose", ("X",), "_mVarXt2", exec_type="DIST",
                  shard_axes=shard_axes))
        A(Compute("matmul", ("_mVarXt2", "y"), "_mVarB", exec_type="DIST",
                  shard_axes=shard_axes))
        A(Collective("reduce_scatter", "_mVarB", shard_axes))
        A(Collective("all_gather", "_mVarB", shard_axes,
                     bytes_override=sc.n * 8 / n_dev))

    # ---- A + lambda*I; solve; write ----
    A(Compute("add", ("_mVarA", "_mVarD"), "_mVarA2", exec_type="CP"))
    A(Compute("solve", ("_mVarA2", "_mVarB"), "beta", exec_type="CP"))
    A(IO("write", "beta", src=MemState.HBM, dst=MemState.DISK))
    A(RmVar(("_mVarI", "_mVarD", "_mVarA", "_mVarA2", "_mVarB")))
    prog.blocks.append(core)
    return prog, choice


def CpVarLike(src: str, dst: str):
    # cosmetic alias so EXPLAIN shows the paper's cpvar pREADX X lines
    from repro.core.plan import CpVar
    return CpVar(src, dst)
