"""The paper's contribution: costing generated runtime execution plans.

Public API (see ``docs/ARCHITECTURE.md`` for the paper-section -> module
map and ``docs/COST_MODEL.md`` for the formulas):

  * plan IR            — :mod:`repro.core.plan`
  * symbol table       — :mod:`repro.core.symbols`
  * cost estimator     — :func:`repro.core.costmodel.estimate` (``C(P, cc)``),
                         emitting :class:`~repro.core.costmodel.ProgramTotals`
                         work totals alongside the costed tree
  * compiled-plan cost — :mod:`repro.core.hlo_cost` (cost the generated HLO)
  * EXPLAIN            — :func:`repro.core.explain.explain`
  * plan optimizer     — :func:`repro.core.planner.choose_plan` (staged beam
                         over sharding plans, memoized via
                         :class:`~repro.core.costmodel.PlanCostCache`;
                         ``search="batched"`` costs one lane-vector walk
                         per structure group via
                         :func:`~repro.core.planner.cost_candidates_batched`,
                         and :class:`~repro.core.planner.IncrementalCoster`
                         re-costs single-knob mutations marginally)
  * dominance pool     — :class:`repro.core.dominance.DominancePool`
                         (anytime-search pruning by sound lower bounds)
  * resource optimizer — :func:`repro.core.resource.optimize_resources`
                         (cluster x plan co-search under step-time / $-per-
                         step / $-per-job / SLO objectives)
  * typed workloads    — :mod:`repro.core.workload`
                         (:class:`~repro.core.workload.TrainWorkload` /
                         :class:`~repro.core.workload.ServeWorkload` /
                         :class:`~repro.core.workload.Objective`)
  * serving schedules  — :func:`repro.core.serving.optimize_serving`
                         ((pool x slots x plan) co-search under p99-TTFT /
                         tokens-per-$ objectives; disaggregated pools)
  * scenario sweeps    — :class:`repro.core.sweep.SweepEngine`
  * calibration        — :mod:`repro.core.calibration`
                         (:class:`~repro.core.calibration.CalibrationProfile`
                         fitted factors, :func:`~repro.core.calibration
                         .fit_profile` least squares)
  * running example    — :mod:`repro.core.linreg` (paper §2, LinReg DS)
"""
from repro.core.calibration import (CalibrationProfile, CalibrationSample,
                                    FitResult, features_from_totals,
                                    fit_profile, shape_class)
from repro.core.cluster import (ClusterConfig, ChipSpec, CHIPS, TPU_V5E,
                                TPU_V5P, TPU_V6E, CPU_HOST,
                                single_pod_config, multi_pod_config,
                                single_chip_config, cpu_host_config,
                                torus_3d_config, dtype_bytes)
from repro.core.costmodel import (CacheStats, CostBreakdown, CostEstimator,
                                  CostedProgram, PlanCostCache, ProgramTotals,
                                  estimate)
from repro.core.explain import explain
from repro.core.hlo_cost import (CompiledCost, CollectiveStat, from_compiled,
                                 lower_and_cost, parse_collectives)
from repro.core.plan import (Block, Call, Collective, Compute, CpVar,
                             CreateVar, DataGen, ForBlock, FunctionBlock,
                             GenericBlock, IfBlock, Instruction, IO, JitCall,
                             P2P, ParForBlock, PipelinedLoopBlock, Program,
                             RmVar, WhileBlock)
from repro.core.dominance import DominancePool, pareto_dominates
from repro.core.planner import (IncrementalCoster, PlanDecision, SearchStats,
                                ShardingPlan, build_step_program, choose_plan,
                                cost_candidates_batched, enumerate_plans,
                                estimate_hbm, reference_plans,
                                resident_components)
from repro.core.resource import (DEFAULT_STEPS_PER_JOB, ClusterCandidate,
                                 ResourceDecision, ResourceSearchStats,
                                 checkpoint_bytes, checkpoint_restore_seconds,
                                 checkpoint_write_seconds,
                                 cluster_floor_time, enumerate_clusters,
                                 format_decisions, job_dollars, job_seconds,
                                 mesh_candidates, mesh_factorizations_3d,
                                 optimize_resources)
from repro.core.serving import (ServingCandidate, ServingDecision,
                                ServingScheduleCost, cost_serving_schedule,
                                cross_pool_pairs, disaggregate,
                                enumerate_serving_clusters, optimize_serving,
                                serve_cell)
from repro.core.symbols import MemState, SymbolTable, TensorStat
from repro.core.sweep import (SweepCell, SweepEngine, format_table,
                              rank_cells, sweep_rows)
from repro.core.workload import (SERVE_WORKLOADS, LengthDistribution,
                                 Objective, ServeWorkload, TrainWorkload,
                                 as_objective)

__all__ = [
    "CalibrationProfile", "CalibrationSample", "FitResult",
    "features_from_totals", "fit_profile", "shape_class",
    "ClusterConfig", "ChipSpec", "CHIPS", "TPU_V5E", "TPU_V5P", "TPU_V6E",
    "CPU_HOST", "single_pod_config",
    "multi_pod_config", "single_chip_config", "cpu_host_config",
    "torus_3d_config", "dtype_bytes",
    "CacheStats", "CostBreakdown", "CostEstimator", "CostedProgram",
    "PlanCostCache", "ProgramTotals", "estimate", "explain",
    "CompiledCost", "CollectiveStat", "from_compiled", "lower_and_cost",
    "parse_collectives", "Block", "Call", "Collective", "Compute", "CpVar",
    "CreateVar", "DataGen", "ForBlock", "FunctionBlock", "GenericBlock",
    "IfBlock", "Instruction", "IO", "JitCall", "P2P", "ParForBlock",
    "PipelinedLoopBlock", "Program",
    "RmVar", "WhileBlock", "PlanDecision", "SearchStats", "ShardingPlan",
    "build_step_program", "choose_plan", "cost_candidates_batched",
    "enumerate_plans", "estimate_hbm", "reference_plans",
    "resident_components", "IncrementalCoster", "DominancePool",
    "pareto_dominates",
    "DEFAULT_STEPS_PER_JOB", "ClusterCandidate", "ResourceDecision",
    "ResourceSearchStats", "cluster_floor_time", "enumerate_clusters",
    "format_decisions", "job_dollars", "job_seconds",
    "checkpoint_bytes", "checkpoint_restore_seconds",
    "checkpoint_write_seconds",
    "mesh_candidates", "mesh_factorizations_3d", "optimize_resources",
    "MemState", "SymbolTable", "TensorStat",
    "SweepCell", "SweepEngine", "format_table", "rank_cells", "sweep_rows",
    "ServingCandidate", "ServingDecision", "ServingScheduleCost",
    "cost_serving_schedule", "cross_pool_pairs", "disaggregate",
    "enumerate_serving_clusters", "optimize_serving", "serve_cell",
    "SERVE_WORKLOADS", "LengthDistribution", "Objective", "ServeWorkload",
    "TrainWorkload", "as_objective",
]
