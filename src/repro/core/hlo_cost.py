"""Costing the *generated* plan — the paper's headline technique, on XLA.

SystemML costs runtime plans *after* all compilation phases so every
optimizer decision is automatically reflected.  The XLA analogue: lower and
compile the jitted step under a concrete mesh + shardings, then extract

  * FLOPs and HBM bytes from ``compiled.cost_analysis()`` (per-device — the
    compiled module is the SPMD per-device program),
  * per-collective payloads by walking the optimized HLO text (GSPMD has
    already chosen the collectives — exactly like piggybacking had already
    packed the MR jobs in the paper),
  * per-device memory occupancy from ``compiled.memory_analysis()`` (the
    memory-budget check).

The result (:class:`CompiledCost`) is a pure-data artifact: it can be
costed under any :class:`ClusterConfig` (R3), serialized to JSON for the
dry-run record, and embedded into a runtime plan as a ``JitCall``.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.cluster import ClusterConfig
from repro.core.linalg_ops import collective_cost

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_HLO_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    # sub-byte
    "s4": 0.5, "u4": 0.5, "s2": 0.25, "u2": 0.25, "f4e2m1fn": 0.5,
    # fp8 family (incl. the fnuz/b11 variants and the scale dtype)
    "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2": 1, "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    # zero-size control types
    "token": 0,
}

# dtype token: letters+digits with an optional exponent/mantissa suffix
# tail ("fn", "fnuz", "b11fnuz", ...), immediately followed by [dims]
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*(?:e[0-9]+m[0-9]+[a-z0-9]*)?)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(sig: str, unknown: Optional[set] = None) -> float:
    """Sum byte sizes of every dtype[dims] token in a type signature.

    A dtype missing from the table used to be *silently skipped*, which
    undercounted collective payloads and corrupted any calibration profile
    fitted from them.  Unknowns now take a conservative 4-byte estimate
    and are reported through ``unknown`` (a set the caller may pass) so
    downstream consumers — :attr:`CompiledCost.unknown_dtypes` — can
    reject polluted samples instead of fitting garbage.
    """
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(sig):
        nbytes = _HLO_DTYPE_BYTES.get(dtype)
        if nbytes is None:
            nbytes = 4
            if unknown is not None:
                unknown.add(dtype)
        cells = 1
        if dims:
            for d in dims.split(","):
                cells *= int(d)
        total += cells * nbytes
    return total


@dataclasses.dataclass
class CollectiveStat:
    kind: str                  # canonical: all_gather, all_reduce, ...
    operand_bytes: float       # per-device input payload
    result_bytes: float
    group_size: int
    hlo_name: str = ""

    def attribute_axis(self, cc: ClusterConfig) -> Optional[str]:
        """Best-effort mesh-axis attribution of an unnamed collective by
        its replica-group size.  Compiled HLO never names mesh axes, but
        the group size constrains which fabric the payload rode:

        * a group exactly the size of one ICI axis is priced on that axis
          (the most generous one when several match — consistent with the
          best-case default);
        * a group exactly the size of a DCN ("pod") axis crossed DCN;
        * a group spanning MORE chips than all ICI axes combined cannot
          have stayed on the torus — it crossed the pod axis, and pricing
          it at torus-doubled ICI rates flatters every DCN-bound cell;
        * anything else (a multi-axis ICI group) stays unattributed
          (``None`` — callers fall back to best-case ICI).
        """
        g = self.group_size
        if g <= 1:
            return None
        ici_axes = [a for a in cc.mesh_axes if cc.link_class(a) == "ici"]
        dcn_axes = [a for a in cc.mesh_axes if cc.link_class(a) == "dcn"]
        exact_ici = [a for a in ici_axes if cc.axis_size(a) == g]
        if exact_ici:
            return max(exact_ici, key=cc.axis_links)
        exact_dcn = [a for a in dcn_axes if cc.axis_size(a) == g]
        if exact_dcn:
            return exact_dcn[0]
        ici_chips = 1
        for a in ici_axes:
            ici_chips *= cc.axis_size(a)
        if g > ici_chips and dcn_axes:
            return dcn_axes[0]
        return None

    def time(self, cc: ClusterConfig, axis: Optional[str] = None) -> float:
        # Topology-aware rate via the links= form (2 links/axis on a
        # 3D-torus mesh) — the same rate the analytical estimator charges,
        # so JitCall-embedded and native plans stay commensurable on torus
        # meshes.  Unnamed collectives are attributed by group size
        # (attribute_axis); only genuinely ambiguous multi-axis ICI groups
        # keep the best-case ICI assumption at max_ici_links.
        if axis is None:
            axis = self.attribute_axis(cc)
        if axis is not None:
            bw, links = cc.link_bw(axis), cc.axis_links(axis)
        else:
            bw, links = cc.ici_bw_eff, cc.max_ici_links
        return collective_cost(self.kind, self.operand_bytes, self.group_size,
                               bw, cc.collective_phase_latency, links=links)


def parse_collectives(hlo_text: str,
                      unknown_out: Optional[set] = None
                      ) -> List[CollectiveStat]:
    """Extract every collective op's payload from optimized HLO text.

    Operand shapes are not inline in modern HLO dumps, so we first build a
    name -> result-type map over all instruction definitions, then resolve
    each collective's operand list against it.  ``*-done`` ops are skipped
    (their payload was counted at ``*-start``).  Dtypes missing from the
    byte table are counted at a conservative 4 bytes and collected into
    ``unknown_out`` (when given) so callers can flag polluted payloads.
    """
    shapes: Dict[str, str] = {}
    coll_lines: List[Tuple[str, str, str, str]] = []  # (name, sig, opcode, line)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, sig, opcode = m.groups()
        shapes[name] = sig
        base = opcode
        for c in COLLECTIVE_OPS:
            if opcode == c or opcode == c + "-start":
                coll_lines.append((name, sig, c, line))
                break

    out: List[CollectiveStat] = []
    for name, sig, kind, line in coll_lines:
        # operands: %names inside the first (...) after the opcode
        try:
            args_str = line.split(kind, 1)[1]
            args_str = args_str[args_str.index("("): args_str.index(")") + 1]
        except (ValueError, IndexError):
            args_str = ""
        operand_bytes = 0.0
        for op_name in _OPERAND_RE.findall(args_str):
            operand_bytes += _shape_bytes(shapes.get(op_name, ""),
                                          unknown=unknown_out)
        result_bytes = _shape_bytes(sig, unknown=unknown_out)
        if operand_bytes == 0.0:
            # parameter-less forms: fall back to result size
            operand_bytes = result_bytes
        gm = _IOTA_GROUPS_RE.search(line)
        if gm:
            group_size = int(gm.group(2))
        else:
            ge = _EXPLICIT_GROUPS_RE.search(line)
            group_size = len(ge.group(1).split(",")) if ge else 1
        out.append(CollectiveStat(kind.replace("-", "_"), operand_bytes,
                                  result_bytes, group_size, name))
    return out


@dataclasses.dataclass
class CompiledCost:
    """Pure-data cost record of one compiled executable (per-device view)."""

    name: str
    flops_per_device: float
    bytes_per_device: float          # HBM bytes accessed
    collectives: List[CollectiveStat]
    num_devices: int
    # memory_analysis (per device, bytes)
    argument_bytes: float = 0.0
    output_bytes: float = 0.0
    temp_bytes: float = 0.0
    peak_memory_bytes: float = 0.0
    dispatch_count: int = 1          # jit calls represented (for latency)
    # dtype tokens the HLO walk could not size (counted at a conservative
    # 4 bytes each) — non-empty means collective payloads are estimates,
    # and calibration fitting must reject this record as polluted.
    unknown_dtypes: List[str] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------- derive
    @property
    def total_flops(self) -> float:
        return self.flops_per_device * self.num_devices

    @property
    def collective_bytes(self) -> float:
        return sum(c.operand_bytes for c in self.collectives)

    def collective_bytes_by_kind(self) -> Dict[str, float]:
        agg: Dict[str, float] = {}
        for c in self.collectives:
            agg[c.kind] = agg.get(c.kind, 0.0) + c.operand_bytes
        return agg

    def fits(self, cc: ClusterConfig) -> bool:
        used = self.peak_memory_bytes or (self.argument_bytes + self.output_bytes
                                          + self.temp_bytes)
        return used <= cc.hbm_budget

    # The three roofline terms (assignment §Roofline) -------------------
    def roofline(self, cc: ClusterConfig, dtype: str = "bfloat16") -> Dict[str, Any]:
        compute_s = self.flops_per_device / cc.chip.peak(dtype)
        memory_s = self.bytes_per_device / cc.chip.hbm_bw
        collective_s = sum(
            collective_cost(c.kind, c.operand_bytes, c.group_size,
                            cc.chip.ici_bw_per_link, cc.collective_phase_latency)
            for c in self.collectives)
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": collective_s}
        dominant = max(terms, key=terms.get)
        bound = max(terms.values())
        total = sum(terms.values())
        return {
            **terms,
            "dominant": dominant,
            "roofline_bound_s": bound,
            "roofline_fraction": bound / total if total > 0 else 1.0,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes,
        }

    def time_breakdown(self, cc: ClusterConfig):
        """Estimated wall time of one call under ``cc`` (for JitCall)."""
        from repro.core.costmodel import CostBreakdown  # local: avoid cycle
        r = self.roofline(cc)
        # achievable (not peak) rates for the time estimate; compiled
        # modules report bf16-dominated MXU work, and cc.mxu_util routes
        # through the shape-class ramp / fitted calibration profile
        compute = max(self.flops_per_device
                      / (cc.chip.peak("bfloat16")
                         * cc.mxu_util("bfloat16", self.flops_per_device)),
                      self.bytes_per_device / cc.hbm_bw_eff)
        # Compiled HLO does not name mesh axes; CollectiveStat.time
        # attributes each collective to a fabric by replica-group size
        # (exact ICI-axis matches ride that axis's torus-aware rate, a
        # group spanning more chips than the whole torus is priced at DCN
        # rates, ambiguous multi-axis ICI groups keep the best-case ICI
        # assumption) — a single-axis 2D/3D ICI mesh prices exactly as
        # the analytical estimator would.
        collective = sum(c.time(cc) for c in self.collectives)
        return CostBreakdown(io=0.0, compute=compute, collective=collective,
                             latency=cc.dispatch_latency * self.dispatch_count)

    def summary(self) -> str:
        return (f"{self.flops_per_device:.3g} flops/dev, "
                f"{self.bytes_per_device:.3g} B/dev, "
                f"{self.collective_bytes:.3g} coll B/dev x{len(self.collectives)}")

    # --------------------------------------------------------------- (de)ser
    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return d

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "CompiledCost":
        d = dict(d)
        d["collectives"] = [CollectiveStat(**c) for c in d.get("collectives", [])]
        return CompiledCost(**d)


def from_compiled(name: str, compiled, num_devices: int,
                  dispatch_count: int = 1) -> CompiledCost:
    """Build a :class:`CompiledCost` from a ``jax`` compiled executable."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    unknown: set = set()
    colls = parse_collectives(text, unknown_out=unknown)
    return CompiledCost(
        name=name,
        flops_per_device=flops,
        bytes_per_device=byts,
        collectives=colls,
        num_devices=num_devices,
        argument_bytes=float(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=float(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=float(getattr(ma, "temp_size_in_bytes", 0)),
        peak_memory_bytes=float(getattr(ma, "peak_memory_in_bytes", 0) or 0),
        dispatch_count=dispatch_count,
        unknown_dtypes=sorted(unknown),
    )


def lower_and_cost(name: str, fn, args_specs: Sequence[Any], mesh,
                   in_shardings=None, out_shardings=None,
                   donate_argnums: Tuple[int, ...] = (),
                   static_argnums: Tuple[int, ...] = ()) -> Tuple[Any, CompiledCost]:
    """lower+compile ``fn`` on ``mesh`` and cost the generated plan."""
    import jax

    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    jitted = jax.jit(fn, donate_argnums=donate_argnums,
                     static_argnums=static_argnums, **kw)
    with mesh:
        lowered = jitted.lower(*args_specs)
        compiled = lowered.compile()
    return compiled, from_compiled(name, compiled, mesh.devices.size)
