"""Costing the *generated* plan — the paper's headline technique, on XLA.

SystemML costs runtime plans *after* all compilation phases so every
optimizer decision is automatically reflected.  The XLA analogue: lower and
compile the jitted step under a concrete mesh + shardings, then extract

  * FLOPs and HBM bytes from ``compiled.cost_analysis()`` (per-device — the
    compiled module is the SPMD per-device program),
  * per-collective payloads by walking the optimized HLO text (GSPMD has
    already chosen the collectives — exactly like piggybacking had already
    packed the MR jobs in the paper),
  * per-device memory occupancy from ``compiled.memory_analysis()`` (the
    memory-budget check).

The result (:class:`CompiledCost`) is a pure-data artifact: it can be
costed under any :class:`ClusterConfig` (R3), serialized to JSON for the
dry-run record, and embedded into a runtime plan as a ``JitCall``.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.cluster import ClusterConfig
from repro.core.linalg_ops import collective_cost

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_HLO_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(sig: str) -> float:
    """Sum byte sizes of every dtype[dims] token in a type signature."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(sig):
        nbytes = _HLO_DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        cells = 1
        if dims:
            for d in dims.split(","):
                cells *= int(d)
        total += cells * nbytes
    return total


@dataclasses.dataclass
class CollectiveStat:
    kind: str                  # canonical: all_gather, all_reduce, ...
    operand_bytes: float       # per-device input payload
    result_bytes: float
    group_size: int
    hlo_name: str = ""

    def time(self, cc: ClusterConfig, axis: Optional[str] = None) -> float:
        # Topology-aware rate via the links= form (2 links/axis on a
        # 3D-torus mesh) — the same rate the analytical estimator charges,
        # so JitCall-embedded and native plans stay commensurable on torus
        # meshes.  Unattributed collectives (compiled HLO does not name
        # mesh axes) assume ICI at the mesh's best per-axis link count.
        if axis is not None:
            bw, links = cc.link_bw(axis), cc.axis_links(axis)
        else:
            bw, links = cc.ici_bw_eff, cc.max_ici_links
        return collective_cost(self.kind, self.operand_bytes, self.group_size,
                               bw, cc.collective_phase_latency, links=links)


def parse_collectives(hlo_text: str) -> List[CollectiveStat]:
    """Extract every collective op's payload from optimized HLO text.

    Operand shapes are not inline in modern HLO dumps, so we first build a
    name -> result-type map over all instruction definitions, then resolve
    each collective's operand list against it.  ``*-done`` ops are skipped
    (their payload was counted at ``*-start``).
    """
    shapes: Dict[str, str] = {}
    coll_lines: List[Tuple[str, str, str, str]] = []  # (name, sig, opcode, line)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, sig, opcode = m.groups()
        shapes[name] = sig
        base = opcode
        for c in COLLECTIVE_OPS:
            if opcode == c or opcode == c + "-start":
                coll_lines.append((name, sig, c, line))
                break

    out: List[CollectiveStat] = []
    for name, sig, kind, line in coll_lines:
        # operands: %names inside the first (...) after the opcode
        try:
            args_str = line.split(kind, 1)[1]
            args_str = args_str[args_str.index("("): args_str.index(")") + 1]
        except (ValueError, IndexError):
            args_str = ""
        operand_bytes = 0.0
        for op_name in _OPERAND_RE.findall(args_str):
            operand_bytes += _shape_bytes(shapes.get(op_name, ""))
        result_bytes = _shape_bytes(sig)
        if operand_bytes == 0.0:
            # parameter-less forms: fall back to result size
            operand_bytes = result_bytes
        gm = _IOTA_GROUPS_RE.search(line)
        if gm:
            group_size = int(gm.group(2))
        else:
            ge = _EXPLICIT_GROUPS_RE.search(line)
            group_size = len(ge.group(1).split(",")) if ge else 1
        out.append(CollectiveStat(kind.replace("-", "_"), operand_bytes,
                                  result_bytes, group_size, name))
    return out


@dataclasses.dataclass
class CompiledCost:
    """Pure-data cost record of one compiled executable (per-device view)."""

    name: str
    flops_per_device: float
    bytes_per_device: float          # HBM bytes accessed
    collectives: List[CollectiveStat]
    num_devices: int
    # memory_analysis (per device, bytes)
    argument_bytes: float = 0.0
    output_bytes: float = 0.0
    temp_bytes: float = 0.0
    peak_memory_bytes: float = 0.0
    dispatch_count: int = 1          # jit calls represented (for latency)

    # ------------------------------------------------------------- derive
    @property
    def total_flops(self) -> float:
        return self.flops_per_device * self.num_devices

    @property
    def collective_bytes(self) -> float:
        return sum(c.operand_bytes for c in self.collectives)

    def collective_bytes_by_kind(self) -> Dict[str, float]:
        agg: Dict[str, float] = {}
        for c in self.collectives:
            agg[c.kind] = agg.get(c.kind, 0.0) + c.operand_bytes
        return agg

    def fits(self, cc: ClusterConfig) -> bool:
        used = self.peak_memory_bytes or (self.argument_bytes + self.output_bytes
                                          + self.temp_bytes)
        return used <= cc.hbm_budget

    # The three roofline terms (assignment §Roofline) -------------------
    def roofline(self, cc: ClusterConfig, dtype: str = "bfloat16") -> Dict[str, Any]:
        compute_s = self.flops_per_device / cc.chip.peak(dtype)
        memory_s = self.bytes_per_device / cc.chip.hbm_bw
        collective_s = sum(
            collective_cost(c.kind, c.operand_bytes, c.group_size,
                            cc.chip.ici_bw_per_link, cc.collective_phase_latency)
            for c in self.collectives)
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": collective_s}
        dominant = max(terms, key=terms.get)
        bound = max(terms.values())
        total = sum(terms.values())
        return {
            **terms,
            "dominant": dominant,
            "roofline_bound_s": bound,
            "roofline_fraction": bound / total if total > 0 else 1.0,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes,
        }

    def time_breakdown(self, cc: ClusterConfig):
        """Estimated wall time of one call under ``cc`` (for JitCall)."""
        from repro.core.costmodel import CostBreakdown  # local: avoid cycle
        r = self.roofline(cc)
        # achievable (not peak) rates for the time estimate
        compute = max(self.flops_per_device / (cc.chip.peak("bfloat16") * cc.matmul_util),
                      self.bytes_per_device / cc.hbm_bw_eff)
        # compiled HLO does not name mesh axes, so collectives ride ICI at
        # the mesh's best per-axis link count — the same torus-aware rate
        # the analytical estimator charges, keeping JitCall-embedded plans
        # commensurable with native ones on 3D meshes (on 2D meshes
        # max_ici_links == 1 and this is exactly the old rate)
        collective = sum(
            collective_cost(c.kind, c.operand_bytes, c.group_size,
                            cc.ici_bw_eff, cc.collective_phase_latency,
                            links=cc.max_ici_links)
            for c in self.collectives)
        return CostBreakdown(io=0.0, compute=compute, collective=collective,
                             latency=cc.dispatch_latency * self.dispatch_count)

    def summary(self) -> str:
        return (f"{self.flops_per_device:.3g} flops/dev, "
                f"{self.bytes_per_device:.3g} B/dev, "
                f"{self.collective_bytes:.3g} coll B/dev x{len(self.collectives)}")

    # --------------------------------------------------------------- (de)ser
    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return d

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "CompiledCost":
        d = dict(d)
        d["collectives"] = [CollectiveStat(**c) for c in d.get("collectives", [])]
        return CompiledCost(**d)


def from_compiled(name: str, compiled, num_devices: int,
                  dispatch_count: int = 1) -> CompiledCost:
    """Build a :class:`CompiledCost` from a ``jax`` compiled executable."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    colls = parse_collectives(text)
    return CompiledCost(
        name=name,
        flops_per_device=flops,
        bytes_per_device=byts,
        collectives=colls,
        num_devices=num_devices,
        argument_bytes=float(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=float(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=float(getattr(ma, "temp_size_in_bytes", 0)),
        peak_memory_bytes=float(getattr(ma, "peak_memory_in_bytes", 0) or 0),
        dispatch_count=dispatch_count,
    )


def lower_and_cost(name: str, fn, args_specs: Sequence[Any], mesh,
                   in_shardings=None, out_shardings=None,
                   donate_argnums: Tuple[int, ...] = (),
                   static_argnums: Tuple[int, ...] = ()) -> Tuple[Any, CompiledCost]:
    """lower+compile ``fn`` on ``mesh`` and cost the generated plan."""
    import jax

    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    jitted = jax.jit(fn, donate_argnums=donate_argnums,
                     static_argnums=static_argnums, **kw)
    with mesh:
        lowered = jitted.lower(*args_specs)
        compiled = lowered.compile()
    return compiled, from_compiled(name, compiled, mesh.devices.size)
