"""Live-variable tracking (paper §3.2, "Tracking Live Variable States").

A runtime plan is costed in a single pass; while walking it we maintain a
symbol table of live variables: their *size information* (shape, dtype,
sparsity — the paper's m, n, s) and their *memory state* (the paper's
HDFS-vs-in-memory distinction, generalized to the TPU storage hierarchy).

The state machine is the heart of "IO is paid exactly once": persistent
inputs start on DISK/HOST; the first instruction that consumes them pays the
transfer and flips the state to HBM; later consumers read for free (HBM
traffic is part of each op's compute-side roofline, not a separate IO term).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Optional, Tuple

from repro.core.cluster import dtype_bytes
from repro.core.npvec import dim_int, pmax


class MemState(enum.Enum):
    DISK = "disk"      # persistent store (checkpoint / dataset shard)  ~ HDFS
    HOST = "host"      # host DRAM (staged batch, spilled tensor)
    HBM = "hbm"        # device memory — "in-memory" in the paper's sense


@dataclasses.dataclass
class TensorStat:
    """Size information for one matrix/tensor variable.

    ``sparsity`` is the paper's s = nnz/(m*n).  Dense tensors use 1.0.  For
    MoE routed activations we reuse it as the expected expert-load fraction,
    which makes expected-size math identical to the paper's sparse-size math.

    ``shards`` is the number of devices the tensor is partitioned over —
    per-device bytes are total/shards (the paper divides by the degree of
    parallelism at instruction level; we track it on the variable so hybrid
    plans can mix replicated and sharded intermediates).
    """

    shape: Tuple[int, ...]
    dtype: str = "float32"
    sparsity: float = 1.0
    state: MemState = MemState.HBM
    shards: int = 1

    # -- size estimates (paper's M-hat and M-hat') ------------------------
    @property
    def cells(self) -> int:
        c = self.__dict__.get("_cells")
        if c is None:
            # dim_int: a dim may be a knob-grid lane vector (batched walk),
            # in which case the product is one too and the cast is skipped.
            c = dim_int(math.prod(self.shape)) if self.shape else 1
            self.__dict__["_cells"] = c
        return c

    @property
    def nnz(self) -> float:
        return self.cells * self.sparsity

    def bytes_in_memory(self) -> float:
        """M-hat: in-memory size (dense layout on device)."""
        b = self.__dict__.get("_bim")
        if b is None:
            b = self.cells * dtype_bytes(self.dtype)
            self.__dict__["_bim"] = b
        return b

    def bytes_serialized(self) -> float:
        """M-hat': serialized size (sparse-aware, e.g. checkpoint on disk)."""
        if self.sparsity >= 0.4:  # dense format cheaper beyond ~40% like SystemML
            return self.cells * dtype_bytes(self.dtype)
        # CSR-ish: value + column index per nnz + row pointers
        return self.nnz * (dtype_bytes(self.dtype) + 4) + 4 * (self.shape[0] if self.shape else 1)

    def bytes_per_device(self) -> float:
        b = self.__dict__.get("_bpd")
        if b is None:
            b = self.bytes_in_memory() / pmax(1, self.shards)
            self.__dict__["_bpd"] = b
        return b

    def with_state(self, state: MemState) -> "TensorStat":
        return dataclasses.replace(self, state=state)

    @property
    def sig(self) -> Tuple:
        """Hashable identity for cost memoization: any field the cost model
        may consult.  Cached per instance (instances are never mutated in
        place — state changes go through ``with_state``/``replace``)."""
        s = self.__dict__.get("_sig")
        if s is None:
            s = (self.shape, self.dtype, self.sparsity, self.state.value,
                 self.shards)
            self.__dict__["_sig"] = s
        return s


class Recorder:
    """Captures one cacheable sub-walk of the cost estimator (§memoization).

    While active it accumulates (a) the *read set* — external variables the
    walk consulted, fingerprinted by the stat they had at first read; (b) the
    *write set* — names the walk mutated; and (c) the peak live-HBM
    excursion relative to the walk's start.  Because a matching read-set
    fingerprint guarantees an identical walk, the walk's effect can be
    summarized as the NET symbol-table delta (final stat per written name +
    one HBM byte delta) and applied in O(written) on every replay.
    """

    __slots__ = ("reads", "written", "start_hbm", "max_rel_hbm", "poisoned")

    def __init__(self, start_hbm: float) -> None:
        self.reads: Dict[str, Optional[Tuple]] = {}
        self.written: set = set()
        self.start_hbm = start_hbm
        self.max_rel_hbm = 0.0
        self.poisoned = False


class SymbolTable:
    """Name -> TensorStat with the paper's createvar/cpvar/rmvar semantics."""

    def __init__(self) -> None:
        self._vars: Dict[str, TensorStat] = {}
        self._hbm_bytes = 0.0          # incremental live-HBM accumulator
        self._recorders: list = []     # active Recorder stack (innermost last)

    def _acct(self, st: Optional[TensorStat], sign: float) -> None:
        if st is not None and st.state == MemState.HBM:
            self._hbm_bytes += sign * st.bytes_per_device()

    # --- recording (cost-memoization support) ---
    def begin_record(self) -> Recorder:
        rec = Recorder(self._hbm_bytes)
        self._recorders.append(rec)
        return rec

    def end_record(self, rec: Recorder) -> None:
        popped = self._recorders.pop()
        assert popped is rec, "unbalanced begin_record/end_record"
        if rec.poisoned and self._recorders:
            # a poisoned inner walk poisons every enclosing walk too
            self._recorders[-1].poisoned = True

    def net_delta(self, rec: Recorder) -> Dict[str, Optional[TensorStat]]:
        """Summarize a finished recording as name -> final stat (None means
        the walk removed the variable).  Read at end_record time, when the
        table holds the walk's final state."""
        get = self._vars.get
        return {name: get(name) for name in rec.written}

    def _note_read(self, name: str) -> None:
        for rec in self._recorders:
            if name not in rec.written and name not in rec.reads:
                st = self._vars.get(name)
                rec.reads[name] = st.sig if st is not None else None

    def matches(self, reads: Dict[str, Optional[Tuple]]) -> bool:
        """Probe: does the current table state fingerprint-match a recorded
        read set?  Pure query — registers nothing with active recorders."""
        get = self._vars.get
        for name, sig in reads.items():
            st = get(name)
            if st is None:
                if sig is not None:
                    return False
            else:
                ssig = st.__dict__.get("_sig")
                if ssig is None:
                    ssig = st.sig
                if ssig != sig:
                    return False
        return True

    def replay(self, reads: Dict[str, Optional[Tuple]],
               net: Dict[str, Optional[TensorStat]], hbm_delta: float,
               max_rel_hbm: float) -> float:
        """Re-apply a recorded walk's net effect: register its reads and
        writes with any enclosing recorders, overwrite the written names
        with their final stats, bump the live-HBM accumulator by the net
        delta, and return the absolute peak live-HBM the walk reaches."""
        start = self._hbm_bytes
        if self._recorders:
            for name in reads:
                self._note_read(name)
            peak = start + max_rel_hbm
            for rec in self._recorders:
                rec.written.update(net)
                rec.max_rel_hbm = max(rec.max_rel_hbm, peak - rec.start_hbm)
        variables = self._vars
        for name, stat in net.items():
            if stat is None:
                variables.pop(name, None)
            else:
                variables[name] = stat
        self._hbm_bytes = start + hbm_delta
        return start + max_rel_hbm

    # --- instruction analogues ---
    def createvar(self, name: str, stat: TensorStat) -> None:
        if self._recorders:
            # the overwrite delta depends on the old stat (absence included)
            self._note_read(name)
            for rec in self._recorders:
                rec.written.add(name)
        self._acct(self._vars.get(name), -1.0)
        self._vars[name] = stat
        self._acct(stat, +1.0)

    def cpvar(self, src: str, dst: str) -> None:
        if src in self._vars:   # __contains__ registers the read when recording
            self.createvar(dst, dataclasses.replace(self._vars[src]))

    def rmvar(self, *names: str) -> None:
        if self._recorders:
            for n in names:
                self._note_read(n)      # freed bytes depend on the stat
            for rec in self._recorders:
                rec.written.update(names)
        for n in names:
            self._acct(self._vars.get(n), -1.0)
            self._vars.pop(n, None)

    # --- queries/updates used by the cost estimator ---
    def get(self, name: str) -> Optional[TensorStat]:
        if self._recorders:
            self._note_read(name)
        return self._vars.get(name)

    def __contains__(self, name: str) -> bool:
        if self._recorders:
            self._note_read(name)
        return name in self._vars

    def __len__(self) -> int:
        return len(self._vars)

    def names(self):
        return list(self._vars)

    def state_of(self, name: str) -> Optional[MemState]:
        if self._recorders:
            self._note_read(name)
        st = self._vars.get(name)
        return st.state if st else None

    def touch_hbm(self, *names: str) -> None:
        """Mark variables device-resident (consumers after the first read free)."""
        for n in names:
            if self._recorders:
                self._note_read(n)
                for rec in self._recorders:
                    rec.written.add(n)
            st = self._vars.get(n)
            if st is not None and st.state != MemState.HBM:
                self._vars[n] = st.with_state(MemState.HBM)
                self._hbm_bytes += st.bytes_per_device()

    def set_state(self, name: str, state: MemState) -> None:
        if self._recorders:
            self._note_read(name)
            for rec in self._recorders:
                rec.written.add(name)
        st = self._vars.get(name)
        if st is not None:
            self._acct(st, -1.0)
            new = st.with_state(state)
            self._vars[name] = new
            self._acct(new, +1.0)

    def live_hbm_bytes(self, per_device: bool = True) -> float:
        if per_device:
            for rec in self._recorders:
                rec.max_rel_hbm = max(rec.max_rel_hbm,
                                      self._hbm_bytes - rec.start_hbm)
            return self._hbm_bytes
        return sum(st.bytes_in_memory() for st in self._vars.values()
                   if st.state == MemState.HBM)

    def snapshot(self) -> Dict[str, TensorStat]:
        return {k: dataclasses.replace(v) for k, v in self._vars.items()}

    def restore(self, snap: Dict[str, TensorStat]) -> None:
        # Wholesale state replacement cannot be expressed in the replay log,
        # so any walk that restores a snapshot is not cacheable.
        for rec in self._recorders:
            rec.poisoned = True
        self._vars = {k: dataclasses.replace(v) for k, v in snap.items()}
        self._hbm_bytes = sum(st.bytes_per_device()
                              for st in self._vars.values()
                              if st.state == MemState.HBM)

    def copy(self) -> "SymbolTable":
        t = SymbolTable()
        t.restore(self._vars)
        return t
