"""Live-variable tracking (paper §3.2, "Tracking Live Variable States").

A runtime plan is costed in a single pass; while walking it we maintain a
symbol table of live variables: their *size information* (shape, dtype,
sparsity — the paper's m, n, s) and their *memory state* (the paper's
HDFS-vs-in-memory distinction, generalized to the TPU storage hierarchy).

The state machine is the heart of "IO is paid exactly once": persistent
inputs start on DISK/HOST; the first instruction that consumes them pays the
transfer and flips the state to HBM; later consumers read for free (HBM
traffic is part of each op's compute-side roofline, not a separate IO term).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Optional, Tuple

from repro.core.cluster import dtype_bytes


class MemState(enum.Enum):
    DISK = "disk"      # persistent store (checkpoint / dataset shard)  ~ HDFS
    HOST = "host"      # host DRAM (staged batch, spilled tensor)
    HBM = "hbm"        # device memory — "in-memory" in the paper's sense


@dataclasses.dataclass
class TensorStat:
    """Size information for one matrix/tensor variable.

    ``sparsity`` is the paper's s = nnz/(m*n).  Dense tensors use 1.0.  For
    MoE routed activations we reuse it as the expected expert-load fraction,
    which makes expected-size math identical to the paper's sparse-size math.

    ``shards`` is the number of devices the tensor is partitioned over —
    per-device bytes are total/shards (the paper divides by the degree of
    parallelism at instruction level; we track it on the variable so hybrid
    plans can mix replicated and sharded intermediates).
    """

    shape: Tuple[int, ...]
    dtype: str = "float32"
    sparsity: float = 1.0
    state: MemState = MemState.HBM
    shards: int = 1

    # -- size estimates (paper's M-hat and M-hat') ------------------------
    @property
    def cells(self) -> int:
        c = self.__dict__.get("_cells")
        if c is None:
            c = int(math.prod(self.shape)) if self.shape else 1
            self.__dict__["_cells"] = c
        return c

    @property
    def nnz(self) -> float:
        return self.cells * self.sparsity

    def bytes_in_memory(self) -> float:
        """M-hat: in-memory size (dense layout on device)."""
        return self.cells * dtype_bytes(self.dtype)

    def bytes_serialized(self) -> float:
        """M-hat': serialized size (sparse-aware, e.g. checkpoint on disk)."""
        if self.sparsity >= 0.4:  # dense format cheaper beyond ~40% like SystemML
            return self.cells * dtype_bytes(self.dtype)
        # CSR-ish: value + column index per nnz + row pointers
        return self.nnz * (dtype_bytes(self.dtype) + 4) + 4 * (self.shape[0] if self.shape else 1)

    def bytes_per_device(self) -> float:
        return self.bytes_in_memory() / max(1, self.shards)

    def with_state(self, state: MemState) -> "TensorStat":
        return dataclasses.replace(self, state=state)


class SymbolTable:
    """Name -> TensorStat with the paper's createvar/cpvar/rmvar semantics."""

    def __init__(self) -> None:
        self._vars: Dict[str, TensorStat] = {}
        self._hbm_bytes = 0.0          # incremental live-HBM accumulator

    def _acct(self, st: Optional[TensorStat], sign: float) -> None:
        if st is not None and st.state == MemState.HBM:
            self._hbm_bytes += sign * st.bytes_per_device()

    # --- instruction analogues ---
    def createvar(self, name: str, stat: TensorStat) -> None:
        self._acct(self._vars.get(name), -1.0)
        self._vars[name] = stat
        self._acct(stat, +1.0)

    def cpvar(self, src: str, dst: str) -> None:
        if src in self._vars:
            self.createvar(dst, dataclasses.replace(self._vars[src]))

    def rmvar(self, *names: str) -> None:
        for n in names:
            self._acct(self._vars.get(n), -1.0)
            self._vars.pop(n, None)

    # --- queries/updates used by the cost estimator ---
    def get(self, name: str) -> Optional[TensorStat]:
        return self._vars.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._vars

    def __len__(self) -> int:
        return len(self._vars)

    def names(self):
        return list(self._vars)

    def state_of(self, name: str) -> Optional[MemState]:
        st = self._vars.get(name)
        return st.state if st else None

    def touch_hbm(self, *names: str) -> None:
        """Mark variables device-resident (consumers after the first read free)."""
        for n in names:
            st = self._vars.get(n)
            if st is not None and st.state != MemState.HBM:
                self._vars[n] = st.with_state(MemState.HBM)
                self._hbm_bytes += st.bytes_per_device()

    def set_state(self, name: str, state: MemState) -> None:
        st = self._vars.get(name)
        if st is not None:
            self._acct(st, -1.0)
            new = st.with_state(state)
            self._vars[name] = new
            self._acct(new, +1.0)

    def live_hbm_bytes(self, per_device: bool = True) -> float:
        if per_device:
            return self._hbm_bytes
        return sum(st.bytes_in_memory() for st in self._vars.values()
                   if st.state == MemState.HBM)

    def snapshot(self) -> Dict[str, TensorStat]:
        return {k: dataclasses.replace(v) for k, v in self._vars.items()}

    def restore(self, snap: Dict[str, TensorStat]) -> None:
        self._vars = {k: dataclasses.replace(v) for k, v in snap.items()}
        self._hbm_bytes = sum(st.bytes_per_device()
                              for st in self._vars.values()
                              if st.state == MemState.HBM)

    def copy(self) -> "SymbolTable":
        t = SymbolTable()
        t.restore(self._vars)
        return t
