"""Resource optimization: co-search cluster configs and sharding plans.

The paper's cost model exists *for* optimizers — SystemML's resource
optimizer enumerates cluster configurations and re-costs the program under
each.  The TPU analogue enumerates **cluster candidates** (chip type from
the :data:`repro.core.cluster.CHIPS` table, pod count, mesh shape / axis
layout, DCN- vs ICI-linked multi-slice topologies) and, for each, runs the
staged beam :func:`repro.core.planner.choose_plan` through one shared
:class:`repro.core.costmodel.PlanCostCache`, ranking the results under a
pluggable objective:

  * ``step_time``       — fastest feasible step,
  * ``cost`` (alias ``device_seconds``) — cheapest step: step time x chips
    weighted by :attr:`ChipSpec.cost_per_chip_hour` (the $-cost proxy),
  * ``job_cost``        — cheapest **job**: :func:`job_dollars` amortizes
    startup, checkpoint restore and expected-preemption overhead over
    ``steps_per_job`` steps (big cheap-per-step slices get preempted more),
  * ``slo``             — cheapest config whose step time meets an SLO.

Candidate clusters are pruned *soundly* before any plan is costed: a
cluster whose analytic **cost floor** already loses to the incumbent
cannot contain the winner, so the whole (cluster x plan) subtree is
skipped.  The floor (:func:`cluster_floor_time`) is built from the cost
estimator's own work totals (:class:`repro.core.costmodel.ProgramTotals`)
of one minimum-work reference plan per axis-role class — compute/memory
rooflines *plus* the role's unavoidable collective wire volume over
ICI/DCN — so the floor shares the estimator's linearization semantics by
construction, and memory-bound decode cells (whose collectives dominate)
prune as hard as train cells.  Together with the staged beam inside each
cluster and the shared sub-plan cache, the co-search returns the exact
exhaustive-scan winner at a small fraction of the full plan evaluations
(gated by tests and benchmarks).  The soundness argument is spelled out in
``docs/COST_MODEL.md``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.cluster import (CHIPS, DEFAULT_CHECKPOINT_RESTORE_SECONDS,
                                ChipSpec, ClusterConfig)
from repro.core.costmodel import (VPU_FRACTION, CacheStats, PlanCostCache,
                                  ProgramTotals, estimate)
from repro.core.dominance import DominancePool
from repro.core.planner import (MAX_MICROBATCHES, OVERLAP_FRACTION,
                                PlanDecision, SearchStats,
                                build_step_program, choose_plan,
                                enumerate_plans, reference_plans)
from repro.core.workload import (DEFAULT_STEPS_PER_JOB, OBJECTIVE_ALIASES,
                                 SERVING_OBJECTIVES, TRAIN_OBJECTIVES,
                                 Objective, ServeWorkload, TrainWorkload,
                                 as_objective)

OBJECTIVES = TRAIN_OBJECTIVES
# Spellings that canonicalize to a *training* objective kind; serving-only
# kinds are recognized (for the helpful error below) but not accepted here.
_OBJECTIVE_ALIASES = {k: v for k, v in OBJECTIVE_ALIASES.items()
                      if v in TRAIN_OBJECTIVES}

# Purchasable slice granularity per chip generation (chips per pod slice).
POD_CHIPS = {"tpu_v5e": 256, "tpu_v5p": 64, "tpu_v6e": 256}


# ---------------------------------------------------------------------------
# Cluster candidates
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClusterCandidate:
    """One enumerable cluster configuration, with a stable display id."""

    cid: str
    cc: ClusterConfig


def _short(chip: ChipSpec) -> str:
    return chip.name.replace("tpu_", "")


def _make_cc(chip: ChipSpec, mesh_shape: Tuple[int, ...],
             mesh_axes: Tuple[str, ...],
             base: Optional[ClusterConfig] = None,
             torus_links: Tuple[int, ...] = ()) -> ClusterConfig:
    if base is not None:
        return dataclasses.replace(base, chip=chip, mesh_shape=mesh_shape,
                                   mesh_axes=mesh_axes,
                                   torus_links=tuple(torus_links))
    return ClusterConfig(chip=chip, mesh_shape=mesh_shape,
                         mesh_axes=mesh_axes,
                         torus_links=tuple(torus_links))


def torus_links_for(axes: Tuple[str, ...], chip: ChipSpec,
                    mesh_shape: Tuple[int, ...]) -> Tuple[int, ...]:
    """Per-axis ICI link counts for a candidate mesh layout.

    A 3-ICI-axis layout on a chip whose fabric builds a 3D torus earns the
    wrapped-ring rate (2 links) — but only on axes whose extent spans a
    whole number of the chip's building-block cubes
    (``ChipSpec.ici_cube_dim``; v5p slices compose 4x4x4 cubes).  A
    sub-cube extent (e.g. the 2-wide axis of an 8x4x2 slice) has no
    wraparound to close the ring: it is an open line, 1 link.  Everything
    else — 2D layouts, or any layout on a 2D-torus chip — keeps the
    calibrated flat model (empty -> 1 link per axis); so does a slice with
    no full-cube axis at all, making full-cube cells (4x4x4, 12x4x4, ...)
    bit-identical to the pre-fidelity behavior.  The chip gate lives here
    so no caller can accidentally price wrapped rings on hardware without
    a third fabric dimension."""
    ici_axes = sum(1 for a in axes if a != "pod")
    if ici_axes < 3 or chip.ici_torus_dims < 3:
        return ()
    cube = max(int(chip.ici_cube_dim), 1)
    links = tuple(
        1 if (a == "pod" or n < 2 or n % cube) else 2
        for a, n in zip(axes, mesh_shape))
    return links if any(l == 2 for l in links) else ()


def mesh_factorizations_3d(n: int, variants: int = 2
                           ) -> List[Tuple[Tuple[int, ...], Tuple[str, ...]]]:
    """(data, model, depth) splits of an n-chip 3D-torus slice, most
    cube-balanced first.  The model and depth axes are power-of-two sized;
    the data axis takes the remainder ``n / (model * depth)`` (e.g. 192
    splits as (12, 4, 4)).  Ordered ``data >= model >= depth >= 2`` so
    each candidate names a distinct physical layout."""
    out: List[Tuple[Tuple[int, ...], Tuple[str, ...]]] = []
    z = 2
    while z * z * z <= n:
        if n % z == 0:
            m = z
            while m * m * z <= n:
                if (n // z) % m == 0:
                    out.append(((n // (m * z), m, z),
                                ("data", "model", "depth")))
                m *= 2
        z *= 2
    out.sort(key=lambda mz: (mz[0][0] / mz[0][2], mz[0]))
    return out[:variants]


def mesh_factorizations(n: int, variants: int = 2, torus_dims: int = 2
                        ) -> List[Tuple[Tuple[int, ...], Tuple[str, ...]]]:
    """Mesh splits of an n-chip slice: the 2D (data, model) layouts —
    balanced first, then a wide-data / narrow-model variant — plus, when
    the chip's fabric builds a 3D torus (``torus_dims >= 3``), the
    near-cubic (data, model, depth) layouts appended after them.  The 2D
    list is unchanged by the torus dimension, so pre-torus candidate ids
    and costs are stable."""
    if n <= 1:
        return [((1,), ("data",))]
    out: List[Tuple[Tuple[int, ...], Tuple[str, ...]]] = []
    balanced_model = 1
    while balanced_model * balanced_model * 4 <= n:
        balanced_model *= 2
    seen = set()
    for model in (balanced_model, max(balanced_model // 4, min(4, n))):
        if n % model:
            continue
        mesh = (n // model, model) if model > 1 else (n,)
        axes = ("data", "model") if model > 1 else ("data",)
        if mesh not in seen:
            seen.add(mesh)
            out.append((mesh, axes))
        if len(out) >= variants:
            break
    out = out or [((n,), ("data",))]
    if torus_dims >= 3:
        out.extend(mesh_factorizations_3d(n, variants))
    return out


def mesh_candidates(chip: ChipSpec, num_chips: int,
                    base: Optional[ClusterConfig] = None
                    ) -> List[ClusterCandidate]:
    """All single-slice mesh layouts for a fixed chip count (elastic
    re-meshing: the devices that survived, re-factored).

    Never returns an empty list for ``num_chips >= 1``: a chip count with
    no 2D factorization beyond trivial (primes, odd survivor counts)
    still yields the degenerate 1D all-data mesh, so
    :func:`repro.runtime.elastic.replan` always has a candidate to cost
    after device loss.  Chips whose fabric builds a 3D torus
    (``ici_torus_dims >= 3``) also contribute the 3D layouts of the
    survivor count."""
    if num_chips < 1:
        raise ValueError(f"mesh_candidates needs >=1 chip, got {num_chips}")
    out = []
    seen = set()
    for model in (1, 2, 4, 8, 16, 32):
        if num_chips % model or model > num_chips:
            continue
        mesh = (num_chips // model, model) if model > 1 else (num_chips,)
        axes = ("data", "model") if model > 1 else ("data",)
        if mesh in seen:
            continue
        seen.add(mesh)
        out.append(ClusterCandidate(
            f"{_short(chip)}-{'x'.join(map(str, mesh))}",
            _make_cc(chip, mesh, axes, base)))
    if chip.ici_torus_dims >= 3:
        for mesh, axes in mesh_factorizations_3d(num_chips):
            if mesh in seen:
                continue
            seen.add(mesh)
            out.append(ClusterCandidate(
                f"{_short(chip)}-{'x'.join(map(str, mesh))}-3d",
                _make_cc(chip, mesh, axes, base,
                         torus_links=torus_links_for(axes, chip, mesh))))
    if not out:          # unreachable (model=1 always fits) — belt/braces
        out.append(ClusterCandidate(
            f"{_short(chip)}-{num_chips}",
            _make_cc(chip, (num_chips,), ("data",), base)))
    return out


def enumerate_clusters(chips: Optional[Sequence[Union[str, ChipSpec]]] = None,
                       pod_counts: Sequence[int] = (1, 2, 4),
                       mesh_variants: int = 2,
                       base: Optional[ClusterConfig] = None
                       ) -> List[ClusterCandidate]:
    """The default cluster grid: chip type x pod count x mesh layout, with
    both ICI-linked superslices (when the chip's ICI domain allows) and
    DCN-linked multi-pod topologies.  Chips whose fabric builds a 3D torus
    (v5p: ``ici_torus_dims == 3``) contribute the near-cubic 3D layouts of
    each ICI slice alongside the 2D ones — plus, for multi-slice counts, a
    (pod x 3D inner torus) 4-axis family — with per-axis link counts set
    by :func:`torus_links_for` (wrapped rings only on full-cube axes)."""
    chip_specs = [CHIPS[c] if isinstance(c, str) else c
                  for c in (chips if chips is not None else CHIPS)]
    out: List[ClusterCandidate] = []
    for chip in chip_specs:
        pod = POD_CHIPS.get(chip.name, 256)
        for p in pod_counts:
            total = pod * p
            fits_ici = total <= chip.ici_domain
            if fits_ici:
                for mesh, axes in mesh_factorizations(
                        total, mesh_variants,
                        torus_dims=chip.ici_torus_dims):
                    tag = "-3d" if len(mesh) >= 3 else ""
                    out.append(ClusterCandidate(
                        f"{_short(chip)}-{'x'.join(map(str, mesh))}{tag}",
                        _make_cc(chip, mesh, axes, base,
                                 torus_links=torus_links_for(axes, chip,
                                                             mesh))))
            if p > 1:
                # DCN multi-slice: "pod" axis crosses the data-center network
                nv = 1 if fits_ici else mesh_variants
                for mesh, axes in mesh_factorizations(pod, nv):
                    out.append(ClusterCandidate(
                        f"{_short(chip)}-{p}x{'x'.join(map(str, mesh))}-dcn",
                        _make_cc(chip, (p,) + mesh, ("pod",) + axes, base)))
                if chip.ici_torus_dims >= 3:
                    # (pod x 3D inner torus): a 4-axis mesh.  The role
                    # assignment has handled 4 axes since the depth axis
                    # landed; this emits the candidates — and it is where
                    # pipeline-over-DCN meets wrapped-ring slices.
                    for mesh, axes in mesh_factorizations_3d(pod, nv):
                        full_mesh, full_axes = (p,) + mesh, ("pod",) + axes
                        out.append(ClusterCandidate(
                            f"{_short(chip)}-{p}x"
                            f"{'x'.join(map(str, mesh))}-dcn-3d",
                            _make_cc(chip, full_mesh, full_axes, base,
                                     torus_links=torus_links_for(
                                         full_axes, chip, full_mesh))))
    return out


def _as_candidate(c) -> ClusterCandidate:
    if isinstance(c, ClusterCandidate):
        return c
    if isinstance(c, ClusterConfig):
        label = "x".join(str(s) for s in c.mesh_shape)
        return ClusterCandidate(f"{c.chip.name}[{label}]", c)
    if isinstance(c, tuple) and len(c) == 2:
        return ClusterCandidate(str(c[0]), c[1])
    raise TypeError(f"not a cluster candidate: {c!r}")


# ---------------------------------------------------------------------------
# Sound per-cluster cost floors (prune whole clusters without costing plans)
# ---------------------------------------------------------------------------
#
# One minimum-work reference plan per axis-role class is generated and
# costed through the estimator itself; the floor is read off the resulting
# ProgramTotals.  There is no second plan walker to keep in sync (the old
# ``_walk_totals`` hand-mirror and its runtime tripwire are gone): the
# totals come from the same recursive pass that produces the costs, so the
# floor inherits the estimator's semantics by construction.

# Reference walks share one cache: role bodies repeat across geometries.
_FLOOR_CACHE = PlanCostCache()


@functools.lru_cache(maxsize=None)
def _plan_space_size(arch: ArchConfig, shape: ShapeConfig,
                     mesh_shape: Tuple[int, ...],
                     mesh_axes: Tuple[str, ...]) -> int:
    """|enumerate_plans| for the exhaustive-scan statistic.  The space
    depends only on the mesh geometry (roles/knobs never consult the chip),
    so the count is cached instead of re-enumerated per optimize call."""
    cc = ClusterConfig(mesh_shape=mesh_shape, mesh_axes=mesh_axes)
    return len(enumerate_plans(arch, shape, cc))


@functools.lru_cache(maxsize=None)
def _floor_totals(arch: ArchConfig, shape: ShapeConfig,
                  mesh_shape: Tuple[int, ...],
                  mesh_axes: Tuple[str, ...],
                  fusion: str = "off"
                  ) -> Tuple[Tuple[str, ProgramTotals, int], ...]:
    """Estimator-charged work totals of each role's minimum-work reference
    plan (:func:`repro.core.planner.reference_plans`) on a mesh geometry,
    keyed by role name and paired with the role's pipeline-stage count S
    (1 for every non-pipelined role).

    Totals (per-device flops/bytes after sharding, collective wire volume
    per link class) never consult the chip, so one entry serves every chip
    generation with that geometry — the walks amortize across the whole
    candidate grid and across optimize calls."""
    cc = ClusterConfig(mesh_shape=mesh_shape, mesh_axes=mesh_axes)
    return tuple(
        (plan.name,
         estimate(build_step_program(arch, shape, plan, cc), cc,
                  cache=_FLOOR_CACHE).totals,
         plan.degree(cc, plan.pp_axes))
        for plan in reference_plans(arch, shape, cc, fusion=fusion))


def role_floor_times(arch: ArchConfig, shape: ShapeConfig,
                     cc: ClusterConfig,
                     fusion: str = "off") -> Dict[str, float]:
    """Per-role sound lower bounds on ``C(P, cc)``: role name -> a floor
    that every enumerated plan *in that role* must at least pay, knob
    values included (see :func:`cluster_floor_time` for the derivation —
    the cluster floor is exactly the minimum over these values).  The
    plan searcher's dominance pool (``choose_plan(search="batched")``)
    uses the per-role resolution to skip whole structure groups whose
    role floor already loses to a feasible incumbent.

    ``fusion="search"`` makes the floors sound over the fusion-widened
    plan space: :func:`repro.core.planner.reference_plans` then yields a
    second, traffic-minimal ``fusion="full"`` representative per role and
    the per-name ``min`` below keeps whichever bounds lower — "full"
    members are no longer under-bounded by an off-only rep."""
    vpu_peak = cc.chip.peak("float32") * VPU_FRACTION
    ici_bw_best = cc.ici_bw_eff * cc.max_ici_links
    # The wire discount must match the most generous overlap any plan can
    # earn — per fabric, because a calibrated profile may hide more ICI
    # than DCN time (or vice versa).  Overlap-enabled plans are costed
    # under with_overlap(OVERLAP_FRACTION), whose cc.overlap(fabric)
    # resolves the calibrated per-fabric value; uncalibrated both fabrics
    # give exactly OVERLAP_FRACTION and the lumped pre-calibration form is
    # kept bit-identical.
    occ = cc.with_overlap(OVERLAP_FRACTION)
    o_ici, o_dcn = occ.overlap("ici"), occ.overlap("dcn")
    floors: Dict[str, float] = {}
    for name, t, pp_s in _floor_totals(arch, shape, cc.mesh_shape,
                                       cc.mesh_axes, fusion):
        t_flops = sum(f / (cc.chip.peak(dt) * cc.mxu_util_ceiling(dt))
                      for dt, f in t.mxu_flops.items())
        t_flops += t.vpu_flops / vpu_peak
        t_mem = t.hbm_bytes / cc.hbm_bw_eff
        if pp_s > 1:
            cand = (max(t_flops, t_mem) / pp_s
                    * (1.0 + (pp_s - 1) / MAX_MICROBATCHES))
        else:
            if o_ici == o_dcn:
                t_coll = (t.ici_bytes / ici_bw_best
                          + t.dcn_bytes / cc.dcn_bw_eff) * (1.0 - o_ici)
            else:
                t_coll = (t.ici_bytes / ici_bw_best * (1.0 - o_ici)
                          + t.dcn_bytes / cc.dcn_bw_eff * (1.0 - o_dcn))
            cand = max(t_flops, t_mem) + t_coll
        floors[name] = min(floors.get(name, float("inf")), cand)
    return floors


def cluster_floor_time(arch: ArchConfig, shape: ShapeConfig,
                       cc: ClusterConfig) -> float:
    """A sound lower bound on ``C(P, cc)`` over every enumerated plan P.

    For each axis-role class, the estimator charges its reference plan a
    set of per-device totals that every plan in the class must at least
    match (see :func:`repro.core.planner.reference_plans`).  The estimator
    prices those totals as a *sum over instructions* of
    ``max(t_flops, t_mem)`` plus collectives at
    ``(wire/link_bw + hops·latency) · (1 − overlap)`` plus nonnegative
    IO/latency terms; this floor keeps only

      ``max(Σ t_flops, Σ t_mem)
        + wire_ici/ici_bw_best · (1 − o_ici)
        + wire_dcn/dcn_bw_eff · (1 − o_dcn)``

    at the most generous rates (the per-dtype MXU ceiling
    ``cc.mxu_util_ceiling`` for every MXU op, effective link bandwidths at
    the mesh's *best* per-axis link count, no phase latency, the
    per-fabric overlap discount o_ici/o_dcn of an overlap-*enabled* plan),
    each a term-wise lower bound of what the estimator charges.  The
    per-fabric split matters once a :class:`CalibrationProfile` fits
    different overlap for ICI and DCN: lumping both fabrics under one
    discount would over- or under-discount one of them.  Every rate above
    consults ``cc.calibration`` exactly as the estimator does, so the
    floor stays a term-wise bound under ANY profile — and with fitted
    factors ≤ 1 each calibrated rate only drops below its hand-set value,
    never above peak (see docs/COST_MODEL.md §Calibration).
    On a 3D-torus mesh the estimator prices each ICI axis at up to
    ``ici_bw_eff · axis_links`` (wrapped rings expose 2 links), so the
    floor divides the pooled ICI wire volume by ``ici_bw_eff ·
    max_ici_links`` — never charging more for the wire than any actual
    axis assignment could.  2D meshes have ``max_ici_links == 1`` and keep
    the pre-torus floor bit-identical.  The minimum over role classes then
    bounds the whole plan space — including memory-bound decode cells,
    whose unavoidable tensor-parallel collectives now tighten the floor
    instead of being ignored.

    **Pipelined roles** overlap stage times, so their reference totals —
    which sum work over every stage, as the estimator's sequential-weight
    aggregation must — would overstate a pipelined plan's time if priced
    as one roofline.  For a role with S stages the schedule satisfies

        T  =  Σ_s T_s,first + (M-1) · max_s T_s,warm
           >= R/M + (M-1)/M · R/S  =  (R/S) · (1 + (S-1)/M)

    where R is the roofline of the role's (microbatch-invariant) totals:
    a microbatch's stage times sum to at least its roofline R/M, and the
    slowest of S stages is at least 1/S of their sum.  The bound is
    decreasing in M, so evaluating it at the knob ceiling
    ``MAX_MICROBATCHES`` lower-bounds every enumerable M.  The role's
    nonnegative p2p/collective time is dropped (a floor may only err
    low), so the pipeline floor can only *drop* below the sequential
    roofline where pipelining genuinely helps — verified by full plan
    enumeration in tests/test_pipeline.py."""
    return min(role_floor_times(arch, shape, cc).values(),
               default=float("inf"))


# ---------------------------------------------------------------------------
# Job-level pricing ($/job: amortized startup, restore, preemption)
# ---------------------------------------------------------------------------

# Bytes written per parameter into a training checkpoint: fp32 master
# weights + the two fp32 Adam moments.  Analytical constant (R1), like the
# chip table.
CHECKPOINT_BYTES_PER_PARAM = 12.0


def checkpoint_bytes(arch: ArchConfig) -> float:
    """Total checkpoint size (bytes) for one architecture."""
    return arch.param_counts()["total"] * CHECKPOINT_BYTES_PER_PARAM


def _checkpoint_path_seconds(cc: ClusterConfig, arch: ArchConfig) -> float:
    """Seconds to move one checkpoint across the disk <-> PCIe path, each
    host handling its own shard — the shared derivation behind both the
    restore and the write term of job pricing (the path is symmetric)."""
    per_dev = checkpoint_bytes(arch) / max(cc.num_chips, 1)
    return per_dev / cc.chip.disk_bw + per_dev / cc.chip.pcie_bw


def checkpoint_restore_seconds(cc: ClusterConfig,
                               arch: Optional[ArchConfig] = None) -> float:
    """Seconds to read + reshard one checkpoint onto the cluster.

    Derived from the architecture's checkpoint bytes over the disk + PCIe
    path, sharded across the cluster's chips (each host restores its own
    shard) — so job pricing scales with model size instead of charging a
    0.5B model and a 671B model the same constant.  A non-``None``
    ``cc.checkpoint_restore_seconds`` overrides the derivation (backward
    compatibility); with no architecture in hand the old constant is the
    fallback."""
    if cc.checkpoint_restore_seconds is not None:
        return float(cc.checkpoint_restore_seconds)
    if arch is None:
        return DEFAULT_CHECKPOINT_RESTORE_SECONDS
    return _checkpoint_path_seconds(cc, arch)


def checkpoint_write_seconds(cc: ClusterConfig,
                             arch: Optional[ArchConfig] = None) -> float:
    """Seconds the job stalls to write one checkpoint (device -> host ->
    disk, each host writing its own shard).  Symmetric to
    :func:`checkpoint_restore_seconds`'s derivation; with no architecture
    in hand there are no bytes to price, so the stall is 0 (the pre-PR-5
    behavior for anonymous callers)."""
    if arch is None:
        return 0.0
    return _checkpoint_path_seconds(cc, arch)


def job_seconds(cc: ClusterConfig, step_time: float,
                steps_per_job: int = DEFAULT_STEPS_PER_JOB,
                arch: Optional[ArchConfig] = None) -> float:
    """Expected wall-clock seconds to complete ``steps_per_job`` steps.

    The base time is ``startup + compute + checkpoint-write stalls``
    (one :func:`checkpoint_write_seconds` stall every
    ``checkpoint_interval_steps``).  Preemptions arrive at a rate
    proportional to *wall* time — a job inflated by restarts is exposed
    to further preemptions during those restarts — so the expectation is
    the fixpoint ``wall = base + λ·wall·restart`` with
    ``λ = preemption_rate_per_chip_hour · num_chips / 3600`` (per wall
    second) and ``restart = startup + checkpoint restore
    (:func:`checkpoint_restore_seconds`, per-arch bytes over disk/PCIe
    when ``arch`` is given) + half a checkpoint interval of recomputed
    steps``.  The closed form of the geometric restart series is

        wall = base / (1 - λ · restart),

    diverging to ``inf`` when ``λ · restart >= 1`` (each restart breeds
    at least one more preemption — the job never finishes; such configs
    rank after every finite one).

    Strictly increasing in ``step_time`` for a fixed cluster — base and
    restart both grow with it, so the inflation factor does too — which
    is what lets the job-cost objective prune clusters by their step-time
    floor (:func:`cluster_floor_time`) without losing soundness."""
    steps = max(int(steps_per_job), 1)
    compute = step_time * steps
    n_checkpoints = steps // max(int(cc.checkpoint_interval_steps), 1)
    base = (cc.job_startup_seconds + compute
            + n_checkpoints * checkpoint_write_seconds(cc, arch))
    restart = (cc.job_startup_seconds + checkpoint_restore_seconds(cc, arch)
               + 0.5 * cc.checkpoint_interval_steps * step_time)
    lam = cc.preemption_rate_per_chip_hour * cc.num_chips / 3600.0
    denom = 1.0 - lam * restart
    if denom <= 0.0:
        return float("inf")
    return base / denom


def job_dollars(cc: ClusterConfig, step_time: float,
                steps_per_job: int = DEFAULT_STEPS_PER_JOB,
                arch: Optional[ArchConfig] = None) -> float:
    """$ to complete a job: expected wall seconds x chips x $/chip-hour."""
    return (job_seconds(cc, step_time, steps_per_job, arch) * cc.num_chips
            * cc.chip.cost_per_chip_hour / 3600.0)


# ---------------------------------------------------------------------------
# Decisions + ranking
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResourceDecision:
    """One cluster candidate's outcome: its best plan (or why it was pruned)
    plus the objective values the ranking uses."""

    cluster_id: str
    cc: ClusterConfig
    decision: Optional[PlanDecision]        # None when pruned before costing
    floor_time: float
    pruned: str = ""                        # non-empty: skipped, why
    search: Optional[SearchStats] = None
    steps_per_job: int = DEFAULT_STEPS_PER_JOB
    arch: Optional[ArchConfig] = None       # prices per-arch restore time

    @property
    def time(self) -> float:
        return self.decision.time if self.decision else float("inf")

    @property
    def feasible(self) -> bool:
        return bool(self.decision and self.decision.feasible)

    @property
    def device_seconds(self) -> float:
        return self.time * self.cc.num_chips

    @property
    def cost_per_step(self) -> float:
        """$ per step: device-seconds priced at cost_per_chip_hour."""
        return self.device_seconds * self.cc.chip.cost_per_chip_hour / 3600.0

    @property
    def job_seconds(self) -> float:
        """Expected wall seconds for a ``steps_per_job``-step job."""
        return job_seconds(self.cc, self.time, self.steps_per_job, self.arch)

    @property
    def cost_per_job(self) -> float:
        """$ per job, overheads amortized (see :func:`job_dollars`)."""
        return job_dollars(self.cc, self.time, self.steps_per_job, self.arch)

    def meets(self, slo: Optional[float]) -> bool:
        return self.feasible and slo is not None and self.time <= slo

    def describe(self) -> str:
        if self.pruned:
            return f"{self.cluster_id}: pruned ({self.pruned})"
        return (f"{self.cluster_id}: {self.decision.plan.describe()} "
                f"T={self.time * 1e3:.2f}ms ${self.cost_per_step:.4f}/step "
                f"${self.cost_per_job:.2f}/job")


@dataclasses.dataclass
class ResourceSearchStats:
    """Observability for one co-search: how much of the (cluster x plan)
    space was actually evaluated."""

    clusters_total: int = 0
    clusters_costed: int = 0
    clusters_pruned: int = 0
    plan_evals: int = 0                 # full generate+cost evaluations run
    exhaustive_plan_space: int = 0      # sum over clusters of |enumerate_plans|
    cache: Optional[CacheStats] = None
    # per-worker local-cache traffic of the jobs>1 warm phase (unset when
    # the search ran serially); the driver's own traffic is in `cache`
    worker_cache: Optional[List[CacheStats]] = None

    @property
    def evals_ratio(self) -> float:
        """How many times fewer evaluations than the exhaustive scan."""
        return self.exhaustive_plan_space / max(self.plan_evals, 1)

    def describe(self) -> str:
        bits = [f"clusters={self.clusters_costed}/{self.clusters_total}",
                f"evals={self.plan_evals}/{self.exhaustive_plan_space}"
                f"({self.evals_ratio:.1f}x)"]
        if self.cache is not None:
            bits.append(f"cache={self.cache.hits}/"
                        f"{self.cache.hits + self.cache.misses}")
        if self.worker_cache:
            agg = self.worker_cache[0]
            for w in self.worker_cache[1:]:
                agg = agg + w
            bits.append(f"workers={len(self.worker_cache)}"
                        f"({agg.hits}/{agg.hits + agg.misses})")
        return " ".join(bits)


def _canon_objective(objective: str, slo: Optional[float]) -> str:
    key = _OBJECTIVE_ALIASES.get(objective)
    if key is None:
        if OBJECTIVE_ALIASES.get(objective) in SERVING_OBJECTIVES:
            raise ValueError(
                f"objective {objective!r} ranks serving schedules; pass a "
                f"ServeWorkload as the shape (see repro.core.serving)")
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of {sorted(set(_OBJECTIVE_ALIASES))}")
    if key == "slo" and slo is None:
        raise ValueError("objective 'slo' needs a step-time target (slo=...)")
    return key


def _rank_key(objective: str, slo: Optional[float]):
    def key(rd: ResourceDecision) -> Tuple:
        if rd.pruned:
            return (1, 0, rd.floor_time, 0.0, rd.cluster_id)
        if objective == "step_time":
            vals: Tuple = (rd.time, rd.cost_per_step)
        elif objective == "cost":
            vals = (rd.cost_per_step, rd.time)
        elif objective == "job_cost":
            vals = (rd.cost_per_job, rd.time)
        else:
            vals = (0 if rd.meets(slo) else 1, rd.cost_per_step, rd.time)
        return (0, 0 if rd.feasible else 1) + vals + (rd.cluster_id,)
    return key


def _floor_cannot_win(objective: str, slo: Optional[float],
                      incumbent: ResourceDecision, cc: ClusterConfig,
                      floor_t: float, steps_per_job: int,
                      arch: Optional[ArchConfig] = None) -> bool:
    """Sound pruning test: could ANY plan on this cluster outrank the
    (feasible) incumbent?  Uses strict inequalities so exact ties are still
    costed and resolved by the deterministic tie-break.  For the job-cost
    objective the step-time floor maps through :func:`job_dollars` (with
    the same per-arch restore pricing the ranking uses), which is strictly
    increasing in step time, so the mapped value is still a lower bound on
    any plan's $/job."""
    floor_cost = floor_t * cc.num_chips * cc.chip.cost_per_chip_hour / 3600.0
    if objective == "step_time":
        return floor_t > incumbent.time
    if objective == "cost":
        return floor_cost > incumbent.cost_per_step
    if objective == "job_cost":
        return (job_dollars(cc, floor_t, steps_per_job, arch)
                > incumbent.cost_per_job)
    if incumbent.meets(slo):
        return floor_t > slo or floor_cost > incumbent.cost_per_step
    return floor_t > slo and floor_cost > incumbent.cost_per_step


def _visit_order_key(objective: str, slo: Optional[float],
                     steps_per_job: int, arch: Optional[ArchConfig] = None):
    def key(entry) -> Tuple:
        cand, floor_t = entry
        floor_cost = (floor_t * cand.cc.num_chips
                      * cand.cc.chip.cost_per_chip_hour / 3600.0)
        if objective == "step_time":
            return (floor_t, floor_cost, cand.cid)
        if objective == "cost":
            return (floor_cost, floor_t, cand.cid)
        if objective == "job_cost":
            return (job_dollars(cand.cc, floor_t, steps_per_job, arch),
                    floor_t, cand.cid)
        return (0 if (slo is None or floor_t <= slo) else 1,
                floor_cost, floor_t, cand.cid)
    return key


# ---------------------------------------------------------------------------
# The co-search
# ---------------------------------------------------------------------------


def optimize_resources(arch: ArchConfig,
                       shape: Union[ShapeConfig, TrainWorkload,
                                    ServeWorkload],
                       clusters: Optional[Sequence] = None,
                       objective: Union[str, Objective] = "step_time",
                       slo: Optional[float] = None, *,
                       search: str = "beam", beam_width: int = 4,
                       prune: Optional[bool] = None,
                       steps_per_job: int = DEFAULT_STEPS_PER_JOB,
                       cache: Optional[PlanCostCache] = None,
                       stats: Optional[ResourceSearchStats] = None,
                       jobs: int = 1) -> List[ResourceDecision]:
    """Rank cluster candidates (with their best sharding plan) under an
    objective.

    ``search="beam"`` (default) prunes clusters by their sound cost floor
    and plans by the staged beam; ``search="exhaustive"`` costs every
    (cluster x plan) cell — the verification oracle.  Both return the
    identical winner (gated by tests/benchmarks).  ``steps_per_job`` sizes
    the job the ``job_cost`` objective prices (ignored otherwise).  Pass a
    shared :class:`PlanCostCache` to reuse sub-plan costs across calls and
    a :class:`ResourceSearchStats` to observe how much of the space was
    actually evaluated.

    The workload may be typed: a :class:`TrainWorkload` carries its own
    ``steps_per_job``; a :class:`ServeWorkload` dispatches to
    :func:`repro.core.serving.optimize_serving` (the schedule co-search,
    returning :class:`~repro.core.serving.ServingDecision` rows).  A typed
    :class:`Objective` is accepted anywhere the string spelling is.

    ``jobs`` > 1 warms the cache in parallel first: the search itself
    runs on candidate shards across a worker pool (decisions discarded,
    cache deltas merged), then the serial pass below re-runs against the
    warm cache — incumbent pruning is visit-order dependent, so this is
    how the parallel path stays bit-identical to ``jobs=1``.
    """
    if isinstance(shape, ServeWorkload):
        from repro.core import serving
        return serving.optimize_serving(
            arch, shape, clusters, objective=objective, slo=slo,
            search=search, beam_width=beam_width, prune=prune,
            cache=cache, stats=stats, jobs=jobs)
    if isinstance(shape, TrainWorkload):
        if steps_per_job == DEFAULT_STEPS_PER_JOB:
            steps_per_job = shape.steps_per_job
        shape = shape.shape
    obj = as_objective(objective, slo, steps_per_job)
    slo = obj.slo
    if obj.steps_per_job is not None:
        steps_per_job = obj.steps_per_job
    objective = _canon_objective(obj.kind, slo)
    if prune is None:
        prune = search == "beam"
    cands = [_as_candidate(c) for c in
             (clusters if clusters is not None else enumerate_clusters())]
    if cache is None:
        cache = PlanCostCache()
    if stats is None:
        stats = ResourceSearchStats()
    if jobs > 1 and len(cands) > 1:
        from repro.core import parallel
        stats.worker_cache = parallel.warm_shards(
            "resource", arch, shape, cands,
            dict(objective=objective, slo=slo, search=search,
                 beam_width=beam_width, prune=prune,
                 steps_per_job=steps_per_job),
            jobs, cache)
    entries = [(cand, cluster_floor_time(arch, shape, cand.cc))
               for cand in cands]
    stats.clusters_total += len(entries)
    stats.exhaustive_plan_space += sum(
        _plan_space_size(arch, shape, cand.cc.mesh_shape, cand.cc.mesh_axes)
        for cand, _ in entries)
    if prune:
        entries.sort(key=_visit_order_key(objective, slo, steps_per_job,
                                          arch))
    key = _rank_key(objective, slo)
    pool = DominancePool(
        rank_key=key,
        cannot_win=(lambda bound, best: _floor_cannot_win(
            objective, slo, best, bound[0].cc, bound[1], steps_per_job,
            arch)) if prune else None)
    out: List[ResourceDecision] = []
    for cand, floor_t in entries:
        if not pool.admit((cand, floor_t)):
            stats.clusters_pruned += 1
            out.append(ResourceDecision(
                cand.cid, cand.cc, None, floor_t,
                pruned=f"floor {floor_t * 1e3:.2f}ms loses to "
                       f"{pool.best.cluster_id}",
                steps_per_job=steps_per_job, arch=arch))
            continue
        pstats = SearchStats()
        best = choose_plan(arch, shape, cand.cc, top_k=1, search=search,
                           beam_width=beam_width, cache=cache,
                           stats=pstats)[0]
        stats.plan_evals += pstats.costed
        stats.clusters_costed += 1
        rd = ResourceDecision(cand.cid, cand.cc, best, floor_t, search=pstats,
                              steps_per_job=steps_per_job, arch=arch)
        out.append(rd)
        if rd.feasible:
            pool.offer(rd)
    stats.cache = cache.stats()
    out.sort(key=key)
    return out


def format_decisions(decisions: Sequence[ResourceDecision],
                     slo: Optional[float] = None) -> str:
    """Fixed-width ranked table for examples / EXPLAIN output."""
    header = (f"{'#':>3} {'cluster':24} {'chips':>6} {'step':>10} "
              f"{'$/step':>9} {'$/job':>9} {'feas':>4}  "
              f"{'chosen plan':40} {'search':28}")
    lines = [header, "-" * len(header)]
    for i, rd in enumerate(decisions, 1):
        if rd.pruned:
            lines.append(f"{i:>3} {rd.cluster_id:24} "
                         f"{rd.cc.num_chips:>6} {'--':>10} {'--':>9} "
                         f"{'--':>9} {'cut':>4}  pruned: {rd.pruned[:56]}")
            continue
        feas = "y" if rd.feasible else "OOM"
        if slo is not None:
            feas = "slo" if rd.meets(slo) else feas
        lines.append(
            f"{i:>3} {rd.cluster_id:24} {rd.cc.num_chips:>6} "
            f"{rd.time * 1e3:9.2f}ms {rd.cost_per_step:9.5f} "
            f"{rd.cost_per_job:9.2f} {feas:>4}  "
            f"{rd.decision.plan.describe():40} "
            f"{rd.search.describe() if rd.search else '':28}")
    return "\n".join(lines)
