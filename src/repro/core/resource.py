"""Resource optimization: co-search cluster configs and sharding plans.

The paper's cost model exists *for* optimizers — SystemML's resource
optimizer enumerates cluster configurations and re-costs the program under
each.  The TPU analogue enumerates **cluster candidates** (chip type from
the :data:`repro.core.cluster.CHIPS` table, pod count, mesh shape / axis
layout, DCN- vs ICI-linked multi-slice topologies) and, for each, runs the
staged beam :func:`repro.core.planner.choose_plan` through one shared
:class:`repro.core.costmodel.PlanCostCache`, ranking the results under a
pluggable objective:

  * ``step_time``       — fastest feasible step,
  * ``cost`` (alias ``device_seconds``) — cheapest step: step time x chips
    weighted by :attr:`ChipSpec.cost_per_chip_hour` (the $-cost proxy),
  * ``slo``             — cheapest config whose step time meets an SLO.

Candidate clusters are pruned *soundly* before any plan is costed: a
cluster whose analytic **cost floor** (an aggregate compute/memory roofline
lower bound that no plan on that cluster can beat — see
:func:`cluster_floor_time`) already loses to the incumbent cannot contain
the winner, so the whole (cluster x plan) subtree is skipped.  Together
with the staged beam inside each cluster and the shared sub-plan cache,
the co-search returns the exact exhaustive-scan winner at a small fraction
of the full plan evaluations (gated by tests and benchmarks).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import linalg_ops
from repro.core.cluster import CHIPS, ChipSpec, ClusterConfig
from repro.core.costmodel import (VPU_FRACTION, CacheStats, PlanCostCache)
from repro.core.plan import (Call, Collective, Compute, CpVar, CreateVar,
                             DataGen, ForBlock, FunctionBlock, GenericBlock,
                             IfBlock, IO, JitCall, ParForBlock, Program,
                             RmVar, WhileBlock)
from repro.core.planner import (PlanDecision, SearchStats, ShardingPlan,
                                build_step_program, choose_plan,
                                enumerate_plans)

OBJECTIVES = ("step_time", "cost", "slo")
_OBJECTIVE_ALIASES = {
    "step_time": "step_time", "time": "step_time",
    "cost": "cost", "device_seconds": "cost", "cost_per_step": "cost",
    "slo": "slo", "slo_cheapest": "slo",
}

# Purchasable slice granularity per chip generation (chips per pod slice).
POD_CHIPS = {"tpu_v5e": 256, "tpu_v5p": 64, "tpu_v6e": 256}


# ---------------------------------------------------------------------------
# Cluster candidates
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClusterCandidate:
    """One enumerable cluster configuration, with a stable display id."""

    cid: str
    cc: ClusterConfig


def _short(chip: ChipSpec) -> str:
    return chip.name.replace("tpu_", "")


def _make_cc(chip: ChipSpec, mesh_shape: Tuple[int, ...],
             mesh_axes: Tuple[str, ...],
             base: Optional[ClusterConfig] = None) -> ClusterConfig:
    if base is not None:
        return dataclasses.replace(base, chip=chip, mesh_shape=mesh_shape,
                                   mesh_axes=mesh_axes)
    return ClusterConfig(chip=chip, mesh_shape=mesh_shape, mesh_axes=mesh_axes)


def mesh_factorizations(n: int, variants: int = 2
                        ) -> List[Tuple[Tuple[int, ...], Tuple[str, ...]]]:
    """(data, model) splits of an n-chip slice: balanced first, then a
    wide-data / narrow-model variant (the axis-layout dimension)."""
    if n <= 1:
        return [((1,), ("data",))]
    out: List[Tuple[Tuple[int, ...], Tuple[str, ...]]] = []
    balanced_model = 1
    while balanced_model * balanced_model * 4 <= n:
        balanced_model *= 2
    seen = set()
    for model in (balanced_model, max(balanced_model // 4, min(4, n))):
        if n % model:
            continue
        mesh = (n // model, model) if model > 1 else (n,)
        axes = ("data", "model") if model > 1 else ("data",)
        if mesh not in seen:
            seen.add(mesh)
            out.append((mesh, axes))
        if len(out) >= variants:
            break
    return out or [((n,), ("data",))]


def mesh_candidates(chip: ChipSpec, num_chips: int,
                    base: Optional[ClusterConfig] = None
                    ) -> List[ClusterCandidate]:
    """All single-slice mesh layouts for a fixed chip count (elastic
    re-meshing: the devices that survived, re-factored)."""
    out = []
    seen = set()
    for model in (1, 2, 4, 8, 16, 32):
        if num_chips % model or model > num_chips:
            continue
        mesh = (num_chips // model, model) if model > 1 else (num_chips,)
        axes = ("data", "model") if model > 1 else ("data",)
        if mesh in seen:
            continue
        seen.add(mesh)
        out.append(ClusterCandidate(
            f"{_short(chip)}-{'x'.join(map(str, mesh))}",
            _make_cc(chip, mesh, axes, base)))
    return out


def enumerate_clusters(chips: Optional[Sequence[Union[str, ChipSpec]]] = None,
                       pod_counts: Sequence[int] = (1, 2, 4),
                       mesh_variants: int = 2,
                       base: Optional[ClusterConfig] = None
                       ) -> List[ClusterCandidate]:
    """The default cluster grid: chip type x pod count x mesh layout, with
    both ICI-linked superslices (when the chip's ICI domain allows) and
    DCN-linked multi-pod topologies."""
    chip_specs = [CHIPS[c] if isinstance(c, str) else c
                  for c in (chips if chips is not None else CHIPS)]
    out: List[ClusterCandidate] = []
    for chip in chip_specs:
        pod = POD_CHIPS.get(chip.name, 256)
        for p in pod_counts:
            total = pod * p
            fits_ici = total <= chip.ici_domain
            if fits_ici:
                for mesh, axes in mesh_factorizations(total, mesh_variants):
                    out.append(ClusterCandidate(
                        f"{_short(chip)}-{'x'.join(map(str, mesh))}",
                        _make_cc(chip, mesh, axes, base)))
            if p > 1:
                # DCN multi-slice: "pod" axis crosses the data-center network
                nv = 1 if fits_ici else mesh_variants
                for mesh, axes in mesh_factorizations(pod, nv):
                    out.append(ClusterCandidate(
                        f"{_short(chip)}-{p}x{'x'.join(map(str, mesh))}-dcn",
                        _make_cc(chip, (p,) + mesh, ("pod",) + axes, base)))
    return out


def _as_candidate(c) -> ClusterCandidate:
    if isinstance(c, ClusterCandidate):
        return c
    if isinstance(c, ClusterConfig):
        label = "x".join(str(s) for s in c.mesh_shape)
        return ClusterCandidate(f"{c.chip.name}[{label}]", c)
    if isinstance(c, tuple) and len(c) == 2:
        return ClusterCandidate(str(c[0]), c[1])
    raise TypeError(f"not a cluster candidate: {c!r}")


# ---------------------------------------------------------------------------
# Sound per-cluster cost floors (prune whole clusters without costing plans)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ProgramFloor:
    """Cluster-independent work totals of a step program: global MXU FLOPs
    by dtype, VPU FLOPs, and HBM bytes moved — every candidate plan for the
    same (arch, shape) executes at least this much work."""

    mxu_flops: Tuple[Tuple[str, float], ...]
    vpu_flops: float
    hbm_bytes: float


def _walk_totals(nodes, env: Dict, mult: float, functions: Dict,
                 stack: Tuple[str, ...], acc: Dict) -> None:
    for node in nodes:
        if isinstance(node, CreateVar):
            env[node.name] = node.stat
        elif isinstance(node, CpVar):
            if node.src in env:
                env[node.dst] = env[node.src]
        elif isinstance(node, RmVar):
            for n in node.names:
                env.pop(n, None)
        elif isinstance(node, DataGen):
            env[node.output] = node.stat
        elif isinstance(node, Compute):
            stats = [env[n] for n in node.inputs]
            prof = linalg_ops.profile(node.opcode, stats, **node.attrs)
            if prof.util == "mxu":
                dt = stats[0].dtype if stats else "bfloat16"
                acc["mxu"][dt] = acc["mxu"].get(dt, 0.0) + prof.flops * mult
            else:
                acc["vpu"] += prof.flops * mult
            acc["bytes"] += prof.bytes * mult
            env[node.output] = prof.out
        elif isinstance(node, Collective):
            if node.output and node.var in env:
                env[node.output] = env[node.var]
        elif isinstance(node, (IO, JitCall)):
            pass                       # adds cost only; no flop/byte floor
        elif isinstance(node, Call):
            if node.func not in stack:
                fn = functions.get(node.func)
                if fn is not None:
                    _walk_totals(fn.body, env, mult, functions,
                                 stack + (node.func,), acc)
        elif isinstance(node, GenericBlock):
            _walk_totals(node.children, env, mult, functions, stack, acc)
        elif isinstance(node, (ForBlock, WhileBlock)):
            n = max(int(node.iterations), 1) if node.iterations else 1
            _walk_totals(node.predicate, env, mult * n, functions, stack, acc)
            _walk_totals(node.body, env, mult * n, functions, stack, acc)
        elif isinstance(node, ParForBlock):
            n = max(int(node.iterations), 1) if node.iterations else 1
            w = math.ceil(n / max(int(node.parallelism), 1))
            _walk_totals(node.body, env, mult * w, functions, stack, acc)
        elif isinstance(node, IfBlock):
            _walk_totals(node.predicate, env, mult, functions, stack, acc)
            nb = max(len(node.branches), 1)
            weights = list(node.weights) if node.weights else [1.0 / nb] * nb
            base = dict(env)
            branch_envs = []
            for br, w in zip(node.branches, weights):
                benv = dict(base)      # each branch starts from the pre-If env
                _walk_totals(br, benv, mult * w, functions, stack, acc)
                branch_envs.append(benv)
            # merge like CostEstimator._cost_if: a name survives only when
            # every branch leaves it defined (shapes from the first branch)
            merged = branch_envs[0] if branch_envs else base
            for benv in branch_envs[1:]:
                for name in list(merged):
                    if name not in benv:
                        del merged[name]
            env.clear()
            env.update(merged)
        elif isinstance(node, FunctionBlock):
            _walk_totals(node.body, env, mult, functions, stack, acc)
        else:
            raise TypeError(f"unknown plan node {type(node)}")


def program_totals(prog: Program) -> ProgramFloor:
    """Global (plan- and cluster-independent) work totals of a program."""
    acc = {"mxu": {}, "vpu": 0.0, "bytes": 0.0}
    env = dict(prog.inputs)
    _walk_totals(prog.blocks, env, 1.0, prog.functions, (), acc)
    return ProgramFloor(tuple(sorted(acc["mxu"].items())), acc["vpu"],
                        acc["bytes"])


@functools.lru_cache(maxsize=None)
def _plan_space_size(arch: ArchConfig, shape: ShapeConfig,
                     mesh_shape: Tuple[int, ...],
                     mesh_axes: Tuple[str, ...]) -> int:
    """|enumerate_plans| for the exhaustive-scan statistic.  The space
    depends only on the mesh geometry (roles/knobs never consult the chip),
    so the count is cached instead of re-enumerated per optimize call."""
    cc = ClusterConfig(mesh_shape=mesh_shape, mesh_axes=mesh_axes)
    return len(enumerate_plans(arch, shape, cc))


@functools.lru_cache(maxsize=None)
def _floor_for(arch: ArchConfig, shape: ShapeConfig) -> ProgramFloor:
    # The minimal-work reference: remat=none (no recompute), micro=1.  All
    # candidate plans emit the same compute ops at the same global shapes
    # (sharding divides per-device work, never global work), so this is a
    # true floor over the whole plan space.
    ref = ShardingPlan(name="floor-ref", batch_axes=("data",),
                       remat="none", microbatches=1)
    ref_cc = ClusterConfig(mesh_shape=(1,), mesh_axes=("data",))
    return program_totals(build_step_program(arch, shape, ref, ref_cc))


def cluster_floor_time(arch: ArchConfig, shape: ShapeConfig,
                       cc: ClusterConfig) -> float:
    """A sound lower bound on ``C(P, cc)`` over EVERY sharding plan P.

    Per instruction the estimator charges max(flops/(shards·peak·util),
    bytes/(shards·hbm_bw)); shards never exceeds the chip count (times one
    duplicated axis for MoE ep+tp plans), util never exceeds matmul_util,
    and collectives/latency/IO only add — so aggregate compute and memory
    rooflines at full-cluster parallelism bound any plan from below."""
    fl = _floor_for(arch, shape)
    dup = max(cc.mesh_shape) if arch.moe is not None else 1
    denom = max(cc.num_chips * dup, 1)
    util = max(cc.matmul_util, cc.small_matmul_util)
    t_flops = sum(f / (denom * cc.chip.peak(dt) * util)
                  for dt, f in fl.mxu_flops)
    t_flops += fl.vpu_flops / (denom * cc.chip.peak("float32") * VPU_FRACTION)
    t_mem = fl.hbm_bytes / (denom * cc.hbm_bw_eff)
    return max(t_flops, t_mem)


# ---------------------------------------------------------------------------
# Decisions + ranking
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResourceDecision:
    """One cluster candidate's outcome: its best plan (or why it was pruned)
    plus the objective values the ranking uses."""

    cluster_id: str
    cc: ClusterConfig
    decision: Optional[PlanDecision]        # None when pruned before costing
    floor_time: float
    pruned: str = ""                        # non-empty: skipped, why
    search: Optional[SearchStats] = None

    @property
    def time(self) -> float:
        return self.decision.time if self.decision else float("inf")

    @property
    def feasible(self) -> bool:
        return bool(self.decision and self.decision.feasible)

    @property
    def device_seconds(self) -> float:
        return self.time * self.cc.num_chips

    @property
    def cost_per_step(self) -> float:
        """$ per step: device-seconds priced at cost_per_chip_hour."""
        return self.device_seconds * self.cc.chip.cost_per_chip_hour / 3600.0

    def meets(self, slo: Optional[float]) -> bool:
        return self.feasible and slo is not None and self.time <= slo

    def describe(self) -> str:
        if self.pruned:
            return f"{self.cluster_id}: pruned ({self.pruned})"
        return (f"{self.cluster_id}: {self.decision.plan.describe()} "
                f"T={self.time * 1e3:.2f}ms ${self.cost_per_step:.4f}/step")


@dataclasses.dataclass
class ResourceSearchStats:
    """Observability for one co-search: how much of the (cluster x plan)
    space was actually evaluated."""

    clusters_total: int = 0
    clusters_costed: int = 0
    clusters_pruned: int = 0
    plan_evals: int = 0                 # full generate+cost evaluations run
    exhaustive_plan_space: int = 0      # sum over clusters of |enumerate_plans|
    cache: Optional[CacheStats] = None

    @property
    def evals_ratio(self) -> float:
        """How many times fewer evaluations than the exhaustive scan."""
        return self.exhaustive_plan_space / max(self.plan_evals, 1)

    def describe(self) -> str:
        bits = [f"clusters={self.clusters_costed}/{self.clusters_total}",
                f"evals={self.plan_evals}/{self.exhaustive_plan_space}"
                f"({self.evals_ratio:.1f}x)"]
        if self.cache is not None:
            bits.append(f"cache={self.cache.hits}/"
                        f"{self.cache.hits + self.cache.misses}")
        return " ".join(bits)


def _canon_objective(objective: str, slo: Optional[float]) -> str:
    key = _OBJECTIVE_ALIASES.get(objective)
    if key is None:
        raise ValueError(f"unknown objective {objective!r}; "
                         f"one of {sorted(set(_OBJECTIVE_ALIASES))}")
    if key == "slo" and slo is None:
        raise ValueError("objective 'slo' needs a step-time target (slo=...)")
    return key


def _rank_key(objective: str, slo: Optional[float]):
    def key(rd: ResourceDecision) -> Tuple:
        if rd.pruned:
            return (1, 0, rd.floor_time, 0.0, rd.cluster_id)
        if objective == "step_time":
            vals: Tuple = (rd.time, rd.cost_per_step)
        elif objective == "cost":
            vals = (rd.cost_per_step, rd.time)
        else:
            vals = (0 if rd.meets(slo) else 1, rd.cost_per_step, rd.time)
        return (0, 0 if rd.feasible else 1) + vals + (rd.cluster_id,)
    return key


def _floor_cannot_win(objective: str, slo: Optional[float],
                      incumbent: ResourceDecision, cc: ClusterConfig,
                      floor_t: float) -> bool:
    """Sound pruning test: could ANY plan on this cluster outrank the
    (feasible) incumbent?  Uses strict inequalities so exact ties are still
    costed and resolved by the deterministic tie-break."""
    floor_cost = floor_t * cc.num_chips * cc.chip.cost_per_chip_hour / 3600.0
    if objective == "step_time":
        return floor_t > incumbent.time
    if objective == "cost":
        return floor_cost > incumbent.cost_per_step
    if incumbent.meets(slo):
        return floor_t > slo or floor_cost > incumbent.cost_per_step
    return floor_t > slo and floor_cost > incumbent.cost_per_step


def _visit_order_key(objective: str, slo: Optional[float]):
    def key(entry) -> Tuple:
        cand, floor_t = entry
        floor_cost = (floor_t * cand.cc.num_chips
                      * cand.cc.chip.cost_per_chip_hour / 3600.0)
        if objective == "step_time":
            return (floor_t, floor_cost, cand.cid)
        if objective == "cost":
            return (floor_cost, floor_t, cand.cid)
        return (0 if (slo is None or floor_t <= slo) else 1,
                floor_cost, floor_t, cand.cid)
    return key


# ---------------------------------------------------------------------------
# The co-search
# ---------------------------------------------------------------------------


def optimize_resources(arch: ArchConfig, shape: ShapeConfig,
                       clusters: Optional[Sequence] = None,
                       objective: str = "step_time",
                       slo: Optional[float] = None, *,
                       search: str = "beam", beam_width: int = 4,
                       prune: Optional[bool] = None,
                       cache: Optional[PlanCostCache] = None,
                       stats: Optional[ResourceSearchStats] = None
                       ) -> List[ResourceDecision]:
    """Rank cluster candidates (with their best sharding plan) under an
    objective.  ``search="beam"`` (default) prunes clusters by their sound
    cost floor and plans by the staged beam; ``search="exhaustive"`` costs
    every (cluster x plan) cell — the verification oracle.  Pass a shared
    :class:`PlanCostCache` to reuse sub-plan costs across calls."""
    objective = _canon_objective(objective, slo)
    if prune is None:
        prune = search == "beam"
    cands = [_as_candidate(c) for c in
             (clusters if clusters is not None else enumerate_clusters())]
    if cache is None:
        cache = PlanCostCache()
    if stats is None:
        stats = ResourceSearchStats()
    entries = [(cand, cluster_floor_time(arch, shape, cand.cc))
               for cand in cands]
    stats.clusters_total += len(entries)
    stats.exhaustive_plan_space += sum(
        _plan_space_size(arch, shape, cand.cc.mesh_shape, cand.cc.mesh_axes)
        for cand, _ in entries)
    if prune:
        entries.sort(key=_visit_order_key(objective, slo))
    key = _rank_key(objective, slo)
    incumbent: Optional[ResourceDecision] = None
    out: List[ResourceDecision] = []
    for cand, floor_t in entries:
        if (prune and incumbent is not None
                and _floor_cannot_win(objective, slo, incumbent, cand.cc,
                                      floor_t)):
            stats.clusters_pruned += 1
            out.append(ResourceDecision(
                cand.cid, cand.cc, None, floor_t,
                pruned=f"floor {floor_t * 1e3:.2f}ms loses to "
                       f"{incumbent.cluster_id}"))
            continue
        pstats = SearchStats()
        best = choose_plan(arch, shape, cand.cc, top_k=1, search=search,
                           beam_width=beam_width, cache=cache,
                           stats=pstats)[0]
        stats.plan_evals += pstats.costed
        stats.clusters_costed += 1
        rd = ResourceDecision(cand.cid, cand.cc, best, floor_t, search=pstats)
        if rd.time < floor_t * (1.0 - 1e-9):
            # Tripwire for the one invariant pruning depends on: the floor
            # walker (_walk_totals) mirroring CostEstimator's semantics.
            # Drift shows up here on every search instead of as a silently
            # mispruned winner.
            raise RuntimeError(
                f"unsound cluster floor for {cand.cid}: best plan costs "
                f"{rd.time:.6g}s < floor {floor_t:.6g}s — _walk_totals has "
                "drifted from CostEstimator; fix it before trusting pruning")
        out.append(rd)
        if rd.feasible and (incumbent is None or key(rd) < key(incumbent)):
            incumbent = rd
    stats.cache = cache.stats()
    out.sort(key=key)
    return out


def format_decisions(decisions: Sequence[ResourceDecision],
                     slo: Optional[float] = None) -> str:
    """Fixed-width ranked table for examples / EXPLAIN output."""
    header = (f"{'#':>3} {'cluster':24} {'chips':>6} {'step':>10} "
              f"{'$/step':>9} {'feas':>4}  {'chosen plan':40} {'search':28}")
    lines = [header, "-" * len(header)]
    for i, rd in enumerate(decisions, 1):
        if rd.pruned:
            lines.append(f"{i:>3} {rd.cluster_id:24} "
                         f"{rd.cc.num_chips:>6} {'--':>10} {'--':>9} "
                         f"{'cut':>4}  pruned: {rd.pruned[:56]}")
            continue
        feas = "y" if rd.feasible else "OOM"
        if slo is not None:
            feas = "slo" if rd.meets(slo) else feas
        lines.append(
            f"{i:>3} {rd.cluster_id:24} {rd.cc.num_chips:>6} "
            f"{rd.time * 1e3:9.2f}ms {rd.cost_per_step:9.5f} {feas:>4}  "
            f"{rd.decision.plan.describe():40} "
            f"{rd.search.describe() if rd.search else '':28}")
    return "\n".join(lines)
