"""Anytime-search dominance pool: prune candidates before costing them.

The optimizers (`choose_plan`, `optimize_resources`, `optimize_serving`)
stream candidates in a fixed visit order and keep an *incumbent* — the best
fully-costed result so far.  Before paying the cost walk for the next
candidate, a cheap lower bound (the geometry floor from
`resource.cluster_floor_time` / `serving.serving_floor`) is compared
against the incumbent: if the bound already loses, the candidate is
pruned *provably* — the true cost can only be worse than its floor.

:class:`DominancePool` packages that discipline.  Two modes:

* **rank-key mode** (``rank_key=`` given): a single incumbent, ordered by
  the optimizer's ranking tuple.  ``admit(bound)`` consults a
  ``cannot_win(bound, incumbent)`` predicate — sound as long as the
  predicate only returns True when *no* completion of ``bound`` can rank
  ahead of the incumbent (the existing ``_floor_cannot_win`` contracts).
  This is exactly the incumbent logic `optimize_resources` and
  `optimize_serving` grew organically; the pool centralizes it and counts
  admissions/prunes.

* **Pareto mode** (no ``rank_key``): the pool keeps the non-dominated
  frontier of (cost, hbm, evals)-style tuples under weak Pareto dominance
  — ``a`` dominates ``b`` when ``a`` is ≤ in every coordinate and < in at
  least one.  ``admit(t)`` is True unless some frontier member dominates
  ``t``; ``offer(t)`` inserts ``t`` and evicts members it dominates.
  Ties (equal tuples) are admitted, so any ranking monotone in each
  coordinate still sees its winner: the exhaustive optimum is never
  strictly dominated, hence never pruned (tests/test_dominance.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence


def pareto_dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """Weak Pareto dominance: ``a`` ≤ ``b`` everywhere and < somewhere."""
    le_all = True
    lt_any = False
    for ai, bi in zip(a, b):
        if ai > bi:
            le_all = False
            break
        if ai < bi:
            lt_any = True
    return le_all and lt_any


@dataclass
class DominancePool:
    """Streaming dominance filter with admitted/pruned counters.

    rank-key mode::

        pool = DominancePool(rank_key=key_fn, cannot_win=floor_fn)
        for cand in stream:
            if not pool.admit(bound_of(cand)):   # provably loses
                continue                          # -> pool.pruned += 1
            result = cost(cand)                   # the expensive walk
            pool.offer(result)                    # maybe new incumbent

    Pareto mode::

        pool = DominancePool()
        if pool.admit((cost_lb, hbm_lb, evals_lb)):
            pool.offer((cost, hbm, evals))
    """

    rank_key: Optional[Callable[[Any], Any]] = None
    cannot_win: Optional[Callable[[Any, Any], bool]] = None
    dominates: Callable[[Sequence[float], Sequence[float]], bool] = pareto_dominates
    admitted: int = 0
    pruned: int = 0
    best: Any = None
    frontier: List[Any] = field(default_factory=list)

    def admit(self, bound: Any) -> bool:
        """True when ``bound`` might still win and must be costed.

        In rank-key mode the verdict comes from ``cannot_win(bound, best)``
        (never prunes while there is no incumbent).  In Pareto mode the
        bound tuple is checked against the frontier; only *strict*
        dominance prunes, so exact ties survive to be costed and ranked.
        """
        if self.rank_key is not None:
            ok = self.best is None or self.cannot_win is None or not self.cannot_win(bound, self.best)
        else:
            ok = not any(self.dominates(m, bound) for m in self.frontier)
        if ok:
            self.admitted += 1
        else:
            self.pruned += 1
        return ok

    def offer(self, result: Any) -> bool:
        """Insert a fully-costed result; True if it entered the pool.

        Rank-key mode replaces the incumbent when the new key ranks
        strictly ahead.  Pareto mode drops ``result`` if dominated, else
        inserts it and evicts now-dominated members.
        """
        if self.rank_key is not None:
            if self.best is None or self.rank_key(result) < self.rank_key(self.best):
                self.best = result
                return True
            return False
        if any(self.dominates(m, result) for m in self.frontier):
            return False
        self.frontier = [m for m in self.frontier if not self.dominates(result, m)]
        self.frontier.append(result)
        return True

    def __len__(self) -> int:
        if self.rank_key is not None:
            return 0 if self.best is None else 1
        return len(self.frontier)
