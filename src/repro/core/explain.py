"""SystemML-style EXPLAIN with cost annotations (paper Figures 4 & 5).

Produces the text form the paper uses throughout::

    PROGRAM                         # total cost C=3.31s
    --MAIN PROGRAM                  # C=3.31s
    ----GENERIC (lines 1-3)         # C=2.8E-8s
    ------CP tsmm X _mVar2 LEFT     # C=[0.51s, 2.32s]

Leaf instructions show the [IO, compute] split (collective/latency appended
when nonzero); blocks show their aggregated total.
"""
from __future__ import annotations

from typing import List

from repro.core.costmodel import CostedNode, CostedProgram


def _fmt(x: float) -> str:
    if x == 0:
        return "0s"
    if x >= 0.01:
        return f"{x:.3g}s"
    return f"{x:.2g}s".replace("e-0", "E-").replace("e-", "E-")


def _annotate(node: CostedNode) -> str:
    c = node.cost
    if node.children:
        # pipelined loops carry a schedule note (critical stage, bubble
        # fraction) worth surfacing inline — the whole point of costing
        # them as control flow is that the overlap is visible here
        if node.note:
            return f"# C={_fmt(c.total)} [{node.note}]"
        return f"# C={_fmt(c.total)}"
    parts = f"# C=[{_fmt(c.io)}, {_fmt(c.compute)}"
    if c.collective:
        parts += f", coll={_fmt(c.collective)}"
    if c.latency > 1e-7:
        parts += f", lat={_fmt(c.latency)}"
    return parts + "]"


def explain(costed: CostedProgram, max_depth: int = 99,
            show_notes: bool = False) -> str:
    lines: List[str] = []

    def walk(node: CostedNode, depth: int) -> None:
        if depth > max_depth:
            return
        prefix = "--" * depth if depth else ""
        pad = max(2, 64 - len(prefix) - len(node.label))
        lines.append(f"{prefix}{node.label}{' ' * pad}{_annotate(node)}")
        if show_notes and node.note:
            lines.append(f"{prefix}  .. {node.note}")
        for ch in node.children:
            walk(ch, depth + 1)

    walk(costed.root, 0)
    lines.append(f"# total cost C={_fmt(costed.total)}  "
                 f"(io={_fmt(costed.breakdown.io)}, compute={_fmt(costed.breakdown.compute)}, "
                 f"collective={_fmt(costed.breakdown.collective)}, "
                 f"latency={_fmt(costed.breakdown.latency)}; "
                 f"peak HBM/device={costed.peak_hbm_per_device/1e9:.3g} GB)")
    return "\n".join(lines)
