"""Fitted calibration profiles — closing the estimate↔reality loop.

The estimator's efficiency constants (``ClusterConfig.matmul_util``,
``hbm_eff``, ``ici_eff``, ``dcn_eff``, the plan-gated overlap fraction)
are hand-set analogues of the paper's MMD_corr corrections.  This module
retrofits *fitted* values onto the same analytical model — the approach of
"Cost Models for Big Data Query Processing: Learning, Retrofitting"
(arXiv:2002.12393): a small set of interpretable factors, least-squared
from measured runtimes, with the bit-exact uncalibrated model as the
default (``ClusterConfig.calibration is None`` changes nothing).

A :class:`CalibrationProfile` describes ONE chip type:

* ``mxu[dtype][shape_class]`` — achieved fraction of MXU peak per dtype
  and matmul shape class (``small``/``medium``/``large``, the same
  1e8/1e10-FLOP breakpoints as the estimator's log-linear util ramp).
* ``hbm_fraction`` / ``ici_fraction`` / ``dcn_fraction`` — achieved
  fraction of peak HBM / per-link ICI / DCN bandwidth, replacing
  ``hbm_eff`` / ``ici_eff`` / ``dcn_eff`` when present.
* ``overlap_ici`` / ``overlap_dcn`` — achieved per-fabric overlap when a
  plan enables compute/comm overlap, replacing the plan-gated
  ``OVERLAP_FRACTION`` constant.

Every field is optional; absent fields fall back to the hand-set
constants, so an empty profile is an exact identity.

Fitting model: each sample's runtime is linearized as

    measured ≈ fixed + Σ_k x_k / f_k        (x_k = ideal seconds at PEAK)

so with β_k = 1/f_k the problem is ordinary least squares on
``measured − fixed ≈ Σ β_k x_k``; :func:`fit_profile` solves it by
min-norm lstsq and inverts/clamps the coefficients into achieved
fractions.  The min-norm solution matters for the online path: a single
drifting workload is an underdetermined system, and min-norm distributes
the drift across terms proportionally to their feature magnitude — which
is exactly what lets a re-cost change the *ranking* of plans with
different term mixes instead of scaling every plan uniformly.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

# Matmul shape classes, aligned with the estimator's util ramp breakpoints
# (``ClusterConfig.mxu_util``: small_matmul_util below 1e8 FLOPs, matmul_util
# above 1e10, log-linear in between).
SHAPE_CLASSES = ("small", "medium", "large")
SMALL_FLOPS = 1e8
LARGE_FLOPS = 1e10

# Canonical feature keys (see :func:`features_from_totals`).
HBM_KEY = "hbm"
ICI_KEY = "ici"
DCN_KEY = "dcn"


def shape_class(flops: float) -> str:
    """Shape class of a matmul charged ``flops`` — the discretization of
    the estimator's util ramp that calibration fits per-class factors on."""
    if flops <= SMALL_FLOPS:
        return "small"
    if flops >= LARGE_FLOPS:
        return "large"
    return "medium"


def mxu_key(dtype: str, cls: str) -> str:
    """Feature key of one (dtype, shape-class) MXU term."""
    return f"mxu:{dtype}:{cls}"


def _clean_mxu(mxu: Mapping[str, Mapping[str, float]]
               ) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for dtype, by_cls in (mxu or {}).items():
        row = {cls: float(v) for cls, v in by_cls.items() if v is not None}
        if row:
            out[str(dtype)] = row
    return out


@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """Fitted achieved-fraction corrections for one chip type.

    All factors are *achieved fractions of peak* in (0, 1]; a field left
    ``None`` (or a missing ``mxu`` entry) falls back to the hand-set
    ``ClusterConfig`` constant, so the empty profile is an identity.
    """

    chip_name: str = ""
    # dtype -> shape_class -> achieved fraction of MXU peak
    mxu: Mapping[str, Mapping[str, float]] = dataclasses.field(
        default_factory=dict)
    hbm_fraction: Optional[float] = None
    ici_fraction: Optional[float] = None
    dcn_fraction: Optional[float] = None
    # achieved overlap per fabric, applied only when the plan enables
    # overlap (the gate stays with the plan; calibration refines the value)
    overlap_ici: Optional[float] = None
    overlap_dcn: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "mxu", _clean_mxu(self.mxu))

    # ----------------------------------------------------------- queries
    def mxu_util(self, dtype: str, flops: float) -> Optional[float]:
        """Fitted MXU fraction for one op, or ``None`` when this profile
        has no entry for the op's (dtype, shape-class)."""
        by_cls = self.mxu.get(dtype)
        if not by_cls:
            return None
        return by_cls.get(shape_class(flops))

    def mxu_ceiling(self, dtype: str, default_ceiling: float) -> float:
        """The most generous MXU fraction any op of ``dtype`` can earn
        under this profile — what a sound floor must price FLOPs at.
        When the class table is incomplete for the dtype, uncovered
        classes still fall back to the hand-set ramp, so the ceiling must
        include ``default_ceiling`` too."""
        by_cls = self.mxu.get(dtype)
        if not by_cls:
            return default_ceiling
        vals = list(by_cls.values())
        if len(by_cls) < len(SHAPE_CLASSES):
            vals.append(default_ceiling)
        return max(vals)

    def is_empty(self) -> bool:
        return (not self.mxu and self.hbm_fraction is None
                and self.ici_fraction is None and self.dcn_fraction is None
                and self.overlap_ici is None and self.overlap_dcn is None)

    # ------------------------------------------------------------ identity
    def fingerprint(self) -> Tuple:
        """Hashable identity — folded into ``ClusterConfig.fingerprint()``
        so ``PlanCostCache`` never mixes calibrated and uncalibrated
        costs."""
        return (self.chip_name,
                tuple(sorted((dt, tuple(sorted(by.items())))
                             for dt, by in self.mxu.items())),
                self.hbm_fraction, self.ici_fraction, self.dcn_fraction,
                self.overlap_ici, self.overlap_dcn)

    def describe(self) -> str:
        parts = []
        for dt in sorted(self.mxu):
            by = self.mxu[dt]
            parts.append("mxu[%s]=%s" % (
                dt, "/".join(f"{c}:{by[c]:.3f}" for c in SHAPE_CLASSES
                             if c in by)))
        for k in ("hbm_fraction", "ici_fraction", "dcn_fraction",
                  "overlap_ici", "overlap_dcn"):
            v = getattr(self, k)
            if v is not None:
                parts.append(f"{k}={v:.3f}")
        return ";".join(parts) or "identity"

    # ------------------------------------------------------------- (de)ser
    def to_json(self) -> Dict[str, Any]:
        return {
            "chip_name": self.chip_name,
            "mxu": {dt: dict(by) for dt, by in self.mxu.items()},
            "hbm_fraction": self.hbm_fraction,
            "ici_fraction": self.ici_fraction,
            "dcn_fraction": self.dcn_fraction,
            "overlap_ici": self.overlap_ici,
            "overlap_dcn": self.overlap_dcn,
        }

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "CalibrationProfile":
        return CalibrationProfile(
            chip_name=d.get("chip_name", ""),
            mxu=d.get("mxu", {}),
            hbm_fraction=d.get("hbm_fraction"),
            ici_fraction=d.get("ici_fraction"),
            dcn_fraction=d.get("dcn_fraction"),
            overlap_ici=d.get("overlap_ici"),
            overlap_dcn=d.get("overlap_dcn"),
        )

    def dumps(self) -> str:
        return json.dumps(self.to_json(), sort_keys=True)

    @staticmethod
    def loads(s: str) -> "CalibrationProfile":
        return CalibrationProfile.from_json(json.loads(s))


# ---------------------------------------------------------------------------
# Samples and features
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CalibrationSample:
    """One (estimated-terms, measured-seconds) pair.

    ``features`` maps canonical term keys (``mxu:<dtype>:<class>``,
    ``hbm``, ``ici``, ``dcn``) to *ideal seconds at peak rates* — the
    estimator's time terms with every efficiency factor set to 1, so the
    fitted coefficient of a term IS its achieved fraction.
    ``fixed_seconds`` holds the non-calibratable part of the estimate
    (VPU work, dispatch/phase latency, host IO); it is subtracted from
    the measurement before fitting.  ``polluted`` marks samples whose
    measurement path is suspect (e.g. ``CompiledCost.unknown_dtypes``):
    the fitter rejects them.
    """

    features: Mapping[str, float]
    measured_seconds: float
    estimated_seconds: float = 0.0
    fixed_seconds: float = 0.0
    label: str = ""
    polluted: bool = False


def features_from_totals(totals, cc, mxu_class: Optional[str] = None,
                         flops_per_op: Optional[float] = None
                         ) -> Dict[str, float]:
    """Peak-rate feature vector of one program's charged work totals.

    ``totals`` is a :class:`repro.core.costmodel.ProgramTotals`; ``cc``
    supplies peak rates only (chip peaks, link counts) — no efficiency
    factor enters a feature.  A full program aggregates many matmuls into
    one per-dtype FLOP total, so the shape class is taken from
    ``flops_per_op`` when given (else from the total — a full train step's
    MXU work is dominated by large matmuls, and the total lands in
    ``large`` exactly when they do), or pinned with ``mxu_class``.
    """
    x: Dict[str, float] = {}
    for dt, f in getattr(totals, "mxu_flops", {}).items():
        if f <= 0:
            continue
        cls = mxu_class or shape_class(
            flops_per_op if flops_per_op is not None else f)
        key = mxu_key(dt, cls)
        x[key] = x.get(key, 0.0) + f / cc.chip.peak(dt)
    hbm = getattr(totals, "hbm_bytes", 0.0)
    if hbm > 0:
        x[HBM_KEY] = hbm / cc.chip.hbm_bw
    ici = getattr(totals, "ici_bytes", 0.0)
    if ici > 0:
        x[ICI_KEY] = ici / (cc.chip.ici_bw_per_link * cc.max_ici_links)
    dcn = getattr(totals, "dcn_bytes", 0.0)
    if dcn > 0:
        x[DCN_KEY] = dcn / cc.chip.dcn_bw
    return x


# ---------------------------------------------------------------------------
# The fitter
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FitResult:
    profile: CalibrationProfile
    factors: Dict[str, float]      # term key -> fitted achieved fraction
    residual: float                # RMS relative residual on accepted samples
    n_samples: int                 # samples the fit used
    n_rejected: int                # polluted / degenerate samples dropped


def fit_profile(samples: Sequence[CalibrationSample], chip_name: str = "",
                *, max_factor: float = 1.0, min_factor: float = 0.02
                ) -> FitResult:
    """Least-squares the achieved fractions from measured samples.

    Solves ``measured − fixed ≈ Σ_k β_k · x_k`` for β (min-norm lstsq),
    then inverts ``f_k = 1/β_k`` and clamps into ``[min_factor,
    max_factor]`` — a term the fit says ran *faster than peak* (β below
    1/max_factor: noise, or work the measurement overlapped away) clamps
    to ``max_factor`` so a profile can never promise super-peak rates,
    keeping every calibrated floor sound (factors ≤ 1 only slow terms
    down).  Terms with no feature mass in any accepted sample are left
    out of the profile (they fall back to the hand-set constants).
    """
    import numpy as np

    accepted = []
    rejected = 0
    for s in samples:
        y = s.measured_seconds - s.fixed_seconds
        if s.polluted or not s.features or y <= 0:
            rejected += 1
            continue
        accepted.append((s, y))
    keys = sorted({k for s, _ in accepted for k, v in s.features.items()
                   if v > 0})
    if not accepted or not keys:
        return FitResult(CalibrationProfile(chip_name=chip_name), {},
                         float("nan"), 0, rejected)

    X = np.array([[s.features.get(k, 0.0) for k in keys]
                  for s, _ in accepted], dtype=float)
    y = np.array([t for _, t in accepted], dtype=float)
    beta, *_ = np.linalg.lstsq(X, y, rcond=None)

    factors: Dict[str, float] = {}
    for k, b in zip(keys, beta):
        if b <= 0:
            # lstsq drove the term negative (collinear features): treat as
            # unobserved rather than inventing a super-peak rate
            continue
        factors[k] = min(max_factor, max(min_factor, 1.0 / float(b)))

    pred = X @ np.array([1.0 / factors[k] if k in factors else 0.0
                         for k in keys])
    rel = (pred - y) / np.maximum(y, 1e-30)
    residual = float(np.sqrt(np.mean(rel * rel)))

    mxu: Dict[str, Dict[str, float]] = {}
    hbm = ici = dcn = None
    for k, f in factors.items():
        if k.startswith("mxu:"):
            _, dt, cls = k.split(":")
            mxu.setdefault(dt, {})[cls] = f
        elif k == HBM_KEY:
            hbm = f
        elif k == ICI_KEY:
            ici = f
        elif k == DCN_KEY:
            dcn = f
    profile = CalibrationProfile(chip_name=chip_name, mxu=mxu,
                                 hbm_fraction=hbm, ici_fraction=ici,
                                 dcn_fraction=dcn)
    return FitResult(profile, factors, residual, len(accepted), rejected)
