"""Typed workload and objective descriptions for the resource optimizer.

The paper's resource optimizer consumes a *program* and a *cluster grid*;
what it historically lacked is a declarative description of the thing the
program is run **for** — a training job of so-many steps, or a serving
fleet under so-much traffic.  PAPERS.md's workload-aware-costing line of
work ("Cost Models for Big Data Query Processing", "A Cost-based Optimizer
for Gradient Descent Optimization") argues the optimizer should take that
description as a first-class input, not a bag of kwargs.  This module is
that input surface:

  * :class:`TrainWorkload`  — a step shape plus the job length that the
    ``job_cost`` objective amortizes overheads over,
  * :class:`ServeWorkload`  — a request-arrival model: Poisson arrival
    rate plus prompt/output length distributions (mean + p99), the
    traffic that :mod:`repro.core.serving` turns into costed schedules,
  * :class:`Objective`      — a typed (kind, slo, steps_per_job) triple
    accepted anywhere a string objective is (the strings remain thin
    aliases; every pre-existing call site works unchanged).

Everything here is a frozen dataclass: hashable (the floor caches key on
workloads) and inert (no jax, no model state).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

from repro.configs.base import ShapeConfig

# Default job length for the job-level objective: long enough that compute
# dominates startup on healthy configs, short enough that preemption-heavy
# giant slices pay visibly for their restarts.  (Lives here so both the
# resource optimizer and the typed API share one constant; re-exported by
# :mod:`repro.core.resource` for compatibility.)
DEFAULT_STEPS_PER_JOB = 10_000

# Canonical objective kinds.  The first four rank training-style step
# workloads (see resource.py); the last two only make sense for a
# ServeWorkload (see serving.py) — traffic, not steps, sets their scale.
TRAIN_OBJECTIVES = ("step_time", "cost", "job_cost", "slo")
SERVING_OBJECTIVES = ("ttft_p99", "tokens_per_dollar")

# Every accepted spelling -> canonical kind.  String objectives stay
# supported forever; `Objective` is the typed spelling of the same thing.
OBJECTIVE_ALIASES: Dict[str, str] = {
    "step_time": "step_time", "time": "step_time",
    "cost": "cost", "device_seconds": "cost", "cost_per_step": "cost",
    "job_cost": "job_cost", "cost_per_job": "job_cost", "job": "job_cost",
    "slo": "slo", "slo_cheapest": "slo",
    "ttft_p99": "ttft_p99", "ttft": "ttft_p99",
    "tokens_per_dollar": "tokens_per_dollar",
    "tokens_per_sec_per_dollar": "tokens_per_dollar",
    "throughput_per_dollar": "tokens_per_dollar",
}


@dataclasses.dataclass(frozen=True)
class Objective:
    """What "best" means for one optimize call.

    ``kind`` is a canonical objective name (any :data:`OBJECTIVE_ALIASES`
    spelling is accepted and canonicalized).  ``slo`` is the target the
    SLO-style kinds rank against — a step-time bound for ``slo``, a p99
    time-to-first-token bound (seconds) for ``ttft_p99``.  ``steps_per_job``
    sizes the job priced by ``job_cost`` (``None`` defers to the workload
    or the caller's default)."""

    kind: str
    slo: Optional[float] = None
    steps_per_job: Optional[int] = None

    def __post_init__(self):
        canon = OBJECTIVE_ALIASES.get(self.kind)
        if canon is None:
            raise ValueError(
                f"unknown objective kind {self.kind!r}; "
                f"one of {sorted(set(OBJECTIVE_ALIASES))}")
        object.__setattr__(self, "kind", canon)
        if self.slo is not None and self.slo <= 0:
            raise ValueError(f"slo must be positive, got {self.slo}")

    # -- typed constructors (the readable spellings) ----------------------
    @classmethod
    def step_time(cls) -> "Objective":
        return cls("step_time")

    @classmethod
    def cost_per_step(cls) -> "Objective":
        return cls("cost")

    @classmethod
    def job_cost(cls, steps_per_job: Optional[int] = None) -> "Objective":
        return cls("job_cost", steps_per_job=steps_per_job)

    @classmethod
    def step_slo(cls, slo: float) -> "Objective":
        """Cheapest config whose *step time* meets ``slo`` seconds."""
        return cls("slo", slo=slo)

    @classmethod
    def ttft_p99(cls, slo: Optional[float] = None) -> "Objective":
        """Cheapest serving config whose p99 TTFT meets ``slo`` seconds
        (``None`` defers to :attr:`ServeWorkload.ttft_slo`)."""
        return cls("ttft_p99", slo=slo)

    @classmethod
    def tokens_per_dollar(cls) -> "Objective":
        return cls("tokens_per_dollar")


def as_objective(objective: Union[str, Objective],
                 slo: Optional[float] = None,
                 steps_per_job: Optional[int] = None) -> Objective:
    """Canonicalize a string-or-typed objective plus the legacy loose
    kwargs into one :class:`Objective`.  Fields set on a typed objective
    win over the loose kwargs (the typed spelling is the explicit one)."""
    if isinstance(objective, Objective):
        return Objective(
            objective.kind,
            slo=objective.slo if objective.slo is not None else slo,
            steps_per_job=(objective.steps_per_job
                           if objective.steps_per_job is not None
                           else steps_per_job))
    return Objective(objective, slo=slo, steps_per_job=steps_per_job)


@dataclasses.dataclass(frozen=True)
class TrainWorkload:
    """A step-shaped workload: exactly what the optimizer always took,
    now with the job length attached to the thing being optimized instead
    of passed alongside it."""

    shape: ShapeConfig
    steps_per_job: int = DEFAULT_STEPS_PER_JOB

    def __post_init__(self):
        if self.steps_per_job < 1:
            raise ValueError("steps_per_job must be >= 1")

    @property
    def name(self) -> str:
        return self.shape.name


@dataclasses.dataclass(frozen=True)
class LengthDistribution:
    """Token-length distribution summarized by its mean and p99 — the two
    moments the analytical serving model consumes (mean sizes steady-state
    work; p99 sizes tail residency and tail latency)."""

    mean: float
    p99: Optional[float] = None

    def __post_init__(self):
        if self.mean <= 0:
            raise ValueError(f"mean length must be positive, got {self.mean}")
        if self.p99 is None:
            object.__setattr__(self, "p99", float(self.mean))
        if self.p99 < self.mean:
            raise ValueError(f"p99 ({self.p99}) below mean ({self.mean})")


@dataclasses.dataclass(frozen=True)
class ServeWorkload:
    """A request-arrival model: the serving analogue of a ShapeConfig.

    ``arrival_rate`` is the Poisson mean in requests/second; the length
    distributions are in tokens.  ``ttft_slo`` is the default p99
    time-to-first-token target (seconds) for the ``ttft_p99`` objective.
    ``kv_page_tokens`` is the paged-KV allocator's page size — it feeds
    the KV-paging HBM-residency term (slots reserve whole pages up to the
    p99 context, not the mean)."""

    name: str
    arrival_rate: float
    prompt_len: LengthDistribution
    output_len: LengthDistribution
    ttft_slo: Optional[float] = None
    kv_page_tokens: int = 128

    def __post_init__(self):
        if self.arrival_rate < 0:
            raise ValueError("arrival_rate must be >= 0")
        if self.kv_page_tokens < 0:
            raise ValueError("kv_page_tokens must be >= 0")

    @property
    def tokens_per_second(self) -> float:
        """Offered decode-token demand: arrival rate x mean output length."""
        return self.arrival_rate * self.output_len.mean


# Named serving workloads, analogous to configs.SHAPES: accepted anywhere
# a shape id is (sweep grids, examples, benchmarks).
SERVE_WORKLOADS: Dict[str, ServeWorkload] = {
    # Interactive chat: short-ish prompts, heavy aggregate decode demand.
    "chat_2k": ServeWorkload(
        "chat_2k", arrival_rate=8.0,
        prompt_len=LengthDistribution(2048, 6144),
        output_len=LengthDistribution(256, 1024),
        ttft_slo=0.5),
    # Retrieval-augmented serving: long prompts make prefill the
    # contended resource — the disaggregation scenario.
    "rag_32k": ServeWorkload(
        "rag_32k", arrival_rate=2.0,
        prompt_len=LengthDistribution(32768, 65536),
        output_len=LengthDistribution(512, 1024),
        ttft_slo=2.0),
}
