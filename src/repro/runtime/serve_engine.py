"""Batched serving engine: prefill + decode with KV caches.

Static batching (the assignment's "serve a small model with batched
requests"): requests are grouped into a fixed-slot batch, left-padded to a
common prompt length, prefilled together, then decoded in lockstep with
greedy/temperature sampling.  Per-request stop handling masks finished
slots.  The decode step is one jit-compiled executable — the `serve_step`
the dry-run lowers at production shapes.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    prompt: List[int]
    tokens: List[int]
    prefill_time_s: float
    decode_time_s: float


class ServeEngine:
    def __init__(self, model: Model, params: Any, *, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0,
                 capacity_factor: Optional[float] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.capacity_factor = capacity_factor
        self._rng = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(partial(
            model.prefill, capacity_factor=capacity_factor))
        self._decode = jax.jit(partial(
            model.decode_step, capacity_factor=capacity_factor))

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, sub = jax.random.split(self._rng)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1).astype(jnp.int32)

    def generate(self, requests: Sequence[Request],
                 frontend: Optional[jax.Array] = None) -> List[Completion]:
        """Serve one batch of requests to completion."""
        bsz = len(requests)
        plen = max(len(r.prompt) for r in requests)
        prompts = np.zeros((bsz, plen), np.int32)
        for i, r in enumerate(requests):            # left-pad
            prompts[i, plen - len(r.prompt):] = r.prompt
        max_new = max(r.max_new_tokens for r in requests)

        cache = self.model.init_cache(bsz, self.max_len)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      cache, frontend)
        prefill_t = time.perf_counter() - t0

        tokens = np.zeros((bsz, max_new), np.int32)
        done = np.zeros((bsz,), bool)
        t0 = time.perf_counter()
        tok = self._sample(logits)
        for t in range(max_new):
            tokens[:, t] = np.where(done, 0, np.asarray(tok))
            for i, r in enumerate(requests):
                if t + 1 >= r.max_new_tokens:
                    done[i] = True
                if r.eos_id is not None and tokens[i, t] == r.eos_id:
                    done[i] = True
            if done.all():
                break
            logits, cache = self._decode(self.params, tok, cache)
            tok = self._sample(logits)
        decode_t = time.perf_counter() - t0

        outs = []
        for i, r in enumerate(requests):
            toks = tokens[i].tolist()
            if r.eos_id is not None and r.eos_id in toks:
                toks = toks[:toks.index(r.eos_id) + 1]
            outs.append(Completion(r.prompt, toks[:r.max_new_tokens],
                                   prefill_t, decode_t))
        return outs
