"""Batched serving engine: continuous batching around a submit()/step() core.

The engine is the executable twin of :mod:`repro.core.serving`'s costed
schedules: a pool of decode *slots* advances in lockstep one token per
:meth:`ServeEngine.step`, and an *admission round* refills free slots from
the submission queue by prefilling the newcomers (the slot-refill loop the
schedule model prices).  Static batching — the assignment's "serve a small
model with batched requests" — is the degenerate schedule: every request
admitted in one round, zero refills.

Bookkeeping is per-request: a finished slot still occupies its batch lane
until the next admission compacts it away, but its sampled tokens are
masked out of the accounting (``stats["wasted_slot_steps"]`` counts the
padding decodes) and each completion reports *its own* decode seconds —
the numbers that can later calibrate the analytical schedule model.

Admission re-prefills the full token history of every surviving slot
alongside the newcomers (prefill/decode equivalence makes the greedy
continuation exact); a production engine would scatter the live KV rows
instead, but this reference engine keeps the cache dense and the code
honest about it.  The decode step is one jit-compiled executable — the
`serve_step` the dry-run lowers at production shapes.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    prompt: List[int]
    tokens: List[int]
    prefill_time_s: float     # this request's admission-round prefill
    decode_time_s: float      # decode seconds while THIS request was live
    rid: int = -1             # submit() ticket this completion answers


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine policy knobs, separated from the model/params payload.

    ``batching="static"`` admits every queued request in a single round
    (the degenerate continuous-batching schedule); ``"continuous"`` caps
    concurrency at ``slots`` and refills free slots between decode steps.
    ``slots=None`` sizes the pool to whatever is queued at first step."""

    max_len: int = 256
    temperature: float = 0.0
    seed: int = 0
    capacity_factor: Optional[float] = None
    batching: str = "static"          # "static" | "continuous"
    slots: Optional[int] = None

    def __post_init__(self):
        if self.batching not in ("static", "continuous"):
            raise ValueError(f"unknown batching policy {self.batching!r}")
        if self.slots is not None and self.slots < 1:
            raise ValueError("slots must be >= 1")


@dataclasses.dataclass
class _Slot:
    """One live request's lane: emitted tokens plus its pending next token
    (sampled but not yet committed — prefill logits seed the first one)."""

    request: Request
    rid: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    pending: int = 0
    done: bool = False
    prefill_s: float = 0.0
    decode_s: float = 0.0


class ServeEngine:
    def __init__(self, model: Model, params: Any,
                 config: Optional[EngineConfig] = None, *,
                 max_len: int = 256, temperature: float = 0.0,
                 seed: int = 0, capacity_factor: Optional[float] = None):
        if config is None:
            config = EngineConfig(max_len=max_len, temperature=temperature,
                                  seed=seed, capacity_factor=capacity_factor)
        self.model = model
        self.params = params
        self.config = config
        # Legacy attribute surface (pre-EngineConfig callers read these).
        self.max_len = config.max_len
        self.temperature = config.temperature
        self.capacity_factor = config.capacity_factor
        self._rng = jax.random.PRNGKey(config.seed)
        self._prefill = jax.jit(partial(
            model.prefill, capacity_factor=config.capacity_factor))
        self._decode = jax.jit(partial(
            model.decode_step, capacity_factor=config.capacity_factor))
        self._queue: List[_Slot] = []
        self._active: List[_Slot] = []
        self._cache: Any = None
        self._next_rid = 0
        self.stats: Dict[str, int] = {"decode_steps": 0,
                                      "admission_rounds": 0,
                                      "wasted_slot_steps": 0}

    # -- submission ------------------------------------------------------
    def submit(self, request: Request) -> int:
        """Queue one request; it joins the pool at the next admission
        round.  Returns the request id completions are matched by."""
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(_Slot(request, rid))
        return rid

    @property
    def pending_requests(self) -> int:
        return len(self._queue) + sum(1 for s in self._active if not s.done)

    # -- internals -------------------------------------------------------
    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.config.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._rng, sub = jax.random.split(self._rng)
        return jax.random.categorical(
            sub, logits / self.config.temperature, axis=-1).astype(jnp.int32)

    def _slot_budget(self) -> int:
        if self.config.batching == "static" or self.config.slots is None:
            return len(self._active) + len(self._queue)
        return self.config.slots

    def _admit(self, frontend: Optional[jax.Array] = None) -> None:
        """Admission round: compact finished slots out of the pool, admit
        queued requests into the freed lanes, and prefill the new batch's
        full histories (survivors continue exactly — prefill/decode
        equivalence)."""
        survivors = [s for s in self._active if not s.done]
        free = self._slot_budget() - len(survivors)
        admitted = self._queue[:max(free, 0)]
        self._queue = self._queue[len(admitted):]
        batch = survivors + admitted
        self._active = batch
        if not batch:
            self._cache = None
            return
        self.stats["admission_rounds"] += 1
        hists = [list(s.request.prompt) + s.tokens for s in batch]
        plen = max(len(h) for h in hists)
        prompts = np.zeros((len(batch), plen), np.int32)
        for i, h in enumerate(hists):               # left-pad
            prompts[i, plen - len(h):] = h
        cache = self.model.init_cache(len(batch), self.config.max_len)
        t0 = time.perf_counter()
        logits, self._cache = self._prefill(self.params,
                                            jnp.asarray(prompts), cache,
                                            frontend)
        dt = time.perf_counter() - t0
        tok = np.asarray(self._sample(logits))
        new_rids = {s.rid for s in admitted}
        for i, s in enumerate(batch):
            s.pending = int(tok[i])
            if s.rid in new_rids:
                s.prefill_s += dt

    def _commit(self, slot: _Slot) -> None:
        """Move the pending token into the transcript and update the stop
        conditions (eos is included in the output, as before)."""
        r = slot.request
        slot.tokens.append(slot.pending)
        if len(slot.tokens) >= r.max_new_tokens:
            slot.done = True
        if r.eos_id is not None and slot.tokens[-1] == r.eos_id:
            slot.done = True

    def _completion(self, slot: _Slot) -> Completion:
        return Completion(slot.request.prompt, list(slot.tokens),
                          slot.prefill_s, slot.decode_s, rid=slot.rid)

    # -- the continuous-batching core ------------------------------------
    def step(self, frontend: Optional[jax.Array] = None) -> List[Completion]:
        """Advance the pool one schedule tick: admit if lanes free up,
        commit each live slot's pending token, decode one token for the
        still-running slots.  Returns the requests that finished."""
        if self._queue and (self._cache is None
                            or any(s.done for s in self._active)
                            or len(self._active) < self._slot_budget()):
            if frontend is not None and self._active:
                raise NotImplementedError(
                    "frontend features are single-admission only: submit "
                    "all requests before the first step")
            self._admit(frontend)
        finished: List[Completion] = []
        if not self._active:
            return finished
        for s in self._active:
            if not s.done:
                self._commit(s)
                if s.done:
                    finished.append(self._completion(s))
        live = [s for s in self._active if not s.done]
        if not live:
            self._active = []
            self._cache = None
            return finished
        # One lockstep decode over the whole batch; finished lanes ride
        # along as padding until the next admission compacts them, and
        # their samples are masked out of the accounting below.
        tok = jnp.asarray(np.array([s.pending for s in self._active],
                                   np.int32))
        t0 = time.perf_counter()
        logits, self._cache = self._decode(self.params, tok, self._cache)
        nxt = np.asarray(self._sample(logits))
        dt = time.perf_counter() - t0
        self.stats["decode_steps"] += 1
        self.stats["wasted_slot_steps"] += len(self._active) - len(live)
        for i, s in enumerate(self._active):
            if not s.done:
                s.pending = int(nxt[i])
                s.decode_s += dt
        return finished

    def run(self, frontend: Optional[jax.Array] = None) -> List[Completion]:
        """Drain the queue and pool to completion (submission order)."""
        done: List[Completion] = []
        first = True
        while self.pending_requests:
            done.extend(self.step(frontend if first else None))
            first = False
        return sorted(done, key=lambda c: c.rid)

    # -- batch convenience (the original surface) ------------------------
    def generate(self, requests: Sequence[Request],
                 frontend: Optional[jax.Array] = None) -> List[Completion]:
        """Serve one batch of requests to completion.

        A fresh session: live state and the sampling stream reset to the
        seed, so identical request lists reproduce identical outputs."""
        self._queue, self._active, self._cache = [], [], None
        self._rng = jax.random.PRNGKey(self.config.seed)
        rids = [self.submit(r) for r in requests]
        by_rid = {c.rid: c for c in self.run(frontend)}
        return [by_rid[rid] for rid in rids]
