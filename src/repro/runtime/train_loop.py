"""Training runtime: jitted step factory + orchestration loop.

``make_train_step`` assembles the full step the planner's decision vector
describes: remat policy, microbatch accumulation (lax.scan), gradient
compression, AdamW — all inside ONE jit so XLA/GSPMD generates a single
runtime plan that ``hlo_cost`` can cost (the paper's object of study).

``Trainer`` adds the operational shell: cost-based plan selection,
sharded data pipeline, async checkpointing + resume, straggler monitoring,
and elastic re-mesh on cluster-size change.

``OnlineRecalibrator`` closes the estimate↔reality loop at runtime: it
watches the measured/estimated step-time ratio (EWMA), refits a
:class:`repro.core.calibration.CalibrationProfile` when the drift leaves
a band, and — only when the *re-costed plan ranking changes* — routes
through :func:`repro.runtime.elastic.replan` to switch plans.
"""
from __future__ import annotations

import dataclasses
import os
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.calibration import (CalibrationProfile, CalibrationSample,
                                    features_from_totals, fit_profile)
from repro.core.cluster import ClusterConfig
from repro.core.costmodel import (PlanCostCache, VPU_FRACTION, estimate)
from repro.core.planner import (OVERLAP_FRACTION, ShardingPlan,
                                build_step_program, choose_plan)
from repro.data.pipeline import make_pipeline
from repro.models.model import Model, build_model
from repro.optim import adamw, compress
from repro.runtime.straggler import StepTimeMonitor, decide_remesh


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    plan: ShardingPlan, *, compress_scheme: str = "none",
                    use_kernel: bool = False) -> Callable:
    """Returns train_step(params, opt_state, ef_state, batch) ->
    (params, opt_state, ef_state, metrics)."""

    def loss_of(params, batch):
        loss, metrics = model.loss(params, batch, remat=plan.remat,
                                   use_kernel=use_kernel)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)
    micro = max(plan.microbatches, 1)

    def train_step(params, opt_state, ef_state, batch):
        if micro > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape((micro, b // micro) + x.shape[1:])
            micro_batches = jax.tree.map(split, batch)

            def mb_step(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = grad_fn(params, mb)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / micro, gacc, grads)
                return (gacc, lacc + loss / micro), None

            gacc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                mb_step, (gacc0, jnp.zeros((), jnp.float32)), micro_batches)
            metrics = {}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        grads, ef_state = compress.compress_grads(grads, ef_state,
                                                  compress_scheme)
        new_params, new_opt, opt_metrics = adamw.apply(opt_cfg, opt_state,
                                                       grads, params)
        out_metrics = {"loss": loss, **opt_metrics,
                       **{k: v for k, v in metrics.items()}}
        return new_params, new_opt, ef_state, out_metrics

    return train_step


# ---------------------------------------------------------------------------
# Online recalibration (estimate↔reality loop)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RecalibrationEvent:
    """One drift-triggered refit: the EWMA ratio that tripped the band,
    the profile fitted from it, and — when the re-costed ranking changed —
    the elastic replan that switches the job onto the new winner."""

    step: int
    ratio: float                        # EWMA measured/estimated at refit
    profile: CalibrationProfile
    replanned: bool
    old_plan: str
    new_plan: str
    elastic: Optional[Any] = None       # ElasticPlan when replanned


class OnlineRecalibrator:
    """Maintains an EWMA of measured/estimated step time and refits the
    calibration profile when drift leaves the band.

    The refit path: the incumbent plan's charged :class:`ProgramTotals`
    become one peak-rate feature vector (``features_from_totals``), the
    EWMA measured time its target, and :func:`fit_profile`'s min-norm
    least squares distributes the drift across the plan's term mix —
    comm-heavy drift lands mostly on the fabric factors, compute-heavy
    drift on the MXU factors.  The candidate ranking is then re-costed
    under the fitted profile (through the shared :class:`PlanCostCache`;
    the calibration-aware cluster fingerprint keeps calibrated and
    uncalibrated entries apart) and :func:`repro.runtime.elastic.replan`
    fires only when the winner actually changes — a uniform slowdown
    rescales every candidate and changes nothing, which is exactly the
    "not merely when the ratio moves" contract.
    """

    def __init__(self, arch: ArchConfig, shape: ShapeConfig,
                 cc: ClusterConfig, *,
                 plan: Optional[ShardingPlan] = None,
                 band: Tuple[float, float] = (0.85, 1.18),
                 alpha: float = 0.25,
                 min_observations: int = 8,
                 cooldown_steps: int = 16,
                 candidates: Optional[List[ShardingPlan]] = None,
                 cache: Optional[PlanCostCache] = None):
        self.arch, self.shape = arch, shape
        self.cc = cc
        self.band = band
        self.alpha = alpha
        self.min_observations = min_observations
        self.cooldown_steps = cooldown_steps
        # an optional vetted plan family: both the ranking check and the
        # elastic replan stay inside it (None = the full enumeration)
        self.candidates = list(candidates) if candidates is not None else None
        self.cache = cache if cache is not None else PlanCostCache()
        self.events: List[RecalibrationEvent] = []
        if plan is None:
            plan = choose_plan(arch, shape, cc, top_k=1,
                               candidates=self.candidates,
                               cache=self.cache)[0].plan
        self._n = 0
        self._step = 0
        self._last_refit: Optional[int] = None
        self.ewma: Optional[float] = None
        self._set_plan(plan)

    # ------------------------------------------------------------------
    def _set_plan(self, plan: ShardingPlan) -> None:
        """Re-cost the incumbent plan under the current (possibly
        calibrated) cc: the estimate the measured ratio is taken against,
        its charged totals (the refit features), and the non-calibratable
        part of the estimate (VPU work, IO, latency)."""
        cc_p = self.cc.with_overlap(OVERLAP_FRACTION if plan.overlap else 0.0)
        est = estimate(build_step_program(self.arch, self.shape, plan, cc_p),
                       cc_p, cache=self.cache)
        self.plan = plan
        self.estimated = est.total
        self._totals = est.totals
        vpu_t = est.totals.vpu_flops / (cc_p.chip.peak("float32")
                                        * VPU_FRACTION)
        self._fixed = est.breakdown.io + est.breakdown.latency + vpu_t

    # ------------------------------------------------------------------
    def observe(self, measured_seconds: float,
                step: Optional[int] = None) -> Optional[RecalibrationEvent]:
        """Feed one measured step time; returns a
        :class:`RecalibrationEvent` when drift triggered a refit."""
        self._n += 1
        self._step = step if step is not None else self._n
        ratio = measured_seconds / self.estimated
        self.ewma = (ratio if self.ewma is None
                     else (1.0 - self.alpha) * self.ewma + self.alpha * ratio)
        if self._n < self.min_observations:
            return None
        if self.band[0] <= self.ewma <= self.band[1]:
            return None
        if (self._last_refit is not None
                and self._step - self._last_refit < self.cooldown_steps):
            return None
        return self._refit()

    # ------------------------------------------------------------------
    def _refit(self) -> RecalibrationEvent:
        from repro.runtime import elastic

        self._last_refit = self._step
        measured = self.ewma * self.estimated
        sample = CalibrationSample(
            features=features_from_totals(self._totals, self.cc),
            measured_seconds=measured,
            estimated_seconds=self.estimated,
            # the fixed offset can't exceed the measurement it is
            # subtracted from (clock noise on very fast steps)
            fixed_seconds=min(self._fixed, 0.5 * measured),
            label=f"online:{self.plan.name}@{self._step}")
        profile = fit_profile([sample], chip_name=self.cc.chip.name).profile
        new_cc = self.cc.with_calibration(profile)
        winner = choose_plan(self.arch, self.shape, new_cc, top_k=1,
                             candidates=self.candidates,
                             cache=self.cache)[0].plan
        replanned = winner != self.plan
        event = RecalibrationEvent(
            step=self._step, ratio=self.ewma, profile=profile,
            replanned=replanned, old_plan=self.plan.describe(),
            new_plan=winner.describe())
        old_plan = self.plan
        self.cc = new_cc
        if replanned:
            event.elastic = elastic.replan(
                self.arch, self.shape, old_cc=new_cc,
                new_mesh_shape=new_cc.mesh_shape,
                new_mesh_axes=new_cc.mesh_axes,
                candidates=self.candidates, cache=self.cache)
            self.cc = event.elastic.cc
            self._set_plan(event.elastic.decision.plan)
        else:
            self._set_plan(old_plan)
        # rebase the EWMA against the calibrated estimate: the fit just
        # explained the drift, so the loop restarts near ratio 1 and only
        # *new* drift can trip the band again
        self.ewma = measured / self.estimated if not replanned else None
        self._n = 0 if replanned else self._n
        self.events.append(event)
        return event


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    ckpt_dir: Optional[str] = None
    seed: int = 0
    compress_scheme: str = "none"
    use_kernel: bool = False
    donate: bool = True
    # Enable the online estimate↔reality loop: an OnlineRecalibrator
    # watches measured step times and refits the calibration profile when
    # drift leaves its band (see OnlineRecalibrator for the replan rule).
    recalibrate: bool = False


class Trainer:
    """End-to-end orchestration (CPU-runnable at reduced scale)."""

    def __init__(self, arch: ArchConfig, shape: ShapeConfig,
                 cc: ClusterConfig, mesh, *,
                 plan: Optional[ShardingPlan] = None,
                 opt_cfg: Optional[adamw.AdamWConfig] = None,
                 tcfg: Optional[TrainerConfig] = None):
        from repro.launch import shardings as S
        self.arch, self.shape, self.cc, self.mesh = arch, shape, cc, mesh
        self.tcfg = tcfg or TrainerConfig()
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(
            total_steps=self.tcfg.steps)
        if plan is None:
            plan = choose_plan(arch, shape, cc, top_k=1)[0].plan
        self.plan = plan
        self.model = build_model(arch)

        # --- shardings from the plan ---
        pshapes = self.model.init_shapes()
        self.param_sh = S.params_shardings(mesh, plan, pshapes)
        batch_shapes = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)}
        fshape = self.model.frontend_shape(shape.global_batch)
        if fshape is not None:
            batch_shapes["frontend"] = jax.ShapeDtypeStruct(
                fshape, jnp.float32)
        self.batch_sh = S.batch_shardings(mesh, plan, batch_shapes)
        opt_shapes = jax.eval_shape(partial(adamw.init, self.opt_cfg), pshapes)
        self.opt_sh = S.opt_state_shardings(mesh, plan, self.param_sh,
                                            opt_shapes)

        step_fn = make_train_step(self.model, self.opt_cfg, plan,
                                  compress_scheme=self.tcfg.compress_scheme,
                                  use_kernel=self.tcfg.use_kernel)
        donate = (0, 1) if self.tcfg.donate else ()
        self.train_step = jax.jit(step_fn, donate_argnums=donate)
        self.monitor = StepTimeMonitor()
        self.recalibrator = (OnlineRecalibrator(arch, shape, cc,
                                                plan=self.plan)
                             if self.tcfg.recalibrate else None)
        self.checkpointer = (store.AsyncCheckpointer(self.tcfg.ckpt_dir)
                             if self.tcfg.ckpt_dir else None)

    # ------------------------------------------------------------------
    def init_state(self, rng=None):
        rng = rng if rng is not None else jax.random.PRNGKey(self.tcfg.seed)
        with self.mesh:
            params = jax.jit(self.model.init,
                             out_shardings=self.param_sh)(rng)
            opt_state = jax.jit(partial(adamw.init, self.opt_cfg),
                                out_shardings=self.opt_sh)(params)
        ef = compress.init_error_feedback(params) \
            if self.tcfg.compress_scheme == "int8_ef" else \
            compress.EFState(residual=jax.tree.map(lambda p: jnp.zeros((),
                             jnp.float32), params))
        return params, opt_state, ef

    def maybe_resume(self, params, opt_state):
        if not self.tcfg.ckpt_dir:
            return params, opt_state, 0
        step = store.latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return params, opt_state, 0
        tree = {"params": params, "opt": opt_state}
        sh = {"params": self.param_sh, "opt": self.opt_sh}
        restored, step = store.restore(self.tcfg.ckpt_dir, tree, shardings=sh)
        # the checkpoint holds post-step-N state: resume at N+1
        return restored["params"], restored["opt"], step + 1

    def run(self, *, start_step: int = 0, params=None, opt_state=None,
            ef=None, on_metrics: Optional[Callable] = None) -> Dict[str, Any]:
        if params is None:
            params, opt_state, ef = self.init_state()
            params, opt_state, start_step = self.maybe_resume(params, opt_state)
        fshape = self.model.frontend_shape(self.shape.global_batch)
        pipe = make_pipeline(self.arch.vocab_size, self.shape.seq_len,
                             self.shape.global_batch, seed=self.tcfg.seed,
                             frontend_shape=fshape, start_step=start_step)
        history = []
        try:
            with self.mesh:
                for gstep, batch in pipe:
                    if gstep >= self.tcfg.steps:
                        break
                    t0 = time.perf_counter()
                    batch = {k: jax.device_put(v, self.batch_sh[k])
                             for k, v in batch.items()}
                    params, opt_state, ef, metrics = self.train_step(
                        params, opt_state, ef, batch)
                    metrics = {k: float(v) for k, v in metrics.items()}
                    dt = time.perf_counter() - t0
                    self.monitor.record({0: dt})
                    if self.recalibrator is not None:
                        # observation only: acting on a replan (restore
                        # under new shardings) stays with the caller, who
                        # reads .events / the returned history
                        self.recalibrator.observe(dt, step=gstep)
                    if gstep % self.tcfg.log_every == 0:
                        history.append({"step": gstep, "time_s": dt, **metrics})
                        if on_metrics:
                            on_metrics(history[-1])
                    if (self.checkpointer and gstep > 0
                            and gstep % self.tcfg.checkpoint_every == 0):
                        self.checkpointer.save(
                            gstep, {"params": params, "opt": opt_state})
        finally:
            pipe.close()
            if self.checkpointer:
                self.checkpointer.wait()
        return {"params": params, "opt_state": opt_state,
                "history": history}
