"""Training runtime: jitted step factory + orchestration loop.

``make_train_step`` assembles the full step the planner's decision vector
describes: remat policy, microbatch accumulation (lax.scan), gradient
compression, AdamW — all inside ONE jit so XLA/GSPMD generates a single
runtime plan that ``hlo_cost`` can cost (the paper's object of study).

``Trainer`` adds the operational shell: cost-based plan selection,
sharded data pipeline, async checkpointing + resume, straggler monitoring,
and elastic re-mesh on cluster-size change.
"""
from __future__ import annotations

import dataclasses
import os
import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.cluster import ClusterConfig
from repro.core.planner import ShardingPlan, choose_plan
from repro.data.pipeline import make_pipeline
from repro.models.model import Model, build_model
from repro.optim import adamw, compress
from repro.runtime.straggler import StepTimeMonitor, decide_remesh


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    plan: ShardingPlan, *, compress_scheme: str = "none",
                    use_kernel: bool = False) -> Callable:
    """Returns train_step(params, opt_state, ef_state, batch) ->
    (params, opt_state, ef_state, metrics)."""

    def loss_of(params, batch):
        loss, metrics = model.loss(params, batch, remat=plan.remat,
                                   use_kernel=use_kernel)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)
    micro = max(plan.microbatches, 1)

    def train_step(params, opt_state, ef_state, batch):
        if micro > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape((micro, b // micro) + x.shape[1:])
            micro_batches = jax.tree.map(split, batch)

            def mb_step(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = grad_fn(params, mb)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / micro, gacc, grads)
                return (gacc, lacc + loss / micro), None

            gacc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                mb_step, (gacc0, jnp.zeros((), jnp.float32)), micro_batches)
            metrics = {}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        grads, ef_state = compress.compress_grads(grads, ef_state,
                                                  compress_scheme)
        new_params, new_opt, opt_metrics = adamw.apply(opt_cfg, opt_state,
                                                       grads, params)
        out_metrics = {"loss": loss, **opt_metrics,
                       **{k: v for k, v in metrics.items()}}
        return new_params, new_opt, ef_state, out_metrics

    return train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    ckpt_dir: Optional[str] = None
    seed: int = 0
    compress_scheme: str = "none"
    use_kernel: bool = False
    donate: bool = True


class Trainer:
    """End-to-end orchestration (CPU-runnable at reduced scale)."""

    def __init__(self, arch: ArchConfig, shape: ShapeConfig,
                 cc: ClusterConfig, mesh, *,
                 plan: Optional[ShardingPlan] = None,
                 opt_cfg: Optional[adamw.AdamWConfig] = None,
                 tcfg: Optional[TrainerConfig] = None):
        from repro.launch import shardings as S
        self.arch, self.shape, self.cc, self.mesh = arch, shape, cc, mesh
        self.tcfg = tcfg or TrainerConfig()
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(
            total_steps=self.tcfg.steps)
        if plan is None:
            plan = choose_plan(arch, shape, cc, top_k=1)[0].plan
        self.plan = plan
        self.model = build_model(arch)

        # --- shardings from the plan ---
        pshapes = self.model.init_shapes()
        self.param_sh = S.params_shardings(mesh, plan, pshapes)
        batch_shapes = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)}
        fshape = self.model.frontend_shape(shape.global_batch)
        if fshape is not None:
            batch_shapes["frontend"] = jax.ShapeDtypeStruct(
                fshape, jnp.float32)
        self.batch_sh = S.batch_shardings(mesh, plan, batch_shapes)
        opt_shapes = jax.eval_shape(partial(adamw.init, self.opt_cfg), pshapes)
        self.opt_sh = S.opt_state_shardings(mesh, plan, self.param_sh,
                                            opt_shapes)

        step_fn = make_train_step(self.model, self.opt_cfg, plan,
                                  compress_scheme=self.tcfg.compress_scheme,
                                  use_kernel=self.tcfg.use_kernel)
        donate = (0, 1) if self.tcfg.donate else ()
        self.train_step = jax.jit(step_fn, donate_argnums=donate)
        self.monitor = StepTimeMonitor()
        self.checkpointer = (store.AsyncCheckpointer(self.tcfg.ckpt_dir)
                             if self.tcfg.ckpt_dir else None)

    # ------------------------------------------------------------------
    def init_state(self, rng=None):
        rng = rng if rng is not None else jax.random.PRNGKey(self.tcfg.seed)
        with self.mesh:
            params = jax.jit(self.model.init,
                             out_shardings=self.param_sh)(rng)
            opt_state = jax.jit(partial(adamw.init, self.opt_cfg),
                                out_shardings=self.opt_sh)(params)
        ef = compress.init_error_feedback(params) \
            if self.tcfg.compress_scheme == "int8_ef" else \
            compress.EFState(residual=jax.tree.map(lambda p: jnp.zeros((),
                             jnp.float32), params))
        return params, opt_state, ef

    def maybe_resume(self, params, opt_state):
        if not self.tcfg.ckpt_dir:
            return params, opt_state, 0
        step = store.latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return params, opt_state, 0
        tree = {"params": params, "opt": opt_state}
        sh = {"params": self.param_sh, "opt": self.opt_sh}
        restored, step = store.restore(self.tcfg.ckpt_dir, tree, shardings=sh)
        # the checkpoint holds post-step-N state: resume at N+1
        return restored["params"], restored["opt"], step + 1

    def run(self, *, start_step: int = 0, params=None, opt_state=None,
            ef=None, on_metrics: Optional[Callable] = None) -> Dict[str, Any]:
        if params is None:
            params, opt_state, ef = self.init_state()
            params, opt_state, start_step = self.maybe_resume(params, opt_state)
        fshape = self.model.frontend_shape(self.shape.global_batch)
        pipe = make_pipeline(self.arch.vocab_size, self.shape.seq_len,
                             self.shape.global_batch, seed=self.tcfg.seed,
                             frontend_shape=fshape, start_step=start_step)
        history = []
        try:
            with self.mesh:
                for gstep, batch in pipe:
                    if gstep >= self.tcfg.steps:
                        break
                    t0 = time.perf_counter()
                    batch = {k: jax.device_put(v, self.batch_sh[k])
                             for k, v in batch.items()}
                    params, opt_state, ef, metrics = self.train_step(
                        params, opt_state, ef, batch)
                    metrics = {k: float(v) for k, v in metrics.items()}
                    dt = time.perf_counter() - t0
                    self.monitor.record({0: dt})
                    if gstep % self.tcfg.log_every == 0:
                        history.append({"step": gstep, "time_s": dt, **metrics})
                        if on_metrics:
                            on_metrics(history[-1])
                    if (self.checkpointer and gstep > 0
                            and gstep % self.tcfg.checkpoint_every == 0):
                        self.checkpointer.save(
                            gstep, {"params": params, "opt": opt_state})
        finally:
            pipe.close()
            if self.checkpointer:
                self.checkpointer.wait()
        return {"params": params, "opt_state": opt_state,
                "history": history}
