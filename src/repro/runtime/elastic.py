"""Elastic re-meshing: resume training on a different device count.

The paper's R3 (resource awareness) taken to its logical end: a cluster
resize is *just a re-costing* — rebuild ClusterConfig, re-run the planner,
restore the checkpoint under the new shardings, rescale data-parallel
hyperparameters.  The checkpoint store is layout-agnostic (global arrays),
so restoring onto any mesh is a device_put with new shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.cluster import ClusterConfig
from repro.core.planner import PlanDecision, ShardingPlan, choose_plan


@dataclasses.dataclass
class ElasticPlan:
    cc: ClusterConfig
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    decision: PlanDecision
    lr_scale: float                 # linear-scaling rule on DP resize


def replan(arch: ArchConfig, shape: ShapeConfig, *,
           old_cc: ClusterConfig, new_mesh_shape: Tuple[int, ...],
           new_mesh_axes: Optional[Tuple[str, ...]] = None) -> ElasticPlan:
    axes = new_mesh_axes or old_cc.mesh_axes
    new_cc = old_cc.with_mesh(new_mesh_shape, axes)
    decision = choose_plan(arch, shape, new_cc, top_k=1)[0]
    old_dp = _dp_degree(old_cc)
    new_dp = _dp_degree(new_cc)
    return ElasticPlan(new_cc, tuple(new_mesh_shape), tuple(axes), decision,
                       lr_scale=new_dp / max(old_dp, 1))


def _dp_degree(cc: ClusterConfig) -> int:
    d = 1
    for ax in ("pod", "data"):
        d *= cc.axis_size(ax)
    return d


def reshard(tree: Any, shardings: Any) -> Any:
    """Move a restored (host or old-mesh) pytree onto new shardings."""
    if shardings is None:
        return tree
    flat_t, treedef = jax.tree.flatten(tree)
    flat_s = jax.tree.leaves(shardings)
    return treedef.unflatten(
        [jax.device_put(t, s) if s is not None else t
         for t, s in zip(flat_t, flat_s)])
