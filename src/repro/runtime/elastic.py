"""Elastic re-meshing: resume training on a different device count.

The paper's R3 (resource awareness) taken to its logical end: a cluster
resize is *just a re-costing* — rebuild ClusterConfig, re-run the planner,
restore the checkpoint under the new shardings, rescale data-parallel
hyperparameters.  The checkpoint store is layout-agnostic (global arrays),
so restoring onto any mesh is a device_put with new shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.calibration import CalibrationProfile
from repro.core.cluster import ClusterConfig
from repro.core.costmodel import PlanCostCache
from repro.core.planner import PlanDecision, ShardingPlan, choose_plan
from repro.core.resource import (DEFAULT_STEPS_PER_JOB, torus_links_for,
                                 mesh_candidates, optimize_resources)
from repro.core.workload import (Objective, ServeWorkload, TrainWorkload)


@dataclasses.dataclass
class ElasticPlan:
    cc: ClusterConfig
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    decision: PlanDecision
    lr_scale: float                 # linear-scaling rule on DP resize


def replan(arch: ArchConfig,
           shape: Union[ShapeConfig, TrainWorkload, ServeWorkload], *,
           old_cc: ClusterConfig,
           new_mesh_shape: Optional[Tuple[int, ...]] = None,
           new_mesh_axes: Optional[Tuple[str, ...]] = None,
           available_chips: Optional[int] = None,
           objective: Union[str, Objective] = "step_time",
           steps_per_job: int = DEFAULT_STEPS_PER_JOB,
           cache: Optional[PlanCostCache] = None,
           calibration: Optional[CalibrationProfile] = None,
           candidates=None) -> ElasticPlan:
    """Re-cost the program for a resized cluster.

    Pass ``new_mesh_shape`` to pin the mesh explicitly (the old behavior),
    or just ``available_chips`` — e.g. the device count that survived a
    failure — and the resource optimizer picks the best mesh factorization
    of the survivors (same chip: every (data x model) layout, the 3D-torus
    layouts on 3D-capable chips, and always at least the degenerate 1D
    all-data mesh, so prime survivor counts never strand the job) by
    ``C(P, cc)`` under ``objective``, instead of a hand-rolled dp-degree
    guess.
    ``objective="job_cost"`` (with ``steps_per_job`` for the remaining job
    length) picks the cheapest way to *finish the job* — relevant after a
    loss, when restart overheads have just been paid.

    The workload may be typed (:class:`TrainWorkload` /
    :class:`ServeWorkload`) and the objective a typed :class:`Objective`:
    a serving fleet that loses a slice replans its (pool x slots x plan)
    schedule under its traffic model, e.g. ``objective="ttft_p99"``.

    ``calibration`` attaches (or, as ``old_cc.calibration`` does by
    default, carries over) a fitted :class:`CalibrationProfile`: the
    replan is then priced under measured rates — this is the path the
    online recalibrator (:class:`repro.runtime.train_loop
    .OnlineRecalibrator`) takes when drift flips the plan ranking.  Note
    ``with_mesh``/``dataclasses.replace`` preserve ``old_cc.calibration``
    on every derived config, so a calibrated job stays calibrated across
    resizes without re-passing the profile.  ``candidates`` restricts the
    plan search to a vetted plan family (a sequence of
    :class:`ShardingPlan`; plain ``ShapeConfig`` workloads only) — the
    online recalibrator passes its own family through here so the
    drift-triggered replan can never jump outside the plans operations
    has signed off on.
    """
    if calibration is not None:
        old_cc = dataclasses.replace(old_cc, calibration=calibration)
    if new_mesh_shape is not None:
        axes = new_mesh_axes or old_cc.mesh_axes
        # A pinned 3-axis mesh on a 3D-torus-capable chip gets the same
        # wrapped-ring link counts the candidate enumeration would give
        # it — both replan entry points must price identical hardware
        # identically (torus_links_for gates on the chip's fabric).
        new_cc = old_cc.with_mesh(
            new_mesh_shape, axes,
            torus_links=torus_links_for(tuple(axes), old_cc.chip,
                                        tuple(new_mesh_shape)))
        if isinstance(shape, (TrainWorkload, ServeWorkload)):
            best = optimize_resources(arch, shape, [("pinned", new_cc)],
                                      objective=objective,
                                      steps_per_job=steps_per_job,
                                      cache=cache)[0]
            decision = best.decision
        else:
            decision = choose_plan(arch, shape, new_cc, top_k=1,
                                   candidates=candidates, cache=cache)[0]
    elif available_chips is not None:
        cands = mesh_candidates(old_cc.chip, available_chips, base=old_cc)
        if not cands:
            raise ValueError(f"no candidate meshes for {available_chips} "
                             "surviving chips")
        best = optimize_resources(arch, shape, cands, objective=objective,
                                  steps_per_job=steps_per_job,
                                  cache=cache)[0]
        new_cc, decision = best.cc, best.decision
    else:
        raise ValueError("replan needs new_mesh_shape or available_chips")
    old_dp = _dp_degree(old_cc)
    new_dp = _dp_degree(new_cc)
    return ElasticPlan(new_cc, tuple(new_cc.mesh_shape),
                       tuple(new_cc.mesh_axes), decision,
                       lr_scale=new_dp / max(old_dp, 1))


def _dp_degree(cc: ClusterConfig) -> int:
    d = 1
    for ax in ("pod", "data"):
        d *= cc.axis_size(ax)
    return d


def reshard(tree: Any, shardings: Any) -> Any:
    """Move a restored (host or old-mesh) pytree onto new shardings."""
    if shardings is None:
        return tree
    flat_t, treedef = jax.tree.flatten(tree)
    flat_s = jax.tree.leaves(shardings)
    return treedef.unflatten(
        [jax.device_put(t, s) if s is not None else t
         for t, s in zip(flat_t, flat_s)])
