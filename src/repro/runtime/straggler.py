"""Straggler detection + cost-based mitigation decision.

SPMD steps are lockstep, so a slow host drags the whole pod; the TPU-world
mitigation is *exclude and re-mesh* (checkpoint -> rebuild without the slow
pod), not MR-style backup tasks.  The novelty here, in the paper's spirit:
the decision is **cost-based** — we compare the estimated cost of the two
plans (keep limping vs. pay the re-mesh) with the same linearized
time-cost machinery used everywhere else.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core.cluster import ClusterConfig


@dataclasses.dataclass
class StragglerVerdict:
    is_straggler: bool
    slow_entities: List[int]
    slowdown: float                # measured step-time inflation factor
    action: str                    # "none" | "tolerate" | "remesh"
    detail: str = ""


class StepTimeMonitor:
    """Robust (median/MAD) outlier detection over per-entity step times.

    Entities are whatever granularity the runtime reports: hosts, pods, or
    data-parallel groups.  ``record`` takes a dict entity->seconds.
    """

    def __init__(self, window: int = 32, z_threshold: float = 4.0,
                 min_samples: int = 8):
        self.window = window
        self.z = z_threshold
        self.min_samples = min_samples
        self._hist: Dict[int, Deque[float]] = {}

    def record(self, times: Dict[int, float]) -> None:
        for ent, t in times.items():
            self._hist.setdefault(ent, deque(maxlen=self.window)).append(float(t))

    def detect(self) -> StragglerVerdict:
        if not self._hist or any(len(v) < self.min_samples
                                 for v in self._hist.values()):
            return StragglerVerdict(False, [], 1.0, "none", "warming up")
        med_per_ent = {e: float(np.median(v)) for e, v in self._hist.items()}
        meds = np.asarray(list(med_per_ent.values()))
        overall = float(np.median(meds))
        mad = float(np.median(np.abs(meds - overall))) + 1e-9
        slow = [e for e, m in med_per_ent.items()
                if (m - overall) / (1.4826 * mad) > self.z
                and m > 1.05 * overall]
        if not slow:
            return StragglerVerdict(False, [], 1.0, "none")
        worst = max(med_per_ent[e] for e in slow)
        return StragglerVerdict(True, sorted(slow), worst / overall,
                                "detected")


def decide_remesh(verdict: StragglerVerdict, *, cc: ClusterConfig,
                  healthy_step_time: float, remaining_steps: int,
                  checkpoint_bytes_per_device: float,
                  excluded_fraction: float) -> StragglerVerdict:
    """Cost-based mitigation: C(tolerate) vs C(remesh).

    tolerate: remaining_steps * healthy_step_time * slowdown
    remesh:   restore IO + recompile + remaining_steps * healthy_step_time
              / (1 - excluded_fraction)   [fewer chips -> slower steps]
    """
    if not verdict.is_straggler:
        return verdict
    c_tolerate = remaining_steps * healthy_step_time * verdict.slowdown
    restore_t = (checkpoint_bytes_per_device / cc.chip.disk_bw
                 + checkpoint_bytes_per_device / cc.chip.pcie_bw)
    recompile_t = 120.0                     # conservative constant
    c_remesh = (restore_t + recompile_t
                + remaining_steps * healthy_step_time
                / max(1.0 - excluded_fraction, 1e-6))
    action = "remesh" if c_remesh < c_tolerate else "tolerate"
    return dataclasses.replace(
        verdict, action=action,
        detail=(f"C(tolerate)={c_tolerate:.1f}s vs C(remesh)={c_remesh:.1f}s "
                f"(restore={restore_t:.1f}s)"))
