"""Serving driver: ``python -m repro.launch.serve --arch <id> --reduced``.

Batched prefill+decode with the ServeEngine; production-shape serving
plans are exercised (lowered+compiled) via dryrun.py's decode cells.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.model import build_model
from repro.runtime.serve_engine import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    arch = get_config(args.arch)
    if args.reduced:
        arch = dataclasses.replace(arch.reduced(), dtype="float32")
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_len=args.max_len,
                         temperature=args.temperature)

    rng = np.random.default_rng(0)
    reqs = [Request(prompt=list(rng.integers(1, arch.vocab_size,
                                             size=args.prompt_len)),
                    max_new_tokens=args.max_new)
            for _ in range(args.batch)]
    frontend = None
    fs = model.frontend_shape(args.batch)
    if fs is not None:
        frontend = jax.numpy.asarray(rng.standard_normal(fs),
                                     jax.numpy.float32)
    outs = engine.generate(reqs, frontend)
    for i, c in enumerate(outs):
        print(f"req{i}: prompt[:8]={c.prompt[:8]} -> tokens={c.tokens}")
    print(f"prefill {outs[0].prefill_time_s*1e3:.1f}ms, "
          f"decode {outs[0].decode_time_s*1e3:.1f}ms "
          f"({args.max_new} steps, batch {args.batch})")


if __name__ == "__main__":
    main()
