"""Component-level costing of the generated plan (fixes scan undercount).

XLA's ``cost_analysis()`` visits a while/scan body ONCE, so a model scanned
over layers reports ~1/n_layers of its true FLOPs and collective bytes.
The paper's own methodology is the fix: cost each *instruction* of the
runtime program and aggregate over the program structure (Eq 1).  Here the
"instructions" are compiled XLA executables:

    step_cost = sum_i  count_i * CompiledCost(component_i)

Components per architecture family:
  * dense/moe/mla/vlm : decoder block  x n_layers (dense + moe stacks split)
  * ssm               : mamba block    x n_layers
  * hybrid            : mamba block x n_layers + shared attn x n_apply
  * enc-dec           : encoder block x n_enc + decoder block x n_dec
  * window-pattern    : one component per distinct window value
  plus a tail (embed + chunked-CE head + optimizer update + cross-replica
  grad reduce for train; lm head for serve).  Decode components carry their
  per-layer KV/state cache so cache-read traffic is costed.

Each component is lowered+compiled under the SAME mesh/shardings as the
full step, so GSPMD generates the per-layer collectives (TP psums, EP
all-to-alls, DP grad reduces) and they are counted exactly count_i times.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import hlo_cost
from repro.core.cluster import ClusterConfig
from repro.core.planner import ShardingPlan
from repro.launch import shardings as S
from repro.models import transformer as T
from repro.models.model import build_model
from repro.optim import adamw


@dataclasses.dataclass
class Component:
    name: str
    count: int
    cost: hlo_cost.CompiledCost


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype,
                                sharding=sharding)


def _sz(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def _guarded(mesh, dim, axes):
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes or dim % _sz(mesh, axes) != 0 or _sz(mesh, axes) <= 1:
        return None
    return axes if len(axes) > 1 else axes[0]


def _ns(mesh, *spec):
    return NamedSharding(mesh, P(*spec))


def _param_specs(mesh, plan, shapes_tree, path_prefix: str, drop_stack: bool):
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes_tree)
    out = []
    for path, leaf in flat:
        key = path_prefix + "/" + "/".join(S._pstr(p) for p in path)
        full = S.param_sharding(mesh, plan, key, tuple(leaf.shape))
        spec = list(full.spec) + [None] * (len(leaf.shape) - len(full.spec))
        if drop_stack:
            spec, shape = spec[1:], leaf.shape[1:]
        else:
            shape = leaf.shape
        out.append(_sds(shape, leaf.dtype, _ns(mesh, *spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


def _act_spec(mesh, plan, batch, seq, d, dtype):
    b = _guarded(mesh, batch, plan.batch_axes)
    s = _guarded(mesh, seq, plan.seq_axes)
    return _sds((batch, seq, d), dtype, _ns(mesh, b, s, None))


def _cache_slice_specs(mesh, plan, shapes: Dict[str, Any]):
    """Shardings for one layer's cache slice (no leading stack dim)."""
    out = {}
    for key, sds in shapes.items():
        shp = sds.shape
        nd = len(shp)
        if key == "kpos":
            out[key] = _sds(shp, sds.dtype, _ns(mesh))
            continue
        b = _guarded(mesh, shp[0], plan.batch_axes)
        if nd == 4:      # [B, H, cap, hd] kv  / [B, H, P, N] ssm state
            h = _guarded(mesh, shp[1], plan.tp_axes)
            s = None
            if b is None and key in ("k", "v"):
                s = _guarded(mesh, shp[2], plan.batch_axes)
            out[key] = _sds(shp, sds.dtype, _ns(mesh, b, h, s, None))
        elif nd == 3:    # [B, S, r] mla latent / [B, W-1, C] conv
            s = None
            if b is None and key in ("ckv", "krope"):
                s = _guarded(mesh, shp[1], plan.batch_axes)
            out[key] = _sds(shp, sds.dtype, _ns(mesh, b, s, None))
        else:
            out[key] = _sds(shp, sds.dtype, _ns(mesh, b, *([None] * (nd - 1))))
    return out


def _train_wrap(fn, remat: str):
    inner = fn
    if remat == "full":
        inner = jax.checkpoint(fn)
    elif remat == "selective":
        inner = jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    def wrapped(p, x):
        y, vjp = jax.vjp(inner, p, x)
        dp, dx = vjp(jnp.ones_like(y))
        return y.sum(), dp, dx
    return wrapped


def _compile(name, fn, specs, mesh) -> hlo_cost.CompiledCost:
    from repro.models import costing_mode
    with costing_mode.costing_unroll():
        with mesh:
            compiled = jax.jit(fn).lower(*specs).compile()
    return hlo_cost.from_compiled(name, compiled, mesh.devices.size)


def component_costs(arch: ArchConfig, shape: ShapeConfig, plan: ShardingPlan,
                    mesh) -> List[Component]:
    cfg = arch
    model = build_model(cfg)
    mode = shape.mode
    dtype = jnp.dtype(cfg.dtype)
    micro = max(plan.microbatches, 1) if mode == "train" else 1
    batch = max(shape.global_batch // micro, 1)
    q_len = 1 if mode == "decode" else shape.seq_len
    kv_len = shape.seq_len
    d = cfg.d_model

    pshapes = model.init_shapes()
    # Layer components are compiled as ONE data-parallel replica: the batch
    # is pre-sliced by the dp degree and dp axes dropped, so GSPMD does not
    # emit per-layer param-grad psums (the real program accumulates grads
    # locally and reduces ONCE — counted by the grad_reduce component).
    # TP/EP axes (and their collectives) are kept.
    dp_deg = max(_sz(mesh, tuple(a for a in plan.batch_axes
                                 if a in mesh.shape)), 1)
    sp_deg = max(_sz(mesh, tuple(a for a in plan.seq_axes
                                 if a in mesh.shape)), 1)
    local_plan = dataclasses.replace(plan, batch_axes=(), seq_axes=())
    batch = max(batch // dp_deg, 1)
    if mode != "decode":
        q_len = max(q_len // sp_deg, 1)
    x_spec = _act_spec(mesh, local_plan, batch, q_len, d, dtype)
    cache_shapes_full = (model.cache_shapes(batch, kv_len)
                         if mode == "decode" else None)
    plan_for_caches = local_plan
    comps: List[Component] = []

    def layer_cache_slice(group_key: str):
        grp = cache_shapes_full[group_key]
        sliced = {k: _sds(v.shape[1:], v.dtype) for k, v in grp.items()}
        return _cache_slice_specs(mesh, plan_for_caches, sliced)

    def block_fwd(window, moe, cache_group):
        def fwd_nocache(p, x):
            pos = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                   (x.shape[0], x.shape[1]))
            out, _, _ = T.block_apply(cfg, p, x, positions=pos,
                                      window=window, moe=moe)
            return out

        def fwd_cache(p, x, c):
            pos = jnp.full((x.shape[0], 1), kv_len - 1, jnp.int32)
            out, c2, _ = T.block_apply(cfg, p, x, positions=pos,
                                       window=window, moe=moe, kv_cache=c)
            return out, c2
        return fwd_cache if cache_group else fwd_nocache

    def add_block(name, count, stacked, *, kind="attn", window=None,
                  moe=False, cache_group=None, stacked_is_layer=False):
        count = count * micro          # layers run once per microbatch
        lay_specs = (_param_specs(mesh, plan, stacked, "blocks", False)
                     if stacked_is_layer else
                     _param_specs(mesh, plan, stacked, "blocks", True))
        if kind == "mamba":
            if mode == "decode":
                cache_specs = layer_cache_slice("mamba")

                def fn(p, x, c):
                    return T.mamba_layer_apply(cfg, p, x, c)[:2]
                specs = (lay_specs, x_spec, cache_specs)
            else:
                def fwd(p, x):
                    return T.mamba_layer_apply(cfg, p, x, None)[0]
                fn = _train_wrap(fwd, plan.remat) if mode == "train" else fwd
                specs = (lay_specs, x_spec)
        else:
            if mode == "decode":
                cache_specs = layer_cache_slice(cache_group)
                fn = block_fwd(window, moe, True)
                specs = (lay_specs, x_spec, cache_specs)
            else:
                fwd = block_fwd(window, moe, False)
                fn = _train_wrap(fwd, plan.remat) if mode == "train" else fwd
                specs = (lay_specs, x_spec)
        comps.append(Component(name, count, _compile(name, fn, specs, mesh)))

    fam = cfg.family
    if fam == "ssm":
        add_block("mamba_layer", cfg.n_layers, pshapes["blocks"], kind="mamba")
    elif fam == "hybrid":
        add_block("mamba_layer", cfg.n_layers, pshapes["blocks"], kind="mamba")
        n_app = cfg.n_layers // cfg.hybrid.attn_every
        shared = pshapes["shared_attn"][0]
        lay_specs = _param_specs(mesh, plan, shared, "shared", False)
        if mode == "decode":
            grp = cache_shapes_full["attn"]
            sliced = {k: _sds(v.shape[1:], v.dtype) for k, v in grp.items()}
            cache_specs = _cache_slice_specs(mesh, plan, sliced)
            fn = block_fwd(None, False, True)
            comps.append(Component("shared_attn", n_app * micro,
                                   _compile("shared_attn", fn,
                                            (lay_specs, x_spec, cache_specs),
                                            mesh)))
        else:
            fwd = block_fwd(None, False, False)
            fn = _train_wrap(fwd, plan.remat) if mode == "train" else fwd
            comps.append(Component("shared_attn", n_app * micro,
                                   _compile("shared_attn", fn,
                                            (lay_specs, x_spec), mesh)))
    elif cfg.enc_dec is not None:
        enc_len = cfg.enc_dec.encoder_seq
        enc_x = _act_spec(mesh, local_plan, batch, enc_len, d, dtype)
        enc_specs = _param_specs(mesh, plan, pshapes["enc_blocks"],
                                 "enc_blocks", True)

        def enc_fwd(p, x):
            pos = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                   (x.shape[0], x.shape[1]))
            return T.block_apply(cfg, p, x, positions=pos, window=None,
                                 causal=False)[0]
        # encoder runs only at prefill/train (decode reuses cached cross-KV)
        if mode != "decode":
            fn = _train_wrap(enc_fwd, plan.remat) if mode == "train" else enc_fwd
            comps.append(Component("encoder_layer",
                                   cfg.enc_dec.n_encoder_layers * micro,
                                   _compile("encoder_layer", fn,
                                            (enc_specs, enc_x), mesh)))

        dec_specs = _param_specs(mesh, plan, pshapes["blocks"], "blocks", True)
        nkv, hd = cfg.n_kv_heads, cfg.head_dim_
        ck_spec = _sds((batch, nkv, enc_len, hd), dtype,
                       _ns(mesh, None,
                           _guarded(mesh, nkv, plan.tp_axes), None, None))
        if mode == "decode":
            sliced = {k: _sds(v.shape[1:], v.dtype)
                      for k, v in cache_shapes_full["self"].items()}
            cache_specs = _cache_slice_specs(mesh, plan_for_caches, sliced)

            def fn(p, x, c, ck, cv):
                pos = jnp.full((x.shape[0], 1), kv_len - 1, jnp.int32)
                out, c2, _ = T.block_apply(cfg, p, x, positions=pos,
                                           window=None, kv_cache=c,
                                           cross_state=(ck, cv))
                return out, c2
            comps.append(Component("decoder_layer", cfg.n_layers * micro,
                                   _compile("decoder_layer", fn,
                                            (dec_specs, x_spec, cache_specs,
                                             ck_spec, ck_spec), mesh)))
        else:
            def dec_fwd3(p, x, e):
                pos = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                       (x.shape[0], x.shape[1]))
                ck, cv = T.cross_kv(cfg, p["cross"], e)
                return T.block_apply(cfg, p, x, positions=pos, window=None,
                                     cross_state=(ck, cv))[0]
            if mode == "train":
                def fn(p, x, e):
                    y, vjp = jax.vjp(dec_fwd3, p, x, e)
                    dp, dx, de = vjp(jnp.ones_like(y))
                    return y.sum(), dp, dx
            else:
                fn = dec_fwd3
            comps.append(Component("decoder_layer", cfg.n_layers * micro,
                                   _compile("decoder_layer", fn,
                                            (dec_specs, x_spec, enc_x), mesh)))
    elif cfg.moe is not None:
        nd = cfg.moe.first_dense_layers
        if nd and "dense_blocks" in pshapes:
            add_block("dense_layer", nd, pshapes["dense_blocks"],
                      cache_group="dense")
        add_block("moe_layer", cfg.n_layers - nd, pshapes["blocks"],
                  moe=True, cache_group="moe")
    elif cfg.window_pattern is not None:
        period = len(cfg.window_pattern)
        n_cycles = cfg.n_layers // period
        counts = Counter(cfg.window_pattern)
        for w, cnt in counts.items():
            stacked = pshapes["cycles"][cfg.window_pattern.index(w)]
            eff_w = None if w is None else min(w, kv_len)
            add_block(f"layer_w{w or 'global'}", n_cycles * cnt, stacked,
                      window=eff_w,
                      cache_group=f"p{cfg.window_pattern.index(w)}")
    else:
        add_block("decoder_layer", cfg.n_layers, pshapes["blocks"],
                  cache_group="self")

    # ------------------------------------------------------------- tail
    embed_specs = {
        "embed": _sds(pshapes["embed"].shape, dtype,
                      S.param_sharding(mesh, plan, "embed",
                                       tuple(pshapes["embed"].shape))),
        "final_norm": _sds((d,), jnp.float32),
    }
    if "lm_head" in pshapes:
        embed_specs["lm_head"] = _sds(
            pshapes["lm_head"].shape, dtype,
            S.param_sharding(mesh, plan, "lm_head",
                             tuple(pshapes["lm_head"].shape)))
    if mode == "train":
        tok_spec = _sds((batch, q_len), jnp.int32, _ns(mesh, None, None))

        # CE head costed UNCHUNKED over the microbatch: same FLOPs and
        # logits write+read traffic as the real chunked scan, but head-
        # weight grads reduce once (as in the real step, where the scan
        # accumulates locally) instead of once per chunk.
        ce_tokens = batch * max(q_len - 1, 1)
        hce_spec = _sds((ce_tokens, d), dtype, _ns(mesh, None, None))
        tce_spec = _sds((ce_tokens,), jnp.int32, _ns(mesh, None))

        def ce_fn(ep, hc, tc):
            def inner(ep, hc):
                logits = T._head(cfg, ep, hc[None])[0]
                logz = jax.nn.logsumexp(logits, axis=-1)
                ll = jnp.take_along_axis(logits, tc[:, None], axis=-1)[:, 0]
                return (logz - ll).sum()
            ce, vjp = jax.vjp(inner, ep, hc)
            dp, dh = vjp(jnp.ones_like(ce))
            return ce, dp, dh
        comps.append(Component("ce_head", micro,
                               _compile("ce_head", ce_fn,
                                        (embed_specs, hce_spec, tce_spec),
                                        mesh)))

        def embed_fn(ep, tokens):
            def inner(e):
                return jnp.take(e, tokens, axis=0)
            y, vjp = jax.vjp(inner, ep["embed"])
            (de,) = vjp(jnp.ones_like(y))
            return y.sum(), de
        comps.append(Component("embed", micro,
                               _compile("embed", embed_fn,
                                        (embed_specs, tok_spec), mesh)))

        psh = S.params_shardings(mesh, plan, pshapes)
        pspecs = jax.tree.map(lambda sds, sh: _sds(sds.shape, sds.dtype, sh),
                              pshapes, psh)
        opt_shapes = jax.eval_shape(partial(adamw.init, adamw.AdamWConfig()),
                                    pshapes)
        osh = S.opt_state_shardings(mesh, plan, psh, opt_shapes)
        ospecs = jax.tree.map(lambda sds, sh: _sds(sds.shape, sds.dtype, sh),
                              opt_shapes, osh)
        ocfg = adamw.AdamWConfig()

        def opt_fn(params, opt_state, grads):
            p2, o2, _ = adamw.apply(ocfg, opt_state, grads, params)
            return p2, o2
        comps.append(Component("optimizer", 1,
                               _compile("optimizer", opt_fn,
                                        (pspecs, ospecs, pspecs), mesh)))

        dp_axes = tuple(a for a in plan.batch_axes if a in mesh.shape)
        if _sz(mesh, dp_axes) > 1 and not plan.fsdp_axes:
            from jax.experimental import shard_map as shmap
            gd = jnp.dtype(plan.grad_reduce_dtype)

            def psum_fn(g):
                return jax.tree.map(lambda x: jax.lax.psum(x, dp_axes), g)
            in_specs = jax.tree.map(lambda s: s.spec, psh)
            fn = shmap.shard_map(psum_fn, mesh=mesh, in_specs=(in_specs,),
                                 out_specs=in_specs)
            gspecs = jax.tree.map(
                lambda sds, sh: _sds(sds.shape, gd, sh), pshapes, psh)
            comps.append(Component("grad_reduce", 1,
                                   _compile("grad_reduce", fn, (gspecs,),
                                            mesh)))
    else:
        def head_fn(ep, h):
            return T._head(cfg, ep, h)
        comps.append(Component("lm_head", 1,
                               _compile("lm_head", head_fn,
                                        (embed_specs, x_spec), mesh)))
    return comps


def aggregate(comps: List[Component], cc: ClusterConfig) -> Dict[str, Any]:
    """Eq (1): weighted sum of component costs -> step roofline terms."""
    flops = bytes_ = coll_bytes = 0.0
    coll_time = 0.0
    per = []
    for c in comps:
        r = c.cost.roofline(cc)
        flops += c.count * c.cost.flops_per_device
        bytes_ += c.count * c.cost.bytes_per_device
        coll_bytes += c.count * c.cost.collective_bytes
        coll_time += c.count * r["collective_s"]
        per.append({"name": c.name, "count": c.count,
                    "flops_per_device": c.cost.flops_per_device,
                    "bytes_per_device": c.cost.bytes_per_device,
                    "collective_bytes": c.cost.collective_bytes,
                    "collectives": c.cost.collective_bytes_by_kind()})
    compute_s = flops / cc.chip.peak("bfloat16")
    memory_s = bytes_ / cc.chip.hbm_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_time}
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "dominant": dominant,
        "roofline_bound_s": max(terms.values()),
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "collective_bytes_per_device": coll_bytes,
        "components": per,
    }
