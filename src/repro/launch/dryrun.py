import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves (without hardware) that the distribution config
is coherent: jit(train_step|serve_step).lower(specs).compile() succeeds on
the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh, then records

  * compiled.memory_analysis()  — fits-in-HBM evidence,
  * compiled.cost_analysis()    — per-device FLOPs / bytes,
  * the generated collectives (parsed from optimized HLO),
  * the three roofline terms (§Roofline),

into ``benchmarks/artifacts/dryrun_<arch>_<shape>_<mesh>[_<tag>].json``.
Cells are cached — delete the JSON or pass --force to re-run.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  ... --plan '{"remat": "full", "microbatches": 4}'   (hillclimb override)
"""
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.core import hlo_cost
from repro.core.cluster import multi_pod_config, single_pod_config
from repro.core.planner import ShardingPlan, choose_plan
from repro.launch import shardings as S
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.optim import adamw
from repro.runtime.train_loop import make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts")


def _specs_with_shardings(shapes_tree: Any, shardings_tree: Any) -> Any:
    return jax.tree.map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        shapes_tree, shardings_tree)


def input_specs(arch_id: str, shape_id: str, mesh, plan: ShardingPlan
                ) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    arch = get_config(arch_id)
    shape = SHAPES[shape_id]
    model = build_model(arch)
    out: Dict[str, Any] = {}

    pshapes = model.init_shapes()
    psh = S.params_shardings(mesh, plan, pshapes)
    out["params"] = _specs_with_shardings(pshapes, psh)

    if shape.mode == "train":
        batch_shapes = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)}
        fshape = model.frontend_shape(shape.global_batch)
        if fshape is not None:
            batch_shapes["frontend"] = jax.ShapeDtypeStruct(fshape, jnp.float32)
        bsh = S.batch_shardings(mesh, plan, batch_shapes)
        out["batch"] = _specs_with_shardings(batch_shapes, bsh)
        opt_shapes = jax.eval_shape(
            partial(adamw.init, adamw.AdamWConfig()), pshapes)
        osh = S.opt_state_shardings(mesh, plan, psh, opt_shapes)
        out["opt_state"] = _specs_with_shardings(opt_shapes, osh)
    elif shape.mode == "prefill":
        batch_shapes = {"tokens": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)}
        fshape = model.frontend_shape(shape.global_batch)
        if fshape is not None:
            batch_shapes["frontend"] = jax.ShapeDtypeStruct(fshape, jnp.float32)
        bsh = S.batch_shardings(mesh, plan, batch_shapes)
        out["batch"] = _specs_with_shardings(batch_shapes, bsh)
        cache_shapes = model.cache_shapes(shape.global_batch, shape.seq_len)
        csh = S.cache_shardings(mesh, plan, cache_shapes)
        out["cache"] = _specs_with_shardings(cache_shapes, csh)
    else:  # decode
        tok_shapes = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        out["token"] = jax.ShapeDtypeStruct(
            tok_shapes.shape, tok_shapes.dtype,
            sharding=S.batch_shardings(mesh, plan, {"t": tok_shapes})["t"])
        cache_shapes = model.cache_shapes(shape.global_batch, shape.seq_len)
        csh = S.cache_shardings(mesh, plan, cache_shapes)
        out["cache"] = _specs_with_shardings(cache_shapes, csh)
    return out


def build_step_fn(arch_id: str, shape_id: str, plan: ShardingPlan):
    arch = get_config(arch_id)
    shape = SHAPES[shape_id]
    model = build_model(arch)
    if shape.mode == "train":
        opt_cfg = adamw.AdamWConfig()
        step = make_train_step(model, opt_cfg, plan)

        def train_step(params, opt_state, batch):
            from repro.optim.compress import EFState
            ef = EFState(residual=jax.tree.map(
                lambda p: jnp.zeros((), jnp.float32), params))
            p2, o2, _, metrics = step(params, opt_state, ef, batch)
            return p2, o2, metrics["loss"]
        return train_step, ("params", "opt_state", "batch"), (0, 1)
    if shape.mode == "prefill":
        def prefill_step(params, batch, cache):
            return model.prefill(params, batch["tokens"], cache,
                                 batch.get("frontend"))
        return prefill_step, ("params", "batch", "cache"), (2,)

    def serve_step(params, token, cache):
        return model.decode_step(params, token, cache)
    return serve_step, ("params", "token", "cache"), (2,)


def run_cell(arch_id: str, shape_id: str, mesh_kind: str, *,
             plan_override: Optional[Dict] = None, tag: str = "",
             force: bool = False, artifact_dir: str = ARTIFACT_DIR,
             components_only: bool = False) -> Dict[str, Any]:
    os.makedirs(artifact_dir, exist_ok=True)
    name = f"dryrun_{arch_id}_{shape_id}_{mesh_kind}{('_' + tag) if tag else ''}"
    path = os.path.join(artifact_dir, name.replace("/", "_") + ".json")
    if os.path.exists(path) and not force and not components_only:
        with open(path) as f:
            return json.load(f)
    if components_only and os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
        if record["status"] != "ok":
            return record
        if (not force
                and record.get("roofline", {}).get("source") == "components"
                and "error" not in (record.get("roofline_components") or {})):
            return record                      # already componentized
        return _add_components(record, path, plan_override)

    arch = get_config(arch_id)
    shape = SHAPES[shape_id]
    ok, why = shape_applicable(arch, shape)
    record: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_id, "mesh": mesh_kind, "tag": tag,
        "status": "skip" if not ok else "pending", "why": why,
    }
    if not ok:
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        return record

    multi = mesh_kind == "multi"
    cc = multi_pod_config() if multi else single_pod_config()
    mesh = make_production_mesh(multi_pod=multi)

    # plan: analytical cost-based selection (+ hillclimb overrides)
    decision = choose_plan(arch, shape, cc, top_k=1)[0]
    plan = decision.plan
    if plan_override:
        plan = dataclasses.replace(plan, **plan_override)
    record["plan"] = plan.describe()
    record["plan_fields"] = {k: list(v) if isinstance(v, tuple) else v
                             for k, v in dataclasses.asdict(plan).items()}
    record["analytical_time_s"] = decision.time
    record["analytical_hbm_gb"] = decision.hbm_est / 1e9

    t0 = time.perf_counter()
    try:
        step_fn, arg_names, donate = build_step_fn(arch_id, shape_id, plan)
        specs = input_specs(arch_id, shape_id, mesh, plan)
        args = [specs[n] for n in arg_names]
        jitted = jax.jit(step_fn, donate_argnums=donate)
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t1
        cost = hlo_cost.from_compiled(name, compiled, mesh.devices.size)
        ma = compiled.memory_analysis()
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        # component-level costing (fixes while/scan flop undercount):
        # cost each layer/tail executable, aggregate per program structure
        try:
            from repro.launch import component_cost as CC_
            comps = CC_.component_costs(arch, shape, plan, mesh)
            record["roofline_components"] = CC_.aggregate(comps, cc)
        except Exception as ce:
            record["roofline_components"] = {
                "error": f"{type(ce).__name__}: {ce}"}
        record.update({
            "status": "ok",
            "lower_s": t_lower, "compile_s": t_compile,
            "memory_analysis": {
                "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
                "peak_bytes": int(getattr(ma, "peak_memory_in_bytes", 0) or 0),
                "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            },
            "cost_analysis": {k: float(v) for k, v in
                              (ca[0] if isinstance(ca, (list, tuple)) else ca).items()
                              if isinstance(v, (int, float)) and "utilization" not in k},
            "compiled_cost": cost.to_json(),
            "roofline": cost.roofline(cc),
            "collectives_by_kind": cost.collective_bytes_by_kind(),
        })
        # model flops: 6*N*D (dense) / 6*N_active*D (MoE); serve: 2*N*D
        pc = arch.param_counts()
        n_active = pc["active"]
        toks = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
        mult = 6.0 if shape.mode == "train" else 2.0
        record["model_flops"] = mult * n_active * toks
        rc = record.get("roofline_components") or {}
        if "flops_per_device" in rc:
            record["roofline_entry_only"] = record["roofline"]
            record["roofline"] = {
                k: rc[k] for k in ("compute_s", "memory_s", "collective_s",
                                   "dominant", "roofline_bound_s",
                                   "flops_per_device", "bytes_per_device",
                                   "collective_bytes_per_device")}
            record["roofline"]["source"] = "components"
            comp_total = rc["flops_per_device"] * mesh.devices.size
            record["useful_flops_ratio"] = (record["model_flops"] / comp_total
                                            if comp_total else None)
        else:
            hlo_total = cost.total_flops
            record["useful_flops_ratio"] = (record["model_flops"] / hlo_total
                                            if hlo_total else None)
    except Exception as e:  # record failures — they are bugs to fix
        record["status"] = "fail"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["wall_s"] = time.perf_counter() - t0
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def _add_components(record: Dict[str, Any], path: str,
                    plan_override: Optional[Dict] = None) -> Dict[str, Any]:
    """Augment an existing ok artifact with component-level roofline."""
    from repro.launch import component_cost as CC_
    arch = get_config(record["arch"])
    shape = SHAPES[record["shape"]]
    multi = record["mesh"] == "multi"
    cc = multi_pod_config() if multi else single_pod_config()
    mesh = make_production_mesh(multi_pod=multi)
    pf = dict(record["plan_fields"])
    for k in ("batch_axes", "tp_axes", "fsdp_axes", "ep_axes", "seq_axes",
              "pp_axes"):
        if k in pf:
            pf[k] = tuple(pf[k])
    plan = ShardingPlan(**pf)
    t0 = time.perf_counter()
    try:
        comps = CC_.component_costs(arch, shape, plan, mesh)
        rc = CC_.aggregate(comps, cc)
        record["roofline_components"] = rc
        record["roofline_entry_only"] = record.get(
            "roofline_entry_only", record["roofline"])
        record["roofline"] = {
            k: rc[k] for k in ("compute_s", "memory_s", "collective_s",
                               "dominant", "roofline_bound_s",
                               "flops_per_device", "bytes_per_device",
                               "collective_bytes_per_device")}
        record["roofline"]["source"] = "components"
        comp_total = rc["flops_per_device"] * mesh.devices.size
        if record.get("model_flops"):
            record["useful_flops_ratio"] = record["model_flops"] / comp_total
    except Exception as e:
        record["roofline_components"] = {"error": f"{type(e).__name__}: {e}",
                                         "traceback": traceback.format_exc()[-2000:]}
    record["components_wall_s"] = time.perf_counter() - t0
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--plan", default=None,
                    help="JSON dict of ShardingPlan field overrides")
    ap.add_argument("--components-only", action="store_true",
                    help="augment existing artifacts with component costing")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    override = None
    if args.plan:
        override = json.loads(args.plan)
        for k in ("batch_axes", "tp_axes", "fsdp_axes", "ep_axes", "seq_axes",
                  "pp_axes"):
            if k in override:
                override[k] = tuple(override[k])

    results = []
    for a in archs:
        for s in shapes:
            for m in meshes:
                r = run_cell(a, s, m, plan_override=override, tag=args.tag,
                             force=args.force,
                             components_only=args.components_only)
                status = r["status"]
                extra = ""
                if status == "ok":
                    rf = r["roofline"]
                    src = rf.get("source", "entry")
                    cerr = (r.get("roofline_components") or {}).get("error", "")
                    extra = (f" dom={rf['dominant']} bound={rf['roofline_bound_s']*1e3:.2f}ms"
                             f" src={src}{(' CERR:' + cerr[:60]) if cerr else ''}")
                elif status == "fail":
                    extra = " " + r["error"][:120]
                print(f"[{status:4s}] {a} x {s} x {m}{extra}", flush=True)
                results.append(r)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), {n_fail} FAILED")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
