"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Shapes per the assignment: one v5e pod = (16, 16) over
("data", "model"); two pods = (2, 16, 16) over ("pod", "data", "model"),
the "pod" axis crossing DCN.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    # jax >= 0.5 takes axis_types (and defaults axes to Auto); 0.4.x has
    # neither the kwarg nor jax.sharding.AxisType — same semantics either way
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def abstract_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Version-portable jax.sharding.AbstractMesh (device-free mesh for
    sharding-rule tests): jax 0.4.x wants ((name, size), ...) pairs, newer
    jax wants (sizes, names)."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_host_mesh():
    """Whatever devices exist locally, as a 1-axis 'data' mesh (tests)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
