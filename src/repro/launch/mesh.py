"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Shapes per the assignment: one v5e pod = (16, 16) over
("data", "model"); two pods = (2, 16, 16) over ("pod", "data", "model"),
the "pod" axis crossing DCN.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a 1-axis 'data' mesh (tests)."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
